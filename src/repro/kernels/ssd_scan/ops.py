"""jit'd wrapper exposing the model-layer interface (the layout used by
repro.models.ssd.ssd_chunked): (b, nc, l, h, ...) chunked tensors."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_bchl


def ssd_intra_chunk(xc, dtc, cs, Bc, Cc, *,
                    interpret: bool = False) -> jnp.ndarray:
    """xc: (b, nc, l, h, p); dtc, cs: (b, nc, l, h);
    Bc, Cc: (b, nc, l, h, n) → y_diag (b, nc, l, h, p) fp32."""
    b, nc, l, h, p = xc.shape
    bn = b * nc

    def to_k(t):     # (b,nc,l,h,...) -> (bn,h,l,...)
        t = jnp.moveaxis(t, 3, 2)                    # (b,nc,h,l,...)
        return t.reshape((bn, h, l) + t.shape[4:])

    y = ssd_intra_chunk_bchl(to_k(xc), to_k(dtc), to_k(cs),
                             to_k(Bc), to_k(Cc), interpret=interpret)
    y = y.reshape(b, nc, h, l, p)
    return jnp.moveaxis(y, 2, 3)                     # (b,nc,l,h,p)
