"""Learned per-edge relevance R for DDAL's eq. 4 weighting.

The paper sets R uniform for homogeneous groups (§6); the
heterogeneous-agents follow-up (arXiv 2501.11818) shows that when
agents face *different* tasks, a uniform prior weights misleading
knowledge the same as useful knowledge. This module estimates
relevance **online** instead of wiring it statically:

* ``grad_cosine`` — instantaneous src→dst relevance from the cosine
  similarity of the agents' gradient directions: agents descending the
  same loss landscape produce aligned gradients, agents on unrelated
  tasks produce near-orthogonal (cos ≈ 0) or conflicting (cos < 0)
  ones. Mapped to [min_rel, 1] by ``to_relevance`` and smoothed with
  an EMA over share steps (``ema_update``), this is the
  ``relevance_mode="grad_cos"`` estimator threaded through
  ``repro.core.ddal.DDAL`` and the streaming trainer's
  ``_combine_topo`` segment-sum.
* ``obs_overlap`` — a *static* prior from observation statistics: the
  Gaussian overlap of two agents' observation distributions (running
  mean/scale), for callers that can summarise their input streams.
  Attach it via ``Topology.with_relevance`` / the ``relevance=``
  argument of the group entry points.

Estimates are kept as dense (n, n) ``R[src, dst]`` matrices — O(n²)
*scalars*, negligible next to the O(n·k·D·|params|) delay line — so
they survive ``DynamicTopology`` resampling; ``gather_edges`` projects
them onto the current (n, k) edge table. The effective per-edge
relevance is the product of the topology's static prior and the
learned estimate (``repro.core.weighting.combine_relevance``), so
``relevance_mode="uniform"`` (learned factor ≡ 1) reproduces the
static eq. 4 weights exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Modes GroupSpec.relevance_mode accepts. "obs_overlap" is a static
# prior (no online signal reaches the trainers), so the online
# estimators are uniform | grad_cos.
RELEVANCE_MODES = ("uniform", "grad_cos")


def flatten_agents(grads) -> jnp.ndarray:
    """Concatenate a pytree with leading (n,) agent axis into an
    (n, P) matrix of flattened per-agent vectors."""
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    return jnp.concatenate(
        [jnp.reshape(x, (n, -1)).astype(jnp.float32) for x in leaves],
        axis=1)


def grad_cosine(grads, eps: float = 1e-8) -> jnp.ndarray:
    """Pairwise cosine similarity of per-agent gradients.

    grads: pytree with leading (n,) axis. Returns a symmetric (n, n)
    matrix ``C[src, dst] ∈ [-1, 1]`` with ones on the diagonal (an
    agent's own knowledge is always fully relevant to itself); an
    all-zero gradient row yields cosine 0 against everyone else.
    """
    g = flatten_agents(grads)                          # (n, P)
    norm = jnp.sqrt(jnp.sum(g * g, axis=1))            # (n,)
    gn = g / jnp.maximum(norm, eps)[:, None]
    c = jnp.clip(gn @ gn.T, -1.0, 1.0)
    n = c.shape[0]
    return jnp.where(jnp.eye(n, dtype=bool), 1.0, c)


def to_relevance(cos, min_rel: float = 1e-3) -> jnp.ndarray:
    """Map cosine similarity [-1, 1] onto a relevance weight
    [min_rel, 1]: ``R = (1 + cos) / 2``, floored so a piece is
    down-weighted by conflict, never silently discarded (eq. 4
    renormalises, so the floor keeps every delivered piece's weight
    finite and nonzero)."""
    return jnp.clip(0.5 * (1.0 + cos), min_rel, 1.0)


def ema_update(prev, obs, decay, enabled=True) -> jnp.ndarray:
    """EMA over share steps: ``decay·prev + (1−decay)·obs`` where
    ``enabled`` (a traced bool is fine), ``prev`` elsewhere — warm-up
    epochs hold the estimate at its prior."""
    new = decay * prev + (1.0 - decay) * obs
    return jnp.where(jnp.asarray(enabled), new, prev)


def gather_edges(dense, nbr) -> jnp.ndarray:
    """Project a dense (n, n) ``X[src, dst]`` matrix onto an (n, k)
    edge table: ``out[i, j] = X[nbr[i, j], i]``. Works with a traced
    ``nbr`` (dynamic topologies)."""
    n = dense.shape[0]
    dst = jnp.arange(n)[:, None]
    return dense[nbr, dst]


def init_relevance(n: int) -> jnp.ndarray:
    """The uniform prior every estimator starts from (and the fixed
    point of ``relevance_mode="uniform"``)."""
    return jnp.ones((n, n), jnp.float32)


def update_relevance(rel, grads, mode: str, decay: float,
                     enabled=True) -> jnp.ndarray:
    """One online step of the (n, n) relevance estimate: a no-op for
    ``"uniform"``, an EMA toward the current gradient-cosine relevance
    for ``"grad_cos"``."""
    if mode == "uniform":
        return rel
    if mode == "grad_cos":
        return ema_update(rel, to_relevance(grad_cosine(grads)),
                          decay, enabled)
    raise ValueError(
        f"unknown relevance mode {mode!r}; expected one of "
        f"{RELEVANCE_MODES}")


def obs_overlap(mean, scale, eps: float = 1e-6) -> jnp.ndarray:
    """Static relevance prior from observation statistics: treating
    each agent's observation stream as an isotropic Gaussian with the
    given per-agent ``mean`` (n, d) and ``scale`` (n,) (std), return
    the (n, n) Gaussian-overlap matrix

        R[i, j] = exp( −|μ_i − μ_j|² / (2 (σ_i² + σ_j²)) )

    — 1 for identical streams, → 0 as they separate. Symmetric with a
    unit diagonal; use via ``Topology.with_relevance`` or the
    ``relevance=`` argument of the group entry points."""
    mean = jnp.asarray(mean, jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    d2 = jnp.sum(
        jnp.square(mean[:, None, :] - mean[None, :, :]), axis=-1)
    var = jnp.square(scale)
    denom = jnp.maximum(2.0 * (var[:, None] + var[None, :]), eps)
    return jnp.exp(-d2 / denom)
