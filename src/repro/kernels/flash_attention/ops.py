"""jit'd wrapper exposing the model-layer interface: (B, S, H, D)
layout, GQA, causal + optional sliding window."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, S, K, D) → (B, S, H, D)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               scale=scale, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
