"""Config registry: ``get_arch_config("<id>")`` for every assigned
architecture (plus the paper's own RL configs in repro.rl)."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    LONG_CONTEXT_WINDOW,
    ArchConfig,
    GroupSpec,
    HybridConfig,
    MLAConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
)

_ARCH_MODULES = {
    "yi-34b": "yi_34b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-7b": "qwen2_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "zamba2-7b": "zamba2_7b",
    "mamba2-780m": "mamba2_780m",
    "granite-3-8b": "granite_3_8b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_arch_config(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.get_config()


def arch_for_shape(cfg: ArchConfig, shape_name: str) -> ArchConfig:
    """Apply per-shape variants: dense/VLM/audio archs get the
    sliding-window attention variant for long_500k (sub-quadratic
    requirement — DESIGN.md §5); SSM/hybrid run natively."""
    if shape_name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        if cfg.sliding_window is None:
            return cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg
