"""Per-architecture smoke tests (deliverable f).

Every assigned arch instantiates a REDUCED variant (≤2 layers,
d_model ≤ 512, ≤4 experts) and runs a real forward + one train step on
CPU, asserting output shapes and the absence of NaNs. Decode paths are
exercised through a prefill → decode roundtrip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_arch_config
from repro.configs.base import GroupSpec, ShapeConfig
from repro.core import init_train_state, make_group_train_step
from repro.data import StreamSpec, make_group_batch
from repro.models import get_model, make_batch

# full model-zoo sweep (~2–3 min): excluded from the CI tier-1 fast
# lane, still part of the full local tier-1 run
pytestmark = pytest.mark.slow

SHAPE = ShapeConfig("smoke", 64, 2, "train")


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def _reduced(arch_id):
    cfg = get_arch_config(arch_id).reduced()
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    return cfg


def test_forward_shapes_and_no_nans(arch):
    cfg = _reduced(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    batch = make_batch(cfg, SHAPE, key)
    logits, _ = model.forward(cfg, params, batch, None)
    B, S, V = 2, SHAPE.seq_len, cfg.vocab_size
    if cfg.family == "audio":
        assert logits.shape == (B, cfg.n_codebooks, S, V)
    else:
        assert logits.shape == (B, S, V)
    assert np.isfinite(np.asarray(logits)).all()


def test_train_step_loss_and_no_nans(arch):
    cfg = _reduced(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    batch = make_batch(cfg, SHAPE, key)
    loss = model.loss(cfg, params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one real gradient step reduces nothing catastrophically
    grads = jax.grad(lambda p: model.loss(cfg, p, batch))(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)


def test_group_train_step(arch):
    """Two-agent DDAL step over the sharded (streaming) trainer."""
    cfg = _reduced(arch)
    spec = GroupSpec(n_agents=2, threshold=1, minibatch=2,
                     knowledge_mode="streaming")
    opt = optim.adamw(1e-3)
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, spec, opt, key)
    step = jax.jit(make_group_train_step(cfg, spec, opt))
    batch = make_group_batch(cfg, SHAPE, StreamSpec(), 2, 0)
    for i in range(4):
        state, m = step(state, batch)
        assert np.isfinite(np.asarray(m["loss"])).all()
    assert int(state.step) == 4


def test_prefill_decode_roundtrip(arch):
    cfg = _reduced(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    B, P = 2, 16
    cache = model.make_cache(cfg, B, 64)
    pbatch = make_batch(cfg, ShapeConfig("p", P, B, "prefill"), key)
    logits, cache = model.forward(cfg, params, pbatch, cache)
    assert np.isfinite(np.asarray(logits)).all()
    dbatch = make_batch(cfg, ShapeConfig("d", P, B, "decode"), key)
    # decode positions continue after the prefix
    if cfg.family == "vlm":
        dbatch["positions"] = jnp.full((B, 3, 1), P, jnp.int32)
    else:
        dbatch["positions"] = jnp.full((B, 1), P, jnp.int32)
    dlogits, cache2 = model.decode(cfg, params, dbatch, cache)
    v = cfg.vocab_size
    if cfg.family == "audio":
        assert dlogits.shape == (B, cfg.n_codebooks, 1, v)
    else:
        assert dlogits.shape == (B, 1, v)
    assert np.isfinite(np.asarray(dlogits)).all()


def test_mla_absorption_equivalence():
    """DeepSeek MLA decode with weight absorption (score against the
    rank-r latent) must equal the expanded-K/V reference (§Perf it.6)."""
    cfg = get_arch_config("deepseek-v2-lite-16b").reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    B = 2
    cache = model.make_cache(cfg, B, 64)
    pb = make_batch(cfg, ShapeConfig("p", 16, B, "prefill"), key)
    _, cache = model.forward(cfg, params, pb, cache)
    db = {"tokens": jnp.asarray([[5], [9]], jnp.int32),
          "positions": jnp.full((B, 1), 16, jnp.int32)}
    l_abs, _ = model.decode(cfg.with_(mla_absorb=True), params, db,
                            cache)
    l_ref, _ = model.decode(cfg.with_(mla_absorb=False), params, db,
                            cache)
    np.testing.assert_allclose(np.asarray(l_abs), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_full_forward(arch):
    """Teacher-forced decode must reproduce the full-sequence logits
    (the serving path computes the same function as training)."""
    cfg = _reduced(arch)
    if cfg.family in ("audio",):
        pytest.skip("audio decode interleaves codebooks — covered by "
                    "shape test")
    if cfg.moe is not None:
        # capacity-based routing drops tokens differently at S=8 vs
        # S=1 (a property of capacity dispatch, not a bug); use a
        # no-drop capacity for the equivalence check.
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts + 1)))
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    B = 1
    S = 8 + (cfg.vision_prefix if cfg.family == "vlm" else 0)
    fbatch = make_batch(cfg, ShapeConfig("f", S, B, "train"), key)
    full_logits, _ = model.forward(cfg, params, fbatch, None)

    cache = model.make_cache(cfg, B, 32)
    toks = fbatch["tokens"]
    step_logits = []
    for t in range(S):
        if cfg.family == "vlm":
            if t < cfg.vision_prefix:
                continue
            db = {"tokens": toks[:, t - cfg.vision_prefix:
                                 t - cfg.vision_prefix + 1],
                  "positions": jnp.full((B, 3, 1), t, jnp.int32)}
            if t == cfg.vision_prefix:
                # prefill the vision prefix first
                pb = {"tokens": toks[:, :0],
                      "vision": fbatch["vision"],
                      "positions": jnp.broadcast_to(
                          jnp.arange(cfg.vision_prefix, dtype=jnp.int32),
                          (B, 3, cfg.vision_prefix))}
                _, cache = model.forward(cfg, params, pb, cache)
        else:
            db = {"tokens": toks[:, t:t + 1],
                  "positions": jnp.full((B, 1), t, jnp.int32)}
        lg, cache = model.decode(cfg, params, db, cache)
        step_logits.append(lg[:, 0])
    got = jnp.stack(step_logits, axis=1)
    want = full_logits if cfg.family != "vlm" else \
        full_logits[:, cfg.vision_prefix:]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
