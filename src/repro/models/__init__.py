from repro.models.model import (  # noqa: F401
    Model,
    cache_specs,
    get_model,
    input_specs,
    make_batch,
    param_logical_axes,
    param_specs,
)
