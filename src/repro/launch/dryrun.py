import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run CLI (deliverable e).

Lowers + compiles every (architecture × input shape) pair on the
production meshes — 16×16 single-pod and 2×16×16 multi-pod — entirely
from ShapeDtypeStructs (no allocation), printing memory / cost /
roofline records and writing them to JSON for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import sys


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--arch", default=None)
    parser.add_argument("--shape", default=None)
    parser.add_argument("--all", action="store_true")
    parser.add_argument("--multi-pod", action="store_true",
                        help="2×16×16 (512-chip) mesh instead of 16×16")
    parser.add_argument("--out", default=None, help="JSON output path")
    parser.add_argument("--verbose", action="store_true",
                        help="print memory_analysis / cost_analysis")
    args = parser.parse_args(argv)

    # imports AFTER the XLA_FLAGS line above (jax locks device count
    # at first initialisation)
    from repro.configs import ARCH_IDS
    from repro.configs.base import INPUT_SHAPES
    from repro.launch.dryrun_lib import dryrun_pair
    from repro.launch.mesh import make_production_mesh

    if args.all:
        pairs = [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
    else:
        if not (args.arch and args.shape):
            parser.error("need --arch and --shape, or --all")
        pairs = [(args.arch, args.shape)]

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    results = []
    n_fail = 0
    for arch_id, shape_name in pairs:
        res = dryrun_pair(arch_id, shape_name, mesh)
        results.append(res.to_dict())
        if res.ok:
            r = res.roofline
            print(f"[OK]   {arch_id:22s} {shape_name:12s} "
                  f"mesh={res.mesh_name:8s} "
                  f"compile={res.compile_s:6.1f}s "
                  f"mem/dev={res.memory['total_bytes_per_device']/2**30:7.2f}GiB "
                  f"t_comp={r['t_compute']:.3e}s "
                  f"t_mem={r['t_memory']:.3e}s "
                  f"t_coll={r['t_collective']:.3e}s "
                  f"dom={r['dominant']:10s} "
                  f"useful={r['useful_ratio']:.2f}")
            if args.verbose:
                print(json.dumps(res.memory, indent=2))
                print(json.dumps(r, indent=2))
        else:
            n_fail += 1
            print(f"[FAIL] {arch_id:22s} {shape_name:12s}\n{res.error}")
        sys.stdout.flush()

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {len(results)} records to {args.out}")
    print(f"{len(pairs) - n_fail}/{len(pairs)} pairs lowered+compiled OK")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
