"""Paper Fig. 2 — DDA3C: single-agent A2C vs 2-agent group learning
on CartPole-v0 (max 100 steps/episode).

Paper claims reproduced here:
  * the single A2C agent keeps fluctuating and never locks to a
    stable optimal policy;
  * the 2-agent group locks to reward 100 with very small fluctuation
    after knowledge sharing starts (threshold = 40% of the budget,
    matching the paper's 20k/50k split).
"""
from __future__ import annotations


from benchmarks.common import run_a2c_group, sparkline


def main(epochs: int = 5_000, seed: int = 0, verbose: bool = True):
    threshold = int(epochs * 0.4)             # paper: 20k of 50k
    single = run_a2c_group(1, epochs, threshold=epochs + 1, seed=seed)
    group = run_a2c_group(2, epochs, threshold=threshold, seed=seed)

    if verbose:
        print(single.summary("fig2a single-agent A2C"))
        print("  " + sparkline(single.rewards[:, 0]))
        print(group.summary(f"fig2bc DDA3C 2-agent (share@{threshold})"))
        for a in range(2):
            print("  " + sparkline(group.rewards[:, a]))

    # the paper's claims are about STABILITY at the optimum (Fig. 2:
    # "keep very stable at 100"), with outlier agents explicitly
    # documented (Figs. 3-4) — so the checks compare the group's best
    # agent, not the group mean, against the single-agent baseline
    s_tail, g_tail = single.tail(), group.tail()
    g_std = g_tail.std(axis=0)
    checks = {
        "a group agent locks at the optimum (frac@100 > 0.9)":
            float((g_tail >= 100).mean(axis=0).max()) > 0.9,
        "that agent is steadier than the single agent":
            float(g_std.min()) < float(s_tail.std(axis=0).mean()),
        "single agent never fully stabilises (frac@100 < 0.99)":
            float((s_tail >= 100).mean()) < 0.99,
    }
    if verbose:
        for k, v in checks.items():
            print(f"  [{'PASS' if v else 'FAIL'}] {k}")
    return {"single": single, "group": group, "checks": checks}


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--epochs", type=int, default=5_000)
    p.add_argument("--full", action="store_true",
                   help="paper scale (50k epochs)")
    p.add_argument("--seed", type=int, default=0)
    a = p.parse_args()
    main(50_000 if a.full else a.epochs, a.seed)
