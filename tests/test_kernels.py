"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles, executed in interpret mode on CPU (deliverable c)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ----------------------------------------------------------------------
# ddal_wavg — the paper's eq. 4 contraction
# ----------------------------------------------------------------------
from repro.kernels.ddal_wavg import ops as wavg_ops
from repro.kernels.ddal_wavg import ref as wavg_ref


@pytest.mark.parametrize("m,n", [(1, 128), (3, 100), (8, 8192),
                                 (5, 20_000), (16, 4_097)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wavg_flat(m, n, dtype):
    key = jax.random.PRNGKey(m * 1000 + n)
    G = jax.random.normal(key, (m, n), jnp.float32).astype(dtype)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (m,))
    got = wavg_ops.wavg(G, w, interpret=True)
    want = wavg_ref.wavg(G, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_wavg_tree():
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (4, 17, 33)),
            "b": jax.random.normal(key, (4, 12_000)),
            "c": {"d": jax.random.normal(key, (4, 8))}}
    w = jax.random.uniform(key, (4,))
    got = wavg_ops.tree_wavg(tree, w, interpret=True)
    want = wavg_ref.tree_wavg(tree, w)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5), got, want)


def test_wavg_zero_weights():
    G = jnp.ones((3, 256))
    w = jnp.zeros((3,))
    got = wavg_ops.wavg(G, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(256))


# ----------------------------------------------------------------------
# flash_attention
# ----------------------------------------------------------------------
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref


@pytest.mark.parametrize(
    "B,S,H,K,D,win,blk",
    [(2, 128, 4, 2, 32, None, 64),
     (1, 256, 4, 4, 64, None, 128),
     (2, 96, 8, 2, 32, None, 32),
     (1, 256, 4, 2, 32, 64, 64),
     (1, 64, 2, 1, 16, 16, 32),     # MQA + window
     (2, 80, 4, 4, 32, None, 32)])  # padded seq (80 % 32 != 0)
def test_flash_attention(B, S, H, K, D, win, blk):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, K, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, K, D), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, window=win, block_q=blk,
                                 block_k=blk, interpret=True)
    want = fa_ref.attention(q, k, v, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 128, 4, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(key, (1, 128, 2, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(key, (1, 128, 2, 32)).astype(jnp.bfloat16)
    got = fa_ops.flash_attention(q, k, v, interpret=True)
    want = fa_ref.attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2)


# ----------------------------------------------------------------------
# ssd_scan — Mamba2 intra-chunk dual form
# ----------------------------------------------------------------------
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


def _ssd_inputs(key, b, nc, l, h, n, p):
    ks = jax.random.split(key, 5)
    xc = jax.random.normal(ks[0], (b, nc, l, h, p), jnp.float32)
    dtc = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    cs = jnp.cumsum(dtc * A, axis=2)
    Bc = jax.random.normal(ks[3], (b, nc, l, h, n), jnp.float32)
    Cc = jax.random.normal(ks[4], (b, nc, l, h, n), jnp.float32)
    return xc, dtc, cs, Bc, Cc


@pytest.mark.parametrize("b,nc,l,h,p,n",
                         [(2, 2, 32, 3, 16, 16),
                          (1, 4, 64, 2, 32, 64),
                          (2, 1, 128, 4, 64, 128)])
def test_ssd_intra_chunk(b, nc, l, h, p, n):
    xc, dtc, cs, Bc, Cc = _ssd_inputs(jax.random.PRNGKey(0),
                                      b, nc, l, h, n, p)
    got = ssd_ops.ssd_intra_chunk(xc, dtc, cs, Bc, Cc, interpret=True)
    want = ssd_ref.ssd_intra_chunk(xc, dtc, cs, Bc, Cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ssd_chunked_end_to_end():
    """Full ssd_chunked with the Pallas intra-chunk path == XLA path."""
    from repro.models.ssd import ssd_chunked
    key = jax.random.PRNGKey(0)
    b, s, h, p, n, chunk = 1, 128, 2, 16, 32, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y1, s1 = ssd_chunked(x, dt, A, B, C, chunk, impl="xla")
    y2, s2 = ssd_chunked(x, dt, A, B, C, chunk,
                         impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_model_level_kernel_equivalence():
    """attention_impl / ssd_impl flags do not change model outputs."""
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.models import get_model, make_batch
    for arch, flag in [("llama3.2-3b", "attention_impl"),
                       ("mamba2-780m", "ssd_impl")]:
        cfg = get_arch_config(arch).reduced()
        model = get_model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(cfg, key)
        batch = make_batch(cfg, ShapeConfig("t", 64, 2, "train"), key)
        l1 = model.loss(cfg, params, batch)
        l2 = model.loss(cfg.with_(**{flag: "pallas_interpret"}),
                        params, batch)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
