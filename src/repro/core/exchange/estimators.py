"""Relevance estimators — *how much is src's knowledge worth to dst*.

A :class:`RelevanceEstimator` owns the learned per-edge relevance
state the trainers carry (``GroupState.relevance`` in the buffer loop,
``Knowledge.rel`` in the streaming loop) and the observation rule that
updates it. Four strategies are registered:

``uniform``
    The paper §6 prior: R ≡ 1, nothing learned, ``observe`` is the
    identity — the bitwise fixed point every equivalence oracle pins.
``grad_cos``
    Exact pairwise gradient-cosine relevance
    (:func:`repro.core.relevance.grad_cosine`), EMA-smoothed over
    share steps — O(n²·|params|) comparisons, peak intermediate one
    leaf.
``grad_cos+sketch``
    The same estimator at LLM scale: gradients stream through the
    seeded ±1 projection (``repro.kernels.grad_sketch``) into (n, d)
    sketches and cosines are taken on sketches — O(n·|params|)
    streaming + O(n²·d) comparisons. The streaming trainer carries
    the window sketch (``Knowledge.sk``) and passes it to ``observe``
    so nothing parameter-sized is re-read at share time.
``obs_stats``
    Observation-statistics relevance (ROADMAP plumbing): running
    per-agent obs mean/variance — streamed from
    :func:`repro.rl.rollout.obs_moments` through the trainer's
    metrics — feed :func:`repro.core.relevance.obs_overlap`, so the
    static prior refreshes itself from the agents' actual input
    streams instead of being supplied by hand.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import relevance as REL
from repro.core.exchange.registry import ESTIMATORS


class RelevanceEstimator:
    """Interface: learned relevance state + its observation rule.

    learns
        False only for ``uniform`` — lets trainers keep the learned
        factor out of jitted programs entirely (the bitwise static
        path).
    sketch_dim
        Nonzero only for sketched estimators: the streaming trainer
        carries an (n, d) window sketch and calls ``sketch_step`` on
        every accumulation step.
    init(n)
        Fresh estimator state (the uniform prior).
    observe(state, *, grads, sketch, aux, rnd, enabled, alive)
        One online update. ``grads`` is a stacked gradient pytree
        (leading (n,) axis), ``sketch`` an already-accumulated (n, d)
        window sketch (preferred over re-sketching ``grads`` when
        given), ``aux`` trainer-specific side data (obs moments),
        ``rnd`` the share-round index seeding per-round projections,
        ``enabled`` a (traced) bool holding the state during warm-up,
        ``alive`` ((n,) bool, optional) freezing every estimate entry
        that touches a dead agent — a corpse's rows/cols hold at
        their last live value instead of decaying toward garbage.
    matrix(state)
        The dense (n, n) ``R[src, dst]`` the weighting consumes.
    """

    learns: bool = True
    sketch_dim: int = 0
    #: True when ``observe`` consumes ``aux`` (obs moments) — trainers
    #: only thread the side channel for estimators that want it.
    wants_obs: bool = False

    def init(self, n: int) -> Any:
        raise NotImplementedError

    def observe(self, state, *, grads=None, sketch=None, aux=None,
                rnd=0, enabled=True, alive=None):
        raise NotImplementedError

    def matrix(self, state) -> jnp.ndarray:
        raise NotImplementedError

    def sketch_step(self, grads, rnd) -> Optional[jnp.ndarray]:
        """This step's (n, d) sketch contribution (sketched modes
        only) — linear in ``grads``, so window sums of sketches equal
        sketches of window sums."""
        del grads, rnd
        return None


@ESTIMATORS.register("uniform")
class UniformEstimator(RelevanceEstimator):
    """R ≡ 1 (paper §6). ``observe`` returns the state untouched, so
    jitted programs containing it are op-for-op the static path."""

    learns = False

    def init(self, n: int) -> jnp.ndarray:
        return REL.init_relevance(n)

    def observe(self, state, **kw):
        return state

    def matrix(self, state) -> jnp.ndarray:
        return state


@ESTIMATORS.register("grad_cos",
                     params={"relevance_ema": ("relevance_ema", float)})
class GradCosEstimator(RelevanceEstimator):
    """Exact pairwise gradient cosines → ``to_relevance`` → EMA."""

    def __init__(self, ema: float):
        self.ema = ema

    def init(self, n: int) -> jnp.ndarray:
        return REL.init_relevance(n)

    def observe(self, state, *, grads=None, sketch=None, aux=None,
                rnd=0, enabled=True, alive=None):
        del sketch, aux, rnd
        cos = REL.grad_cosine(grads)
        return REL.ema_update(state, REL.to_relevance(cos), self.ema,
                              enabled, alive)

    def matrix(self, state) -> jnp.ndarray:
        return state


@ESTIMATORS.register("grad_cos+sketch",
                     params={"relevance_sketch_dim":
                             ("relevance_sketch_dim", int)})
class SketchedGradCosEstimator(RelevanceEstimator):
    """Gradient cosines on seeded sign-JL sketches. With an
    already-carried window ``sketch`` the observation is just
    ``cosine_rows(sketch)``; otherwise ``grads`` are streamed through
    the round's projection first (the buffer trainer's per-epoch
    path, re-seeded by ``rnd`` so replay is bit-deterministic)."""

    def __init__(self, ema: float, dim: int, seed: int):
        if dim <= 0:
            raise ValueError(
                f"grad_cos+sketch needs relevance_sketch_dim > 0, "
                f"got {dim}")
        self.ema = ema
        self.dim = dim
        self.seed = seed
        self.sketch_dim = dim

    def init(self, n: int) -> jnp.ndarray:
        return REL.init_relevance(n)

    def observe(self, state, *, grads=None, sketch=None, aux=None,
                rnd=0, enabled=True, alive=None):
        del aux
        if sketch is not None:
            cos = REL.cosine_rows(sketch)
        else:
            cos = REL.sketch_cosine(grads, self.dim,
                                    REL.fold_seed(self.seed, rnd))
        return REL.ema_update(state, REL.to_relevance(cos), self.ema,
                              enabled, alive)

    def matrix(self, state) -> jnp.ndarray:
        return state

    def sketch_step(self, grads, rnd) -> jnp.ndarray:
        from repro.kernels.grad_sketch import ops as sketch_ops
        return sketch_ops.sketch_pytree(
            grads, REL.fold_seed(self.seed, rnd), self.dim)


class ObsStatsState(NamedTuple):
    """Running per-agent observation moments + the derived relevance.

    count: (n,)    — observations accumulated so far.
    mean:  (n, d)  — running mean observation.
    m2:    (n,)    — running sum of squared deviations (isotropic),
                     so scale = sqrt(m2 / (count·d)).
    rel:   (n, n)  — EMA of the Gaussian-overlap relevance.
    """
    count: jnp.ndarray
    mean: jnp.ndarray
    m2: jnp.ndarray
    rel: jnp.ndarray


@ESTIMATORS.register("obs_stats")
class ObsStatsEstimator(RelevanceEstimator):
    """Relevance from observation-distribution overlap.

    ``aux`` is the per-agent episode moment triple
    ``(obs_sum (n, d), sq_sum (n,), count (n,))`` produced by
    :func:`repro.rl.rollout.obs_moments` and forwarded by the trainer
    from the agent callbacks' metrics. Moments merge by Chan's
    parallel-update rule; the relevance observation is
    :func:`repro.core.relevance.obs_overlap` of the running mean and
    scale, EMA-smoothed like every other estimator. With no ``aux``
    the state holds — the estimator degrades to the uniform prior
    instead of failing, so it composes with observation-free rigs.
    """

    wants_obs = True

    def __init__(self, ema: float, obs_dim: Optional[int]):
        if obs_dim is None:
            raise ValueError(
                "obs_stats needs the observation dimension: pass "
                "obs_dim= to build_exchange (the rl group entry "
                "points forward env.obs_dim automatically)")
        self.ema = ema
        self.obs_dim = int(obs_dim)

    def init(self, n: int) -> ObsStatsState:
        return ObsStatsState(
            count=jnp.zeros((n,), jnp.float32),
            mean=jnp.zeros((n, self.obs_dim), jnp.float32),
            m2=jnp.zeros((n,), jnp.float32),
            rel=REL.init_relevance(n))

    def observe(self, state: ObsStatsState, *, grads=None, sketch=None,
                aux=None, rnd=0, enabled=True,
                alive=None) -> ObsStatsState:
        del grads, sketch, rnd
        if aux is None:
            return state
        obs_sum, sq_sum, cnt = aux
        obs_sum = jnp.asarray(obs_sum, jnp.float32)
        cnt = jnp.asarray(cnt, jnp.float32)
        if alive is not None:
            # a corpse streams no observations: zero its batch count
            # so the Chan merge holds its running moments verbatim
            a = jnp.asarray(alive, bool)
            cnt = jnp.where(a, cnt, 0.0)
            obs_sum = jnp.where(a[:, None], obs_sum, 0.0)
            sq_sum = jnp.where(a, jnp.asarray(sq_sum, jnp.float32),
                               0.0)
        safe = jnp.maximum(cnt, 1.0)
        batch_mean = obs_sum / safe[:, None]                # (n, d)
        # batch M2 around the batch mean (isotropic, summed over dims)
        batch_m2 = (jnp.asarray(sq_sum, jnp.float32)
                    - jnp.sum(batch_mean * obs_sum, axis=1))
        tot = state.count + cnt
        tot_safe = jnp.maximum(tot, 1.0)
        delta = batch_mean - state.mean                     # (n, d)
        mean = state.mean + delta * (cnt / tot_safe)[:, None]
        m2 = (state.m2 + batch_m2
              + jnp.sum(delta * delta, axis=1)
              * state.count * cnt / tot_safe)
        scale = jnp.sqrt(jnp.maximum(m2, 0.0)
                         / (tot_safe * self.obs_dim))
        obs = REL.obs_overlap(mean, scale)
        have = tot > 0
        rel = REL.ema_update(state.rel, obs, self.ema,
                             jnp.asarray(enabled) & jnp.any(have),
                             alive)
        new = ObsStatsState(count=tot, mean=mean, m2=m2, rel=rel)
        # a zero-count batch (all agents) holds everything
        any_obs = jnp.any(cnt > 0)
        return ObsStatsState(
            *(jnp.where(
                jnp.reshape(any_obs, (1,) * x.ndim), x, old)
              for x, old in zip(new, state)))

    def matrix(self, state: ObsStatsState) -> jnp.ndarray:
        return state.rel
