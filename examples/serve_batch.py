"""Batched serving example: prefill + decode over the model zoo's
caches (full-attention KV, MLA latent, SSM state), same code path the
decode-shape dry-runs lower.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-780m
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch_config
from repro.models import get_model
from repro.serving import ServeConfig, ServeEngine, serve_batches

p = argparse.ArgumentParser()
p.add_argument("--arch", default="llama3.2-3b", choices=list(ARCH_IDS))
p.add_argument("--new-tokens", type=int, default=24)
p.add_argument("--temperature", type=float, default=0.8)
args = p.parse_args()

cfg = get_arch_config(args.arch).reduced()
model = get_model(cfg)
params = model.init(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, ServeConfig(
    max_len=128, max_new_tokens=args.new_tokens,
    temperature=args.temperature))

rng = np.random.default_rng(0)
requests = [list(rng.integers(0, cfg.vocab_size, int(n)))
            for n in rng.integers(3, 20, 5)]
print(f"serving {len(requests)} requests on reduced {args.arch} "
      f"(batch=2, temperature={args.temperature})")
t0 = time.time()
for toks, lens in serve_batches(requests, batch_size=2):
    out = engine.generate(toks, lens, jax.random.PRNGKey(1))
    for i in range(out.shape[0]):
        n = int(lens[i])
        print(f"  prompt[{n:2d} toks] -> {np.asarray(out[i])[:12]}...")
print(f"done in {time.time() - t0:.1f}s (includes one-time compile)")
