"""Checkpointing: flatten a pytree to keyed numpy arrays in one .npz.

Path keys are serialised with ``jax.tree_util.keystr`` so arbitrary
dict/list/NamedTuple nests round-trip; restore takes a *template*
pytree (e.g. from ``jax.eval_shape``) and refills its leaves, casting
back to the template dtype. Atomic via write-to-temp + rename.
"""
from __future__ import annotations

import os
import tempfile
import zipfile
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                              "float8_e5m2"):
            # np.savez cannot serialise ml_dtypes; f32 is lossless for
            # bf16 and restore() casts back to the template dtype.
            arr = arr.astype(np.float32)
        out[jax.tree_util.keystr(path)] = arr
    return out


def save(path: str, tree: Any, step: int | None = None) -> None:
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(path: str, template: Any, strict: bool = True) -> Any:
    """Refill ``template``'s leaves from ``path`` (dtypes follow the
    template; shapes must match exactly). ``strict=False`` keeps the
    template's value for leaves absent from the checkpoint — e.g.
    restoring a pre-elastic checkpoint into an elastic state whose
    ``alive`` mask the checkpoint never saw.

    A damaged checkpoint is detected up front and raises one
    ``ValueError`` describing everything wrong — an unreadable /
    truncated archive, every missing leaf (strict mode), and every
    shape mismatch with the checkpoint vs template shapes — instead
    of a raw ``KeyError`` / broadcast error surfacing from deep
    inside the tree map."""
    # open the handle ourselves: np.load(path) can leak its file
    # object when the zip directory is unreadable (truncated write),
    # and the test suite promotes ResourceWarning to an error
    with open(path, "rb") as fh:
        try:
            data = np.load(fh)
        except (zipfile.BadZipFile, ValueError, OSError) as e:
            raise ValueError(
                f"checkpoint {path!r} is unreadable (truncated, or "
                f"not an .npz archive): {e}") from e
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
            template)
        problems = []
        new_leaves = []
        for kpath, leaf in paths_leaves:
            key = jax.tree_util.keystr(kpath)
            if key not in data:
                if not strict:
                    new_leaves.append(leaf)
                else:
                    problems.append(
                        f"missing leaf {key!r} (template expects "
                        f"shape {tuple(leaf.shape)})")
                continue
            try:
                arr = data[key]
            except (zipfile.BadZipFile, ValueError, OSError) as e:
                problems.append(
                    f"unreadable leaf {key!r} (truncated entry: {e})")
                continue
            if tuple(arr.shape) != tuple(leaf.shape):
                problems.append(
                    f"shape mismatch at {key!r}: checkpoint "
                    f"{tuple(arr.shape)} vs template "
                    f"{tuple(leaf.shape)}")
                continue
            new_leaves.append(arr.astype(leaf.dtype))
        if problems:
            raise ValueError(
                f"checkpoint {path!r} does not match the template "
                f"({len(problems)} problem"
                f"{'s' if len(problems) > 1 else ''}): "
                + "; ".join(problems))
        return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_step(path: str) -> int | None:
    with np.load(path) as data:
        return int(data["__step__"]) if "__step__" in data else None
