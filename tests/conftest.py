import os

import jax

# CPU tests run in fp32 (reduced configs set this too); keep x64 off.
jax.config.update("jax_enable_x64", False)

# ---------------------------------------------------------------------
# hypothesis fallback: CI installs the real package (pyproject.toml
# [dev] extra); on bare rigs without it we register a minimal shim so
# the property tests still run — deterministic seeded random sampling
# instead of real shrinking/coverage. Must happen before test modules
# import `hypothesis`, which is why it lives in conftest.
# ---------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401

    # Bounded CI profile: per-test @settings(max_examples=...) caps are
    # tuned for thoroughness; the CI fast lane trades examples for wall
    # time so the whole lane stays inside its ~5 min budget. deadline
    # is off in both profiles — first-call jit compilation blows any
    # per-example deadline.
    hypothesis.settings.register_profile(
        "ci", max_examples=15, deadline=None, derandomize=True)
    hypothesis.settings.register_profile(
        "dev", max_examples=40, deadline=None)
    hypothesis.settings.load_profile(
        "ci" if os.environ.get("CI") else "dev")
except ImportError:
    import functools
    import inspect
    import random
    import sys
    import types
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, allow_nan=False,
                allow_infinity=False, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(size)]
        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def _settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    # profile API used by this conftest's real-hypothesis branch;
    # harmless no-ops under the shim
    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    def _given(*strats, **kwstrats):
        def deco(fn):
            @functools.wraps(fn)
            def runner():
                # mirror the real profiles: bounded on CI, fuller on dev
                default_n = 15 if os.environ.get("CI") else 40
                n = getattr(fn, "_shim_max_examples", default_n)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    args = [s.draw(rng) for s in strats]
                    kwargs = {k: s.draw(rng)
                              for k, s in kwstrats.items()}
                    fn(*args, **kwargs)
            # hide the wrapped signature so pytest doesn't mistake the
            # strategy parameters for fixtures
            runner.__signature__ = inspect.Signature()
            del runner.__wrapped__
            return runner
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _st.tuples = _tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
