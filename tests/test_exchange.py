"""Exchange-protocol API tests (ISSUE 5): registry error surfaces,
the full (schedule × estimator × combiner) build-and-step matrix,
``build_exchange`` purity, the new ``relevance_topk`` schedule
(seeded determinism, relevance bias, the pinned exploration-rate
property) and ``obs_stats`` estimator (moment algebra, rl
integration), protocol-vs-legacy-flag equivalence, and the int8
bit-packed sign path of the off-TPU gradient sketch."""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import GroupSpec
from repro.core import DDAL
from repro.core.exchange import (
    COMBINERS,
    DELAYS,
    ESTIMATORS,
    SCHEDULES,
    RelevanceTopKSchedule,
    build_exchange,
)
from repro.core.sharded_ddal import (
    TrainState,
    init_knowledge,
    make_group_train_step,
)


# ----------------------------------------------------------------------
# registry: unknown keys name the valid choices
# ----------------------------------------------------------------------
@pytest.mark.parametrize("registry,member", [
    (SCHEDULES, "static"), (ESTIMATORS, "grad_cos"),
    (DELAYS, "uniform"), (COMBINERS, "flat"),
])
def test_registry_unknown_key_names_choices(registry, member):
    assert member in registry
    with pytest.raises(ValueError) as err:
        registry.get("definitely_not_registered")
    for choice in registry.choices:
        assert choice in str(err.value)


@pytest.mark.parametrize("field,choices_of", [
    ("exchange_schedule", SCHEDULES),
    ("exchange_estimator", ESTIMATORS),
    ("exchange_delay", DELAYS),
    ("exchange_combiner", COMBINERS),
])
def test_groupspec_validates_exchange_keys(field, choices_of):
    with pytest.raises(ValueError) as err:
        GroupSpec(n_agents=4, **{field: "bogus"})
    for choice in choices_of.choices:
        assert choice in str(err.value)


def test_cli_options_cover_registry_params():
    from repro.core.exchange import cli_options
    opts = cli_options()
    # the four selectors plus every declared strategy parameter
    for key in ("schedule", "estimator", "delay", "combiner",
                "resample_every", "relevance_ema",
                "relevance_sketch_dim", "explore_eps", "pods",
                "topology", "degree", "max_delay"):
        assert key in opts, key
    field, typ = opts["explore_eps"]
    assert field == "explore_eps" and typ is float


# ----------------------------------------------------------------------
# the build-and-step matrix: every (schedule × estimator × combiner)
# ----------------------------------------------------------------------
def _matrix_spec(schedule, estimator, combiner):
    """A valid GroupSpec for one matrix cell (n=4 throughout)."""
    kw = dict(n_agents=4, threshold=1, minibatch=2,
              exchange_schedule=schedule, exchange_estimator=estimator,
              exchange_combiner=combiner)
    if estimator in ("grad_cos", "grad_cos+sketch"):
        kw["relevance_mode"] = "grad_cos"
    if estimator == "grad_cos+sketch":
        kw["relevance_sketch_dim"] = 8
    if schedule in ("dynamic", "relevance_topk"):
        kw.update(topology="random_k", degree=2, resample_every=2)
    elif combiner == "pod":
        kw.update(topology="hierarchical", degree=2, pods=2)
    else:
        kw.update(topology="ring")
    return GroupSpec(**kw)


def _streaming_toy_step(spec, exchange, steps=4):
    opt = optim.sgd(0.1)

    def loss_fn(params, batch):
        return jnp.mean((params["w"] - batch["x"]) ** 2)

    step = jax.jit(make_group_train_step(None, spec, opt,
                                         loss_fn=loss_fn,
                                         exchange=exchange))
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}
    state = TrainState(
        params=params, opt_state=jax.vmap(opt.init)(params),
        know=init_knowledge(params, rel=exchange.streaming_rel_init(),
                            sketch_dim=exchange.sketch_dim),
        step=jnp.zeros((), jnp.int32))
    for i in range(steps):
        batch = {"x": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}
        state, m = step(state, batch)
        assert bool(jnp.isfinite(m["loss"]).all())
    return state


def _buffer_toy_steps(spec, exchange, epochs=4):
    def gen(state, key):
        del key
        return {"w": state["w"] - state["t"]}, {}, state

    def app(state, g):
        return {"w": state["w"] - 0.1 * g["w"], "t": state["t"]}

    ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]},
                exchange=exchange)
    gs = ddal.init({"w": jnp.zeros((4, 3)),
                    "t": jnp.arange(4, dtype=jnp.float32)[:, None]})
    step = jax.jit(ddal.epoch_step)
    for e in range(epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), 4))
    assert bool(jnp.isfinite(gs.agent_states["w"]).all())
    return gs


@pytest.mark.parametrize(
    "schedule,estimator,combiner",
    list(itertools.product(SCHEDULES.choices, ESTIMATORS.choices,
                           COMBINERS.choices)))
def test_every_registered_combo_builds_and_steps(schedule, estimator,
                                                 combiner):
    """Every (schedule × estimator × combiner) cell either builds and
    takes one jitted step on a toy loss, or — for the structurally
    impossible cells — fails at build time with an informative error,
    never inside jit. Impossible: a resampling graph cannot be
    pod-dispatched (a swapped edge could cross pods without touching
    a leader), and an observation-fed estimator cannot serve the
    streaming trainer (no obs side channel — it would silently hold
    the uniform prior)."""
    spec = _matrix_spec(schedule, estimator, combiner)
    kind = "buffer" if combiner == "store" else "streaming"
    if estimator == "obs_stats" and kind == "streaming":
        # checked before combiner assembly, so it wins in build order
        with pytest.raises(ValueError, match="obs"):
            build_exchange(spec, kind=kind, obs_dim=3)
        return
    if combiner == "pod" and schedule in ("dynamic", "relevance_topk"):
        with pytest.raises(ValueError, match="pod"):
            build_exchange(spec, kind=kind, obs_dim=3)
        return
    ex = build_exchange(spec, kind=kind, obs_dim=3)
    if combiner == "store":
        _buffer_toy_steps(spec, ex)
    else:
        _streaming_toy_step(spec, ex)


def test_kind_mismatch_is_rejected():
    spec = GroupSpec(n_agents=4)
    with pytest.raises(ValueError, match="streaming"):
        make_group_train_step(
            None, spec, optim.sgd(0.1),
            loss_fn=lambda p, b: 0.0,
            exchange=build_exchange(spec, kind="buffer"))
    with pytest.raises(ValueError, match="buffer"):
        DDAL(spec, lambda s, k: (s, {}, s), lambda s, g: s,
             lambda s: s,
             exchange=build_exchange(spec, kind="streaming"))


# ----------------------------------------------------------------------
# build_exchange purity: same spec ⇒ bitwise-equal steps
# ----------------------------------------------------------------------
def test_build_exchange_is_pure_bitwise():
    spec = GroupSpec(n_agents=4, threshold=1, minibatch=2,
                     topology="random_k", degree=2, resample_every=2,
                     relevance_mode="grad_cos", relevance_ema=0.5,
                     knowledge_mode="streaming")
    states = [
        _streaming_toy_step(spec, build_exchange(spec,
                                                 kind="streaming"))
        for _ in range(2)]
    for a, b in zip(jax.tree.leaves(states[0]),
                    jax.tree.leaves(states[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_explicit_exchange_equals_spec_built_bitwise():
    """Passing exchange=build_exchange(spec) must reproduce the
    spec-flag construction exactly — the protocol is one object, not
    a parallel code path."""
    spec = GroupSpec(n_agents=4, threshold=1, minibatch=2,
                     topology="ring", relevance_mode="grad_cos",
                     relevance_ema=0.5, knowledge_mode="streaming")
    implicit = _streaming_toy_step(
        spec, build_exchange(spec, kind="streaming"))

    opt = optim.sgd(0.1)

    def loss_fn(params, batch):
        return jnp.mean((params["w"] - batch["x"]) ** 2)

    step = jax.jit(make_group_train_step(None, spec, opt,
                                         loss_fn=loss_fn))
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}
    from repro.core.relevance import init_relevance
    state = TrainState(
        params=params, opt_state=jax.vmap(opt.init)(params),
        know=init_knowledge(params, rel=init_relevance(4)),
        step=jnp.zeros((), jnp.int32))
    for i in range(4):
        batch = {"x": jnp.asarray(rng.normal(size=(4, 5)),
                                  jnp.float32)}
        state, _ = step(state, batch)
    np.testing.assert_array_equal(np.asarray(implicit.params["w"]),
                                  np.asarray(state.params["w"]))
    np.testing.assert_array_equal(np.asarray(implicit.know.rel),
                                  np.asarray(state.know.rel))


# ----------------------------------------------------------------------
# relevance_topk: determinism, bias, exploration rate
# ----------------------------------------------------------------------
def _topk(n=8, k=3, seed=0, every=2, eps=0.0):
    from repro.core.topology import random_k
    return RelevanceTopKSchedule(random_k(n, k, seed), every, seed,
                                 eps)


def test_topk_table_is_k_regular_with_self_slot():
    sched = _topk(eps=0.3)
    rel = jnp.ones((8, 8))
    for e in (0, 2, 4, 100):
        tab = np.asarray(sched.sample_table(e, rel))
        assert tab.shape == (8, 3)
        for i in range(8):
            row = tab[i]
            assert row[0] == i                  # dedicated self slot
            assert (row[1:] != i).all()         # no sampled self-loop
            assert len(set(row.tolist())) == 3  # distinct
            assert ((0 <= row) & (row < 8)).all()


def test_topk_deterministic_in_seed_and_epoch():
    """The resampled graph is a pure function of (seed, epoch, R):
    independently built schedules agree epoch-by-epoch, epochs within
    a round share the table, and a different seed diverges."""
    rel = jnp.asarray(
        np.random.default_rng(3).uniform(0.1, 1.0, (8, 8)), jnp.float32)
    a, b = _topk(seed=5, eps=0.2), _topk(seed=5, eps=0.2)
    c = _topk(seed=6, eps=0.2)
    diverged = False
    for e in range(0, 12, 2):
        ta = np.asarray(a.sample_table(e, rel))
        np.testing.assert_array_equal(ta,
                                      np.asarray(b.sample_table(e, rel)))
        # same round ⇒ same table
        np.testing.assert_array_equal(
            ta, np.asarray(a.sample_table(e + 1, rel)))
        diverged |= not np.array_equal(
            ta, np.asarray(c.sample_table(e, rel)))
    assert diverged


def test_topk_changes_across_rounds_and_under_cond_refresh():
    sched = _topk(seed=1, eps=0.0)
    rel = jnp.ones((8, 8))
    t0 = np.asarray(sched.sample_table(0, rel))
    t2 = np.asarray(sched.sample_table(2, rel))
    assert not np.array_equal(t0, t2)
    # refresh: resample only at round boundaries, else keep the carry
    nbr = sched.init_table()
    nbr = sched.refresh(0, nbr, rel)
    np.testing.assert_array_equal(np.asarray(nbr), t0)
    kept = sched.refresh(1, nbr, rel)
    np.testing.assert_array_equal(np.asarray(kept), t0)
    np.testing.assert_array_equal(np.asarray(sched.refresh(2, kept,
                                                           rel)), t2)


def test_topk_prefers_high_relevance_edges():
    """With ε = 0 and a relevance matrix that strongly favours a
    source subset, nearly all sampled gossip edges come from that
    subset (Gumbel top-k follows the weights)."""
    n, k = 8, 3
    sched = _topk(n=n, k=k, seed=0, eps=0.0)
    favored = set(range(4))
    R = np.full((n, n), 1e-3, np.float32)
    R[:4, :] = 1.0                          # sources 0..3 relevant
    rel = jnp.asarray(R)
    picked, total = 0, 0
    for e in range(0, 40, 2):
        tab = np.asarray(sched.sample_table(e, rel))
        for i in range(n):
            for s in tab[i, 1:]:
                total += 1
                picked += int(s in favored and s != i)
    # each favoured row offers ~3–4 of 7 candidates at 1000× weight
    assert picked / total > 0.9, (picked, total)


def test_topk_exploration_rate_matches_eps():
    """Pinned exploration-rate property: the per-destination ε-coins
    (exposed as ``explore_mask``) hit their rate over many rounds,
    and exploring rows take the uniform-gossip fallback (which keeps
    them k-regular — checked above — and reachable even at R → 0)."""
    eps = 0.3
    sched = _topk(n=8, k=3, seed=7, every=1, eps=eps)
    draws = np.concatenate([np.asarray(sched.explore_mask(e))
                            for e in range(200)])
    rate = draws.mean()
    assert abs(rate - eps) < 0.05, rate
    # ε = 0 never explores; ε = 1 always explores
    assert not np.asarray(_topk(eps=0.0).explore_mask(0)).any()
    assert np.asarray(_topk(eps=1.0).explore_mask(0)).all()
    # an exploring round at ε=1 is exactly the uniform gossip draw —
    # low-relevance edges stay reachable
    rel = jnp.asarray(np.full((8, 8), 1e-3, np.float32))
    tab = np.asarray(_topk(seed=3, eps=1.0).sample_table(0, rel))
    assert (tab[:, 0] == np.arange(8)).all()


def test_topk_ddal_run_is_replay_deterministic():
    """Two identical DDAL runs under relevance_topk produce bitwise
    identical group states — resampling, exploration and the learned
    R all key off (seed, epoch)."""
    spec = GroupSpec(n_agents=6, threshold=1, minibatch=2, m_pieces=6,
                     topology="random_k", degree=3, resample_every=2,
                     exchange_schedule="relevance_topk",
                     explore_eps=0.25, relevance_mode="grad_cos",
                     relevance_ema=0.5, topology_seed=4)

    def run():
        def gen(state, key):
            del key
            return {"w": state["w"] - state["t"]}, {}, state

        def app(state, g):
            return {"w": state["w"] - 0.1 * g["w"], "t": state["t"]}

        ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]})
        gs = ddal.init({"w": jnp.zeros((6, 3)),
                        "t": jnp.arange(6, dtype=jnp.float32)[:, None]})
        step = jax.jit(ddal.epoch_step)
        for e in range(8):
            gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), 6))
        return gs

    a, b = run(), run()
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # the carried table is a live topk draw, not the static base
    assert a.nbr.shape == (6, 3)


# ----------------------------------------------------------------------
# obs_stats: moment algebra + rl integration
# ----------------------------------------------------------------------
def test_obs_stats_estimator_separates_clusters():
    """Two clusters of observation streams: within-cluster relevance
    stays near 1, cross-cluster decays toward 0."""
    from repro.core.exchange.estimators import ObsStatsEstimator
    assert ESTIMATORS.get("obs_stats") is ObsStatsEstimator
    est = ObsStatsEstimator(0.0, 3)
    n = 4
    state = est.init(n)
    rng = np.random.default_rng(0)
    for _ in range(5):
        # agents 0,1 see N(0, 1); agents 2,3 see N(5, 1)
        obs = rng.normal(size=(n, 20, 3)).astype(np.float32)
        obs[2:] += 5.0
        obs_sum = jnp.asarray(obs.sum(axis=1))
        sq_sum = jnp.asarray((obs ** 2).sum(axis=(1, 2)))
        cnt = jnp.full((n,), 20.0)
        state = est.observe(state, aux=(obs_sum, sq_sum, cnt))
    R = np.asarray(est.matrix(state))
    assert R.shape == (n, n)
    assert R[0, 1] > 0.9 and R[2, 3] > 0.9
    assert R[0, 2] < 0.05 and R[1, 3] < 0.05
    # with no aux the state holds bit for bit
    held = est.observe(state, aux=None)
    for a, b in zip(state, held):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_obs_stats_a2c_group_end_to_end():
    """make_a2c_group with the obs_stats estimator: the callbacks
    stream obs moments, the estimator state accumulates them, and the
    run stays finite."""
    from repro.rl import CartPole, make_a2c_group
    spec = GroupSpec(n_agents=2, threshold=1, minibatch=2, m_pieces=4,
                     exchange_estimator="obs_stats",
                     relevance_ema=0.5)
    env = CartPole()
    opt = optim.adamw(1e-3)
    ddal, gs = make_a2c_group(env, opt, spec, jax.random.PRNGKey(0))
    assert ddal.exchange.wants_obs
    gs, metrics = jax.jit(lambda g, k: ddal.run(g, k, 4))(
        gs, jax.random.PRNGKey(1))
    assert "obs_moments" in metrics
    count = np.asarray(gs.relevance.count)
    assert (count > 0).all()               # moments actually streamed
    R = np.asarray(gs.relevance.rel)
    assert np.isfinite(R).all() and (R > 0).all() and (R <= 1.0).all()
    # same environment ⇒ overlapping streams ⇒ high cross relevance
    assert R[0, 1] > 0.5


def test_topk_explicit_topology_keeps_relevance_prior():
    """Regression: an explicit static Topology + relevance_topk must
    carry a dense relevance prior across resamples (it used to be
    silently replaced by ones)."""
    from repro.core.topology import random_k
    spec = GroupSpec(n_agents=6, topology="random_k", degree=3,
                     resample_every=2,
                     exchange_schedule="relevance_topk")
    R = jnp.asarray(
        np.random.default_rng(0).uniform(0.1, 0.9, (6, 6)), jnp.float32)
    ex = build_exchange(spec, kind="buffer",
                        topology=random_k(6, 3, 0), relevance=R)
    topo, _ = ex.topology_at(0, ex.init_table(),
                             ex.init_relevance())
    rel = np.asarray(topo.relevance)
    nbr = np.asarray(topo.nbr)
    dst = np.arange(6)[:, None]
    np.testing.assert_allclose(rel, np.asarray(R)[nbr, dst],
                               rtol=1e-6)


def test_explicit_dynamic_topology_honors_delay_model():
    """Regression: exchange_delay='uniform' must attach to an
    explicitly supplied DynamicTopology too (it used to be dropped)."""
    from repro.core.topology import make_topology
    spec = GroupSpec(n_agents=6, topology="random_k", degree=2,
                     resample_every=2, max_delay=3,
                     exchange_delay="uniform")
    dyn = make_topology(GroupSpec(n_agents=6, topology="random_k",
                                  degree=2, resample_every=2))
    ex = build_exchange(spec, kind="buffer", topology=dyn)
    topo, _ = ex.topology_at(0, ex.init_table(), ex.init_relevance())
    assert (np.asarray(topo.delay) == 3).all()
    assert ex.max_delay == 3


def test_prebuilt_exchange_rejects_stale_wavg_flag():
    spec = GroupSpec(n_agents=4)
    ex = build_exchange(spec, kind="buffer")
    with pytest.raises(ValueError, match="use_wavg_kernel"):
        DDAL(spec, lambda s, k: (s, {}, s), lambda s, g: s,
             lambda s: s, exchange=ex, use_wavg_kernel=True)


def test_prebuilt_exchange_rejects_ignored_override_args():
    """Regression: relevance/topology/delay passed *alongside* a
    prebuilt exchange used to be silently dropped."""
    spec = GroupSpec(n_agents=4)
    R = jnp.ones((4, 4))
    with pytest.raises(ValueError, match="relevance"):
        DDAL(spec, lambda s, k: (s, {}, s), lambda s, g: s,
             lambda s: s, relevance=R,
             exchange=build_exchange(spec, kind="buffer"))
    with pytest.raises(ValueError, match="relevance"):
        make_group_train_step(
            None, spec, optim.sgd(0.1), relevance=R,
            loss_fn=lambda p, b: 0.0,
            exchange=build_exchange(spec, kind="streaming"))


def test_explicit_schedule_key_never_silently_downgrades():
    """Regression: an explicit exchange_schedule must be honored (or
    rejected) with an explicit topology object — relevance_topk with
    a DynamicTopology builds the topk resampler around its base, and
    'dynamic' with a static Topology raises instead of silently
    running a fixed graph."""
    from repro.core.topology import DynamicTopology, random_k, ring
    spec = GroupSpec(n_agents=6, topology="random_k", degree=3,
                     resample_every=2,
                     exchange_schedule="relevance_topk")
    dyn = DynamicTopology(base=random_k(6, 3, 0), resample_every=2,
                          seed=0)
    ex = build_exchange(spec, kind="buffer", topology=dyn)
    assert isinstance(ex.schedule, RelevanceTopKSchedule)
    spec_d = GroupSpec(n_agents=6, topology="random_k", degree=3,
                       resample_every=2, exchange_schedule="dynamic")
    with pytest.raises(ValueError, match="DynamicTopology"):
        build_exchange(spec_d, kind="buffer", topology=ring(6))


def test_static_schedule_key_conflicts_with_resampling_spec():
    """Regression: exchange_schedule='static' with resample_every > 0
    used to silently build a resampling DynamicSchedule — both the
    spec-built and explicit-DynamicTopology routes."""
    with pytest.raises(ValueError, match="static"):
        GroupSpec(n_agents=6, topology="random_k", degree=3,
                  resample_every=5, exchange_schedule="static")
    from repro.core.topology import DynamicTopology, random_k
    dyn = DynamicTopology(base=random_k(6, 3, 0), resample_every=2,
                          seed=0)
    spec = GroupSpec(n_agents=6, topology="random_k", degree=3,
                     exchange_schedule="static")
    with pytest.raises(ValueError, match="static"):
        build_exchange(spec, kind="buffer", topology=dyn)


def test_exact_estimator_rejects_stale_sketch_dim():
    """Regression: exchange_estimator='grad_cos' (exact) with a
    sketch dim would silently ignore it — must raise instead."""
    with pytest.raises(ValueError, match="grad_cos\\+sketch"):
        GroupSpec(n_agents=4, relevance_mode="grad_cos",
                  exchange_estimator="grad_cos",
                  relevance_sketch_dim=64)


def test_non_sketching_estimators_reject_sketch_dim():
    """Validation symmetry: ANY explicit non-sketching estimator with
    a sketch dim raises instead of silently ignoring it."""
    for est in ("uniform", "grad_cos", "obs_stats"):
        with pytest.raises(ValueError, match="sketch"):
            GroupSpec(n_agents=4, relevance_mode="grad_cos",
                      exchange_estimator=est, relevance_sketch_dim=64)


def test_topk_rejects_uncarryable_per_edge_prior():
    """A per-edge relevance prior attached to the base topology
    cannot follow table swaps — reject it (the dense relevance= form
    is carried fine, pinned above)."""
    from repro.core.topology import random_k
    base = random_k(6, 3, 0).with_relevance(
        jnp.full((6, 3), 0.5), per_edge=True)
    with pytest.raises(ValueError, match="dense"):
        RelevanceTopKSchedule(base, 2, 0, 0.1)


def test_sketch_estimator_spelling_needs_no_legacy_mode():
    """Regression: the documented migration spelling
    GroupSpec(exchange_estimator='grad_cos+sketch',
    relevance_sketch_dim=d) used to be rejected by the legacy
    sketch-dim↔relevance_mode validation."""
    spec = GroupSpec(n_agents=4, threshold=1, minibatch=2,
                     exchange_estimator="grad_cos+sketch",
                     relevance_sketch_dim=8)
    ex = build_exchange(spec, kind="streaming")
    assert ex.learns and ex.sketch_dim == 8
    _streaming_toy_step(spec, ex)


def test_obs_stats_rejected_for_streaming_kind():
    """The streaming trainer carries no obs side channel — obs_stats
    must fail at build time, not silently hold the uniform prior."""
    spec = GroupSpec(n_agents=4, exchange_estimator="obs_stats")
    with pytest.raises(ValueError, match="obs"):
        build_exchange(spec, kind="streaming", obs_dim=3)


def test_topk_accepts_dense_delay_over_nonuniform_base():
    """Regression: an explicit DynamicTopology whose delays ride in
    dense_delay over a non-uniform base used to be spuriously
    rejected by relevance_topk's early uniform-base validation."""
    from repro.core.topology import DynamicTopology, random_k
    base = random_k(6, 3, 0).with_delay(
        jnp.ones((6, 3), jnp.int32), per_edge=True)
    dyn = DynamicTopology(base=base, resample_every=2, seed=0,
                          dense_delay=jnp.ones((6, 6), jnp.int32))
    spec = GroupSpec(n_agents=6, topology="random_k", degree=3,
                     resample_every=2,
                     exchange_schedule="relevance_topk")
    ex = build_exchange(spec, kind="buffer", topology=dyn)
    topo, _ = ex.topology_at(0, ex.init_table(), ex.init_relevance())
    assert (np.asarray(topo.delay) == 1).all()


def test_prebuilt_exchange_rejects_ignored_mesh():
    spec = GroupSpec(n_agents=4)
    with pytest.raises(ValueError, match="mesh"):
        make_group_train_step(
            None, spec, optim.sgd(0.1), loss_fn=lambda p, b: 0.0,
            mesh=object(),
            exchange=build_exchange(spec, kind="streaming"))


def test_streaming_rejects_delay_models():
    """The streaming trainer has no delay line; a named delay model
    must fail at build time, not silently do nothing."""
    spec = GroupSpec(n_agents=4, topology="ring", max_delay=2,
                     exchange_delay="uniform")
    with pytest.raises(ValueError, match="streaming"):
        build_exchange(spec, kind="streaming")
    build_exchange(spec, kind="buffer")        # buffer path unaffected


def test_cli_exchange_pods_feeds_mesh_wiring():
    """Regression: `--mesh pods --exchange pods=N` must size the mesh
    from the merged spec, not the legacy flag default."""
    from repro.launch import train as T
    import pytest as _pytest
    argv = ["--mesh", "pods", "--exchange", "topology=hierarchical",
            "--agents", "4", "--exchange", "degree=2", "--steps", "1",
            "--exchange", "pods=2"]
    # 2 pods need >= 2 devices; on a 1-device CPU rig the mesh
    # constructor is what fails — proving spec.pods reached it
    # (the old code exited first with "--mesh pods needs --pods").
    with _pytest.raises((ValueError, SystemExit)) as err:
        T.main(argv + ["--batch", "1", "--seq", "16"])
    assert "--mesh pods needs" not in str(err.value)


def test_cli_legacy_flags_warn_with_migration_pointer():
    """The legacy named flags still parse, but each explicit use must
    emit a DeprecationWarning naming its ``--exchange`` spelling (the
    suite runs with ``filterwarnings = error``, so an unwrapped legacy
    spelling anywhere else fails loudly)."""
    from types import SimpleNamespace
    from repro.launch import train as T
    args = SimpleNamespace(
        **{field: None for field, _ in T._LEGACY_FLAGS.values()})
    args.topology, args.degree = "ring", 2
    with pytest.warns(DeprecationWarning) as rec:
        kw = T._legacy_spec_kw(args)
    msgs = [str(w.message) for w in rec]
    assert any("--exchange topology=ring" in m for m in msgs)
    assert any("--exchange degree=2" in m for m in msgs)
    assert kw["topology"] == "ring" and kw["degree"] == 2


# ----------------------------------------------------------------------
# delay models through the registry
# ----------------------------------------------------------------------
def test_hops_delay_model_through_protocol():
    spec = GroupSpec(n_agents=6, topology="ring", max_delay=2,
                     exchange_delay="hops")
    ex = build_exchange(spec, kind="buffer")
    from repro.core.topology import hop_distances, ring
    hops = hop_distances(ring(6))
    topo = ex.static_topology
    nbr = np.asarray(topo.nbr)
    delay = np.asarray(topo.delay)
    mask = np.asarray(topo.mask)
    for i in range(6):
        for j in range(topo.degree):
            if mask[i, j]:
                assert delay[i, j] == hops[nbr[i, j], i] * 2
    assert ex.max_delay == int(delay.max())


def test_hops_delay_model_rejects_resampling_schedules():
    spec = GroupSpec(n_agents=6, topology="random_k", degree=2,
                     resample_every=2, exchange_delay="hops")
    with pytest.raises(ValueError, match="hops"):
        build_exchange(spec, kind="buffer")


def test_uniform_delay_model_attaches_everywhere():
    spec = GroupSpec(n_agents=4, topology="ring", max_delay=3,
                     exchange_delay="uniform")
    ex = build_exchange(spec, kind="buffer")
    topo = ex.static_topology
    d = np.asarray(topo.delay)[np.asarray(topo.mask)]
    assert (d == 3).all()


# ----------------------------------------------------------------------
# int8 bit-packed sign path (off-TPU sketch bandwidth satellite)
# ----------------------------------------------------------------------
def test_sign_block_i8_matches_fp32_stream():
    from repro.kernels.grad_sketch.kernel import (
        sign_block,
        sign_block_i8,
    )
    f = np.asarray(sign_block(7, 13, 257, 64))
    i = np.asarray(sign_block_i8(7, 13, 257, 64))
    assert i.dtype == np.int8
    assert set(np.unique(i)) <= {-1, 1}
    np.testing.assert_array_equal(f, i.astype(np.float32))


def _fp32_tiled_oracle(G, seed, dim, offset, block):
    """The pre-bit-pack tiled walk: same chunking, fp32 sign blocks —
    the accumulation order the int8 path must reproduce exactly."""
    from repro.kernels.grad_sketch.kernel import sign_block
    n, p = G.shape
    acc = jnp.zeros((n, dim), jnp.float32)
    start = 0
    while start < p:
        w = min(block, p - start)
        g = jax.lax.slice_in_dim(G, start, start + w, axis=1)
        s = sign_block(seed, offset + start, w, dim)
        acc = acc + jnp.dot(g, s, preferred_element_type=jnp.float32)
        start += w
    return acc


def test_xla_sketch_int8_path_bitwise_vs_fp32_signs():
    """The tiled XLA projection now generates one (block, d) **int8**
    sign block per chunk (4× less sign traffic); ±1 is exact in both
    dtypes, so chunk for chunk it must be bitwise the fp32-sign walk —
    including ragged tails and the rolled fori_loop path — and within
    reassociation error of the one-shot jnp oracle."""
    from repro.kernels.grad_sketch import ref
    from repro.kernels.grad_sketch.ops import _xla_sketch_flat
    rng = np.random.default_rng(0)
    G = jnp.asarray(rng.normal(size=(3, 1000)), jnp.float32)
    one_shot = np.asarray(ref.sketch_flat(G, 5, 16, offset=9))
    for block in (256, 100, 8):     # even, ragged tail, rolled loop
        got = np.asarray(_xla_sketch_flat(G, 5, 16, offset=9,
                                          block=block))
        want = np.asarray(_fp32_tiled_oracle(G, 5, 16, 9, block))
        np.testing.assert_array_equal(got, want)
        np.testing.assert_allclose(got, one_shot, rtol=1e-4,
                                   atol=1e-4)
