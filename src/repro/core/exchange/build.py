"""Assemble an :class:`ExchangeProtocol` from a ``GroupSpec``.

``build_exchange(spec, mesh=None)`` is the one place that turns
configuration into strategy objects: it resolves each of the four
families (schedule, estimator, delay model, combiner) against the
string-keyed registries — ``"auto"`` derives the key from the legacy
``GroupSpec`` flags, so every pre-redesign spelling maps onto exactly
the strategies that reproduce it bitwise — and returns one protocol
object both trainers loop over:

    protocol.topology_at(step, nbr, rel)  → the graph in force
    protocol.observe(rel, grads=..., …)   → updated relevance state
    protocol.combine(knowledge, rel, t)   → the eq. 4 update

``build_exchange`` is **pure**: it allocates no traced state and
closes only over host constants, so two calls with the same arguments
produce protocols whose jitted steps are bitwise-equal (pinned in
``tests/test_exchange.py``). That purity is what makes the protocol a
safe unit for a future ``jax.distributed`` driver to construct per
process.

Legacy-flag → strategy mapping (the full table lives in
``docs/exchange.md``):

==============================  =================================
GroupSpec flags                 strategies
==============================  =================================
``topology``/``degree``/seed    ``static`` schedule
``resample_every > 0``          ``dynamic`` schedule
``relevance_mode="uniform"``    ``uniform`` estimator
``relevance_mode="grad_cos"``   ``grad_cos`` estimator
``… + relevance_sketch_dim>0``  ``grad_cos+sketch`` estimator
``pods > 0``                    ``pod`` combiner
(buffer trainer)                ``store`` combiner
(streaming trainer)             ``flat`` combiner
==============================  =================================
"""
from __future__ import annotations

from typing import Any, Optional

import jax.numpy as jnp

from repro.core.exchange.combiners import _edge_effective
from repro.core.exchange.delays import DelayModel
from repro.core.exchange.estimators import (
    GradCosEstimator,
    ObsStatsEstimator,
    RelevanceEstimator,
    SketchedGradCosEstimator,
    UniformEstimator,
)
from repro.core.exchange.registry import (
    COMBINERS,
    DELAYS,
    ESTIMATORS,
    SCHEDULES,
)
from repro.core.exchange.schedules import (
    DynamicSchedule,
    RelevanceTopKSchedule,
    StaticSchedule,
    TopologySchedule,
)
from repro.core.topology import (
    DynamicTopology,
    Topology,
    make_topology,
)

KINDS = ("buffer", "streaming")


class ExchangeProtocol:
    """One knowledge-exchange protocol: the four strategies plus the
    spec facts the trainers still need, behind three calls.

    The buffer trainer (:class:`repro.core.ddal.DDAL`) carries
    ``(nbr, relevance)`` state and drives ``topology_at`` →
    ``observe`` → ``apply_relevance`` → (delay lines) → ``combine``;
    the streaming trainer carries relevance in ``Knowledge.rel`` and
    drives ``sketch_step`` (accumulation) → ``observe`` → ``combine``
    at share steps. Neither branches on a single ``GroupSpec`` flag —
    every decision was resolved here, once, at build time.
    """

    def __init__(self, *, spec, kind: str,
                 schedule: Optional[TopologySchedule],
                 estimator: RelevanceEstimator,
                 delay_model: DelayModel, combiner,
                 static_topology: Topology, transport=None):
        self.spec = spec
        self.kind = kind
        self.schedule = schedule
        self.estimator = estimator
        self.delay_model = delay_model
        self.combiner = combiner
        self.static_topology = static_topology
        self.transport = transport
        sched_delay = schedule.max_delay if schedule is not None else 0
        self.max_delay = max(sched_delay, spec.max_delay)
        if transport is not None:
            # jitter / retransmit backoff / the duplicate's +1 slot
            # all land deeper in the delay line; the headroom is
            # knob-derived (not plan-realised), so the compiled
            # program shape never depends on the fault draw
            self.max_delay += transport.extra_delay
        ms = getattr(spec, "max_staleness", None)
        decay = float(getattr(spec, "transport_decay", 1.0))
        #: stores/delay lines carry per-piece send epochs (staleness
        #: cutoff and/or age-discounted eq. 4 weighting reads them)
        self.track_born = bool(
            kind == "buffer"
            and (ms is not None
                 or (transport is not None and decay < 1.0)))

    def transport_at(self, step):
        """This step's per-edge fault slice (``None`` on a perfect
        transport — the trainers skip the faulted send path)."""
        return (None if self.transport is None
                else self.transport.at(step))

    # -- facts ---------------------------------------------------------
    @property
    def learns(self) -> bool:
        return self.estimator.learns

    @property
    def sketch_dim(self) -> int:
        return self.estimator.sketch_dim

    @property
    def wants_obs(self) -> bool:
        return self.estimator.wants_obs

    # -- state init ----------------------------------------------------
    def init_table(self) -> jnp.ndarray:
        return self.schedule.init_table()

    def init_relevance(self) -> Any:
        """Estimator state at its prior (the buffer trainer's
        ``GroupState.relevance``)."""
        return self.estimator.init(self.spec.n_agents)

    def streaming_rel_init(self) -> Any:
        """``Knowledge.rel`` seed: ``None`` when nothing is learned
        (keeps the uniform streaming state pytree unchanged)."""
        if not self.estimator.learns:
            return None
        return self.estimator.init(self.spec.n_agents)

    # -- the protocol --------------------------------------------------
    def topology_at(self, step, nbr, rel_state=None, alive=None):
        """(graph in force at ``step``, refreshed carried table).
        ``alive`` excludes dead agents from resampled gossip draws
        (elastic membership) — static tables are alive-gated at the
        send/combine sites instead."""
        rel = None
        if self.schedule.uses_relevance:
            rel = self.estimator.matrix(rel_state)
        nbr = self.schedule.refresh(step, nbr, rel, alive)
        return self.schedule.materialize(step, nbr, rel), nbr

    def observe(self, rel_state, *, grads=None, sketch=None, aux=None,
                rnd=0, enabled=True, alive=None):
        """One estimator update (identity for non-learning modes).
        ``alive`` freezes estimate entries that touch a dead agent."""
        return self.estimator.observe(rel_state, grads=grads,
                                      sketch=sketch, aux=aux, rnd=rnd,
                                      enabled=enabled, alive=alive)

    def apply_relevance(self, topo: Topology, rel_state) -> Topology:
        """Effective per-edge R = static prior × learned estimate on
        ``topo``'s edge table; ``topo`` untouched when nothing is
        learned (the structural uniform fixed point)."""
        if not self.estimator.learns:
            return topo
        return _edge_effective(topo, self.estimator.matrix(rel_state))

    def combine(self, knowledge, rel_state, step, alive=None):
        """The eq. 4 aggregation of the chosen combiner strategy.
        ``alive`` masks dead agents' contributions to exactly zero."""
        rel = None
        if self.estimator.learns and rel_state is not None:
            rel = self.estimator.matrix(rel_state)
        return self.combiner(knowledge, rel, step, alive)

    def sketch_step(self, grads, rnd):
        """This step's (n, d) window-sketch contribution (sketched
        estimators only — ``None`` otherwise)."""
        return self.estimator.sketch_step(grads, rnd)


# ---------------------------------------------------------------------
# per-family resolution
# ---------------------------------------------------------------------
def _schedule_key(spec) -> str:
    key = spec.exchange_schedule
    if key != "auto":
        return key
    return "dynamic" if spec.resample_every > 0 else "static"


def _estimator_key(spec) -> str:
    key = spec.exchange_estimator
    if key != "auto":
        return key
    if spec.relevance_mode == "uniform":
        return "uniform"
    return ("grad_cos+sketch" if spec.relevance_sketch_dim > 0
            else "grad_cos")


def _combiner_key(spec, kind: str) -> str:
    key = spec.exchange_combiner
    if key != "auto":
        return key
    if kind == "buffer":
        return "store"
    return "pod" if spec.pods > 0 else "flat"


def _delay_key(spec) -> str:
    key = spec.exchange_delay
    return "none" if key == "auto" else key


def _make_estimator(spec, obs_dim) -> RelevanceEstimator:
    key = _estimator_key(spec)
    cls = ESTIMATORS.get(key)
    if cls is UniformEstimator:
        return UniformEstimator()
    if cls is GradCosEstimator:
        return GradCosEstimator(spec.relevance_ema)
    if cls is SketchedGradCosEstimator:
        dim = spec.relevance_sketch_dim
        if dim <= 0:
            raise ValueError(
                "estimator 'grad_cos+sketch' needs "
                "relevance_sketch_dim > 0 (the sketch width)")
        return SketchedGradCosEstimator(spec.relevance_ema, dim,
                                        spec.topology_seed)
    if cls is ObsStatsEstimator:
        return ObsStatsEstimator(spec.relevance_ema, obs_dim)
    # user-registered estimators construct from the spec directly
    return cls(spec)


def _make_delay_model(spec, delay) -> DelayModel:
    key = _delay_key(spec)
    if key != "none" and delay is not None:
        raise ValueError(
            f"explicit delay= arrays and the {key!r} delay model are "
            f"mutually exclusive — pick one delay source")
    if key == "none":
        return DELAYS.get("none")()
    if key == "uniform":
        return DELAYS.get("uniform")(spec.max_delay)
    if key == "hops":
        return DELAYS.get("hops")(max(spec.max_delay, 1))
    return DELAYS.get(key)(spec)


def _make_schedule(spec, key: str, topology, relevance, delay,
                   delay_model: DelayModel
                   ) -> Optional[TopologySchedule]:
    """Resolve the schedule, attaching explicit ``relevance``/
    ``delay`` overrides and the delay model onto the right object
    (edge table for static graphs, dense carry for resampling ones)."""
    if topology is not None:
        # explicit graph object: honor it, attach overrides exactly as
        # the trainers always did — but never silently downgrade an
        # explicitly requested schedule strategy
        if isinstance(topology, DynamicTopology):
            if key == "relevance_topk":
                # rebuild the resampler around the dynamic object's
                # base, inheriting its dense carries
                sched = RelevanceTopKSchedule(
                    topology.base,
                    topology.resample_every or spec.resample_every,
                    topology.seed, spec.explore_eps,
                    dense_delay=topology.dense_delay,
                    dense_relevance=topology.dense_relevance)
                sched.with_dense(delay=delay, relevance=relevance)
                return sched.with_dense(
                    delay=delay_model.dense_scalar())
            if (spec.exchange_schedule == "static"
                    and topology.resample_every > 0):
                raise ValueError(
                    "exchange_schedule='static' pins a fixed graph "
                    "but the explicit DynamicTopology resamples every "
                    f"{topology.resample_every} epochs — pass its "
                    ".base (a static Topology) or drop the override")
            topology = topology.with_dense(delay=delay,
                                           relevance=relevance)
            scalar = delay_model.dense_scalar()
            if scalar is not None:
                topology = topology.with_dense(delay=scalar)
            if topology.dense_delay is None:
                topology._uniform_base_delay()  # validate early
            return DynamicSchedule(topology)
        if key == "relevance_topk":
            # a resampling schedule: per-edge attachment cannot follow
            # the table swaps, so annotations ride as dense carries
            sched = RelevanceTopKSchedule(topology, spec.resample_every,
                                          spec.topology_seed,
                                          spec.explore_eps)
            sched.with_dense(delay=delay, relevance=relevance)
            return sched.with_dense(delay=delay_model.dense_scalar())
        if key == "dynamic":
            raise ValueError(
                "schedule 'dynamic' was requested with an explicit "
                "static Topology — pass a DynamicTopology (it carries "
                "the resample cadence and dense annotations) or drop "
                "the explicit topology to build one from the spec")
        if relevance is not None:
            topology = topology.with_relevance(relevance)
        if delay is not None:
            topology = topology.with_delay(delay)
        return StaticSchedule(delay_model.attach(topology))

    built = make_topology(spec, delay=delay, relevance=relevance)
    if key == "relevance_topk":
        if isinstance(built, DynamicTopology):
            # make_topology already validated + dense-attached the
            # (n, n) overrides; inherit its carries wholesale
            base, dd, dr = (built.base, built.dense_delay,
                            built.dense_relevance)
        else:
            base, dd, dr = built, None, None
        sched = RelevanceTopKSchedule(base, spec.resample_every,
                                      spec.topology_seed,
                                      spec.explore_eps,
                                      dense_delay=dd,
                                      dense_relevance=dr)
        return sched.with_dense(delay=delay_model.dense_scalar())
    if isinstance(built, DynamicTopology):
        scalar = delay_model.dense_scalar()
        if scalar is not None:
            built = built.with_dense(delay=scalar)
        return DynamicSchedule(built)
    if key == "dynamic":
        raise ValueError(
            "schedule 'dynamic' needs resample_every >= 1 (and "
            "topology='random_k'); use 'static' for a fixed graph")
    return StaticSchedule(delay_model.attach(built))


# ---------------------------------------------------------------------
# the assembler
# ---------------------------------------------------------------------
def build_exchange(spec, mesh=None, *, kind: Optional[str] = None,
                   topology=None, relevance=None, delay=None,
                   obs_dim: Optional[int] = None,
                   use_wavg_kernel: bool = False) -> ExchangeProtocol:
    """Build the exchange protocol for ``spec``.

    ``kind`` selects the trainer family the protocol will serve —
    ``"buffer"`` (piece-faithful stores, :class:`repro.core.ddal.
    DDAL`) or ``"streaming"`` (window accumulators,
    :func:`repro.core.sharded_ddal.make_group_train_step`) — and
    defaults to ``spec.knowledge_mode``. ``topology`` /
    ``relevance`` / ``delay`` are the trainers' explicit-override
    arguments (a graph object, a dense or per-edge R prior, a delay
    matrix); ``obs_dim`` is required only by the ``obs_stats``
    estimator; ``mesh`` only by the ``pod`` combiner's collective
    lowering.
    """
    kind = kind or spec.knowledge_mode
    if kind not in KINDS:
        raise ValueError(
            f"unknown exchange kind {kind!r}; expected one of {KINDS}")

    sched_key = _schedule_key(spec)
    comb_key = _combiner_key(spec, kind)
    if kind == "buffer" and comb_key != "store":
        raise ValueError(
            f"the buffer trainer aggregates knowledge stores and "
            f"needs the 'store' combiner, got {comb_key!r}")
    if kind == "streaming" and comb_key == "store":
        raise ValueError(
            "the 'store' combiner aggregates ring-buffer pieces and "
            "only serves the buffer trainer; streaming wants 'flat' "
            "or 'pod'")

    if kind == "streaming" and _delay_key(spec) != "none":
        raise ValueError(
            f"delay model {_delay_key(spec)!r} has no effect on the "
            f"streaming trainer (window accumulators exchange at "
            f"share steps; there is no delay line to stale) — drop "
            f"exchange_delay, or use the buffer trainer for "
            f"asynchrony simulation")
    delay_model = _make_delay_model(spec, delay)
    estimator = _make_estimator(spec, obs_dim)
    if kind == "streaming" and estimator.wants_obs:
        raise ValueError(
            f"estimator {_estimator_key(spec)!r} needs the trainers' "
            f"observation side channel (metrics['obs_moments']), "
            f"which the streaming train step does not carry — it "
            f"would silently hold the uniform prior forever; use the "
            f"buffer trainer for observation-statistics relevance")

    from repro.core.transport import make_transport, transport_enabled
    faulty = transport_enabled(spec)
    if kind == "streaming":
        if getattr(spec, "max_staleness", None) is not None:
            raise ValueError(
                "max_staleness ages buffer-trainer arrival slots; the "
                "streaming trainer's window accumulators are rebuilt "
                "every share round and have no staleness to cut — "
                "drop max_staleness or use the buffer trainer")
        if faulty and (spec.transport_jitter > 0
                       or spec.transport_retransmit > 0):
            raise ValueError(
                "transport_jitter / transport_retransmit delay "
                "deliveries through the buffer trainer's delay line; "
                "the streaming trainer exchanges whole windows at "
                "share steps (no line to delay — a message is either "
                "in this round or gone), got jitter="
                f"{spec.transport_jitter}, retransmit="
                f"{spec.transport_retransmit}; zero them or use the "
                "buffer trainer")

    # the streaming global-sum fast path: no graph object at all when
    # the spec names the full topology with nothing time-varying (an
    # explicit relevance matrix then weights the dense eq. 4 directly)
    # — a faulty transport drops per-round *edges*, so it always
    # needs the edge-table path
    dense_R = None
    if (kind == "streaming" and topology is None
            and spec.topology == "full" and spec.resample_every == 0
            and sched_key == "static" and not faulty):
        schedule = None
        dense_R = relevance
    else:
        schedule = _make_schedule(spec, sched_key, topology, relevance,
                                  delay, delay_model)

    transport = make_transport(
        spec, tuple(schedule.base.nbr.shape)
        if schedule is not None else (spec.n_agents, spec.n_agents))

    combiner = COMBINERS.get(comb_key)(
        spec=spec, schedule=schedule, estimator=estimator,
        dense_R=dense_R, mesh=mesh, use_wavg_kernel=use_wavg_kernel,
        transport=transport)

    static_topo = schedule.base if schedule is not None else None
    return ExchangeProtocol(spec=spec, kind=kind, schedule=schedule,
                            estimator=estimator,
                            delay_model=delay_model, combiner=combiner,
                            static_topology=static_topo,
                            transport=transport)
