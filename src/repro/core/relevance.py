"""Learned per-edge relevance R for DDAL's eq. 4 weighting.

The paper sets R uniform for homogeneous groups (§6); the
heterogeneous-agents follow-up (arXiv 2501.11818) shows that when
agents face *different* tasks, a uniform prior weights misleading
knowledge the same as useful knowledge. This module estimates
relevance **online** instead of wiring it statically:

* ``grad_cosine`` — instantaneous src→dst relevance from the cosine
  similarity of the agents' gradient directions: agents descending the
  same loss landscape produce aligned gradients, agents on unrelated
  tasks produce near-orthogonal (cos ≈ 0) or conflicting (cos < 0)
  ones. Mapped to [min_rel, 1] by ``to_relevance`` and smoothed with
  an EMA over share steps (``ema_update``), this is the
  ``relevance_mode="grad_cos"`` estimator threaded through
  ``repro.core.ddal.DDAL`` and the streaming trainer's
  ``_combine_topo`` segment-sum.
* ``sketch_cosine`` — the same estimator at LLM scale: instead of the
  exact O(n²·|params|) pairwise dots, each agent's gradient pytree is
  streamed leaf-by-leaf through a seeded ±1 random projection
  (``repro.kernels.grad_sketch``, sign-JL) into an (n, d) sketch, and
  cosines are computed on sketches — O(n·|params|) streaming work
  plus O(n²·d) comparisons, with **no (n, P) concat ever built**.
* ``obs_overlap`` — a *static* prior from observation statistics: the
  Gaussian overlap of two agents' observation distributions (running
  mean/scale), for callers that can summarise their input streams.
  Attach it via ``Topology.with_relevance`` / the ``relevance=``
  argument of the group entry points.

Sketch math and error bound
---------------------------
For a ±1/Rademacher projection S: (P, d) the sketched inner product
``(G S)(G S)ᵀ / d`` is an unbiased estimate of the Gram ``G Gᵀ``, and
the sketched cosine of a pair with true cosine ρ has standard error
``≈ (1 − ρ²)/√d`` (Johnson–Lindenstrauss): d = 256 gives ≈ 0.06
worst-case (ρ = 0), d = 1024 halves it. Pick d so that the *decision*
eq. 4 makes — up-weight aligned agents, floor conflicting ones —
survives the noise: d ≈ 256 separates cosines ~0.4 apart at ≥ 5σ,
which is far coarser than the aligned (ρ → 1) vs unrelated (ρ → 0)
split the estimator exists to detect; the EMA over share steps then
averages *independently seeded* rounds (``fold_seed``), shrinking the
residual error by √(#rounds) on top. ``relevance_sketch_dim = 0``
selects the exact path.

The sketch is **seeded per round**: signs are a pure function of
``(seed, round, position, dim)``, so DynamicTopology replay — same
topology_seed, same epoch sequence — reproduces the estimate
bit-for-bit, while distinct rounds draw fresh projections (the EMA
averaging above). Because the projection is linear and positional,
the sketch of a gradient *sum* is the sum of per-piece sketches —
the streaming trainer exploits this to carry a tiny (n, d) window
sketch alongside its accumulators instead of re-deriving anything
parameter-sized at share time (``repro.core.sharded_ddal``).

Exact path: the Gram matrix is accumulated per-leaf
(``Σ_leaf g_i · g_j``) in one pass over the pytree — the old
``flatten_agents`` (n, P) fp32 concat, an extra HBM copy of every
agent's gradients, is kept only as the test oracle.

Estimates are kept as dense (n, n) ``R[src, dst]`` matrices — O(n²)
*scalars*, negligible next to the O(n·k·D·|params|) delay line — so
they survive ``DynamicTopology`` resampling; ``gather_edges`` projects
them onto the current (n, k) edge table. The effective per-edge
relevance is the product of the topology's static prior and the
learned estimate (``repro.core.weighting.combine_relevance``), so
``relevance_mode="uniform"`` (learned factor ≡ 1) reproduces the
static eq. 4 weights exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Modes the legacy GroupSpec.relevance_mode flag accepts; the
# exchange API (repro.core.exchange.estimators) maps them onto
# estimator strategies ("uniform" | "grad_cos" | "grad_cos+sketch")
# and adds "obs_stats", which turns the static obs_overlap prior into
# an online estimator fed by repro.rl.rollout.obs_moments.
RELEVANCE_MODES = ("uniform", "grad_cos")


def flatten_agents(grads) -> jnp.ndarray:
    """Concatenate a pytree with leading (n,) agent axis into an
    (n, P) matrix of flattened per-agent vectors.

    Test oracle only: this materialises a full fp32 copy of every
    agent's gradients. The production estimators (``grad_cosine``,
    ``sketch_cosine``) stream the pytree leaf-by-leaf instead."""
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    return jnp.concatenate(
        [jnp.reshape(x, (n, -1)).astype(jnp.float32) for x in leaves],
        axis=1)


def flatten_cosine(grads, eps: float = 1e-8) -> jnp.ndarray:
    """The seed's exact estimator: flatten_agents builds the (n, P)
    fp32 concat, then the shared ``cosine_rows`` tail — op for op the
    pre-PR sequence. Kept ONLY as the equivalence oracle (tests and
    ``bench_relevance_sketch``'s bitwise gate import this single
    definition) — production paths stream per-leaf (``grad_cosine``)
    or sketch (``sketch_cosine``)."""
    return cosine_rows(flatten_agents(grads), eps)


def _agent_rows(grads):
    """Yield each leaf as an (n, p_leaf) fp32 matrix (a view-shaped
    reshape + cast, one leaf at a time — never the full concat)."""
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    for x in leaves:
        yield jnp.reshape(x, (n, -1)).astype(jnp.float32)


def cosine_rows(g, eps: float = 1e-8) -> jnp.ndarray:
    """Pairwise cosine similarity of the rows of an (n, p) matrix,
    with ones on the diagonal and all-zero rows yielding cosine 0
    against everyone else. The shared tail of ``grad_cosine`` (p = P)
    and ``sketch_cosine`` (p = d) — the streaming trainer also calls
    it directly on its carried window sketch."""
    norm = jnp.sqrt(jnp.sum(g * g, axis=1))            # (n,)
    gn = g / jnp.maximum(norm, eps)[:, None]
    c = jnp.clip(gn @ gn.T, -1.0, 1.0)
    n = c.shape[0]
    return jnp.where(jnp.eye(n, dtype=bool), 1.0, c)


def grad_cosine(grads, eps: float = 1e-8) -> jnp.ndarray:
    """Exact pairwise cosine similarity of per-agent gradients.

    grads: pytree with leading (n,) axis. Returns a symmetric (n, n)
    matrix ``C[src, dst] ∈ [-1, 1]`` with ones on the diagonal (an
    agent's own knowledge is always fully relevant to itself); an
    all-zero gradient row yields cosine 0 against everyone else.

    Two streaming passes over the leaves — norms, then the Gram of
    the normalised rows (``Σ_leaf ĝ_i · ĝ_j``) — so the peak
    intermediate is one leaf, not the (n, P) concat the seed
    estimator built. Single-leaf pytrees run the identical op
    sequence as the flatten-based oracle (bitwise; pinned in tests);
    multi-leaf trees reassociate the Σ over leaves (≤ 1 ulp drift).
    """
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    sq = jnp.zeros((n,), jnp.float32)
    for g in _agent_rows(grads):
        sq = sq + jnp.sum(g * g, axis=1)
    norm = jnp.sqrt(sq)                                # (n,)
    denom = jnp.maximum(norm, eps)[:, None]
    C = jnp.zeros((n, n), jnp.float32)
    for g in _agent_rows(grads):
        gn = g / denom
        C = C + gn @ gn.T
    c = jnp.clip(C, -1.0, 1.0)
    return jnp.where(jnp.eye(n, dtype=bool), 1.0, c)


def sketch_cosine(grads, dim: int, seed, eps: float = 1e-8, *,
                  impl: str = "auto") -> jnp.ndarray:
    """Sketched pairwise gradient cosines: stream the pytree through
    the seeded ±1 projection (``repro.kernels.grad_sketch``) into an
    (n, d) sketch, then cosine the sketch rows. Same contract as
    ``grad_cosine`` (symmetric, unit diagonal, zero rows → 0) with
    O((1 − ρ²)/√d) estimation error; ``seed`` may be traced — fold it
    per round with ``fold_seed`` so replay is deterministic."""
    from repro.kernels.grad_sketch import ops as sketch_ops
    s = sketch_ops.sketch_pytree(grads, seed, dim, impl=impl)
    return cosine_rows(s, eps)


def fold_seed(seed, rnd) -> jnp.ndarray:
    """Mix a base seed with a share-round index into the scalar seed
    the sign hash consumes: distinct rounds draw independent
    projections (the EMA averages their errors), identical
    (seed, round) pairs replay bit-for-bit. Accepts traced inputs."""
    from repro.kernels.grad_sketch.kernel import MIX_CONSTANTS
    p1, p2, p3 = (jnp.uint32(c) for c in MIX_CONSTANTS)
    x = (jnp.asarray(seed).astype(jnp.uint32) * p1
         + jnp.asarray(rnd).astype(jnp.uint32) * p2)
    x = (x ^ (x >> 16)) * p3
    return (x ^ (x >> 13)).astype(jnp.int32)


def to_relevance(cos, min_rel: float = 1e-3) -> jnp.ndarray:
    """Map cosine similarity [-1, 1] onto a relevance weight
    [min_rel, 1]: ``R = (1 + cos) / 2``, floored so a piece is
    down-weighted by conflict, never silently discarded (eq. 4
    renormalises, so the floor keeps every delivered piece's weight
    finite and nonzero)."""
    return jnp.clip(0.5 * (1.0 + cos), min_rel, 1.0)


def ema_update(prev, obs, decay, enabled=True,
               alive=None) -> jnp.ndarray:
    """EMA over share steps: ``decay·prev + (1−decay)·obs`` where
    ``enabled`` (a traced bool is fine), ``prev`` elsewhere — warm-up
    epochs hold the estimate at its prior.

    ``alive`` ((n,) bool, optional) freezes every entry touching a
    dead agent: a corpse produces no gradients, so decaying its
    rows/cols toward the observation would erase a *valid* estimate
    with garbage — the entry simply holds until both endpoints are
    alive again. ``alive=None`` is the historical two-way select."""
    new = decay * prev + (1.0 - decay) * obs
    upd = jnp.asarray(enabled)
    if alive is not None:
        a = jnp.asarray(alive, bool)
        upd = upd & a[:, None] & a[None, :]
    return jnp.where(upd, new, prev)


def gather_edges(dense, nbr) -> jnp.ndarray:
    """Project a dense (n, n) ``X[src, dst]`` matrix onto an (n, k)
    edge table: ``out[i, j] = X[nbr[i, j], i]``. Works with a traced
    ``nbr`` (dynamic topologies)."""
    n = dense.shape[0]
    dst = jnp.arange(n)[:, None]
    return dense[nbr, dst]


def init_relevance(n: int) -> jnp.ndarray:
    """The uniform prior every estimator starts from (and the fixed
    point of ``relevance_mode="uniform"``)."""
    return jnp.ones((n, n), jnp.float32)


def update_relevance(rel, grads, mode: str, decay: float,
                     enabled=True, *, sketch_dim: int = 0, seed=0,
                     rnd=0, impl: str = "auto") -> jnp.ndarray:
    """One online step of the (n, n) relevance estimate: a no-op for
    ``"uniform"``, an EMA toward the current gradient-cosine
    relevance for ``"grad_cos"`` — exact pairwise cosines when
    ``sketch_dim == 0``, the streaming sketched estimate (projection
    seeded per ``(seed, rnd)``) otherwise. The trainers now reach
    these update rules through the exchange estimator strategies
    (``repro.core.exchange.estimators``), which trace the same ops;
    this flag-dispatch form is kept as the algebraic reference the
    estimator tests pin against."""
    if mode == "uniform":
        return rel
    if mode == "grad_cos":
        if sketch_dim > 0:
            cos = sketch_cosine(grads, sketch_dim,
                                fold_seed(seed, rnd), impl=impl)
        else:
            cos = grad_cosine(grads)
        return ema_update(rel, to_relevance(cos), decay, enabled)
    raise ValueError(
        f"unknown relevance mode {mode!r}; expected one of "
        f"{RELEVANCE_MODES}")


def obs_overlap(mean, scale, eps: float = 1e-6) -> jnp.ndarray:
    """Static relevance prior from observation statistics: treating
    each agent's observation stream as an isotropic Gaussian with the
    given per-agent ``mean`` (n, d) and ``scale`` (n,) (std), return
    the (n, n) Gaussian-overlap matrix

        R[i, j] = exp( −|μ_i − μ_j|² / (2 (σ_i² + σ_j²)) )

    — 1 for identical streams, → 0 as they separate. Symmetric with a
    unit diagonal; use via ``Topology.with_relevance`` or the
    ``relevance=`` argument of the group entry points."""
    mean = jnp.asarray(mean, jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    d2 = jnp.sum(
        jnp.square(mean[:, None, :] - mean[None, :, :]), axis=-1)
    var = jnp.square(scale)
    denom = jnp.maximum(2.0 * (var[:, None] + var[None, :]), eps)
    return jnp.exp(-d2 / denom)
