"""Paper Figs. 3–4 — DDA3C scaling to 4 and 6 agents with earlier
sharing starts (paper: 4 agents share at 10k/20k, 6 agents at 5k/10k
— i.e. at 50% of a shrinking budget).

Claims reproduced: group learning still reaches stable optimal
policies; occasional outlier agents do not poison the rest (the
majority stays at the optimum).
"""
from __future__ import annotations


from benchmarks.common import run_a2c_group, sparkline


def main(epochs4: int = 4_000, epochs6: int = 3_000, seed: int = 0,
         verbose: bool = True):
    out = {}
    for n, epochs in ((4, epochs4), (6, epochs6)):
        res = run_a2c_group(n, epochs, threshold=epochs // 2,
                            seed=seed)
        out[n] = res
        if verbose:
            print(res.summary(f"fig{'3' if n == 4 else '4'} DDA3C "
                              f"{n}-agent (share@{epochs // 2})"))
            for a in range(n):
                print("  " + sparkline(res.rewards[:, a]))

    checks = {}
    for n, res in out.items():
        t = res.tail()
        good = (t.mean(axis=0) > 80).sum()
        checks[f"{n}-agent: majority of agents near-optimal"] = \
            good >= (n // 2 + 1)
    if verbose:
        for k, v in checks.items():
            print(f"  [{'PASS' if v else 'FAIL'}] {k}")
    out["checks"] = checks
    return out


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="paper scale (20k / 10k epochs)")
    p.add_argument("--seed", type=int, default=0)
    a = p.parse_args()
    if a.full:
        main(20_000, 10_000, a.seed)
    else:
        main(seed=a.seed)
