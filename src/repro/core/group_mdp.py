"""Group MDP — the paper's formalisation of GARL (paper §4, eq. 3).

    ⟨S_1..n, A_1..n, P_1..n, R_1..n, γ_1..n, K_1..n, K_-1..-n⟩

Each agent i has its own stationary environment (S_i, A_i, P_i, R_i,
γ_i), a local-knowledge set K_i and a received-knowledge set
K_-i = {K_{j,i}} — the only coupling between agents is knowledge
communication. This module is the *spec* level: it declares the group,
validates its structure and binds per-agent environments/agents; the
learning dynamics live in ``repro.core.ddal``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax.numpy as jnp

from repro.configs.base import GroupSpec


@dataclasses.dataclass(frozen=True)
class AgentEnv:
    """One agent's own MDP: environment + discount. ``env`` is any
    object exposing reset(key) -> state and step(state, action) ->
    (state, obs, reward, done) as pure jax functions."""
    env: Any
    gamma: float = 0.99


@dataclasses.dataclass(frozen=True)
class GroupMDP:
    """A group of n agents, each with its own environment. The special
    case of §6 of the paper (all agents share the same game) is
    ``homogeneous()``; the general case allows distinct envs, reward
    functions and discounts — their knowledge is coupled only through
    the relevance matrix R (R[j, i] = relevance of j's knowledge to i).
    """
    agents: Sequence[AgentEnv]
    spec: GroupSpec
    relevance: Optional[jnp.ndarray] = None   # (n, n), diag included

    def __post_init__(self):
        n = len(self.agents)
        if n != self.spec.n_agents:
            raise ValueError(
                f"GroupSpec.n_agents={self.spec.n_agents} but "
                f"{n} agent environments were given")
        if self.relevance is not None:
            if self.relevance.shape != (n, n):
                raise ValueError(f"relevance must be ({n},{n})")

    @property
    def n(self) -> int:
        return len(self.agents)

    @classmethod
    def homogeneous(cls, env, n: int, spec: Optional[GroupSpec] = None,
                    gamma: float = 0.99) -> "GroupMDP":
        """Paper §6: every agent plays the same game; relevance is
        uniform so R_j is ignored (paper: 'we ignore the R_j
        parameters')."""
        spec = spec or GroupSpec(n_agents=n)
        if spec.n_agents != n:
            spec = dataclasses.replace(spec, n_agents=n)
        return cls(agents=tuple(AgentEnv(env, gamma) for _ in range(n)),
                   spec=spec)
