"""RL substrate for the paper-faithful GARL experiments: pure-JAX
environments (CartPole-v0, GridWorld), A2C and double-dueling-DQN
agents exposing the DDAL callback protocol."""
from repro.rl.a2c import (  # noqa: F401
    A2CState,
    a2c_loss,
    init_a2c,
    make_a2c_callbacks,
    make_a2c_group,
)
from repro.rl.dqn import (  # noqa: F401
    DQNConfig,
    DQNState,
    dqn_loss,
    init_dqn,
    make_dqn_callbacks,
    make_dqn_group,
)
from repro.rl.envs import CartPole, GridWorld  # noqa: F401
from repro.rl.rollout import (  # noqa: F401
    Trajectory,
    episode_return,
    obs_moments,
    run_episode,
)
