"""Pytree arithmetic helpers (no optax available — we build our own).

All helpers are jit-friendly pure functions over arbitrary pytrees of
jnp arrays. They are used by the optimiser, the DDAL weighted-average
(paper eq. 4) and the knowledge stores.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_map(f, *trees):
    return jax.tree.map(f, *trees)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    """Scale every leaf of ``a`` by scalar (or 0-d array) ``s``."""
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_ones_like(a):
    return jax.tree.map(jnp.ones_like, a)


def tree_add_scaled(a, b, s):
    """a + s * b, leafwise."""
    return jax.tree.map(lambda x, y: x + s * y, a, b)


def tree_lerp(a, b, t):
    """(1 - t) * a + t * b, leafwise."""
    return jax.tree.map(lambda x, y: (1.0 - t) * x + t * y, a, b)


def tree_dot(a, b):
    """Inner product of two pytrees."""
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sq_norm(a):
    leaves = jax.tree.map(lambda x: jnp.vdot(x, x), a)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_sq_norm(a))


def tree_count(a) -> int:
    """Total number of scalar parameters (static)."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_weighted_sum(trees_stacked, weights):
    """Weighted sum over the leading axis of every leaf.

    ``trees_stacked`` is a pytree whose leaves have a leading axis of
    size m (m stacked gradient pieces); ``weights`` is an (m,) vector.
    Returns the pytree with the leading axis contracted:
    ``out = sum_j weights[j] * leaf[j]`` — exactly the contraction in
    DDAL's eq. 4 once the weights have been normalised.
    """
    def wsum(leaf):
        w = weights.astype(leaf.dtype)
        return jnp.tensordot(w, leaf, axes=(0, 0))
    return jax.tree.map(wsum, trees_stacked)


def tree_stack(trees):
    """Stack a python list of congruent pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    """Inverse of tree_stack for a static n."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def global_norm_clip(grads, max_norm):
    """Classic global-norm gradient clipping; returns (clipped, norm)."""
    norm = tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return tree_scale(grads, scale), norm
