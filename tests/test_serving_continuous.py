"""Continuous batching: slot refill correctness and equivalence with
the fixed-batch engine on greedy decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch_config
from repro.models import get_model
from repro.serving import ContinuousBatcher, ServeConfig, ServeEngine


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-780m",
                                  "deepseek-v2-lite-16b"])
def test_continuous_matches_fixed_batch_greedy(arch):
    cfg = get_arch_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    serve = ServeConfig(max_len=64, max_new_tokens=5)
    cb = ContinuousBatcher(cfg, params, serve, batch_size=2,
                           prompt_pad=8)
    reqs = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
    out = cb.run(reqs)
    assert set(out) == {0, 1, 2}
    eng = ServeEngine(cfg, params, serve)
    for rid, req in enumerate(reqs):
        toks = np.zeros((1, 8), np.int32)
        toks[0, :len(req)] = req
        ref = np.asarray(eng.generate(jnp.asarray(toks),
                                      jnp.asarray([len(req)],
                                                  jnp.int32)))[0]
        np.testing.assert_array_equal(np.asarray(out[rid]), ref[:5])


def test_more_requests_than_slots():
    cfg = get_arch_config("granite-3-8b").reduced()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    cb = ContinuousBatcher(cfg, params,
                           ServeConfig(max_len=32, max_new_tokens=3),
                           batch_size=2, prompt_pad=8)
    out = cb.run([[i + 1] for i in range(7)])
    assert set(out) == set(range(7))
    assert all(len(v) == 3 for v in out.values())


# ---------------------------------------------------------------------
# stop-criteria boundaries (ISSUE 6): eos, max_new_tokens == 1, and a
# prompt that (nearly) fills the cache — all through the shared
# repro.serving.api.StopCriteria path
# ---------------------------------------------------------------------
@pytest.fixture(scope="module")
def llama():
    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_eos_stops_mid_stream(llama):
    """The request finishes at the first eos token (inclusive) instead
    of padding out to max_new_tokens; eos picked mid-way through the
    eos-free greedy reference so the refill-time and decode-time stop
    paths both stay honest."""
    cfg, params = llama
    prompt = [3, 1, 4, 1, 5]
    ref = ContinuousBatcher(
        cfg, params, ServeConfig(max_len=64, max_new_tokens=8),
        batch_size=2, prompt_pad=8).run([prompt])[0]
    assert len(ref) == 8
    eos = ref[3]
    idx = ref.index(eos)                 # first occurrence may be < 3
    out = ContinuousBatcher(
        cfg, params,
        ServeConfig(max_len=64, max_new_tokens=8, eos_id=eos),
        batch_size=2, prompt_pad=8).run([prompt])[0]
    assert out == ref[:idx + 1]


def test_max_new_tokens_one(llama):
    """mnt=1 stops at refill time: exactly one token, the same first
    token the unbounded run produces."""
    cfg, params = llama
    prompt = [7, 8, 9]
    ref = ContinuousBatcher(
        cfg, params, ServeConfig(max_len=64, max_new_tokens=8),
        batch_size=2, prompt_pad=8).run([prompt])[0]
    out = ContinuousBatcher(
        cfg, params, ServeConfig(max_len=64, max_new_tokens=1),
        batch_size=2, prompt_pad=8).run([prompt])[0]
    assert out == [ref[0]]


def test_prompt_fills_cache(llama):
    """Generation is clipped to the cache capacity: a prompt of n
    tokens in a max_len cache yields max_len - n tokens, and a prompt
    at max_len - 1 yields exactly the prefill token."""
    cfg, params = llama
    serve = ServeConfig(max_len=32, max_new_tokens=10)
    cb = ContinuousBatcher(cfg, params, serve, batch_size=2,
                           prompt_pad=8)
    long_prompt = [(i % 50) + 1 for i in range(28)]
    brim_prompt = [(i % 50) + 1 for i in range(31)]
    out = cb.run([long_prompt, brim_prompt])
    assert len(out[0]) == 32 - 28
    assert len(out[1]) == 1              # stopped at refill time


def test_empty_request_stream(llama):
    cfg, params = llama
    cb = ContinuousBatcher(cfg, params,
                           ServeConfig(max_len=32, max_new_tokens=2),
                           batch_size=2, prompt_pad=8)
    assert cb.run([]) == {}
