"""Pure-jnp oracle for the flash-attention kernel: materialised-score
causal GQA attention with optional sliding window."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None,
              scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, S, K, D) with H % K == 0.
    Returns (B, S, H, D) in q.dtype. Softmax in fp32."""
    B, S, H, D = q.shape
    K = k.shape[2]
    rep = H // K
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    scores = jnp.einsum("bihd,bjhd->bhij", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= (i - j) < window
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhij,bjhd->bihd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
