"""One serving API for every engine (ISSUE 6).

Before this module the three serving code paths each carried private
copies of the same logic: ``ServeEngine._prefill_impl`` and
``ContinuousBatcher._prefill1_impl`` were near-copies of the
family-dispatch prefill, and sampling/stop handling was duplicated
three ways (the fixed-batch scan, the continuous host loop, the
per-request refill sample). Everything shape-generic lives here once:

* :class:`ServeConfig` — the serving knobs every engine shares.
* :func:`build_prefill_batch` — (B, P) prompt ids → the arch family's
  full prefill batch dict (audio codebooks / vlm vision prefix /
  default), any B.
* :func:`prefill` — batch prefill into a fresh cache → per-row
  next-token logits + the filled cache.
* :func:`decode_batch` / :func:`last_logits` — the decode-step batch
  wrapper and next-logit slice.
* :class:`Sampler` — greedy / temperature sampling, one definition for
  jitted (B, V) logits and host-side (V,) refill samples alike.
* :class:`StopCriteria` — eos / max_new_tokens / cache-capacity stop
  logic, jit-side mask and host-side per-slot verdict.
* :func:`cache_batch_dims` / :func:`splice_cache` — per-leaf cache
  batch-dim discovery and B=1→slot splicing for the continuous-style
  engines.

The single-tenant engines are thin wrappers over these (pinned to
their pre-refactor outputs by ``tests/test_serving_continuous.py``);
``repro.serving.group.GroupServeEngine`` consumes the same pieces, so
multi-tenant serving shares every numeric with the single-tenant
oracle by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import get_model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512           # cache capacity
    max_new_tokens: int = 64
    temperature: float = 0.0     # 0 → greedy
    eos_id: int = -1             # -1 → never stops early


# ---------------------------------------------------------------------
# batch construction (the one family-dispatch ladder)
# ---------------------------------------------------------------------
def decode_batch(cfg: ArchConfig, tokens, positions) -> Dict[str, Any]:
    """Wrap a (B, 1) token into the arch's decode-batch dict."""
    if cfg.family == "audio":
        t = jnp.broadcast_to(tokens[:, None, :],
                             (tokens.shape[0], cfg.n_codebooks, 1))
        return {"tokens": t, "positions": positions}
    if cfg.family == "vlm":
        pos3 = jnp.broadcast_to(positions[:, None, :],
                                (positions.shape[0], 3, 1))
        return {"tokens": tokens, "positions": pos3}
    return {"tokens": tokens, "positions": positions}


def last_logits(cfg: ArchConfig, logits):
    """(B, V) next-token logits from a decode/prefill output."""
    if cfg.family == "audio":                  # (B, C, T, V): codebook 0
        return logits[:, 0, -1, :]
    return logits[:, -1, :]


def build_prefill_batch(cfg: ArchConfig, tokens) -> Dict[str, Any]:
    """(B, P) right-padded prompt ids → the family's prefill batch."""
    B, P = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    if cfg.family == "audio":
        return {"tokens": jnp.broadcast_to(
                    tokens[:, None, :], (B, cfg.n_codebooks, P)),
                "positions": pos,
                "cond": jnp.zeros((B, cfg.cond_len, cfg.d_model),
                                  cfg.dtype("compute"))}
    if cfg.family == "vlm":
        return {"tokens": tokens,
                "vision": jnp.zeros((B, cfg.vision_prefix, cfg.d_model),
                                    cfg.dtype("compute")),
                "positions": jnp.broadcast_to(
                    jnp.arange(P + cfg.vision_prefix, dtype=jnp.int32),
                    (B, 3, P + cfg.vision_prefix))}
    return {"tokens": tokens, "positions": pos}


def prefill(cfg: ArchConfig, model, params, tokens, lengths,
            max_len: int) -> Tuple[Any, Any]:
    """Prefill a fresh B-slot cache; next-token logits come from each
    prompt's LAST real token. tokens: (B, P); lengths: (B,)."""
    B = tokens.shape[0]
    cache = model.make_cache(cfg, B, max_len)
    logits, cache = model.forward(cfg, params,
                                  build_prefill_batch(cfg, tokens),
                                  cache)
    idx = jnp.maximum(lengths - 1, 0)
    if cfg.family == "audio":
        nxt = logits[jnp.arange(B), 0, idx, :]
    else:
        nxt = logits[jnp.arange(B), idx, :]
    return nxt, cache


# ---------------------------------------------------------------------
# sampling + stop logic (one definition for all three engines)
# ---------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Sampler:
    """Greedy (temperature ≤ 0) or temperature sampling over the last
    axis; works on (B, V) jit-side logits and host-side (V,) rows."""
    temperature: float = 0.0

    def __call__(self, logits, key=None):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class StopCriteria:
    """When a slot's generation ends: eos, token budget, or cache
    capacity (pos is the post-increment next absolute position)."""
    eos_id: int = -1
    max_new_tokens: int = 64
    max_len: int = 512

    @classmethod
    def from_serve(cls, serve: ServeConfig) -> "StopCriteria":
        return cls(eos_id=serve.eos_id,
                   max_new_tokens=serve.max_new_tokens,
                   max_len=serve.max_len)

    def eos_done(self, next_tok):
        """jit-side done contribution of one sampled token."""
        return next_tok == self.eos_id

    def should_stop(self, n_generated: int, token: int,
                    pos: int) -> bool:
        """Host-side per-slot verdict after appending ``token`` as the
        ``n_generated``-th output, with the slot's next position at
        ``pos``."""
        return (token == self.eos_id
                or n_generated >= self.max_new_tokens
                or pos >= self.max_len - 1)


# ---------------------------------------------------------------------
# slot-cache plumbing (continuous-style engines)
# ---------------------------------------------------------------------
def cache_batch_dims(cfg: ArchConfig, max_len: int) -> Any:
    """Pytree (matching the cache) of each leaf's batch-dim index.

    Per-leaf batch dims differ across cache families (transformer
    caches are (L, B, ...), zamba2's mamba states (nb, mpb, B, ...)) —
    discovered once by diffing ``eval_shape`` at two batch sizes."""
    model = get_model(cfg)
    s1 = jax.eval_shape(lambda: model.make_cache(cfg, 1, max_len))
    s2 = jax.eval_shape(lambda: model.make_cache(cfg, 2, max_len))

    def dim(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch dim in {a.shape}")

    return jax.tree.map(dim, s1, s2)


def splice_cache(batch_cache, one_cache, bdims, slot: int):
    """Insert a B=1 cache into batch slot ``slot`` (static index)."""
    def put(buf, one, d):
        idx = [slice(None)] * buf.ndim
        idx[d] = slot
        one_idx = [slice(None)] * one.ndim
        one_idx[d] = 0
        return buf.at[tuple(idx)].set(one[tuple(one_idx)])

    return jax.tree.map(put, batch_cache, one_cache, bdims)


# ---------------------------------------------------------------------
# --serve key=value vocabulary (mirrors repro.core.exchange.cli_options)
# ---------------------------------------------------------------------
# engine-level knobs that live outside ServeConfig; the launcher maps
# them onto engine constructor / mode selection.
ENGINE_OPTIONS: Dict[str, type] = {
    "engine": str,        # batch | continuous | group
    "slots": int,         # continuous/group batch slots
    "prompt_pad": int,    # prompt padding granularity
    "agents": int,        # group mode: tenants sharing the mesh
    "router": str,        # group mode: fifo | fair
}


def cli_options() -> Dict[str, Tuple[str, type]]:
    """The full ``--serve key=value`` vocabulary: every
    :class:`ServeConfig` field plus the engine-level knobs, each
    mapped to ``(field, type)`` — derived from the dataclass, so new
    serving knobs never need new argparse plumbing
    (``repro.launch.serve``)."""
    opts = {f.name: (f.name, type(f.default))
            for f in dataclasses.fields(ServeConfig)}
    opts.update({k: (k, t) for k, t in ENGINE_OPTIONS.items()})
    return opts
