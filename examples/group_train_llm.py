"""End-to-end driver: DDAL group-agent training of a ~100M-parameter
llama-family model for a few hundred steps on synthetic Markov data.

Each agent is its own "environment" — a distinct order-1 Markov token
stream (50% shared structure) — and the group exchanges gradient
knowledge through the streaming DDAL trainer, exactly the code path
the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/group_train_llm.py             # ~25M
    PYTHONPATH=src python examples/group_train_llm.py --params-100m
"""
import argparse
import time

import jax
import numpy as np

from repro import optim
from repro.checkpoint import save
from repro.configs import get_arch_config
from repro.configs.base import GroupSpec, ShapeConfig
from repro.core import init_train_state, make_group_train_step
from repro.data import StreamSpec, make_group_batch

p = argparse.ArgumentParser()
p.add_argument("--params-100m", action="store_true",
               help="~100M params (slower on CPU)")
p.add_argument("--steps", type=int, default=200)
p.add_argument("--agents", type=int, default=2)
p.add_argument("--ckpt", default=None)
args = p.parse_args()

base = get_arch_config("llama3.2-3b")
if args.params_100m:
    cfg = base.with_(n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
                     head_dim=64, d_ff=1792, vocab_size=32_000,
                     param_dtype="float32", compute_dtype="float32",
                     remat=False)
else:
    cfg = base.with_(n_layers=6, d_model=384, n_heads=6, n_kv_heads=3,
                     head_dim=64, d_ff=1024, vocab_size=16_000,
                     param_dtype="float32", compute_dtype="float32",
                     remat=False)

spec = GroupSpec(n_agents=args.agents, threshold=20, minibatch=10,
                 knowledge_mode="streaming")
shape = ShapeConfig("llm", seq_len=256, global_batch=4, kind="train")
opt = optim.adamw(3e-4)
stream = StreamSpec(seed=0, similarity=0.5)

key = jax.random.PRNGKey(0)
state = init_train_state(cfg, spec, opt, key)
n_params = sum(int(x.size) for x in jax.tree.leaves(state.params)
               ) // spec.n_agents
print(f"{n_params:,} params/agent × {spec.n_agents} agents; "
      f"warm-up {spec.threshold} steps, share every {spec.minibatch}")

step_fn = jax.jit(make_group_train_step(cfg, spec, opt))
t0 = time.time()
losses = []
for i in range(args.steps):
    batch = make_group_batch(cfg, shape, stream, spec.n_agents, i)
    state, m = step_fn(state, batch)
    losses.append(np.asarray(m["loss"]))
    if i % 10 == 0 or i == args.steps - 1:
        ls = " ".join(f"{float(x):6.3f}" for x in m["loss"])
        tag = " <shared>" if int(m["shared"]) else ""
        print(f"step {i:4d} [{ls}]{tag}  "
              f"({(i + 1) / (time.time() - t0):.2f} steps/s)")

losses = np.stack(losses)
print(f"\nloss agent-mean: first10={losses[:10].mean():.3f} "
      f"last10={losses[-10:].mean():.3f} "
      f"(uniform = {np.log(cfg.vocab_size):.3f})")
if args.ckpt:
    save(args.ckpt, state.params, step=args.steps)
    print("checkpoint saved to", args.ckpt)
