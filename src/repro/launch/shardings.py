"""PartitionSpec trees for every step kind (train / prefill / decode).

Parameters get their specs from the logical-axis table
(repro.models.param_logical_axes) mapped through the mesh rule set;
batches shard their leading batch dim over the data axes; caches use
name+rank rules (KV heads / SSM heads / d_inner over "model", batch
over the data axes, sequence slots unsharded).
"""
from __future__ import annotations

from typing import Any, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import cache_specs, param_logical_axes, param_specs

Axis = Union[None, str, Tuple[str, ...]]


def ddal_agent_axis(mesh, pod_axis: str = "pod") -> Axis:
    """The physical mesh axes the DDAL agent dim shards over: both
    levels of a two-level pod mesh (``repro.launch.mesh.make_pod_mesh``
    — agents laid out pod-major so pods align with ``pod_axis``, the
    contract ``repro.core.pod_dispatch`` validates), the ``pod_axis``
    alone on the single-level production mesh, or unsharded."""
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    if pod_axis in names and "agent" in names:
        return (pod_axis, "agent")
    if pod_axis in names:
        return pod_axis
    return None


def agent_sharded_state(state, mesh, pod_axis: str = "pod"):
    """Place a DDAL TrainState (or any pytree of leading-agent-axis
    leaves + scalars) onto ``mesh``: dim 0 of every non-scalar leaf
    shards over ``ddal_agent_axis``, so pods land on their mesh rows
    before the first step instead of being resharded inside jit."""
    axis = ddal_agent_axis(mesh, pod_axis)
    if axis is None:
        return state

    def put(x):
        spec = P(axis) if getattr(x, "ndim", 0) else P()
        return jax.device_put(
            x, jax.sharding.NamedSharding(mesh, spec))

    return jax.tree.map(put, state)


def _is_axes_tuple(x) -> bool:
    return isinstance(x, tuple) and all(
        n is None or isinstance(n, str) for n in x)


def param_partition_specs(cfg: ArchConfig, rules: dict,
                          lead: Tuple[Axis, ...] = ()) -> Any:
    """Physical PartitionSpecs for the param pytree; ``lead`` prefixes
    extra axes (the DDAL agent axis)."""
    shapes = param_specs(cfg)
    logical = param_logical_axes(cfg, shapes)

    def to_phys(tup):
        return P(*lead, *[rules.get(n) if n is not None else None
                          for n in tup])

    return jax.tree.map(to_phys, logical, is_leaf=_is_axes_tuple)


def batch_partition_specs(cfg: ArchConfig, shape: ShapeConfig,
                          batch_axes: Axis,
                          lead: Tuple[Axis, ...] = ()) -> Any:
    """Specs for the input batch dict: dim0 (after ``lead``) is the
    batch dim for every leaf."""
    from repro.models import input_specs
    specs = input_specs(cfg, shape)

    def per_leaf(s):
        extra = len(s.shape) - 1
        return P(*lead, batch_axes, *([None] * extra))

    return {k: per_leaf(v) for k, v in specs.items()}


def group_plane_partition_specs(cfg: ArchConfig, mesh,
                                pod_axis: str = "pod") -> Any:
    """PartitionSpecs for ``repro.serving.group.GroupServeEngine``'s
    stacked per-agent serving planes: dim 0 (the agent axis) shards
    over ``ddal_agent_axis`` — the placement the DDAL trainer already
    keeps ``TrainState.params`` in, so a ``ParamStore.publish`` from a
    live trainer is a handoff, not a reshard — and the per-parameter
    dims stay replicated (the decode step gathers arbitrary tenants'
    planes per slot, so any device may need any agent's row)."""
    axis = ddal_agent_axis(mesh, pod_axis)
    shapes = param_specs(cfg)
    return jax.tree.map(lambda _: P(axis), shapes)


# -- cache rules -------------------------------------------------------
_CACHE_RULES = {
    # key: {rank: {dim: logical}}. KV caches shard batch + SLOTS
    # (flash-decoding sweep; head dims often don't divide the mesh)
    "k":      {5: {1: "B", 2: "slots"}, },
    "v":      {5: {1: "B", 2: "slots"}, },
    "ck":     {5: {1: "B", 3: "model"}, },
    "cv":     {5: {1: "B", 3: "model"}, },
    "pos":    {3: {1: "B", 2: "slots"}},
    "ckv":    {4: {1: "B", 2: "slots"}},
    "k_rope": {4: {1: "B", 2: "slots"}},
    "conv_x": {4: {1: "B", 3: "model"}, 5: {2: "B", 4: "model"}},
    "conv_B": {4: {1: "B"}, 5: {2: "B"}},
    "conv_C": {4: {1: "B"}, 5: {2: "B"}},
    "ssm":    {5: {1: "B", 2: "model"}, 6: {2: "B", 3: "model"}},
}


def cache_partition_specs(cfg: ArchConfig, shape: ShapeConfig,
                          batch_axes: Axis, model_axis: Axis = "model",
                          slots_axis: Axis = "model") -> Any:
    """Specs matching ``repro.models.cache_specs(cfg, shape)``."""
    cache = cache_specs(cfg, shape)

    def rule(path, leaf):
        name = None
        for p in reversed(path):
            key = getattr(p, "key", None)
            if isinstance(key, str):
                name = key
                break
        rank = len(leaf.shape)
        table = _CACHE_RULES.get(name, {})
        dims = table.get(rank, {})
        axes = []
        for d in range(rank):
            a = dims.get(d)
            if a == "B":
                axes.append(batch_axes)
            elif a == "model":
                axes.append(model_axis)
            elif a == "slots":
                axes.append(slots_axis)
            else:
                axes.append(None)
        return P(*axes)

    return jax.tree_util.tree_map_with_path(rule, cache)


# -- TrainState (adamw layout) ----------------------------------------
def train_state_partition_specs(cfg: ArchConfig, rules: dict,
                                agent_axis: Axis,
                                learn_relevance: bool = False,
                                sketch_dim: int = 0) -> Any:
    """Specs for repro.core.sharded_ddal.TrainState with an AdamW
    optimiser (m/v mirror params; count/step are scalars). With
    ``learn_relevance`` (the exchange estimator's ``.learns`` — the
    gradient-cosine estimators of ``repro.core.exchange``) the state
    carries the (A, A) learned relevance EMA — rows shard over the
    agent axis like the other per-agent leaves — and with
    ``sketch_dim > 0`` (the ``grad_cos+sketch`` estimator) also the
    (A, d) window gradient sketch (``Knowledge.sk``), likewise
    row-sharded: the cosine on it is the only cross-agent relevance
    contraction, moving O(A·d) bytes."""
    from repro.core.sharded_ddal import Knowledge, TrainState
    pspec = param_partition_specs(cfg, rules, lead=(agent_axis,))
    vec = P(agent_axis)
    rel = P(agent_axis, None) if learn_relevance else None
    sk = P(agent_axis, None) if (learn_relevance
                                 and sketch_dim > 0) else None
    return TrainState(
        params=pspec,
        opt_state={"m": pspec, "v": pspec, "count": vec},
        know=Knowledge(tg=pspec, tsum=vec, rg=pspec, rsum=vec,
                       rel=rel, sk=sk),
        step=P(),
    )
