"""Topology schedules — *which graph is in force at step t*.

A :class:`TopologySchedule` owns the communication graph's evolution
and nothing else: the trainers carry a small (n, k) gossip table and
ask the schedule to refresh it (carried-table loops, ``DDAL``) or to
materialise the step's :class:`~repro.core.topology.Topology` from
scratch (stateless share steps, the streaming trainer). Three
strategies are registered:

``static``
    The graph never changes. ``materialize``/``at_step`` return the
    *exact* wrapped ``Topology`` object, so the static limit of every
    downstream consumer is structural, not just numerical.
``dynamic``
    Time-varying uniform gossip
    (:class:`~repro.core.topology.DynamicTopology`): the ``random_k``
    table resamples every ``resample_every`` epochs, seeded by
    ``(topology_seed, epoch // resample_every)``.
``relevance_topk``
    Relevance-*aware* resampling (ROADMAP): edge choice is a Gumbel
    top-k over the learned relevance estimate — the gossip graph
    itself adapts, not just the eq. 4 weights — with per-destination
    ε-greedy exploration rows falling back to uniform gossip so no
    edge starves. Fully deterministic in ``(seed, epoch)``: sampling
    keys fold the resample-round index exactly like ``dynamic``, so
    replay reproduces the graph sequence bit for bit.
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import (
    DynamicTopology,
    Topology,
    sample_gossip,
)
from repro.core.exchange.registry import SCHEDULES


class TopologySchedule:
    """Interface: the communication graph over time.

    base
        Static-shape ``Topology`` — fixes (n, k) for delay-line
        allocation and the delivery fast-path hints; annotations (per
        -edge delay / static relevance prior) live here.
    topology
        The wrapped graph object (``Topology`` or ``DynamicTopology``)
        — kept for callers that introspect the schedule (benchmarks,
        ``DDAL.topology`` back-compat).
    init_table()
        The (n, k) int32 gossip table a carried-table loop starts
        from.
    refresh(step, nbr, rel, alive)
        The carried table after ``step``: resampling schedules swap it
        at round boundaries (under a ``lax.cond`` over the tiny
        table), static ones return it untouched. ``rel`` is the dense
        (n, n) learned relevance (consumed only by relevance-aware
        schedules); ``alive`` ((n,) bool, optional) excludes dead
        sources from resampled draws — a corpse never receives a
        fresh gossip edge (static tables are instead masked by the
        send/combine gates downstream).
    materialize(step, nbr, rel)
        The ``Topology`` in force given the carried table.
    at_step(step, rel, alive)
        Stateless form — recompute the step's table from scratch. For
        relevance-free schedules this equals the refresh sequence
        when steps are visited in order from 0; a relevance-aware
        schedule re-ranks with the ``rel`` in force at the call (its
        random draws are still frozen per resample round — see
        ``RelevanceTopKSchedule``), so mid-round calls track the
        evolving estimate where the carried-table form freezes the
        boundary's picks. The streaming trainer uses this at share
        steps.
    """

    base: Topology
    topology: Union[Topology, DynamicTopology]
    #: True when refresh / at_step consume the learned relevance —
    #: trainers may skip materialising the dense matrix otherwise.
    uses_relevance: bool = False

    def init_table(self) -> jnp.ndarray:
        return jnp.asarray(self.base.nbr, jnp.int32)

    def refresh(self, step, nbr, rel, alive=None):
        raise NotImplementedError

    def materialize(self, step, nbr, rel) -> Topology:
        raise NotImplementedError

    def at_step(self, step, rel, alive=None) -> Topology:
        raise NotImplementedError

    @property
    def max_delay(self) -> int:
        return self.topology.max_delay


@SCHEDULES.register("static",
                    params={"topology": ("topology", str),
                            "degree": ("degree", int),
                            "topology_seed": ("topology_seed", int)})
class StaticSchedule(TopologySchedule):
    """The graph named by ``GroupSpec.topology``, fixed for the run."""

    def __init__(self, topo: Topology):
        self.base = topo
        self.topology = topo

    def refresh(self, step, nbr, rel, alive=None):
        del step, rel, alive
        return nbr

    def materialize(self, step, nbr, rel) -> Topology:
        del step, nbr, rel
        return self.base

    def at_step(self, step, rel, alive=None) -> Topology:
        del step, rel, alive
        return self.base


@SCHEDULES.register("dynamic",
                    params={"resample_every": ("resample_every", int)})
class DynamicSchedule(TopologySchedule):
    """Uniform gossip resampling (``DynamicTopology``); with
    ``resample_every <= 0`` it degenerates to the static base —
    returning the exact base object, the pinned static-limit oracle."""

    def __init__(self, dyn: DynamicTopology):
        self.topology = dyn
        self.base = dyn.base
        self._resampling = dyn.resample_every > 0

    def refresh(self, step, nbr, rel, alive=None):
        del rel
        if not self._resampling:
            return nbr
        return self.topology.refresh_table(step, nbr, alive)

    def materialize(self, step, nbr, rel) -> Topology:
        del step, rel
        if not self._resampling:
            return self.base
        return self.topology.with_table(nbr)

    def at_step(self, step, rel, alive=None) -> Topology:
        del rel
        return self.topology.at_epoch(step, alive)


@SCHEDULES.register("relevance_topk",
                    params={"explore_eps": ("explore_eps", float)})
class RelevanceTopKSchedule(TopologySchedule):
    """Gumbel top-k gossip over the learned relevance.

    Every ``resample_every`` epochs each destination redraws its k−1
    in-edges (slot 0 stays the self-loop) by perturbed-score sampling:

        score[dst, src] = log R[src, dst] + Gumbel(key, dst, src)

    and keeps the top k−1 sources — a without-replacement sample whose
    inclusion probabilities follow the relevance weights (Gumbel
    top-k). Exploration: per round, each destination independently
    flips an ε-coin; exploring rows take a fresh *uniform* gossip row
    instead, so low-R edges keep being probed and the estimate can
    recover (the EMA only updates edges that get observed gradients
    under sparse exchange).

    Determinism: all three draws (Gumbel, ε-coins, uniform fallback)
    key off ``fold_in(PRNGKey(seed), step // resample_every)`` — the
    schedule is a pure function of ``(seed, epoch, R)``, so replay
    with the same seed and data reproduces the graph sequence exactly.

    The two trainer forms differ only in *which R ranks a round*: the
    carried-table loop (``refresh``, the buffer trainer) samples once
    at the round boundary and freezes the picks; the stateless form
    (``at_step``, the streaming trainer's share steps) reuses the
    round's frozen draws but ranks with the R in force at the call,
    so within a round the graph moves only if the learned estimate
    itself moves. Both are replay-deterministic.
    """

    uses_relevance = True

    def __init__(self, base: Topology, resample_every: int, seed: int,
                 eps: float, dense_delay=None, dense_relevance=None):
        if resample_every < 1:
            raise ValueError(
                f"relevance_topk resamples on a cadence and needs "
                f"resample_every >= 1, got {resample_every}")
        if not 0.0 <= eps <= 1.0:
            raise ValueError(
                f"explore_eps must be in [0, 1], got {eps}")
        if not np.asarray(base.mask).all():
            raise ValueError(
                "relevance_topk resamples a k-regular table and "
                "cannot carry a padded edge mask — give it a "
                "regular-degree base (e.g. random_k)")
        if (dense_relevance is None
                and (np.asarray(base.relevance)
                     != np.asarray(base.mask, np.float32)).any()):
            raise ValueError(
                "the base topology's per-edge relevance prior cannot "
                "follow relevance_topk's table swaps — pass the prior "
                "as a dense (n, n) relevance= matrix instead")
        self.base = base
        # the DynamicTopology supplies table→Topology materialisation
        # (all-True mask, dense or uniform-base delay, dense or unit
        # relevance prior)
        self.topology = DynamicTopology(base=base,
                                        resample_every=resample_every,
                                        seed=seed,
                                        dense_delay=dense_delay,
                                        dense_relevance=dense_relevance)
        if dense_delay is None:
            self.topology._uniform_base_delay()  # validate early
        self.resample_every = resample_every
        self.seed = seed
        self.eps = eps

    # ------------------------------------------------------------------
    def with_dense(self, delay=None,
                   relevance=None) -> "RelevanceTopKSchedule":
        """Attach dense (resample-surviving) delay / relevance carries
        — the only annotation forms a resampling schedule can honor
        (``DynamicTopology.with_dense`` semantics). Mutates this
        schedule's wrapped topology and base in lockstep."""
        if delay is not None or relevance is not None:
            self.topology = self.topology.with_dense(
                delay=delay, relevance=relevance)
            self.base = self.topology.base
        return self

    # ------------------------------------------------------------------
    def _round_keys(self, step):
        rnd = jnp.asarray(step, jnp.int32) // self.resample_every
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), rnd)
        return jax.random.split(key, 3)

    def explore_mask(self, step) -> jnp.ndarray:
        """(n,) bool — which destinations explore this round. Exposed
        so the pinned exploration-rate property test can check the
        realised rate against ε without reverse-engineering tables."""
        n = self.base.n_agents
        _, ke, _ = self._round_keys(step)
        return jax.random.bernoulli(ke, self.eps, (n,))

    def sample_table(self, step, rel, alive=None) -> jnp.ndarray:
        """The (n, k) table of ``step``'s resample round — a pure
        (traceable) function of ``(seed, step // resample_every, R)``.
        ``rel=None`` (a non-learning estimator) degenerates to
        uniform-weight Gumbel sampling — every edge equally likely,
        like ``dynamic``, but through the same code path. ``alive``
        forces dead source columns to −inf before the top-k (and
        shapes the uniform fallback the same way), so corpses are
        only picked when fewer than k−1 live candidates remain —
        those residual edges carry nothing past the send gate."""
        n, k = self.base.nbr.shape
        kg, ke, ku = self._round_keys(step)
        if rel is None:
            rel = jnp.ones((n, n), jnp.float32)
        R = jnp.maximum(jnp.asarray(rel, jnp.float32), 1e-30)
        u = jax.random.uniform(kg, (n, n), minval=1e-12, maxval=1.0)
        gumbel = -jnp.log(-jnp.log(u))
        # scores[dst, src]; the self column is forced out — slot 0 is
        # the dedicated self-loop, like sample_gossip's layout
        scores = jnp.log(R.T) + gumbel
        scores = jnp.where(jnp.eye(n, dtype=bool), -jnp.inf, scores)
        if alive is not None:
            scores = jnp.where(jnp.asarray(alive, bool)[None, :],
                               scores, -jnp.inf)
        _, picked = jax.lax.top_k(scores, k - 1)           # (n, k-1)
        self_col = jnp.arange(n, dtype=jnp.int32)[:, None]
        greedy = jnp.concatenate(
            [self_col, picked.astype(jnp.int32)], axis=1)
        uniform = sample_gossip(ku, n, k, alive)
        explore = jax.random.bernoulli(ke, self.eps, (n,))
        return jnp.where(explore[:, None], uniform, greedy)

    # ------------------------------------------------------------------
    def refresh(self, step, nbr, rel, alive=None):
        boundary = (jnp.asarray(step, jnp.int32)
                    % self.resample_every) == 0
        return jax.lax.cond(
            boundary,
            lambda _: self.sample_table(step, rel, alive),
            lambda _: jnp.asarray(nbr, jnp.int32),
            None)

    def materialize(self, step, nbr, rel) -> Topology:
        del step, rel
        return self.topology.with_table(nbr)

    def at_step(self, step, rel, alive=None) -> Topology:
        return self.topology.with_table(
            self.sample_table(step, rel, alive))
