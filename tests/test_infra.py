"""Infrastructure tests: roofline HLO parser, checkpointing, data
pipeline determinism, serving engine, optimisers, sharding helpers."""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ----------------------------------------------------------------------
# roofline: HLO collective parsing
# ----------------------------------------------------------------------
from repro.roofline.hlo import collective_bytes, count_ops

_FAKE_HLO = """
HloModule jit_step

fused_computation {
  %p0 = f32[128,256]{1,0} parameter(0)
  ROOT %t = f32[128,256]{1,0} tanh(%p0)
}

ENTRY %main {
  %x = f32[128,256]{1,0} parameter(0)
  %y = bf16[64]{0} parameter(1)
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  %ag = bf16[1024]{0} all-gather(%y), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
  %ags = (bf16[64]{0}, bf16[1024]{0}) all-gather-start(%y), dimensions={0}
  %agd = bf16[1024]{0} all-gather-done(%ags)
  ROOT %out = f32[128,256]{1,0} add(%cp, %x)
}
"""


def test_collective_bytes_parsing():
    out = collective_bytes(_FAKE_HLO)
    f32_mat = 128 * 256 * 4
    assert out["all-reduce"] == f32_mat          # operand %x
    assert out["all-gather"] == 64 * 2 * 2       # two ops, operand %y
    assert out["collective-permute"] == f32_mat  # operand %ar
    assert out["total"] == 2 * f32_mat + 2 * 128
    assert count_ops(_FAKE_HLO, "all-gather") >= 2


def test_collective_bytes_tuple_form():
    """XLA's all-reduce combiner emits TUPLE all-reduces whose result
    types contain /*index=N*/ comments — parser-v2 regression test
    (these were silently skipped before, undercounting gradient ARs)."""
    hlo = """
ENTRY %m {
  %a = f32[64]{0} parameter(0)
  %b = f32[8,2]{1,0} parameter(1)
  %c = f32[4]{0} parameter(2)
  %d = f32[4]{0} parameter(3)
  %e = f32[4]{0} parameter(4)
  %f = f32[4]{0} parameter(5)
  %ar = (f32[64]{0}, f32[8,2]{1,0}, f32[4]{0}, f32[4]{0}, f32[4]{0}, /*index=5*/f32[4]{0}) all-reduce(%a, %b, %c, %d, %e, %f), replica_groups={}
  ROOT %t = f32[64]{0} get-tuple-element(%ar), index=0
}
"""
    out = collective_bytes(hlo)
    want = (64 + 16 + 4 * 4) * 4
    assert out["all-reduce"] == want, out


def test_collective_bytes_real_lowering():
    """Parse a genuinely compiled module with a known all-reduce."""
    mesh = jax.make_mesh((1,), ("m",))
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f = jax.jit(lambda a: a.sum(), in_shardings=(
        jax.sharding.NamedSharding(mesh,
                                   jax.sharding.PartitionSpec("m")),))
    txt = f.lower(x).compile().as_text()
    out = collective_bytes(txt)      # 1-device: no collectives expected
    assert out["total"] >= 0


# ----------------------------------------------------------------------
# roofline: model FLOPs / param counting
# ----------------------------------------------------------------------
def test_active_params_moe_smaller_than_total():
    from repro.configs import get_arch_config
    from repro.roofline import active_param_count, param_count
    cfg = get_arch_config("qwen3-moe-30b-a3b").reduced()
    assert active_param_count(cfg) < param_count(cfg)

    dense = get_arch_config("llama3.2-3b").reduced()
    assert active_param_count(dense) == param_count(dense)


def test_roofline_terms():
    from repro.configs.base import ShapeConfig
    from repro.roofline import analyze
    shape = ShapeConfig("t", 128, 4, "train")
    r = analyze("a", shape, "2x2", 4,
                {"flops": 4e12, "bytes accessed": 8e9},
                {"all-reduce": 1e9, "total": 1e9}, mflops=2e12)
    assert r.t_compute == 4e12 / (4 * 197e12)
    assert r.t_memory == 8e9 / (4 * 819e9)
    assert r.t_collective == 1e9 / (4 * 50e9)
    assert r.dominant == "compute"
    assert 0 < r.useful_ratio < 1


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip_nested():
    from repro.checkpoint import save, restore
    from repro.checkpoint.npz import restore_step
    tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "b": [jnp.ones(4, jnp.bfloat16), {"c": jnp.zeros(())}]}
    path = os.path.join(tempfile.mkdtemp(), "ck.npz")
    save(path, tree, step=42)
    back = restore(path, jax.eval_shape(lambda: tree))
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x, np.float32), np.asarray(y, np.float32)),
        tree, back)
    assert restore_step(path) == 42


def test_checkpoint_shape_mismatch_raises():
    from repro.checkpoint import save, restore
    path = os.path.join(tempfile.mkdtemp(), "ck.npz")
    save(path, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore(path, {"a": jax.ShapeDtypeStruct((3, 2), jnp.float32)})


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------
def test_stream_determinism_and_agent_identity():
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.data import StreamSpec, make_agent_batch
    cfg = get_arch_config("llama3.2-3b").reduced()
    sh = ShapeConfig("t", 64, 2, "train")
    spec = StreamSpec(seed=7)
    a = make_agent_batch(cfg, sh, spec, 0, 3)
    b = make_agent_batch(cfg, sh, spec, 0, 3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = make_agent_batch(cfg, sh, spec, 1, 3)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    d = make_agent_batch(cfg, sh, spec, 0, 4)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(d["tokens"]))


def test_stream_matches_input_specs():
    from repro.configs import ARCH_IDS, get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.data import StreamSpec, make_agent_batch
    from repro.models import input_specs
    sh = ShapeConfig("t", 32, 2, "train")
    for aid in ARCH_IDS:
        cfg = get_arch_config(aid).reduced()
        specs = input_specs(cfg, sh)
        batch = make_agent_batch(cfg, sh, StreamSpec(), 0, 0)
        assert set(batch) == set(specs), aid
        for k, v in specs.items():
            assert batch[k].shape == v.shape, (aid, k)
            assert batch[k].dtype == v.dtype, (aid, k)


def test_musicgen_delay_pattern():
    """Audio stream applies the MusicGen delay pattern: codebook c is
    right-shifted by c frames; pad positions carry no loss."""
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.data import StreamSpec, make_agent_batch
    cfg = get_arch_config("musicgen-medium").reduced()
    b = make_agent_batch(cfg, ShapeConfig("t", 32, 2, "train"),
                         StreamSpec(), 0, 0)
    t = np.asarray(b["tokens"])
    l = np.asarray(b["labels"])
    for c in range(cfg.n_codebooks):
        assert (t[:, c, :c] == 0).all()
        assert (l[:, c, :c] == -100).all()
        assert (l[:, c, c:] == t[:, c, c:]).all()


def test_markov_stream_is_learnable():
    """A tiny model on the markov stream beats the uniform floor."""
    from repro.data.synthetic import StreamSpec, _markov_tokens
    spec = StreamSpec(seed=0, n_states=16, branch=2)
    toks = _markov_tokens(spec, 64, 0, 0, 4, 256)
    # bigram entropy of a branch-2 chain ≤ log(2) < log(16)
    joint = {}
    t = np.asarray(toks)
    for row in t:
        for x, y in zip(row[:-1], row[1:]):
            joint[(int(x), int(y))] = joint.get((int(x), int(y)), 0) + 1
    # every state has at most `branch` successors
    succ = {}
    for (x, y) in joint:
        succ.setdefault(x, set()).add(y)
    assert max(len(s) for s in succ.values()) <= spec.branch


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------
def test_serve_batches_packing():
    from repro.serving import serve_batches
    reqs = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10], [11]]
    batches = serve_batches(reqs, 2)
    assert len(batches) == 3
    toks, lens = batches[0]
    assert toks.shape[0] == 2 and int(lens[0]) == 3 and int(lens[1]) == 1
    # tail batch padded with a dummy request
    toks, lens = batches[-1]
    assert toks.shape[0] == 2


def test_serve_engine_greedy_deterministic():
    from repro.configs import get_arch_config
    from repro.models import get_model
    from repro.serving import ServeConfig, ServeEngine
    cfg = get_arch_config("granite-3-8b").reduced()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=32,
                                               max_new_tokens=6))
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    lens = jnp.asarray([4], jnp.int32)
    o1 = eng.generate(toks, lens)
    o2 = eng.generate(toks, lens)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


# ----------------------------------------------------------------------
# optimisers
# ----------------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    from repro.optim import adamw
    opt = adamw(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for i in range(200):
        g = {"w": params["w"]}          # ∇ of ½‖w‖²
        params, state = opt.update(g, state, params, i)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_global_norm_clip():
    from repro.common.pytree import global_norm_clip, tree_norm
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = global_norm_clip(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    np.testing.assert_allclose(float(tree_norm(clipped)), 1.0,
                               rtol=1e-4)


# ----------------------------------------------------------------------
# sharding helpers
# ----------------------------------------------------------------------
def test_sanitize_partition_specs():
    from jax.sharding import PartitionSpec as P
    from repro.launch.dryrun_lib import _sanitize
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16, "data": 4}
    spec = _sanitize(FakeMesh, P(None, "model"), (10, 8))
    assert spec == P(None, None)          # 8 % 16 != 0 → dropped
    spec = _sanitize(FakeMesh, P("data", "model"), (8, 32))
    assert spec == P("data", "model")
    spec = _sanitize(FakeMesh, P(("data", "model"),), (64, 3))
    assert spec == P(("data", "model"), None)


def test_cache_partition_specs_cover_all_archs():
    from jax.sharding import PartitionSpec as P
    from repro.configs import ARCH_IDS, get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.launch.shardings import cache_partition_specs
    from repro.models import cache_specs
    sh = ShapeConfig("d", 64, 2, "decode")
    for aid in ARCH_IDS:
        cfg = get_arch_config(aid).reduced()
        specs = cache_partition_specs(cfg, sh, "data")
        shapes = cache_specs(cfg, sh)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_shapes = jax.tree.leaves(shapes)
        assert len(flat_specs) == len(flat_shapes), aid


def test_axis_rules_scoping():
    from repro.common.sharding import axis_rules, get_rules, logical_spec
    from jax.sharding import PartitionSpec as P
    assert get_rules() is None
    with axis_rules({"batch": "data"}):
        assert logical_spec("batch", None) == P("data", None)
    assert get_rules() is None
