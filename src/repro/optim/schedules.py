"""Learning-rate schedules (callables step → lr, jit-friendly)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)
    return fn


def cosine_schedule(peak: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
    return fn


def warmup_cosine(peak: float, warmup: int, total_steps: int,
                  floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total_steps - warmup, 1),
                        0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, cos)
    return fn
