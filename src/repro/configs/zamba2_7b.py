"""Zamba2-7B — hybrid Mamba2 + shared attention blocks
[arXiv:2411.15242]. 81 layers realised as 16 super-blocks of
(4 Mamba2 + 1 SHARED attention/MLP block) + 1 closing Mamba2 layer.
The attention block's weights are shared across all 16 call-sites with
per-call-site LoRA adapters (rank 128), following Zamba2's shared-block
design. ssm_state=64 per the assignment."""
from repro.configs.base import ArchConfig, HybridConfig, SSMConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,           # MHA in the shared block
        head_dim=112,            # 3584 / 32 (not 128-aligned; see roofline)
        d_ff=14336,
        vocab_size=32000,
        rope_theta=1e4,
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1,
                      chunk=256, d_conv=4),
        hybrid=HybridConfig(n_super_blocks=16, mamba_per_block=4,
                            tail_mamba=1, lora_rank=128),
        citation="arXiv:2411.15242",
    )
