"""TPU v5e hardware constants (per chip) — the dry-run target."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per ICI link
VMEM_BYTES = 128 * 2 ** 20      # ~128 MiB vector memory (v5e)
