"""eq. 4 fused share-step kernel gate + roofline bench.

The share step's value is HBM-traffic reduction: the historical
multi-op path (``eq4_weights`` → ``tree_weighted_sum``) reads the
fp32 plane stack and re-reads the accumulator per piece; the fused
kernel (``repro.kernels.ddal_wavg``) streams the arrival-slot planes
through VMEM exactly once, regenerates the eq. 4 weights in-kernel
and emits (ḡ, Σw) directly — and the int8 block-quantized variant
reads ~N bytes instead of 4N. This benchmark FAILS (non-zero exit)
unless:

1. **correctness** — the fused Pallas kernel (interpret mode off-TPU)
   matches the multi-op oracle at fp32 and on quantized planes;
2. **bitwise** — the fused *XLA* path (what CPU/GPU trainers compile)
   is bit-identical to the historical multi-op path at
   quantization-off, flat and tree-wise;
3. **one-pass shape** — the fused entry's jaxpr contains exactly one
   ``pallas_call`` (the whole share step is one kernel launch), and
   the quantized XLA path's peak jaxpr intermediate stays far below a
   full fp32 dequant of the plane stack (streaming dequant, never a
   4-byte copy of G);
4. **quantization accuracy** — |ḡ_int8 − ḡ_fp32|∞ ≤ ½·max(scale)
   (the analytic bound: eq. 4 weights are a convex combination) and
   relative L2 error ≤ 1e-2 at every supported block size;
5. **bytes** — an int8 delay line allocates ≥ 3.5× fewer bytes than
   fp32 (``jax.eval_shape``, no host memory), and
   ``pod_dispatch.cross_pod_bytes`` reflects the same saving.

A ``repro.roofline.Roofline`` record for the fused share step is
built from the compiled dry-run artifact (``.cost_analysis()`` of the
fused XLA path on this backend) plus the analytic v5e HBM model for
the Pallas traffic — the interpret-only-validation gap, measured.

Every run writes machine-readable ``BENCH_wavg_kernel.json`` next to
this file (override with ``--json``) so the kernel's trajectory is
tracked across PRs.

    PYTHONPATH=src python benchmarks/bench_wavg_kernel.py \
        [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.weighting import eq4_weights
from repro.common.pytree import tree_weighted_sum
from repro.kernels.ddal_wavg import ops, ref
from repro.roofline.constants import HBM_BW
from repro.roofline.report import Roofline

_DEFAULT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_wavg_kernel.json")

SIZES = [(4, 1_000_000), (8, 10_000_000),
         (16, 10_000_000), (8, 100_000_000)]
SMOKE_SIZES = [(4, 1_000_000), (8, 2_000_000)]
Q_BLOCKS = (128, 512, 2048, 8192)      # multiples of 128 dividing 8192

REL_L2_BOUND = 1e-2                    # pinned int8-vs-fp32 eq. 4 error


def _meta(m: int, seed: int = 0):
    """(T, R, valid) with one invalid piece — the masked regime."""
    kT, kR = jax.random.split(jax.random.PRNGKey(seed))
    T = jnp.abs(jax.random.normal(kT, (m,))) + 0.1
    R = jnp.abs(jax.random.normal(kR, (m,))) + 0.1
    valid = (jnp.arange(m) != 1)
    return T, R, valid


def _legacy(G, T, R, valid):
    """The historical multi-op share step, spelled at the call site."""
    w = eq4_weights(T, R, valid)
    return tree_weighted_sum(G, w), jnp.sum(w)


# ---------------------------------------------------------------------
# jaxpr accounting (shared idiom with bench_relevance_sketch)
# ---------------------------------------------------------------------
def _walk_jaxpr(jaxpr, on_eqn):
    for eqn in jaxpr.eqns:
        on_eqn(eqn)
        for p in eqn.params.values():
            _walk_params(p, on_eqn)


def _walk_params(p, on_eqn):
    if hasattr(p, "jaxpr"):                       # ClosedJaxpr
        _walk_jaxpr(p.jaxpr, on_eqn)
    elif hasattr(p, "eqns"):                      # raw Jaxpr
        _walk_jaxpr(p, on_eqn)
    elif isinstance(p, (tuple, list)):
        for q in p:
            _walk_params(q, on_eqn)


def count_pallas_calls(fn, *args) -> int:
    closed = jax.make_jaxpr(fn)(*args)
    hits = []
    _walk_jaxpr(closed.jaxpr,
                lambda e: hits.append(e)
                if "pallas" in e.primitive.name else None)
    return len(hits)


def peak_intermediate_bytes(fn, *args) -> int:
    """Largest array any equation of ``fn``'s jaxpr produces —
    recursing through nested jaxprs but not into Pallas bodies."""
    closed = jax.make_jaxpr(fn)(*args)
    peak = [0]

    def aval_bytes(aval) -> int:
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None or dtype is None:
            return 0
        return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize

    def on_eqn(eqn):
        for v in eqn.outvars:
            peak[0] = max(peak[0], aval_bytes(v.aval))

    _walk_jaxpr(closed.jaxpr, on_eqn)
    return peak[0]


# ---------------------------------------------------------------------
# gates
# ---------------------------------------------------------------------
def gate_correctness(m: int = 6, n: int = 262_144) -> dict:
    """Fused Pallas (interpret off-TPU) vs the multi-op oracle."""
    G = jax.random.normal(jax.random.PRNGKey(0), (m, n), jnp.float32)
    T, R, valid = _meta(m)
    want_g, want_w = _legacy(G, T, R, valid)

    got_g, got_w = ops.fused_wavg(G, T, R, valid, impl="pallas")
    err_fp32 = float(jnp.max(jnp.abs(got_g - want_g)))
    err_w = float(jnp.abs(got_w - want_w))

    Q, S = ref.quantize_flat(G, 512)
    oq_g, oq_w = ref.fused_wavg_q(Q, S, T, R, valid, 512)
    kq_g, kq_w = ops.fused_wavg_q(Q, S, T, R, valid, 512,
                                  impl="pallas")
    err_q = float(jnp.max(jnp.abs(kq_g - oq_g)))
    err_qw = float(jnp.abs(kq_w - oq_w))
    tol = 2e-5
    return {"pass": bool(max(err_fp32, err_q) <= tol
                         and max(err_w, err_qw) <= 1e-6),
            "tol": tol, "fp32_max_err": err_fp32,
            "quant_kernel_vs_oracle_max_err": err_q,
            "wsum_err": max(err_w, err_qw),
            "detail": "fused Pallas kernel vs multi-op oracle, "
                      "fp32 + int8 planes"}


def gate_bitwise(m: int = 6, n: int = 262_144) -> dict:
    """The fused XLA path (the compiled CPU/GPU share step) must be
    bit-identical to the historical multi-op path at quant-off."""
    G = jax.random.normal(jax.random.PRNGKey(1), (m, n), jnp.float32)
    T, R, valid = _meta(m, seed=1)
    want_g, want_w = _legacy(G, T, R, valid)
    got_g, got_w = ops.fused_wavg(G, T, R, valid, impl="xla")
    flat_ok = bool(jnp.all(got_g == want_g)) and bool(got_w == want_w)

    tree = {"emb": G[:, :65_536].reshape(m, 512, 128),
            "head": G[:, 65_536:65_543]}           # small-leaf path too
    want_t, want_tw = _legacy(tree, T, R, valid)
    got_t, got_tw = ops.tree_fused_wavg(tree, T, R, valid, impl="xla")
    tree_ok = all(bool(jnp.all(a == b)) for a, b in
                  zip(jax.tree.leaves(got_t), jax.tree.leaves(want_t)))
    tree_ok = tree_ok and bool(got_tw == want_tw)
    return {"pass": bool(flat_ok and tree_ok),
            "flat_bitwise": flat_ok, "tree_bitwise": tree_ok,
            "detail": "fused XLA vs eq4_weights + tree_weighted_sum"}


def gate_one_pass(m: int = 8, n: int = 1_048_576) -> dict:
    """Jaxpr shape: one kernel launch for the whole share step; the
    quantized XLA path never materialises a fp32 copy of the stack."""
    G = jnp.zeros((m, n), jnp.float32)
    T, R, valid = _meta(m)
    n_calls = count_pallas_calls(
        lambda g, t, r, v: ops.fused_wavg(g, t, r, v, impl="pallas",
                                          interpret=True),
        G, T, R, valid)

    qb = 512
    Q, S = ref.quantize_flat(G, qb)
    peak_q = peak_intermediate_bytes(
        lambda q, s, t, r, v: ops.fused_wavg_q(q, s, t, r, v, qb,
                                               impl="xla"),
        Q, S, T, R, valid)
    full_dequant = m * n * 4               # what a naive path builds
    return {"pass": bool(n_calls == 1
                         and peak_q <= 0.5 * full_dequant),
            "pallas_calls": n_calls,
            "xla_quant_peak_mb": peak_q / 2**20,
            "full_dequant_mb": full_dequant / 2**20,
            "detail": "1 pallas_call; streaming dequant peak < ½ of a "
                      "full fp32 dequant"}


def gate_quant_error(m: int = 8, n: int = 1_000_000) -> dict:
    """int8 eq. 4 vs fp32 eq. 4, per supported block size: the
    analytic ∞-bound (weights are convex, so error ≤ ½·max scale) and
    the pinned relative-L2 tolerance."""
    G = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.float32)
    T, R, valid = _meta(m, seed=2)
    g32, _ = _legacy(G, T, R, valid)
    ok = True
    per_block = {}
    for qb in Q_BLOCKS:
        Q, S = ref.quantize_flat(G, qb)
        gq, _ = ops.fused_wavg_q(Q, S, T, R, valid, qb, impl="xla")
        inf_err = float(jnp.max(jnp.abs(gq - g32)))
        inf_bound = float(jnp.max(S)) / 2.0 + 1e-7
        rel = float(jnp.linalg.norm(gq - g32) / jnp.linalg.norm(g32))
        per_block[qb] = {"inf_err": inf_err, "inf_bound": inf_bound,
                         "rel_l2": rel}
        ok &= inf_err <= inf_bound and rel <= REL_L2_BOUND
    return {"pass": bool(ok), "rel_l2_bound": REL_L2_BOUND,
            "per_block": per_block,
            "detail": "|ḡ_q − ḡ|∞ ≤ ½·max(scale) and rel-L2 ≤ bound"}


def gate_bytes(qb: int = 512) -> dict:
    """Structure-level accounting: int8 delay line ≥ 3.5× lighter
    (eval_shape — nothing allocated), and the analytic cross-pod
    accounting agrees."""
    from repro.core.knowledge import make_sparse_inflight
    from repro.core.pod_dispatch import _edge_cost
    from repro.core.topology import full

    params_like = {"w": jax.ShapeDtypeStruct((1024, 256), jnp.float32),
                   "b": jax.ShapeDtypeStruct((1024,), jnp.float32)}
    topo = full(8)

    def nbytes(tree) -> int:
        return sum(int(np.prod(x.shape, dtype=np.int64))
                   * x.dtype.itemsize
                   for x in jax.tree.leaves(tree))

    fp = jax.eval_shape(
        lambda: make_sparse_inflight(params_like, topo, 2))
    q8 = jax.eval_shape(
        lambda: make_sparse_inflight(params_like, topo, 2, qb))
    # compare the payload planes (grads + scales); T/R/valid metadata
    # is identical on both sides
    fp_b = nbytes(fp.grads)
    q8_b = nbytes(q8.grads) + nbytes(q8.scale)
    ratio = fp_b / q8_b

    n_params = 10_000_000
    pod_ratio = (_edge_cost(n_params, 4)
                 / _edge_cost(n_params, 4, quant_block=qb))
    return {"pass": bool(ratio >= 3.5 and pod_ratio >= 3.5),
            "delay_line_ratio": ratio, "cross_pod_ratio": pod_ratio,
            "fp32_mb": fp_b / 2**20, "int8_mb": q8_b / 2**20,
            "detail": "int8 planes ≥ 3.5× lighter, structure + "
                      "analytic accounting"}


# ---------------------------------------------------------------------
# roofline from the compiled dry-run artifact
# ---------------------------------------------------------------------
def roofline_record(m: int = 8, n: int = 10_000_000) -> dict:
    """Compile the fused XLA share step on this backend, pull the HLO
    cost model, and fold it into a ``Roofline`` record alongside the
    analytic v5e terms for the Pallas traffic model (which cannot be
    compiled off-TPU — this record is how that gap stays measured)."""
    G = jnp.zeros((m, n), jnp.float32)
    T, R, valid = _meta(m)
    fn = jax.jit(lambda g, t, r, v: ops.fused_wavg(g, t, r, v,
                                                   impl="xla"))
    compiled = fn.lower(G, T, R, valid).compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):            # some backends
        cost = cost[0] if cost else {}
    # useful FLOPs of the share step: m multiply-adds per element
    mflops = 2.0 * m * n
    roof = Roofline(
        arch="ddal_wavg_fused", shape=f"m{m}_n{n}",
        mesh=jax.default_backend(), chips=1,
        hlo_flops=float(cost.get("flops", 0.0) or 0.0),
        hlo_bytes=float(cost.get("bytes accessed", 0.0) or 0.0),
        coll_bytes=0.0, coll_breakdown={},        # single-device op
        model_flops=mflops,
    )
    bytes_pallas_fp32 = 4.0 * n * (m + 1) + 12.0 * m
    bytes_pallas_q = (1.0 * n * m                  # int8 planes
                      + 4.0 * (n // 512) * m       # scales @ qb=512
                      + 4.0 * n + 12.0 * m)
    rec = roof.to_dict()
    rec["analytic_v5e"] = {
        "fused_fp32_us": bytes_pallas_fp32 / HBM_BW * 1e6,
        "fused_int8_us": bytes_pallas_q / HBM_BW * 1e6,
        "unfused_fp32_us": 4.0 * n * 2 * m / HBM_BW * 1e6,
    }
    return rec


# ---------------------------------------------------------------------
# sweep table (analytic v5e + CPU wall of the compiled fused path)
# ---------------------------------------------------------------------
def sweep_rows(smoke: bool) -> list:
    rows = []
    for m, n_params in (SMOKE_SIZES if smoke else SIZES):
        T, R, valid = _meta(m)
        Gf = jnp.zeros((m, n_params), jnp.float32)
        fn = jax.jit(lambda g, t, r, v: ops.fused_wavg(
            g, t, r, v, impl="xla"))
        jax.block_until_ready(fn(Gf, T, R, valid))
        t0 = time.time()
        jax.block_until_ready(fn(Gf, T, R, valid))
        cpu_s = time.time() - t0

        bytes_fused = 4.0 * n_params * (m + 1)
        bytes_fused_q = 1.0 * n_params * m + 4.0 * n_params
        bytes_unfused = 4.0 * n_params * 2 * m
        rows.append({
            "m": m, "n_params": n_params,
            "v5e_roofline_fused_us": bytes_fused / HBM_BW * 1e6,
            "v5e_roofline_fused_int8_us":
                bytes_fused_q / HBM_BW * 1e6,
            "v5e_roofline_unfused_us": bytes_unfused / HBM_BW * 1e6,
            "traffic_saving": bytes_unfused / bytes_fused,
            "traffic_saving_int8": bytes_unfused / bytes_fused_q,
            "cpu_fused_ms": cpu_s * 1e3,
        })
    return rows


def main(argv=None, verbose: bool = True):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI fast path: reduced sizes only")
    p.add_argument("--json", default=_DEFAULT_JSON,
                   help="machine-readable results path")
    args = p.parse_args(argv)

    gates = {
        "correctness": gate_correctness(),
        "bitwise": gate_bitwise(),
        "one_pass": gate_one_pass(),
        "quant_error": gate_quant_error(),
        "bytes": gate_bytes(),
    }
    roof = roofline_record(*(SMOKE_SIZES[-1] if args.smoke
                             else SIZES[1]))
    rows = sweep_rows(args.smoke)

    if verbose:
        for name, g in gates.items():
            print(f"gate {name}: {'PASS' if g['pass'] else 'FAIL'} "
                  f"({ {k: v for k, v in g.items() if k != 'pass'} })")
        print(f"\nroofline ({roof['arch']}, {roof['shape']}, backend "
              f"{roof['mesh']}): hlo_bytes={roof['hlo_bytes']:.3g} "
              f"dominant={roof['dominant']} "
              f"analytic v5e fused fp32 "
              f"{roof['analytic_v5e']['fused_fp32_us']:.1f}µs / int8 "
              f"{roof['analytic_v5e']['fused_int8_us']:.1f}µs")
        print(f"\n{'m':>3} {'N':>12} {'fused µs':>10} {'int8 µs':>9} "
              f"{'unfused µs':>11} {'saving':>7} {'int8 sv':>8} "
              f"{'cpu ms':>8}")
        for r in rows:
            print(f"{r['m']:3d} {r['n_params']:12,} "
                  f"{r['v5e_roofline_fused_us']:10.1f} "
                  f"{r['v5e_roofline_fused_int8_us']:9.1f} "
                  f"{r['v5e_roofline_unfused_us']:11.1f} "
                  f"{r['traffic_saving']:6.2f}x "
                  f"{r['traffic_saving_int8']:7.2f}x "
                  f"{r['cpu_fused_ms']:8.2f}")

    payload = {"bench": "wavg_kernel",
               "backend": jax.default_backend(),
               "gates": gates, "roofline": roof, "rows": rows}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    if verbose:
        print(f"\nwrote {args.json}")

    if not all(g["pass"] for g in gates.values()):
        raise SystemExit("wavg kernel gate FAILED")
    return payload


if __name__ == "__main__":
    main()
