"""Pallas-TPU kernel for the Mamba2 SSD intra-chunk dual form.

TPU adaptation (DESIGN.md §3): the SSD "quadratic dual" inside a chunk
is exactly two MXU-shaped matmuls — (l, n)·(n, l) scores and
(l, l)·(l, p) outputs — sandwiching an elementwise decay mask
L[i,j] = exp(cs_i − cs_j)·dt_j on j ≤ i. The original CUDA kernel
(Triton in the paper's repo) tiles over SMs; here one grid step owns a
whole (chunk × head) block in VMEM — chunk=256, n=128, p=64 gives
l·n + l·l + l·p ≈ 208 KiB fp32, comfortably VMEM-resident, and both
matmuls are 128-aligned for the MXU.

Grid: (b·nc, h). The inter-chunk recurrence stays OUTSIDE the kernel
as a `lax.associative_scan` (log-depth, bandwidth-trivial) — splitting
at the chunk boundary is the TPU-native factorisation of SSD.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, cs_ref, b_ref, c_ref, o_ref):
    """Blocks: x (1,1,l,p); dt, cs (1,1,l); B, C (1,1,l,n); o (1,1,l,p)."""
    x = x_ref[0, 0].astype(jnp.float32)          # (l, p)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (l,)
    cs = cs_ref[0, 0].astype(jnp.float32)        # (l,)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (l, n)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (l, n)
    l = x.shape[0]

    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (l, l) = C·Bᵀ
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.exp(cs[:, None] - cs[None, :])   # exp(cs_i − cs_j)
    Lmask = jnp.where(jj <= ii, decay, 0.0)
    scores = scores * Lmask * dt[None, :]
    o_ref[0, 0] = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_bchl(x, dt, cs, B, C, *,
                         interpret: bool = False) -> jnp.ndarray:
    """x: (bn, h, l, p); dt, cs: (bn, h, l); B, C: (bn, h, l, n).
    Returns (bn, h, l, p) fp32."""
    bn, h, l, p = x.shape
    n = B.shape[-1]
    out = pl.pallas_call(
        _ssd_kernel,
        grid=(bn, h),
        in_specs=[
            pl.BlockSpec((1, 1, l, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, l), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, l), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, l, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, l, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l, p), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bn, h, l, p), jnp.float32),
        interpret=interpret,
    )(x, dt, cs, B, C)
    return out
