"""Qwen2-VL-72B — VLM language backbone with M-RoPE
[arXiv:2409.12191]. Backbone only: the ViT vision encoder + projector
are stubbed per the spec carve-out — ``input_specs`` provides
pre-projected patch embeddings (vision_prefix positions) that are
concatenated ahead of the text tokens; M-RoPE consumes (t, h, w)
position triples with sections (16, 24, 24) of the half head-dim."""
from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_mode="mrope",
        mrope_sections=(16, 24, 24),
        rope_theta=1e6,
        vision_prefix=256,       # stubbed patch-embedding prefix length
        citation="arXiv:2409.12191",
    )
