"""jit'd wrappers for the eq. 4 weighted-average kernel.

``tree_wavg`` applies the kernel leaf-wise over a stacked gradient
pytree (leaves (m, *param_shape)) — the exact contraction DDAL's
knowledge stores perform at every share step. Small leaves (< one
tile) fall back to the jnp oracle: kernel launch overhead would
dominate and XLA already fuses them — that fallback path compiles on
any backend with no interpreter involved.

``interpret=None`` auto-selects: compiled Pallas on TPU, interpreter
mode elsewhere (Pallas-TPU kernels cannot compile on CPU/GPU). An
explicit bool overrides — tests force ``interpret=True`` off-TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ddal_wavg import ref
from repro.kernels.ddal_wavg.kernel import DEFAULT_ROWS, LANES, wavg_flat

_MIN_KERNEL_SIZE = DEFAULT_ROWS * LANES


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None → interpret off-TPU, compiled on TPU; bool → itself."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def wavg(G: jnp.ndarray, w: jnp.ndarray, *,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    """Σ_j w_j·G[j] for G: (m, N) → (N,) fp32."""
    return wavg_flat(G, w, interpret=resolve_interpret(interpret))


def tree_wavg(grads_stacked, w, *, interpret: Optional[bool] = None):
    """Kernel-backed version of pytree eq. 4 contraction."""
    interp = resolve_interpret(interpret)

    def leaf(x):
        m = x.shape[0]
        size = int(x.size) // m
        if size < _MIN_KERNEL_SIZE:
            return ref.wavg(x.reshape(m, -1), w).reshape(x.shape[1:])
        flat = x.reshape(m, size)
        return wavg_flat(flat, w, interpret=interp
                         ).reshape(x.shape[1:])
    return jax.tree.map(leaf, grads_stacked)
