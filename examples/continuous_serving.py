"""Continuous batching: 8 requests stream through 2 persistent decode
slots — finished slots are refilled without stopping the others
(vLLM-style, deliverable b).

    PYTHONPATH=src python examples/continuous_serving.py --arch qwen2-7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch_config
from repro.models import get_model
from repro.serving import ContinuousBatcher, ServeConfig

p = argparse.ArgumentParser()
p.add_argument("--arch", default="llama3.2-3b", choices=list(ARCH_IDS))
p.add_argument("--requests", type=int, default=8)
p.add_argument("--slots", type=int, default=2)
p.add_argument("--new-tokens", type=int, default=12)
args = p.parse_args()

cfg = get_arch_config(args.arch).reduced()
model = get_model(cfg)
params = model.init(cfg, jax.random.PRNGKey(0))
batcher = ContinuousBatcher(
    cfg, params, ServeConfig(max_len=96, max_new_tokens=args.new_tokens),
    batch_size=args.slots, prompt_pad=16)

rng = np.random.default_rng(0)
requests = [list(rng.integers(0, cfg.vocab_size, int(n)))
            for n in rng.integers(2, 14, args.requests)]
print(f"{args.requests} requests → {args.slots} slots "
      f"(reduced {args.arch})")
t0 = time.time()
results = batcher.run(requests)
dt = time.time() - t0
for rid in sorted(results):
    print(f"  req {rid} [{len(requests[rid]):2d} prompt toks] "
          f"-> {results[rid]}")
n_tok = sum(len(v) for v in results.values())
print(f"{n_tok} tokens in {dt:.1f}s (incl. compile)")
