"""Granite-3.0-8B — dense GQA decoder [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab_size=49155,
        rope_theta=1e4,
        tie_embeddings=True,
        citation="hf:ibm-granite/granite-3.0-2b-base",
    )
