"""Three-term roofline model from a compiled dry-run artifact.

    compute    = HLO_FLOPs   / (chips × peak FLOP/s)
    memory     = HLO_bytes   / (chips × HBM bandwidth)
    collective = coll_bytes  / (chips × ICI link bandwidth)

plus MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs. HLO_FLOPs/bytes come from
``compiled.cost_analysis()`` (whole-program, i.e. summed over devices
for SPMD — we treat them as global and divide by chip count);
collective bytes from the HLO parse (repro.roofline.hlo).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import ArchConfig, MoEConfig, ShapeConfig
from repro.roofline import constants as C
from repro.roofline.hlo import collective_bytes


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    bytes_per_device: Optional[float] = None   # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * C.PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * C.HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * C.ICI_BW_PER_LINK)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "bytes_per_device": self.bytes_per_device,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
        }


# ----------------------------------------------------------------------
def param_count(cfg: ArchConfig) -> int:
    """Total parameter count N (exact, from the param pytree)."""
    import jax
    from repro.models import param_specs
    tree = param_specs(cfg)
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top_k + shared experts
    instead of all experts)."""
    import jax
    from repro.models import param_specs
    tree = param_specs(cfg)
    if cfg.moe is None:
        return sum(int(x.size) for x in jax.tree.leaves(tree))
    moe: MoEConfig = cfg.moe
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = [getattr(p, "key", None) for p in path]
        if "experts" in keys:
            # leading axis is the expert count
            per_expert = int(leaf.size) // moe.n_experts
            total += per_expert * moe.top_k
        else:
            total += int(leaf.size)
    return total


def model_flops(cfg: ArchConfig, shape: ShapeConfig,
                n_agents: int = 1) -> float:
    """6·N·D  (N = active params, D = tokens in the step)."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len * n_agents
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    tokens = shape.global_batch          # decode: 1 token per slot
    return 2.0 * n * tokens


def analyze(arch: str, shape: ShapeConfig, mesh_name: str, chips: int,
            cost: dict, coll, mflops: float,
            bytes_per_device: Optional[float] = None) -> Roofline:
    """``coll``: either raw HLO text (parsed here) or a precomputed
    {kind: bytes, "total": bytes} dict (e.g. depth-extrapolated)."""
    if isinstance(coll, str):
        coll = collective_bytes(coll)
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(coll["total"]),
        coll_breakdown={k: v for k, v in coll.items() if k != "total"},
        model_flops=mflops,
        bytes_per_device=bytes_per_device,
    )
