"""Attention layers: GQA/MHA self-attention (RoPE / M-RoPE / none,
optional sliding window, optional QKV bias), cross-attention
(MusicGen conditioning) and Multi-head Latent Attention (DeepSeek-V2).

All functions are pure; decode-time KV caches are functional values
threaded through ``lax.scan`` over layers. Cache slots carry their
absolute position (``pos``, -1 = empty) which uniformly expresses both
full caches and sliding-window ring buffers.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.common.sharding import shard
from repro.models import rope as rope_lib
from repro.models.common import causal_mask_bias, dense_init, softmax_attention


# ----------------------------------------------------------------------
# parameter init
# ----------------------------------------------------------------------
def init_self_attention(cfg, key):
    ks = jax.random.split(key, 4)
    E, H, K, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype("param")
    p = {
        "wq": dense_init(ks[0], (E, H * D), dt),
        "wk": dense_init(ks[1], (E, K * D), dt),
        "wv": dense_init(ks[2], (E, K * D), dt),
        "wo": dense_init(ks[3], (H * D, E), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * D,), dt)
        p["bk"] = jnp.zeros((K * D,), dt)
        p["bv"] = jnp.zeros((K * D,), dt)
    return p


def init_cross_attention(cfg, key):
    ks = jax.random.split(key, 4)
    E, H, D = cfg.d_model, cfg.n_heads, cfg.head_dim
    dt = cfg.dtype("param")
    return {
        "wq": dense_init(ks[0], (E, H * D), dt),
        "wk": dense_init(ks[1], (E, H * D), dt),
        "wv": dense_init(ks[2], (E, H * D), dt),
        "wo": dense_init(ks[3], (H * D, E), dt),
    }


def init_mla(cfg, key):
    m = cfg.mla
    ks = jax.random.split(key, 5)
    E, H = cfg.d_model, cfg.n_heads
    dt = cfg.dtype("param")
    qdim = H * (m.qk_nope_dim + m.qk_rope_dim)
    return {
        "wq": dense_init(ks[0], (E, qdim), dt),
        "w_dkv": dense_init(ks[1], (E, m.kv_lora_rank + m.qk_rope_dim), dt),
        "ln_ckv": jnp.ones((m.kv_lora_rank,), dt),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, H * m.qk_nope_dim), dt),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, H * m.v_dim), dt),
        "wo": dense_init(ks[4], (H * m.v_dim, E), dt),
    }


# ----------------------------------------------------------------------
# cache construction / update
# ----------------------------------------------------------------------
def make_kv_cache(cfg, batch: int, max_len: int, n_layers: int,
                  dtype=None):
    """Stacked-over-layers KV cache. For sliding-window configs the
    cache is a ring buffer of ``window`` slots."""
    dt = dtype or cfg.dtype("compute")
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    K, D = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, slots, K, D), dt),
        "v": jnp.zeros((n_layers, batch, slots, K, D), dt),
        "pos": jnp.full((n_layers, batch, slots), -1, jnp.int32),
    }


def make_mla_cache(cfg, batch: int, max_len: int, n_layers: int,
                   dtype=None):
    dt = dtype or cfg.dtype("compute")
    m = cfg.mla
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "ckv": jnp.zeros((n_layers, batch, slots, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((n_layers, batch, slots, m.qk_rope_dim), dt),
        "pos": jnp.full((n_layers, batch, slots), -1, jnp.int32),
    }


def _write_slots(buf, new, slot_idx):
    """Scatter per-batch rows into cache slots.

    buf: (B, Smax, ...); new: (B, T, ...); slot_idx: (B, T) int32.
    """
    B = buf.shape[0]
    bidx = jnp.arange(B)[:, None] * jnp.ones_like(slot_idx)
    return buf.at[bidx, slot_idx].set(new.astype(buf.dtype))


def _slots_for(cfg, positions):
    """Map absolute positions → cache slots (ring for sliding window)."""
    if cfg.sliding_window:
        return positions % cfg.sliding_window
    return positions


# ----------------------------------------------------------------------
# apply
# ----------------------------------------------------------------------
def _maybe_pallas(cfg, q, k, v, positions, window):
    """Use the Pallas flash kernel for full-sequence (no-cache) passes."""
    if cfg.attention_impl == "xla":
        return None
    from repro.kernels.flash_attention import ops as fa_ops
    interpret = cfg.attention_impl == "pallas_interpret"
    return fa_ops.flash_attention(
        q, k, v, causal=True, window=window,
        scale=1.0 / (q.shape[-1] ** 0.5), interpret=interpret)


def self_attention(cfg, p, x, positions, cache=None, layer_cache=None):
    """GQA self-attention.

    x: (B, S, E); positions: (B, S) or (B, 3, S) for M-RoPE.
    layer_cache: this layer's slice of the KV cache (decode/prefill) or
    None (training). Returns (out, new_layer_cache).
    """
    B, S, E = x.shape
    H, K, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = cfg.dtype("compute")
    xq = x @ p["wq"].astype(cdt)
    xk = x @ p["wk"].astype(cdt)
    xv = x @ p["wv"].astype(cdt)
    if cfg.qkv_bias:
        xq = xq + p["bq"].astype(cdt)
        xk = xk + p["bk"].astype(cdt)
        xv = xv + p["bv"].astype(cdt)
    q = xq.reshape(B, S, H, D)
    k = xk.reshape(B, S, K, D)
    v = xv.reshape(B, S, K, D)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    q = rope_lib.apply_rope(cfg, q, positions)
    k = rope_lib.apply_rope(cfg, k, positions)
    flat_pos = positions[:, -1, :] if positions.ndim == 3 else positions

    scale = 1.0 / (D ** 0.5)
    new_cache = layer_cache
    if layer_cache is None:
        out = _maybe_pallas(cfg, q, k, v, flat_pos, cfg.sliding_window)
        if out is None:
            bias = causal_mask_bias(flat_pos, flat_pos, cfg.sliding_window)
            out = softmax_attention(q, k, v, bias, scale,
                                    cfg.attention_scores_dtype)
    else:
        slots = _slots_for(cfg, flat_pos)
        kc = _write_slots(layer_cache["k"], k, slots)
        vc = _write_slots(layer_cache["v"], v, slots)
        pc = _write_slots(layer_cache["pos"], flat_pos, slots)
        # flash-decoding layout: cache SLOTS shard over "model"; the
        # softmax/contraction over the sharded slot dim reduces to
        # tiny (B,H,1)-scalar combines that GSPMD inserts (§Perf it.5)
        kc = shard(kc, "batch", "kv_slots", None, None)
        vc = shard(vc, "batch", "kv_slots", None, None)
        pc = shard(pc, "batch", "kv_slots")
        new_cache = {"k": kc, "v": vc, "pos": pc}
        k_valid = pc >= 0
        bias = causal_mask_bias(flat_pos, pc, cfg.sliding_window, k_valid)
        out = softmax_attention(q, kc, vc, bias, scale,
                                cfg.attention_scores_dtype)
    out = out.reshape(B, S, H * D)
    return out @ p["wo"].astype(cdt), new_cache


def cross_attention(cfg, p, x, cond, layer_cache=None):
    """MHA cross-attention to a (B, Lc, E) conditioning sequence.
    K/V are position-independent; at decode time they are precomputed
    once (layer_cache = {"ck", "cv"}) and reused every step."""
    B, S, E = x.shape
    H, D = cfg.n_heads, cfg.head_dim
    cdt = cfg.dtype("compute")
    q = (x @ p["wq"].astype(cdt)).reshape(B, S, H, D)
    if layer_cache is not None and "ck" in layer_cache:
        k, v = layer_cache["ck"], layer_cache["cv"]
    else:
        Lc = cond.shape[1]
        k = (cond @ p["wk"].astype(cdt)).reshape(B, Lc, H, D)
        v = (cond @ p["wv"].astype(cdt)).reshape(B, Lc, H, D)
    bias = jnp.zeros((B, 1, S, k.shape[1]), jnp.float32)
    out = softmax_attention(q, k, v, bias, 1.0 / (D ** 0.5))
    out = out.reshape(B, S, H * D) @ p["wo"].astype(cdt)
    return out, {"ck": k, "cv": v}


def mla_attention(cfg, p, x, positions, layer_cache=None):
    """DeepSeek-V2 Multi-head Latent Attention.

    Caches only the rank-r latent ``ckv`` plus the shared rotary key
    (kv_lora_rank + qk_rope_dim floats per token) — the paper's KV-cache
    compression. Per-head K/V are re-expanded from the latent.
    """
    m = cfg.mla
    B, S, E = x.shape
    H = cfg.n_heads
    cdt = cfg.dtype("compute")
    from repro.models.common import rms_norm

    q = (x @ p["wq"].astype(cdt)).reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope_lib.rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"].astype(cdt)
    ckv = rms_norm(dkv[..., :m.kv_lora_rank], p["ln_ckv"], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:][:, :, None, :]       # 1 shared head
    k_rope = rope_lib.rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = layer_cache
    if layer_cache is not None:
        slots = _slots_for(cfg, positions)
        ckv_c = _write_slots(layer_cache["ckv"], ckv, slots)
        kr_c = _write_slots(layer_cache["k_rope"], k_rope, slots)
        pc = _write_slots(layer_cache["pos"], positions, slots)
        ckv_c = shard(ckv_c, "batch", "kv_slots", None)
        kr_c = shard(kr_c, "batch", "kv_slots", None)
        pc = shard(pc, "batch", "kv_slots")
        new_cache = {"ckv": ckv_c, "k_rope": kr_c, "pos": pc}
        ckv_all, k_rope_all, k_pos = ckv_c, kr_c, pc
        k_valid = pc >= 0
    else:
        ckv_all, k_rope_all, k_pos = ckv, k_rope, positions
        k_valid = None

    T = ckv_all.shape[1]
    bias = causal_mask_bias(positions, k_pos, cfg.sliding_window, k_valid)
    scale = 1.0 / ((m.qk_nope_dim + m.qk_rope_dim) ** 0.5)

    if cfg.mla_absorb and layer_cache is not None and S < T:
        # DeepSeek-V2 weight absorption (decode): score the query
        # against the rank-r latent DIRECTLY instead of re-expanding
        # per-head K/V from the whole cache every step —
        #   scores = (q_nope W_ukᵀ) · ckv  +  q_rope · k_rope
        #   out    = (probs · ckv) W_uv
        # Cost per layer drops from O(T·r·H·(dn+dv)) expansion matmuls
        # to O(T·H·r) score/context terms — a (dn=128)× cut at 32k+
        # context (EXPERIMENTS.md §Perf it.6). Exact same math
        # (associativity); the non-absorbed path stays for prefill
        # (S = T) where expansion amortises over the whole sequence.
        f32 = jnp.float32
        wuk = p["w_uk"].astype(cdt).reshape(m.kv_lora_rank, H,
                                            m.qk_nope_dim)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wuk)  # (B,S,H,r)
        s_nope = jnp.einsum("bqhr,btr->bhqt", q_lat.astype(f32),
                            ckv_all.astype(f32))
        s_rope = jnp.einsum("bqhd,btd->bhqt", q_rope.astype(f32),
                            k_rope_all.astype(f32))
        scores = (s_nope + s_rope) * scale + bias
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqt,btr->bqhr", probs,
                         ckv_all.astype(f32))              # (B,S,H,r)
        wuv = p["w_uv"].astype(cdt).reshape(m.kv_lora_rank, H, m.v_dim)
        out = jnp.einsum("bqhr,rhv->bqhv", ctx.astype(cdt), wuv)
    else:
        k_nope = (ckv_all @ p["w_uk"].astype(cdt)
                  ).reshape(B, T, H, m.qk_nope_dim)
        vv = (ckv_all @ p["w_uv"].astype(cdt)).reshape(B, T, H, m.v_dim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :],
                                      (B, T, H, m.qk_rope_dim))],
            axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = softmax_attention(qfull, k, vv, bias, scale,
                                cfg.attention_scores_dtype)
    out = out.reshape(B, S, H * m.v_dim) @ p["wo"].astype(cdt)
    return out, new_cache
