"""Topology scaling sweep: epoch time + delay-line memory vs group
size for the sparse neighbor-indexed delay line.

The dense all-to-all delay line is O(n²·D·|params|); the sparse one is
O(n·k·D). This sweep runs the real DDAL loop (toy quadratic agents so
agent compute is negligible and the exchange dominates) over
n ∈ {4, 16, 64, 256} × topology and reports per-epoch wall time plus
the *actual* delay-line footprint (measured from the SparseInFlight
pytree) next to the dense-equivalent footprint. ``dynamic_k`` rows
resample the gossip table every 5 epochs inside the jitted loop
(``GroupSpec.resample_every``) — same (n, k, D) delay-line shape as
static ``random_k``, so their memory must match exactly.

Acceptance targets (ISSUE 1): n=64 with random_k(k=4) must beat the
dense n=16 epoch time on CPU, and its delay-line bytes must be < 10%
of the dense n=64 equivalent. (ISSUE 2): n=64 dynamic_k delay-line
bytes must equal static random_k's.

``--hetero`` adds the adaptive-wiring ablation: a heterogeneous
CartPole + GridWorld DDA3C group (obs padded to a shared space),
sweeping static vs dynamic gossip × uniform vs learned (grad-cosine)
relevance, reporting per-env mean return and the learned
within-env / cross-env relevance split.

``--pods`` runs the multi-host dispatch sweep instead (ISSUE 3): the
hierarchical streaming combine decomposed onto a two-level
(pod, agent) placement (``repro.core.pod_dispatch``), reporting the
analytic cross-pod bytes per share step of the dispatched path
(O(pods · k_leader · |params|)) against the flat single-mesh combine
(O(n · k · |params|)), plus the per-combine wall time of both
decompositions. Acceptance: at fixed pod count the dispatched
cross-pod bytes must not grow with agent count.

Every run also writes machine-readable
``BENCH_topology_scaling[_pods].json`` (override with ``--json``) so
the perf trajectory is tracked across PRs, mirroring
``bench_relevance_sketch.py``.

    PYTHONPATH=src python benchmarks/bench_topology_scaling.py \
        [--smoke] [--hetero] [--pods] [--json PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GroupSpec
from repro.core import DDAL

def _default_json(mode: str) -> str:
    """Per-mode default path so the --pods sweep doesn't clobber the
    topology sweep's results (CI runs both)."""
    tag = "" if mode == "sweep" else f"_{mode}"
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        f"BENCH_topology_scaling{tag}.json")


def write_json(path: str, mode: str, rows: list) -> None:
    """Machine-readable results, same shape as
    ``bench_relevance_sketch.py``'s emitter, so the perf trajectory
    is diffable across PRs."""
    payload = {"bench": "topology_scaling", "mode": mode,
               "backend": jax.default_backend(), "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"wrote {path}")


def flight_bytes(flight) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(flight))


def _time_min(thunk, epochs: int, repeats: int = 5) -> float:
    """Best-of-``repeats`` per-epoch wall time in ms (min is the
    noise-robust statistic for a deterministic workload)."""
    jax.block_until_ready(thunk())             # compile + warm-up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(thunk())
        best = min(best, time.time() - t0)
    return best / epochs * 1e3


def dense_equiv_bytes(n: int, max_delay: int, n_params: int) -> int:
    """What the seed's (n_dst, D+1, n_src, *param) layout would hold
    (grads fp32 + T/R fp32 + valid bool)."""
    d1 = max_delay + 1
    return n * n * d1 * (n_params * 4 + 4 + 4 + 1)


def make_toy_group(spec: GroupSpec, n_params: int):
    """Quadratic agents: grads = w - target (scalar per-agent target,
    so the exchange — not agent state traffic — dominates)."""
    def gen(state, key):
        del key
        return {"w": state["w"] - state["t"]}, {}, state

    def app(state, g):
        return {"w": state["w"] - 0.1 * g["w"], "t": state["t"]}

    ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]})
    n = spec.n_agents
    gs = ddal.init({
        "w": jnp.zeros((n, n_params), jnp.float32),
        "t": jnp.arange(1, n + 1, dtype=jnp.float32)[:, None],
    })
    return ddal, gs


def _dense_seed_thunk(n: int, n_params: int, epochs: int,
                      max_delay: int, minibatch: int,
                      m_pieces: int = 8):
    """Build a jitted runner for the seed's dense all-to-all delay
    line (``K.InFlight``) through the same toy epoch loop — the
    baseline the sparse subsystem replaces. Returns (thunk, flight)."""
    from repro.core import knowledge as K
    from repro.core.weighting import training_experience

    w0 = jnp.zeros((n, n_params), jnp.float32)
    tgt = jnp.arange(1, n + 1, dtype=jnp.float32)[:, None]
    params0 = {"w": jnp.zeros((n_params,), jnp.float32)}
    stores0 = jax.vmap(lambda _: K.make_store(params0, m_pieces))(
        jnp.arange(n))
    flight0 = K.make_inflight(params0, n, max_delay)
    delay = jnp.zeros((n, n), jnp.int32)
    R = jnp.ones((n, n))

    def epoch(carry, e):
        w, stores, flight = carry
        grads = {"w": w - tgt}
        Tw = jnp.broadcast_to(training_experience(e, "epochs"), (n,))
        flight = K.send(flight, grads, Tw, R, delay, e, True)
        flight, stores = K.deliver(flight, stores, e)
        gbar, wsum = jax.vmap(K.weighted_average)(stores)
        upd = w - 0.1 * gbar["w"]
        do = ((e % minibatch) == 0) & (wsum > 0)
        w = jnp.where(do[:, None], upd, w)
        return (w, stores, flight), None

    def run(carry):
        return jax.lax.scan(epoch, carry,
                            jnp.arange(epochs, dtype=jnp.int32))[0]

    run = jax.jit(run)
    carry = (w0, stores0, flight0)
    return (lambda: run(carry)), flight0


def bench_dense_seed(n: int, n_params: int, epochs: int,
                     max_delay: int, minibatch: int) -> dict:
    thunk, flight0 = _dense_seed_thunk(n, n_params, epochs, max_delay,
                                       minibatch)
    epoch_ms = _time_min(thunk, epochs)
    fb = flight_bytes(flight0)
    return {"n": n, "topology": "dense(seed)", "k": n,
            "epoch_ms": epoch_ms, "flight_mb": fb / 2**20,
            "dense_mb": fb / 2**20, "mem_ratio": 1.0}


def _sparse_thunk(n: int, topology: str, degree: int, n_params: int,
                  epochs: int, max_delay: int, minibatch: int,
                  m_pieces: int = 8, resample_every: int = 0):
    name = "random_k" if topology == "dynamic_k" else topology
    if name == "random_k":
        degree = min(degree, n - 1)    # gossip degree must be < n
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=minibatch,
                     m_pieces=m_pieces, topology=name,
                     degree=degree, max_delay=max_delay,
                     resample_every=(resample_every
                                     if topology == "dynamic_k" else 0))
    ddal, gs = make_toy_group(spec, n_params)
    run = jax.jit(lambda g, k: ddal.run(g, k, epochs))
    key = jax.random.PRNGKey(1)
    return (lambda: run(gs, key)), ddal, gs


def acceptance_pair(n_params: int, epochs: int, max_delay: int,
                    minibatch: int, degree: int,
                    repeats: int = 20):
    """Interleaved best-of-``repeats`` timing of the two acceptance
    configs (dense(seed) n=16 vs sparse random_k n=64) so slow drift
    in machine load biases neither side."""
    td, _ = _dense_seed_thunk(16, n_params, epochs, max_delay,
                              minibatch)
    ts, _, _ = _sparse_thunk(64, "random_k", degree, n_params, epochs,
                             max_delay, minibatch)
    jax.block_until_ready(td())                # compile + warm-up
    jax.block_until_ready(ts())
    best_d = best_s = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(td())
        best_d = min(best_d, time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(ts())
        best_s = min(best_s, time.time() - t0)
    return best_d / epochs * 1e3, best_s / epochs * 1e3


def bench_one(n: int, topology: str, degree: int, n_params: int,
              epochs: int, max_delay: int, minibatch: int = 5,
              resample_every: int = 5) -> dict:
    thunk, ddal, gs = _sparse_thunk(n, topology, degree, n_params,
                                    epochs, max_delay, minibatch,
                                    resample_every=resample_every)
    epoch_ms = _time_min(thunk, epochs)
    fb = flight_bytes(gs.flight)
    db = dense_equiv_bytes(n, ddal.max_delay, n_params)
    return {
        "n": n, "topology": topology, "k": ddal.topology.degree,
        "epoch_ms": epoch_ms, "flight_mb": fb / 2**20,
        "dense_mb": db / 2**20, "mem_ratio": fb / db,
    }


# ---------------------------------------------------------------------
# multi-host pod dispatch sweep (ISSUE 3)
# ---------------------------------------------------------------------
def bench_pod_row(pods: int, pod_size: int, n_params: int) -> dict:
    """One cell of the pod sweep: hierarchical(n = pods · pod_size)
    combined flat vs pod-dispatched (reference decomposition — same
    math the shard_map path runs, timeable on one device), with the
    analytic cross-pod traffic of both placements."""
    from repro.core import topology as T
    from repro.core.pod_dispatch import (
        cross_pod_bytes,
        flat_exchange_bytes,
        make_pod_dispatch,
        split_topology,
    )
    from repro.core.sharded_ddal import Knowledge, _combine_topo

    n = pods * pod_size
    topo = T.hierarchical(n, pod_size)
    lay = T.hierarchical_layout(n, pod_size)
    edges = split_topology(topo, lay)
    rng = np.random.default_rng(0)
    know = Knowledge(
        tg={"w": jnp.asarray(rng.normal(size=(n, n_params)),
                             jnp.float32)},
        tsum=jnp.asarray(rng.uniform(1, 3, n), jnp.float32),
        rg={"w": jnp.asarray(rng.normal(size=(n, n_params)),
                             jnp.float32)},
        rsum=jnp.asarray(rng.uniform(1, 3, n), jnp.float32),
    )
    flat = jax.jit(lambda k: _combine_topo(k, topo))
    pod = jax.jit(make_pod_dispatch(topo, lay))
    flat_ms = _time_min(lambda: flat(know), epochs=1)
    pod_ms = _time_min(lambda: pod(know), epochs=1)
    return {
        "pods": pods, "n": n, "pod_size": pod_size,
        "l_edges": int(edges.ledge.sum()),
        "cross_mb": cross_pod_bytes(edges, n_params) / 2**20,
        "flat_mb": flat_exchange_bytes(topo, n_params) / 2**20,
        "flat_ms": flat_ms, "pod_ms": pod_ms,
    }


def pod_sweep(args, json_path: "str | None" = None) -> list:
    """Pod-count sweep at fixed n, then agent-count sweep at fixed
    pods — the second is the scaling acceptance: dispatched cross-pod
    bytes must be flat in n (they are O(pods · k_leader · |params|)).
    The JSON record is written *before* the acceptance check, so a
    failing run still leaves its numbers behind for diagnosis."""
    n = 16 if args.smoke else 64
    pod_counts = [p for p in (1, 2, 4, 8) if p <= n // 2]
    rows = []
    print(f"pod dispatch sweep (n={n}, {args.params} params/agent):")
    print(f"{'pods':>5} {'n':>4} {'pod_sz':>6} {'l_edges':>7} "
          f"{'cross MB':>9} {'flat MB':>8} {'flat ms':>8} "
          f"{'pod ms':>7}")

    def show(r):
        rows.append(r)
        print(f"{r['pods']:5d} {r['n']:4d} {r['pod_size']:6d} "
              f"{r['l_edges']:7d} {r['cross_mb']:9.2f} "
              f"{r['flat_mb']:8.2f} {r['flat_ms']:8.2f} "
              f"{r['pod_ms']:7.2f}")

    for pods in pod_counts:
        show(bench_pod_row(pods, n // pods, args.params))

    fixed_pods = 4 if n >= 16 else 2
    print(f"\nfixed pods={fixed_pods}, growing agents:")
    sizes = (2, 4) if args.smoke else (4, 8, 16)
    agent_rows = [bench_pod_row(fixed_pods, s, args.params)
                  for s in sizes]
    for r in agent_rows:
        show(r)
    if json_path:
        write_json(json_path, "pods", rows)
    ok_n = len({r["cross_mb"] for r in agent_rows}) == 1
    print(f"\nacceptance: cross-pod bytes at pods={fixed_pods} flat "
          f"in n ({[round(r['cross_mb'], 3) for r in agent_rows]} MB "
          f"for n={[r['n'] for r in agent_rows]}) → "
          f"{'PASS' if ok_n else 'FAIL'}")
    by_pods = {r["pods"]: r for r in rows[:len(pod_counts)]}
    ok_p = all(
        abs(by_pods[p]["cross_mb"]
            - p * (p - 1) / (q * (q - 1)) * by_pods[q]["cross_mb"])
        < 1e-9
        for p in pod_counts for q in pod_counts if p > q > 1)
    print(f"acceptance: cross-pod bytes ∝ pods · k_leader "
          f"(= pods · (pods − 1) directed leader edges) → "
          f"{'PASS' if ok_p else 'FAIL'}")
    if not (ok_n and ok_p):
        raise SystemExit("pod dispatch traffic scaling FAILED")
    return rows


# ---------------------------------------------------------------------
# elastic-membership churn overhead (ISSUE 7)
# ---------------------------------------------------------------------
def _churn_thunk(n: int, elastic: bool, n_params: int, epochs: int,
                 minibatch: int, degree: int, dead=None):
    """Jitted toy-group runner, elastic or not; ``dead`` (bool mask)
    pre-kills agents so the steady-state cost with corpses on the
    roster is measurable too."""
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=minibatch,
                     m_pieces=8, topology="random_k",
                     degree=min(degree, n - 1), elastic=elastic)
    ddal, gs = make_toy_group(spec, n_params)
    if dead is not None and dead.any():
        gs = ddal.kill(gs, jnp.asarray(dead))
    run = jax.jit(lambda g, k: ddal.run(g, k, epochs))
    key = jax.random.PRNGKey(1)
    return lambda: run(gs, key)


def churn_sweep(args, json_path: "str | None" = None) -> list:
    """The alive-mask tax: per-epoch time of the elastic exchange —
    all-alive, and at steady state with ~25% of the roster dead —
    against the non-elastic program on the same config. Timing is
    interleaved best-of-N (same discipline as ``acceptance_pair``) so
    load drift biases neither side. Gate: elastic with everyone alive
    costs <= 2% over non-elastic. Rows merge into the topology-sweep
    JSON so one file tracks the whole exchange perf trajectory."""
    from repro.core.chaos import chaos_schedule

    n = 16 if args.smoke else 64
    epochs = args.epochs or (10 if args.smoke else 50)
    repeats = 30 if args.smoke else 20
    base = _churn_thunk(n, False, args.params, epochs,
                        args.minibatch, args.degree)
    live = _churn_thunk(n, True, args.params, epochs,
                        args.minibatch, args.degree)
    jax.block_until_ready(base())              # compile + warm-up
    jax.block_until_ready(live())
    best_b = best_l = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(base())
        best_b = min(best_b, time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(live())
        best_l = min(best_l, time.time() - t0)
    base_ms = best_b / epochs * 1e3
    live_ms = best_l / epochs * 1e3

    # steady state with corpses: the injector's most lethal epoch
    sched = chaos_schedule(0, n, 32, kill_prob=0.3, revive_after=6,
                           min_alive=max(1, n // 2))
    dead = ~sched[int(np.argmin(sched.sum(axis=1)))]
    dead_ms = _time_min(
        _churn_thunk(n, True, args.params, epochs, args.minibatch,
                     args.degree, dead=dead), epochs)

    overhead = live_ms / base_ms - 1.0
    rows = [
        {"n": n, "topology": "churn_off", "k": args.degree,
         "epoch_ms": base_ms, "alive": n},
        {"n": n, "topology": "churn_all_alive", "k": args.degree,
         "epoch_ms": live_ms, "alive": n,
         "overhead_pct": overhead * 100.0},
        {"n": n, "topology": "churn_dead", "k": args.degree,
         "epoch_ms": dead_ms, "alive": int(n - dead.sum())},
    ]
    print(f"elastic membership churn (n={n}, random_k(k="
          f"{args.degree}), {args.params} params/agent):")
    print(f"{'row':>16} {'alive':>6} {'epoch ms':>9}")
    for r in rows:
        print(f"{r['topology']:>16} {r['alive']:6d} "
              f"{r['epoch_ms']:9.3f}")
    if json_path:
        merged = rows
        if os.path.exists(json_path):
            with open(json_path) as f:
                old = json.load(f).get("rows", [])
            merged = [r for r in old if not str(
                r.get("topology", "")).startswith("churn")] + rows
        write_json(json_path, "sweep", merged)
    ok = overhead <= 0.02
    print(f"\nacceptance: all-alive elastic epoch {live_ms:.3f} ms vs "
          f"non-elastic {base_ms:.3f} ms (+{overhead:.2%}) "
          f"→ {'PASS' if ok else 'FAIL'} (gate ≤ 2%)")
    if not ok:
        raise SystemExit("elastic membership overhead gate FAILED")
    return rows


# ---------------------------------------------------------------------
# heterogeneous CartPole/GridWorld adaptive-wiring ablation
# ---------------------------------------------------------------------
_OBS_DIM, _N_ACT, _MAX_STEPS = 25, 4, 100


@dataclasses.dataclass(frozen=True)
class _Padded:
    """Lift an env into the shared (obs_dim=25, n_actions=4) space so
    CartPole and GridWorld agents can share one vmapped network:
    observations zero-padded, surplus actions folded back with a
    modulus. Bench-local scaffolding, not a library env."""
    inner: object
    obs_dim: int = _OBS_DIM
    n_actions: int = _N_ACT
    max_steps: int = _MAX_STEPS

    def _pad(self, o):
        return jnp.pad(o, (0, self.obs_dim - o.shape[0]))

    def reset(self, key):
        return self.inner.reset(key)

    def obs(self, s):
        return self._pad(self.inner.obs(s))

    def step(self, s, a):
        ns, o, r, d = self.inner.step(s, a % self.inner.n_actions)
        return ns, self._pad(o), r, d


def bench_hetero(n: int, epochs: int, degree: int,
                 resample_every: int, relevance_mode: str,
                 seed: int = 0) -> dict:
    """One cell of the adaptive-wiring ablation: n/2 CartPole + n/2
    GridWorld A2C agents gossiping over random_k(degree), static or
    dynamic, uniform or learned relevance. Returns per-env tail mean
    return and the learned within-env vs cross-env relevance means."""
    from repro import optim
    from repro.rl import a2c_loss, networks as nets
    from repro.rl.envs import CartPole, GridWorld
    from repro.rl.rollout import episode_return, run_episode

    cart = _Padded(CartPole())
    grid = _Padded(GridWorld(max_steps=_MAX_STEPS))
    opt = optim.adamw(3e-3)
    spec = GroupSpec(n_agents=n, threshold=min(20, max(1, epochs // 2)),
                     minibatch=5, m_pieces=16, topology="random_k",
                     degree=min(degree, n - 1),
                     resample_every=resample_every,
                     relevance_mode=relevance_mode)

    def gen(state, key):
        params = state["params"]

        def ep(env):
            def run(k):
                def select(obs, kk):
                    return jax.random.categorical(
                        kk, nets.policy_logits(params, obs))
                return run_episode(env, select, k)
            return run

        traj = jax.lax.cond(state["env_id"] == 0, ep(cart), ep(grid),
                            key)
        loss, grads = jax.value_and_grad(a2c_loss)(params, traj, 0.99)
        return grads, {"return": episode_return(traj)}, state

    def app(state, g):
        params, opt_state = opt.update(g, state["opt"], state["params"],
                                       state["step"])
        return {**state, "params": params, "opt": opt_state,
                "step": state["step"] + 1}

    key = jax.random.PRNGKey(seed)
    k_init, k_run = jax.random.split(key)
    params0 = jax.vmap(
        lambda k: nets.init_policy_value(k, _OBS_DIM, _N_ACT, 64))(
        jax.random.split(k_init, n))
    env_id = (jnp.arange(n) % 2).astype(jnp.int32)   # interleaved
    states = {"params": params0,
              "opt": jax.vmap(opt.init)(params0),
              "step": jnp.zeros((n,), jnp.int32),
              "env_id": env_id}
    ddal = DDAL(spec, gen, app, lambda s: s["params"])
    gs = ddal.init(states)
    gs, metrics = jax.jit(lambda g, k: ddal.run(g, k, epochs))(
        gs, k_run)
    rets = np.asarray(metrics["return"])             # (epochs, n)
    tail = rets[-max(1, epochs // 4):]
    same = np.equal.outer(np.asarray(env_id), np.asarray(env_id))
    rel = np.asarray(gs.relevance)
    off = ~np.eye(n, dtype=bool)
    return {
        "resample": resample_every, "relevance": relevance_mode,
        "cart_ret": float(tail[:, ::2].mean()),
        "grid_ret": float(tail[:, 1::2].mean()),
        "rel_within": float(rel[same & off].mean()),
        "rel_cross": float(rel[~same].mean()),
    }


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI fast path: n ≤ 16, few epochs")
    p.add_argument("--hetero", action="store_true",
                   help="run the heterogeneous CartPole/GridWorld "
                        "static-vs-dynamic × uniform-vs-learned "
                        "relevance ablation")
    p.add_argument("--pods", action="store_true",
                   help="run the multi-host pod dispatch sweep "
                        "instead: cross-pod bytes + combine time, "
                        "flat vs two-level placement")
    p.add_argument("--churn", action="store_true",
                   help="run the elastic-membership overhead rows "
                        "instead: epoch time with the alive mask "
                        "threaded (all-alive, and with ~25%% of the "
                        "roster dead) vs the non-elastic program "
                        "(gate: ≤ 2%% all-alive overhead)")
    p.add_argument("--hetero-epochs", type=int, default=None,
                   help="epochs per hetero ablation cell")
    p.add_argument("--resample-every", type=int, default=5,
                   help="dynamic_k gossip resample period")
    p.add_argument("--params", type=int, default=4096,
                   help="toy agent parameter count")
    p.add_argument("--epochs", type=int, default=None,
                   help="epochs per timing run")
    p.add_argument("--degree", type=int, default=4)
    p.add_argument("--minibatch", type=int, default=5,
                   help="eq. 4 update cadence (paper uses 100)")
    p.add_argument("--max-delay", type=int, default=2)
    p.add_argument("--json", default=None,
                   help="machine-readable results path (defaults to "
                        "BENCH_topology_scaling[_pods].json next to "
                        "this file)")
    args = p.parse_args(argv)

    if args.pods:
        return pod_sweep(args, args.json or _default_json("pods"))
    if args.churn:
        return churn_sweep(args, args.json or _default_json("sweep"))

    sizes = [4, 16] if args.smoke else [4, 16, 64, 256]
    epochs = args.epochs or (5 if args.smoke else 20)
    topologies = ["full", "ring", "torus2d", "random_k", "dynamic_k",
                  "hierarchical"]

    # head-to-head acceptance measurement FIRST, before the sweep
    # pollutes the allocator/caches: interleaved best-of-N so load
    # drift cannot bias either side
    head = None
    if not args.smoke:
        head = acceptance_pair(args.params, max(epochs, 50),
                               args.max_delay, args.minibatch,
                               args.degree)

    rows = []
    print(f"{'n':>4} {'topology':>13} {'k':>4} {'epoch ms':>9} "
          f"{'flight MB':>10} {'dense MB':>9} {'mem':>7}")

    def show(r):
        rows.append(r)
        print(f"{r['n']:4d} {r['topology']:>13} {r['k']:4d} "
              f"{r['epoch_ms']:9.2f} {r['flight_mb']:10.2f} "
              f"{r['dense_mb']:9.2f} {r['mem_ratio']:6.1%}")

    for n in sizes:
        if n <= 64:
            show(bench_dense_seed(n, args.params, epochs,
                                  args.max_delay, args.minibatch))
        else:
            # dense n=256 delay line alone is ~0.8 GiB — the layout
            # this PR retires; report the footprint, skip the run
            print(f"{n:4d} {'dense(seed)':>13}    —  (skipped: "
                  f"delay line ≈ "
                  f"{dense_equiv_bytes(n, args.max_delay, args.params) / 2**30:.1f} GiB)")
        for topo in topologies:
            if topo == "full" and n > 64:
                continue
            show(bench_one(n, topo, args.degree, args.params, epochs,
                           args.max_delay, args.minibatch,
                           resample_every=args.resample_every))

    by = {(r["n"], r["topology"]): r for r in rows}
    gossip64 = by.get((64, "random_k"))
    dyn64 = by.get((64, "dynamic_k"))
    if head is not None and gossip64:
        t_d, t_s = head
        ok_t = t_s < t_d
        ok_m = gossip64["mem_ratio"] < 0.10
        print(f"\nacceptance: n=64 random_k(k={args.degree}) epoch "
              f"{t_s:.3f} ms vs dense(seed) n=16 {t_d:.3f} ms → "
              f"{'PASS' if ok_t else 'FAIL'}")
        print(f"acceptance: n=64/k={args.degree} delay-line memory "
              f"{gossip64['mem_ratio']:.1%} of dense n=64 equivalent "
              f"→ {'PASS' if ok_m else 'FAIL'}")
    if gossip64 and dyn64:
        ok_d = dyn64["flight_mb"] == gossip64["flight_mb"]
        print(f"acceptance: n=64 dynamic_k delay-line "
              f"{dyn64['flight_mb']:.2f} MB == static random_k "
              f"{gossip64['flight_mb']:.2f} MB → "
              f"{'PASS' if ok_d else 'FAIL'}")

    if args.hetero or args.smoke:
        h_epochs = args.hetero_epochs or (10 if args.smoke else 400)
        n_h = 8
        print(f"\nheterogeneous CartPole/GridWorld group (n={n_h}, "
              f"{h_epochs} epochs/cell):")
        print(f"{'gossip':>8} {'relevance':>10} {'cart ret':>9} "
              f"{'grid ret':>9} {'R within':>9} {'R cross':>8}")
        for resample in (0, args.resample_every):
            for mode in ("uniform", "grad_cos"):
                r = bench_hetero(n_h, h_epochs, args.degree, resample,
                                 mode)
                rows.append({"n": n_h, "topology": "hetero", **r})
                gossip = "static" if resample == 0 else "dynamic"
                print(f"{gossip:>8} {mode:>10} {r['cart_ret']:9.2f} "
                      f"{r['grid_ret']:9.3f} {r['rel_within']:9.3f} "
                      f"{r['rel_cross']:8.3f}")
    json_path = args.json or _default_json("sweep")
    # the churn rows (--churn mode) share this file: keep them, the
    # same way churn_sweep keeps these rows
    if os.path.exists(json_path):
        with open(json_path) as f:
            old = json.load(f).get("rows", [])
        rows = rows + [r for r in old if str(
            r.get("topology", "")).startswith("churn")]
    write_json(json_path, "sweep", rows)
    return rows


if __name__ == "__main__":
    main()
