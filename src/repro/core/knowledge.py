"""Knowledge stores (K_i ∪ K_-i) for DDAL — functional jnp structures.

A ``KnowledgeStore`` is a ring buffer of the last ``m`` gradient pieces
an agent holds, each with its (T, R) weighting metadata (paper §5:
every piece travels with its training-experience and relevance
weights). The paper's multiprocessing queues become delay lines: a
piece sent by agent j at epoch t is delivered into agent i's store at
epoch t + delay[j, i] — deterministic asynchrony (DESIGN.md §3).

Two delay-line layouts exist:

* ``SparseInFlight`` (production) — neighbor-indexed over a
  ``repro.core.topology.Topology``; leaves are (n, k, D+2, *param)
  (D+1 delivery planes + 1 scratch), O(n·k·D) memory, send/deliver
  are gather/scatter over the neighbor table. The ``full`` topology
  (k = n, slot j ↔ source j) reproduces the dense semantics bitwise.
  The table may be *traced* (dynamic gossip,
  ``repro.core.topology.DynamicTopology``): both the uniform-delay
  plane-write fast path and the heterogeneous-delay one-hot path
  consume a traced ``nbr`` / ``delay`` / ``relevance`` — only
  *static* facts (mask pattern, delay uniformity) pick the path, so
  resampling the edges never changes the compiled program shape.
* ``InFlight`` (dense reference) — the seed's all-to-all layout with
  (n_dst, D+1, n_src, *param) leaves, O(n²·D) memory. Kept as the
  oracle for the dense-vs-sparse equivalence tests.

All structures carry a leading agent axis when used by the vmapped
group loop in ``repro.core.ddal``.

**Quantized knowledge planes** (opt-in, ``quant_block > 0``): gradient
pieces are stored and shipped as int8 with per-block fp32 scales
(``repro.kernels.ddal_wavg.ref.quantize_flat`` wire format — one scale
per ``quant_block`` consecutive elements of each flattened leaf). The
``scale`` field on both delay-line layouts' production structures
rides through every send/deliver path exactly like ``T``/``R``; it
defaults to ``None``, which jax filters from the pytree, so
non-quantized programs, shardings and existing checkpoints keep their
historical structure bit for bit. Delay-line and store memory drop
~4× (int8 payload + nb·4 scale bytes per plane); eq. 4 then runs over
the quantized planes via the fused kernel entry, dequantising inside
the block loop.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_map, tree_weighted_sum
from repro.core.topology import Topology
from repro.core.weighting import eq4_weights


class KnowledgeStore(NamedTuple):
    grads: Any           # pytree, leaves (m, *param_shape)
    T: jnp.ndarray       # (m,) training-experience weights
    R: jnp.ndarray       # (m,) relevance weights
    valid: jnp.ndarray   # (m,) bool
    ptr: jnp.ndarray     # () int32 — next write slot
    scale: Any = None    # quantized stores: pytree mirroring grads
                         # with fp32 leaves (m, ⌈P/quant_block⌉);
                         # None (filtered from the pytree) keeps
                         # fp32 stores structurally unchanged
    born: Any = None     # staleness tracking: (m,) int32 send epoch
                         # of each piece (transport faults /
                         # max_staleness); None keeps legacy stores
                         # structurally unchanged


def _scale_blocks(x, quant_block: int) -> int:
    """Number of int8 scale blocks for one (unstacked) leaf."""
    p = int(np.prod(x.shape)) if x.shape else 1
    return -(-p // quant_block)


def make_store(params_like, m: int, quant_block: int = 0,
               track_born: bool = False) -> KnowledgeStore:
    """``quant_block > 0`` builds an int8 store: grads leaves are int8
    of the same shapes, plus per-block fp32 scales. ``track_born``
    adds the (m,) int32 send-epoch plane staleness weighting reads."""
    dtype = jnp.int8 if quant_block else jnp.float32
    grads = tree_map(
        lambda x: jnp.zeros((m,) + x.shape, dtype), params_like)
    scale = None
    if quant_block:
        scale = tree_map(
            lambda x: jnp.zeros((m, _scale_blocks(x, quant_block)),
                                jnp.float32), params_like)
    return KnowledgeStore(
        grads=grads,
        T=jnp.zeros((m,), jnp.float32),
        R=jnp.zeros((m,), jnp.float32),
        valid=jnp.zeros((m,), bool),
        ptr=jnp.zeros((), jnp.int32),
        scale=scale,
        born=jnp.zeros((m,), jnp.int32) if track_born else None,
    )


def append(store: KnowledgeStore, piece, T, R,
           enabled=True, scale=None, born=None) -> KnowledgeStore:
    """Append one piece (overwrites the oldest when full). ``enabled``
    may be a traced bool — when False the store is returned unchanged
    (used to mask delivery before the sharing threshold). The write is
    a one-hot masked select rather than a scatter: XLA CPU lowers it
    to a fused elementwise op that vectorises under vmap/scan (dynamic
    scatters there cost ~10× more), and a disabled append is simply an
    all-False mask. Quantized stores take the piece's per-block
    ``scale`` pytree alongside (leaves (nb,))."""
    m = store.T.shape[0]
    en = jnp.asarray(enabled)
    slot = jnp.where(en, store.ptr % m, m)     # m ⇒ mask is all-False
    onehot = jnp.arange(m) == slot             # (m,)

    def write(buf, x):
        mask = jnp.reshape(onehot, (m,) + (1,) * (buf.ndim - 1))
        return jnp.where(mask, x.astype(buf.dtype), buf)

    grads = tree_map(lambda b, x: write(b, x), store.grads, piece)
    new_scale = store.scale
    if store.scale is not None:
        if scale is None:
            raise ValueError("quantized store: append needs the "
                             "piece's scale pytree")
        new_scale = tree_map(lambda b, x: write(b, x),
                             store.scale, scale)
    new_born = store.born
    if store.born is not None:
        if born is None:
            raise ValueError("staleness-tracked store: append needs "
                             "the piece's born epoch")
        new_born = write(store.born,
                         jnp.asarray(born, jnp.int32))
    return KnowledgeStore(
        grads=grads,
        T=write(store.T, jnp.broadcast_to(T, ())),
        R=write(store.R, jnp.broadcast_to(R, ())),
        valid=write(store.valid, jnp.asarray(True)),
        ptr=store.ptr + en.astype(jnp.int32),
        scale=new_scale,
        born=new_born,
    )


def append_many(store: KnowledgeStore, pieces, T, R,
                deliver, scales=None, borns=None) -> KnowledgeStore:
    """Append up to n pieces at once, in one vectorised masked pass.

    Ring semantics are exactly those of n sequential ``append`` calls:
    pieces with ``deliver`` True take consecutive slots from ``ptr``
    (oldest first overwritten), and when more pieces than slots arrive
    the later piece wins. pieces: pytree with leading axis n; T, R,
    deliver: (n,). Quantized stores take the pieces' per-block
    ``scales`` pytree alongside (leaves (n, nb)).
    """
    m = store.T.shape[0]
    n = T.shape[0]
    v = deliver.astype(jnp.int32)
    rank = jnp.cumsum(v) - v                       # exclusive rank
    slot = jnp.where(deliver, (store.ptr + rank) % m, m)   # (n,)
    # hit[s, j]: piece j lands in slot s; the last such j wins —
    # exactly the sequential-overwrite order.
    hit = slot[None, :] == jnp.arange(m)[:, None]          # (m, n)
    sel = jnp.max(jnp.where(hit, jnp.arange(n)[None, :], -1),
                  axis=1)                                  # (m,)
    has = sel >= 0
    sel_c = jnp.maximum(sel, 0)

    def write(buf, xs):
        mask = jnp.reshape(has, (m,) + (1,) * (buf.ndim - 1))
        return jnp.where(mask, xs[sel_c].astype(buf.dtype), buf)

    grads = tree_map(lambda b, x: write(b, x), store.grads, pieces)
    new_scale = store.scale
    if store.scale is not None:
        if scales is None:
            raise ValueError("quantized store: append_many needs the "
                             "pieces' scales pytree")
        new_scale = tree_map(lambda b, x: write(b, x),
                             store.scale, scales)
    new_born = store.born
    if store.born is not None:
        if borns is None:
            raise ValueError("staleness-tracked store: append_many "
                             "needs the pieces' born epochs")
        new_born = write(store.born, jnp.asarray(borns, jnp.int32))
    return KnowledgeStore(
        grads=grads,
        T=write(store.T, T),
        R=write(store.R, R),
        valid=jnp.where(has, True, store.valid),
        ptr=store.ptr + jnp.sum(v),
        scale=new_scale,
        born=new_born,
    )


def weighted_average(store: KnowledgeStore, use_kernel: bool = False,
                     interpret: "bool | None" = None, *,
                     fused: bool = False, quant_block: int = 0,
                     impl: str = "auto"):
    """eq. 4 over the store's valid pieces → (ḡ, total_weight).

    ``interpret=None`` (default) lets the kernel wrapper pick: compiled
    Pallas on TPU, interpreter elsewhere (the old behaviour hardcoded
    ``interpret=True``, so the kernel *always* ran interpreted — even
    on TPU). Pass an explicit bool to override, e.g. tests forcing
    the interpreter off-TPU.

    ``fused=True`` routes through the one-pass share-step entry
    (``repro.kernels.ddal_wavg.ops.tree_fused_wavg``): the ``impl``
    knob picks Pallas / tiled XLA, and the XLA path is bitwise-equal
    to the historical two-op path below. Quantized stores
    (``store.scale is not None``) always take the fused quantized
    entry and need the store's ``quant_block``."""
    if store.scale is not None:
        if quant_block <= 0:
            raise ValueError("quantized store: weighted_average needs "
                             "its quant_block")
        from repro.kernels.ddal_wavg import ops as wavg_ops
        return wavg_ops.tree_fused_wavg_q(
            store.grads, store.scale, store.T, store.R, store.valid,
            quant_block, impl=impl, interpret=interpret)
    if fused:
        from repro.kernels.ddal_wavg import ops as wavg_ops
        return wavg_ops.tree_fused_wavg(
            store.grads, store.T, store.R, store.valid, impl=impl,
            interpret=interpret)
    w = eq4_weights(store.T, store.R, store.valid)
    if use_kernel:
        from repro.kernels.ddal_wavg import ops as wavg_ops
        g = wavg_ops.tree_wavg(store.grads, w, interpret=interpret)
    else:
        g = tree_weighted_sum(store.grads, w)
    return g, jnp.sum(w)


# ---------------------------------------------------------------------
# sparse, topology-aware delay line (production path)
# ---------------------------------------------------------------------
class SparseInFlight(NamedTuple):
    """Neighbor-indexed delay line. For destination agent i, edge slot
    j (< k) carries pieces from source ``topo.nbr[i, j]``; a piece sent
    at epoch t over an edge with delay d sits in delay slot
    (t + d) % (D+1) until epoch t + d pops it. The delay axis holds
    D+2 planes: D+1 delivery slots plus one trailing *scratch* plane
    that absorbs disabled/warm-up writes, so ``sparse_send`` never has
    to read-modify-write a live plane to honor the enable gate.
    Memory is O(n·k·D) versus the dense reference's O(n²·D)."""
    grads: Any            # leaves (n, k, D+2, *param_shape)
    T: jnp.ndarray        # (n, k, D+2)
    R: jnp.ndarray
    valid: jnp.ndarray    # bool
    scale: Any = None     # quantized lines: leaves (n, k, D+2, nb)
                          # fp32 per-block scales; None ⇒ fp32 planes
    chk: Any = None       # faulty transport: (n, k, D+2) fp32 payload
                          # checksum computed at send, verified at
                          # deliver (corruption quarantine); None ⇒
                          # perfect delivery, structurally unchanged
    born: Any = None      # staleness tracking: (n, k, D+2) int32 send
                          # epoch riding with each in-flight piece


def make_sparse_inflight(params_like, topo: Topology,
                         max_delay: int, quant_block: int = 0,
                         transport: bool = False,
                         track_born: bool = False) -> SparseInFlight:
    """``quant_block > 0`` builds an int8 delay line (~4× lighter):
    gradient planes are int8, per-block scales ride alongside.
    ``transport`` adds the checksum planes the faulty transport
    verifies at deliver; ``track_born`` the int32 send-epoch planes
    staleness weighting needs. Both default off — the legacy pytree."""
    n, k = topo.nbr.shape
    planes = max_delay + 2            # D+1 delivery slots + scratch
    dtype = jnp.int8 if quant_block else jnp.float32
    grads = tree_map(
        lambda x: jnp.zeros((n, k, planes) + x.shape, dtype),
        params_like)
    scale = None
    if quant_block:
        scale = tree_map(
            lambda x: jnp.zeros(
                (n, k, planes, _scale_blocks(x, quant_block)),
                jnp.float32), params_like)
    z = jnp.zeros((n, k, planes), jnp.float32)
    return SparseInFlight(
        grads=grads, T=z, R=z, valid=z.astype(bool), scale=scale,
        chk=z if transport else None,
        born=(jnp.zeros((n, k, planes), jnp.int32)
              if track_born else None))


def sparse_send(flight: SparseInFlight, topo: Topology, pieces, T,
                epoch, enabled, alive=None, quant_block: int = 0,
                faults=None) -> SparseInFlight:
    """Every agent publishes its piece; each destination gathers it
    from its in-neighbors only.

    pieces: pytree leaves (n, ...); T: (n,) training experience of the
    sources; per-edge relevance/delay come from ``topo``; enabled:
    scalar bool (sharing started). ``topo`` may carry traced arrays
    (a resampled gossip table, learned relevance): the gathers/writes
    below are trace-polymorphic, and a traced ``delay`` simply takes
    the general one-hot path (delay-plane choice can then differ per
    edge and per epoch).

    ``alive`` ((n,) bool, optional — elastic membership) folds into
    the per-edge gate: a dead source publishes nothing and a dead
    destination's line stays empty (so a revival replays no plane
    staler than its death). With ``alive`` the gate is a traced
    (n, k) mask, so the blind all-True plane write is skipped and the
    gated plane/one-hot paths carry the send; ``alive=None`` compiles
    the historical program unchanged.

    On an int8 delay line (``flight.scale is not None``) each source's
    piece is quantized **once** here — the wire format — and its scale
    planes ride every path below exactly like ``T``/``R``;
    ``quant_block`` must match the line's build-time block size.

    ``faults`` (a ``repro.core.transport.TransportFaults`` slice for
    this epoch, on a line built with ``transport=True``) routes the
    send through the faulted one-hot path: dropped edges select the
    scratch plane (a hole — never delivered), jitter/retransmit
    backoff adds to the edge delay, a duplicate re-arms a second
    arrival slot one epoch later (the same payload twice; colliding
    with the *next* epoch's send to that slot is last-write-wins), and
    corrupted edges get their payload garbled **after** the checksum
    plane is stamped, so ``sparse_deliver`` quarantines them. The
    self-loop edge (an agent's own piece, a local queue) is exempt
    from every fault. Quantized lines checksum + corrupt the int8
    wire payload; scales ride clean (the checksum covers them).
    """
    n, k, planes = flight.T.shape
    scales = None
    if flight.scale is not None:
        if quant_block <= 0:
            raise ValueError("quantized delay line: sparse_send needs "
                             "its quant_block")
        from repro.kernels.ddal_wavg import ops as wavg_ops
        pieces, scales = wavg_ops.quantize_tree(pieces, quant_block,
                                                lead=1)
    D1 = planes - 1                    # last plane = disabled scratch
    src = topo.nbr                                   # (n, k)
    en = jnp.asarray(enabled)
    gate = en & topo.mask                            # (n, k)
    if alive is not None:
        a = jnp.asarray(alive, bool)
        gate = gate & a[src] & a[:, None]            # src AND dst alive

    if flight.chk is not None and faults is None:
        raise ValueError(
            "transport delay line (checksum planes allocated): "
            "sparse_send needs this epoch's TransportFaults slice")
    if faults is not None:
        if flight.chk is None:
            raise ValueError(
                "sparse_send got TransportFaults but the delay line "
                "has no checksum planes — build it with "
                "make_sparse_inflight(..., transport=True)")
        from repro.core import transport as _tp
        self_edge = src == jnp.arange(n)[:, None]            # (n, k)
        live = gate & (self_edge | ~faults.drop)
        delay = topo.delay + jnp.where(self_edge, 0, faults.extra)
        slot = jnp.where(live, (epoch + delay) % D1, D1)
        hot = (jnp.arange(planes)[None, None, :]
               == slot[:, :, None])                  # (n, k, D+2)
        dup_gate = live & faults.dup & ~self_edge
        slot2 = jnp.where(dup_gate, (epoch + delay + 1) % D1, D1)
        hot2 = (jnp.arange(planes)[None, None, :]
                == slot2[:, :, None])
        hot_w = hot | hot2          # same payload at both arrivals
        g_pieces = tree_map(lambda b, x: x[src].astype(b.dtype),
                            flight.grads, pieces)    # (n, k, ...)
        g_scales = (None if scales is None else
                    tree_map(lambda b, x: x[src].astype(b.dtype),
                             flight.scale, scales))
        chk_val = _tp.plane_checksum(g_pieces, g_scales)     # (n, k)
        g_pieces = _tp.corrupt_planes(g_pieces,
                                      faults.corrupt & ~self_edge)

        def put_g(buf, upd):
            mask = jnp.reshape(hot_w,
                               hot_w.shape + (1,) * (buf.ndim - 3))
            return jnp.where(mask, upd[:, :, None], buf)

        e32 = jnp.asarray(epoch, jnp.int32)
        return SparseInFlight(
            grads=tree_map(put_g, flight.grads, g_pieces),
            T=jnp.where(hot_w, T[src][:, :, None], flight.T),
            R=jnp.where(hot_w, topo.relevance[:, :, None], flight.R),
            valid=jnp.where(hot_w, True, flight.valid),
            scale=(None if g_scales is None else
                   tree_map(put_g, flight.scale, g_scales)),
            chk=jnp.where(hot_w, chk_val[:, :, None], flight.chk),
            born=(None if flight.born is None else
                  jnp.where(hot_w, e32, flight.born)),
        )

    uniform_delay = False
    concrete = not (isinstance(topo.delay, jax.core.Tracer)
                    or isinstance(topo.mask, jax.core.Tracer))
    if concrete:
        d_np = np.asarray(topo.delay)
        uniform_delay = bool(d_np.size) and bool(
            (d_np == d_np.flat[0]).all())

    if uniform_delay:
        # uniform-delay fast path: every edge targets the same delay
        # plane, so only that (n, k, 1, ...) slice is touched instead
        # of a one-hot select over the whole flight.
        base = (epoch + int(d_np.flat[0])) % D1      # traced scalar

        if alive is None and bool(np.asarray(topo.mask).all()):
            # no padded edges: route the whole plane write to the
            # scratch slot when disabled — a blind write, no
            # read-modify-write of the live plane and no lax.cond
            # (which would copy the multi-MB flight through the
            # branch).
            slot = jnp.where(en, base, D1)

            def wr(buf, upd):
                return jax.lax.dynamic_update_slice_in_dim(
                    buf, upd.astype(buf.dtype), slot, axis=2)

            return SparseInFlight(
                grads=tree_map(
                    lambda b, x: wr(b, x[src][:, :, None]),
                    flight.grads, pieces),
                T=wr(flight.T, T[src][:, :, None]),
                R=wr(flight.R, topo.relevance[:, :, None]),
                valid=wr(flight.valid, jnp.ones((n, k, 1), bool)),
                scale=None if scales is None else tree_map(
                    lambda b, x: wr(b, x[src][:, :, None]),
                    flight.scale, scales),
                born=None if flight.born is None else wr(
                    flight.born, jnp.broadcast_to(
                        jnp.asarray(epoch, jnp.int32), (n, k, 1))),
            )

        # padded edges: gate per-edge with a plane read-select
        def wr(buf, upd):
            old = jax.lax.dynamic_slice_in_dim(buf, base, 1, axis=2)
            g = jnp.reshape(gate[:, :, None],
                            gate.shape + (1,) * (buf.ndim - 2))
            new = jnp.where(g, upd.astype(buf.dtype), old)
            return jax.lax.dynamic_update_slice_in_dim(buf, new, base,
                                                       axis=2)

        return SparseInFlight(
            grads=tree_map(lambda b, x: wr(b, x[src][:, :, None]),
                           flight.grads, pieces),
            T=wr(flight.T, T[src][:, :, None]),
            R=wr(flight.R, topo.relevance[:, :, None]),
            valid=wr(flight.valid, jnp.ones((n, k, 1), bool)),
            scale=None if scales is None else tree_map(
                lambda b, x: wr(b, x[src][:, :, None]),
                flight.scale, scales),
            born=None if flight.born is None else wr(
                flight.born, jnp.broadcast_to(
                    jnp.asarray(epoch, jnp.int32), (n, k, 1))),
        )

    # heterogeneous delays: fold the enable gate AND the topology mask
    # into the delay-slot one-hot — disabled / masked-out edges select
    # the scratch plane, so live slots never see their writes. The
    # write is a masked select, not a scatter — it fuses and
    # vectorises.
    slot = jnp.where(gate, (epoch + topo.delay) % D1, D1)    # (n, k)
    hot = (jnp.arange(planes)[None, None, :]
           == slot[:, :, None])                    # (n, k, D+2)

    def put(buf, xs):
        # buf: (n, k, D1, ...); xs: (n, ...) — gather along the table
        upd = xs[src].astype(buf.dtype)[:, :, None]  # (n, k, 1, ...)
        mask = jnp.reshape(hot, hot.shape + (1,) * (buf.ndim - 3))
        return jnp.where(mask, upd, buf)

    grads = tree_map(lambda b, x: put(b, x), flight.grads, pieces)
    new_T = jnp.where(hot, T[src][:, :, None], flight.T)
    new_R = jnp.where(hot, topo.relevance[:, :, None], flight.R)
    new_valid = jnp.where(hot, True, flight.valid)
    new_scale = (None if scales is None else
                 tree_map(lambda b, x: put(b, x), flight.scale,
                          scales))
    new_born = (None if flight.born is None else
                jnp.where(hot, jnp.asarray(epoch, jnp.int32),
                          flight.born))
    return SparseInFlight(grads=grads, T=new_T, R=new_R,
                          valid=new_valid, scale=new_scale,
                          born=new_born)


def _regular_exchange(topo: "Topology | None", m: int, k: int) -> bool:
    """True when the topology makes every delivery a full, aligned
    k-block: all edges real (no padding mask), one shared delay, and
    the ring capacity an exact multiple of k. All trace-time facts."""
    if topo is None or k > m or m % k != 0:
        return False
    if isinstance(topo.mask, jax.core.Tracer) or \
            isinstance(topo.delay, jax.core.Tracer):
        return False
    mask = np.asarray(topo.mask)
    d = np.asarray(topo.delay)
    return bool(mask.all()) and bool((d == d.flat[0]).all())


def sparse_deliver(flight: SparseInFlight, stores: KnowledgeStore,
                   epoch, topo: "Topology | None" = None,
                   alive=None
                   ) -> Tuple[SparseInFlight, KnowledgeStore]:
    """Pop epoch's arrival slot for every destination and append the
    valid pieces (k per destination) into the vmapped stores.

    When ``topo`` is given and statically regular (full mask, uniform
    delay, m % k == 0 — see ``_regular_exchange``), every delivery is
    a full aligned k-block: it is written with one contiguous
    ``dynamic_update_slice`` over the batched stores — O(n·k·|param|)
    bytes instead of the masked O(n·m·|param|) pass, with no runtime
    conditional (a ``lax.cond`` here would copy the whole store
    through the branch). Disabled epochs (warm-up) write the same
    k slots with ``valid=False`` payloads and hold ``ptr``, which is
    unobservable through eq. 4 and leaves sharing-phase contents
    bit-identical to the sequential ring semantics — assuming DDAL's
    monotone warm-up → sharing schedule (an empty delivery *after*
    valid ones would stomp k live slots; pass ``topo=None`` to force
    the exact general path under arbitrary gating). The general path
    handles partial / masked deliveries.

    ``alive`` ((n,) bool, optional — elastic membership) drops every
    arrival at a dead destination (defense in depth: the send gate
    plus ``DDAL.kill``'s delay-line scrub already keep such planes
    out of flight). On a regular exchange the aligned k-block write
    is kept — death turns a src's slot into an invalid *hole* (zero
    eq. 4 weight) rather than compacting it away, so the alive mask
    costs O(n·k) bool ops instead of the general path's O(n·m·|param|)
    pass; only the block-advance bit changes (``Vm.any()`` — blocks
    may now be partial per destination, but every destination still
    advances in lockstep each sharing epoch). Consequence: the
    survivor-restriction bitwise oracle on regular configs is the
    same-shape dead-from-birth run (hole patterns match), and a
    revived agent's restored ring forgets up to k slots per epoch
    while its first fresh planes ride the delay line. Irregular
    exchanges take the general compacting path as always.

    On a transport delay line (checksum planes allocated) every
    arrival is integrity-checked: the payload checksum is recomputed
    over the popped slice and compared against the value stamped at
    send. A mismatch — in-flight corruption — **quarantines** the
    piece: its payload (and scales) are zeroed and it is delivered
    invalid, so it carries exactly zero eq. 4 weight through every
    combiner path. Checked deliveries can be partial per destination,
    so the aligned k-block fast path is off (the general compacting
    path runs); staleness-only lines (``born`` without ``chk``) keep
    both paths, with the born epochs riding alongside T/R.
    """
    n, k, planes = flight.T.shape
    D1 = planes - 1                    # last plane = disabled scratch
    slot = epoch % D1
    pieces = tree_map(lambda b: b[:, :, slot], flight.grads)  # (n,k,..)
    Tm = flight.T[:, :, slot]
    Rm = flight.R[:, :, slot]
    Vm = flight.valid[:, :, slot]
    Sm = (None if flight.scale is None else
          tree_map(lambda b: b[:, :, slot], flight.scale))   # (n,k,nb)
    Bm = (None if flight.born is None else flight.born[:, :, slot])
    if alive is not None:
        Vm = Vm & jnp.asarray(alive, bool)[:, None]
    if flight.chk is not None:
        from repro.core import transport as _tp
        recomp = _tp.plane_checksum(pieces, Sm)              # (n, k)
        ok = _tp.checksum_ok(flight.chk[:, :, slot], recomp)
        Vm = Vm & ok

        def scrub(x):   # quarantine: zero the corrupted payload too
            o = jnp.reshape(ok, ok.shape + (1,) * (x.ndim - 2))
            return jnp.where(o, x, jnp.zeros((), x.dtype))

        pieces = tree_map(scrub, pieces)
        Sm = None if Sm is None else tree_map(scrub, Sm)
    m = stores.T.shape[1]

    if _regular_exchange(topo, m, k) and flight.chk is None:
        # all-or-nothing delivery: Vm is uniformly True (sharing) or
        # False (warm-up); ptr stays k-aligned so the block never
        # wraps. Elastic runs write partial blocks (holes at dead
        # srcs' slots), so the advance bit is any-arrival, not
        # slot (0, 0) — identical bits when everyone is alive.
        start = stores.ptr[0] % m
        delivered = Vm[0, 0] if alive is None else Vm.any()

        def wr(buf, xs):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, xs.astype(buf.dtype), start, axis=1)

        new_stores = KnowledgeStore(
            grads=tree_map(wr, stores.grads, pieces),
            T=wr(stores.T, Tm),
            R=wr(stores.R, Rm),
            valid=wr(stores.valid, Vm),
            ptr=stores.ptr + k * delivered.astype(jnp.int32),
            scale=(None if Sm is None else
                   tree_map(wr, stores.scale, Sm)),
            born=None if Bm is None else wr(stores.born, Bm),
        )
    else:
        def pop(dst_store, dst_idx):
            return append_many(
                dst_store, tree_map(lambda x: x[dst_idx], pieces),
                Tm[dst_idx], Rm[dst_idx], Vm[dst_idx],
                scales=(None if Sm is None else
                        tree_map(lambda x: x[dst_idx], Sm)),
                borns=None if Bm is None else Bm[dst_idx])
        new_stores = jax.vmap(pop)(stores, jnp.arange(n))

    cleared = flight._replace(
        valid=flight.valid.at[:, :, slot].set(False))
    return cleared, new_stores


# ---------------------------------------------------------------------
# dense all-to-all delay line (reference / equivalence oracle)
# ---------------------------------------------------------------------
class InFlight(NamedTuple):
    """Delay-line simulating asynchronous delivery. Slot layout:
    (dst, delay_slot, src, *piece); a piece from src→dst sent at epoch
    t sits in slot (t + delay[src, dst]) % (D+1) until epoch
    t + delay[src, dst] pops it."""
    grads: Any            # leaves (n_dst, D+1, n_src, *param_shape)
    T: jnp.ndarray        # (n_dst, D+1, n_src)
    R: jnp.ndarray
    valid: jnp.ndarray    # bool


def make_inflight(params_like, n: int, max_delay: int) -> InFlight:
    D1 = max_delay + 1
    grads = tree_map(
        lambda x: jnp.zeros((n, D1, n) + x.shape, jnp.float32),
        params_like)
    z = jnp.zeros((n, D1, n), jnp.float32)
    return InFlight(grads=grads, T=z, R=z, valid=z.astype(bool))


def send(flight: InFlight, pieces, T, R, delay, epoch,
         enabled) -> InFlight:
    """Every agent broadcasts its piece to every destination.

    pieces: pytree leaves (n_src, ...); T: (n_src,); R: (n_src, n_dst)
    relevance of src's knowledge to dst; delay: (n_src, n_dst) int;
    enabled: scalar bool (sharing started).
    """
    n, D1 = flight.T.shape[0], flight.T.shape[1]
    slot = (epoch + delay) % D1                     # (n_src, n_dst)
    en = jnp.asarray(enabled)
    src = jnp.arange(n)[:, None] * jnp.ones((1, n), jnp.int32)
    dst = jnp.arange(n)[None, :] * jnp.ones((n, 1), jnp.int32)

    def put(buf, xs):
        # buf: (n_dst, D1, n_src, ...); xs: (n_src, ...)
        upd = jnp.broadcast_to(
            xs[:, None, ...], (n, n) + xs.shape[1:])  # (src, dst, ...)
        new = buf.at[dst.T, slot.T, src.T].set(
            jnp.swapaxes(upd, 0, 1).astype(buf.dtype))
        return jnp.where(jnp.reshape(en, (1,) * new.ndim), new, buf)

    grads = tree_map(lambda b, x: put(b, x), flight.grads, pieces)
    Tb = jnp.broadcast_to(T[:, None], (n, n))
    new_T = flight.T.at[dst.T, slot.T, src.T].set(Tb.T)
    new_R = flight.R.at[dst.T, slot.T, src.T].set(R.T)
    new_valid = flight.valid.at[dst.T, slot.T, src.T].set(True)
    pick = lambda new, old: jnp.where(  # noqa: E731
        jnp.reshape(en, (1,) * new.ndim), new, old)
    return InFlight(grads=grads, T=pick(new_T, flight.T),
                    R=pick(new_R, flight.R),
                    valid=pick(new_valid, flight.valid))


def deliver(flight: InFlight, stores: KnowledgeStore, epoch
            ) -> Tuple[InFlight, KnowledgeStore]:
    """Pop epoch's arrival slot for every destination and append the
    valid pieces into the (vmapped) knowledge stores."""
    n, D1 = flight.T.shape[0], flight.T.shape[1]
    slot = epoch % D1

    def pop(dst_store, dst_idx):
        pieces = tree_map(lambda b: b[dst_idx, slot], flight.grads)
        return append_many(
            dst_store, pieces,
            flight.T[dst_idx, slot], flight.R[dst_idx, slot],
            flight.valid[dst_idx, slot])

    new_stores = jax.vmap(pop)(stores, jnp.arange(n))
    cleared = InFlight(
        grads=flight.grads,  # stale slots overwritten by next send
        T=flight.T,
        R=flight.R,
        valid=flight.valid.at[:, slot, :].set(False),
    )
    return cleared, new_stores
