"""Pure-jnp oracle for the gradient-sketch projection kernel.

Materialises the full (P, d) sign matrix, so it is only for tests and
small leaves — the production paths (``ops.sketch_flat`` tiled XLA /
Pallas) regenerate signs block-by-block and never hold more than one
tile. All paths share ``kernel.sign_block``, so they agree on the
sign stream exactly; only fp accumulation order differs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.grad_sketch.kernel import sign_block


def sketch_flat(G: jnp.ndarray, seed, dim: int,
                offset: int = 0) -> jnp.ndarray:
    """G: (n, P), seed: () int → (n, d) fp32 one-shot projection."""
    p = G.shape[1]
    S = sign_block(seed, offset, p, dim)                   # (P, d)
    return jnp.dot(G.astype(jnp.float32), S,
                   preferred_element_type=jnp.float32)


def sketch_pytree(grads, seed, dim: int) -> jnp.ndarray:
    """Leaf-by-leaf oracle: offsets advance by true leaf size, so the
    result equals projecting the flat concatenation (``sketch_oracle``)
    up to fp summation order."""
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    acc = jnp.zeros((n, dim), jnp.float32)
    offset = 0
    for x in leaves:
        p = int(x.size) // n
        acc = acc + sketch_flat(jnp.reshape(x, (n, p)), seed, dim,
                                offset=offset)
        offset += p
    return acc


def sketch_oracle(grads, seed, dim: int) -> jnp.ndarray:
    """The dense reference the streaming pass must reproduce: flatten
    every agent's gradients into one (n, P) matrix (the exact HBM copy
    the streaming estimator exists to avoid) and project it in one
    matmul."""
    from repro.core.relevance import flatten_agents
    g = flatten_agents(grads)
    return sketch_flat(g, seed, dim)
