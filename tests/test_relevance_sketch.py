"""Sketched streaming relevance (ISSUE 4): the grad_sketch kernel vs
its jnp oracle, the streaming pytree pass vs the dense flatten
projection, (seed, round) determinism, the d → error contraction
property, the exact-path (sketch_dim = 0) equivalence oracle, and the
wavg-kernel interpret auto-selection regression."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.configs.base import GroupSpec
from repro.core import DDAL, relevance as REL
from repro.kernels.grad_sketch import ops as SK
from repro.kernels.grad_sketch import ref as SKref
from repro.kernels.grad_sketch.kernel import sign_block, sketch_flat


def _tree(n, seed=0, sizes=(37, 3200, 5000)):
    rng = np.random.default_rng(seed)
    return {f"l{i}": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
            for i, p in enumerate(sizes)}


# ----------------------------------------------------------------------
# kernel vs oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,p,d", [(8, 1024, 128), (3, 4097, 256),
                                   (8, 1000, 128), (16, 2048, 384)])
def test_sketch_kernel_matches_ref(n, p, d):
    """Pallas kernel (interpret) ≡ one-shot jnp projection: same sign
    stream, only tile-accumulation order differs."""
    G = jnp.asarray(np.random.default_rng(n * p).normal(size=(n, p)),
                    jnp.float32)
    got = sketch_flat(G, jnp.int32(7), d, offset=11, interpret=True)
    want = SKref.sketch_flat(G, jnp.int32(7), d, offset=11)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_sketch_xla_path_matches_ref():
    """Tiled XLA fallback walks blocks of the position axis but
    reproduces the one-shot projection (same positional signs)."""
    G = jnp.asarray(np.random.default_rng(0).normal(size=(4, 9000)),
                    jnp.float32)
    got = SK._xla_sketch_flat(G, jnp.int32(3), 192, offset=5,
                              block=1024)
    want = SKref.sketch_flat(G, jnp.int32(3), 192, offset=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_sign_block_positional_and_balanced():
    """Signs are a pure function of (seed, position, dim): tiling the
    position axis changes nothing, and the stream is ±1-balanced."""
    whole = np.asarray(sign_block(jnp.int32(5), 0, 4096, 64))
    lo = np.asarray(sign_block(jnp.int32(5), 0, 1000, 64))
    hi = np.asarray(sign_block(jnp.int32(5), 1000, 3096, 64))
    np.testing.assert_array_equal(whole, np.concatenate([lo, hi]))
    assert set(np.unique(whole).tolist()) == {-1.0, 1.0}
    assert abs(whole.mean()) < 0.02


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_sketch_pytree_equals_flatten_projection(impl):
    """The streaming leaf-by-leaf pass ≡ projecting the (n, P) concat
    (which it exists to avoid): offsets advance by true leaf size."""
    tree = _tree(6)
    got = SK.sketch_pytree(tree, jnp.int32(1), 256, impl=impl)
    want = SKref.sketch_oracle(tree, jnp.int32(1), 256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_sketch_linear_in_gradients():
    """sketch(a + b) == sketch(a) + sketch(b) for a shared seed — the
    property that lets the streaming trainer carry a window sketch
    instead of re-projecting its accumulators."""
    a, b = _tree(4, seed=1), _tree(4, seed=2)
    seed = jnp.int32(9)
    s_sum = SK.sketch_pytree(jax.tree.map(jnp.add, a, b), seed, 128)
    s_ab = (SK.sketch_pytree(a, seed, 128)
            + SK.sketch_pytree(b, seed, 128))
    np.testing.assert_allclose(np.asarray(s_sum), np.asarray(s_ab),
                               rtol=1e-4, atol=1e-3)


# ----------------------------------------------------------------------
# determinism + error contraction
# ----------------------------------------------------------------------
def test_sketch_deterministic_in_seed_and_round():
    tree = _tree(5)
    s1 = REL.sketch_cosine(tree, 128, REL.fold_seed(3, 7))
    s2 = REL.sketch_cosine(tree, 128, REL.fold_seed(3, 7))
    s3 = REL.sketch_cosine(tree, 128, REL.fold_seed(3, 8))
    s4 = REL.sketch_cosine(tree, 128, REL.fold_seed(4, 7))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert (np.asarray(s1) != np.asarray(s3)).any()
    assert (np.asarray(s1) != np.asarray(s4)).any()


@given(st.integers(0, 2 ** 31 - 1))
def test_sketch_error_shrinks_with_dim(seed):
    """Mean |sketched − exact| cosine error contracts ~1/√d: a 64×
    dim gap leaves an 8× expected-error gap, far beyond fluctuation."""
    tree = _tree(8, seed=seed % 1000, sizes=(600, 900))
    exact = np.asarray(REL.grad_cosine(tree))
    off = ~np.eye(8, dtype=bool)

    def mean_err(d):
        sk = np.asarray(REL.sketch_cosine(
            tree, d, REL.fold_seed(seed, 0)))
        return np.abs(sk - exact)[off].mean()

    assert mean_err(512) < mean_err(8)


def test_sketch_cosine_contract():
    """Same contract as grad_cosine: unit diagonal, [-1, 1], and a
    zero gradient row reads as cosine 0 against everyone."""
    tree = {"w": jnp.asarray(
        np.concatenate([np.random.default_rng(0).normal(size=(3, 4096)),
                        np.zeros((1, 4096))]), jnp.float32)}
    c = np.asarray(REL.sketch_cosine(tree, 256, jnp.int32(0)))
    np.testing.assert_allclose(np.diag(c), 1.0)
    assert (c >= -1.0).all() and (c <= 1.0).all()
    np.testing.assert_allclose(c[3, :3], 0.0, atol=1e-6)


# ----------------------------------------------------------------------
# exact path (sketch_dim = 0) equivalence oracle
# ----------------------------------------------------------------------
# the seed's exact estimator — (n, P) flatten concat + one normalised
# Gram, the memory spike the per-leaf path fixes; single shared
# definition with the benchmark's bitwise gate
_pre_pr_grad_cosine = REL.flatten_cosine


def test_exact_path_bitwise_on_single_leaf():
    """Single-leaf pytrees run the identical contraction as the
    pre-PR flatten estimator — bitwise, including through the
    update_relevance dispatch with sketch_dim=0."""
    tree = {"w": jnp.asarray(
        np.random.default_rng(3).normal(size=(6, 20000)), jnp.float32)}
    np.testing.assert_array_equal(
        np.asarray(REL.grad_cosine(tree)),
        np.asarray(_pre_pr_grad_cosine(tree)))
    rel0 = REL.init_relevance(6)
    got = REL.update_relevance(rel0, tree, "grad_cos", 0.7,
                               sketch_dim=0)
    want = REL.ema_update(
        rel0, REL.to_relevance(_pre_pr_grad_cosine(tree)), 0.7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_exact_path_matches_flatten_oracle_multi_leaf():
    """Multi-leaf trees only reassociate the Σ over leaves — the
    per-leaf streaming Gram stays within ulps of the flatten oracle
    and never builds the (n, P) concat (pinned by the benchmark's
    jaxpr peak-intermediate gate)."""
    tree = _tree(7, seed=11)
    np.testing.assert_allclose(
        np.asarray(REL.grad_cosine(tree)),
        np.asarray(_pre_pr_grad_cosine(tree)), rtol=1e-6, atol=1e-6)


def test_update_relevance_sketch_dispatch():
    """sketch_dim > 0 routes through the sketched estimator (close to
    but distinct from the exact path); uniform stays the identity."""
    tree = _tree(4, seed=5)
    rel0 = REL.init_relevance(4)
    exact = REL.update_relevance(rel0, tree, "grad_cos", 0.0)
    sk = REL.update_relevance(rel0, tree, "grad_cos", 0.0,
                              sketch_dim=1024, seed=1, rnd=2)
    assert (np.asarray(sk) != np.asarray(exact)).any()
    np.testing.assert_allclose(np.asarray(sk), np.asarray(exact),
                               atol=0.2)
    out = REL.update_relevance(rel0, tree, "uniform", 0.5,
                               sketch_dim=64)
    assert out is rel0


def test_relevance_exchange_bytes_accounting():
    """Sketched relevance moves (A, d) rows across the mesh; the
    exact Gram moves the (A, P) accumulator rows — flat in |params|
    only for the sketch."""
    from repro.core.pod_dispatch import relevance_exchange_bytes
    assert relevance_exchange_bytes(8, 10**6, 0) == 8 * 10**6 * 4
    assert relevance_exchange_bytes(8, 10**6, 256) == 8 * 256 * 4
    assert (relevance_exchange_bytes(8, 10**6, 256)
            == relevance_exchange_bytes(8, 10**9, 256))


def test_group_spec_sketch_validation():
    with pytest.raises(ValueError, match="relevance_sketch_dim"):
        GroupSpec(n_agents=4, relevance_mode="grad_cos",
                  relevance_sketch_dim=-1)
    with pytest.raises(ValueError, match="grad_cos"):
        GroupSpec(n_agents=4, relevance_mode="uniform",
                  relevance_sketch_dim=64)
    spec = GroupSpec(n_agents=4, relevance_mode="grad_cos",
                     relevance_sketch_dim=256)
    assert spec.relevance_sketch_dim == 256


# ----------------------------------------------------------------------
# integration: sketched relevance reaches eq. 4 in both trainers
# ----------------------------------------------------------------------
def test_ddal_sketch_separates_aligned_from_opposed():
    """The ring-buffer DDAL loop with sketched relevance learns the
    same aligned ≫ opposed split as the exact estimator (the sketch
    dim is large enough that the decision survives the noise)."""
    n = 4
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=8, relevance_mode="grad_cos",
                     relevance_ema=0.5, relevance_sketch_dim=512)

    def gen(state, key):
        del key
        return ({"w": state["sign"] * jnp.ones_like(state["w"])},
                {}, state)

    ddal = DDAL(spec, gen, lambda s, g: s, lambda s: {"w": s["w"]})
    gs = ddal.init({"w": jnp.zeros((n, 4096)),
                    "sign": jnp.asarray([1.0, 1.0, -1.0, -1.0]
                                        )[:, None]})
    step = jax.jit(ddal.epoch_step)
    for e in range(6):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
    rel = np.asarray(gs.relevance)
    assert rel[0, 1] > 0.8
    assert rel[0, 2] < 0.3


def test_streaming_sketch_carry_and_reset():
    """The streaming trainer carries the (A, d) window sketch: it is
    the sketch of the rg accumulator at share time (linearity, fp32
    knowledge dtype), it resets with the window, and the learned rel
    moves off the prior."""
    from repro import optim
    from repro.core.sharded_ddal import (
        TrainState,
        init_knowledge,
        make_group_train_step,
    )

    n, d, mb = 4, 128, 3
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=mb,
                     relevance_mode="grad_cos", relevance_ema=0.5,
                     relevance_sketch_dim=d,
                     knowledge_mode="streaming")

    def loss_fn(params, batch):
        return jnp.sum((params["w"] - batch["t"]) ** 2)

    opt = optim.sgd(0.05)
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (n, 64))}
    # build the state by hand (toy loss needs no ArchConfig)
    state = TrainState(
        params=params,
        opt_state=jax.vmap(opt.init)(params),
        know=init_knowledge(params, rel=REL.init_relevance(n),
                            sketch_dim=d),
        step=jnp.zeros((), jnp.int32))
    assert state.know.sk.shape == (n, d)
    np.testing.assert_array_equal(np.asarray(state.know.sk), 0.0)

    step_fn = jax.jit(make_group_train_step(None, spec, opt,
                                            loss_fn=loss_fn))
    batch = {"t": jnp.asarray(np.random.default_rng(0).normal(
        size=(n, 64)), jnp.float32)}
    # step 0 shares immediately (threshold 0, 0 % mb == 0) and resets;
    # steps 1..mb-1 then accumulate — sk must equal sketch(rg)
    st = state
    for _ in range(mb):
        st, m = step_fn(st, batch)
    seed_r = REL.fold_seed(spec.topology_seed,
                           (st.step - 1 + mb) // mb)
    want = SK.sketch_pytree(st.know.rg, seed_r, d)
    np.testing.assert_allclose(np.asarray(st.know.sk),
                               np.asarray(want), rtol=1e-4, atol=1e-3)
    assert float(jnp.abs(st.know.sk).max()) > 0
    # the share step consumes the sketch and resets the window
    st2, m = step_fn(st, batch)
    assert int(m["shared"]) == 1
    np.testing.assert_array_equal(np.asarray(st2.know.sk), 0.0)
    rel = np.asarray(st2.know.rel)
    assert not np.allclose(rel, 1.0)
    assert (rel > 0).all() and (rel <= 1.0 + 1e-6).all()


# ----------------------------------------------------------------------
# satellite: wavg kernel interpret auto-selection
# ----------------------------------------------------------------------
def test_weighted_average_kernel_auto_interpret():
    """use_kernel=True must run on CPU rigs without hardcoding
    interpret=True at the call site: the wrapper auto-selects
    interpret off-TPU, and the result matches the jnp path."""
    from repro.core import knowledge as K
    from repro.kernels.ddal_wavg.ops import resolve_interpret

    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False

    params = {"w": jnp.zeros((9000,), jnp.float32)}
    store = K.make_store(params, m=4)
    for j in range(4):
        piece = {"w": jnp.full((9000,), float(j + 1))}
        store = K.append(store, piece, T=float(j + 1), R=1.0)
    g_kernel, w_kernel = jax.jit(
        lambda s: K.weighted_average(s, use_kernel=True))(store)
    g_ref, w_ref = K.weighted_average(store, use_kernel=False)
    np.testing.assert_allclose(np.asarray(g_kernel["w"]),
                               np.asarray(g_ref["w"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(w_kernel), float(w_ref))


def test_tree_wavg_small_leaf_fallback_compiles_uninterpreted():
    """Leaves below one kernel tile take the jnp fallback, which must
    compile on CPU even with interpret=False (no Pallas involved) —
    the regression the hardcoded interpret=True was masking."""
    from repro.kernels.ddal_wavg import ops as wavg_ops
    from repro.kernels.ddal_wavg import ref as wavg_ref

    tree = {"a": jnp.ones((3, 17, 4)), "b": jnp.ones((3, 100))}
    w = jnp.asarray([0.2, 0.3, 0.5])
    got = jax.jit(
        lambda t, ww: wavg_ops.tree_wavg(t, ww, interpret=False))(
        tree, w)
    want = wavg_ref.tree_wavg(tree, w)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6), got, want)
