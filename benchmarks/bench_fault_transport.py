"""Fault-transport gates: graceful degradation + fault-free overhead.

Runs the buffer trainer's toy quadratic group (homogeneous target, so
the learning curve is just the distance to the shared optimum) under
three transports and gates the ISSUE 9 acceptance bounds:

1. **Structural identity** — the default spec and an explicit
   ``exchange_transport="none"`` trace the *same jaxpr*: the
   fault-free program is the pre-transport program, bit for bit, so
   its overhead is structurally zero. Epoch times are measured
   interleaved and reported; the ≤ 2% wall-clock bound is the
   backstop gate that fires only if the jaxpr identity is ever lost.
2. **Zero-rate faulty is value-transparent** — forcing ``"faulty"``
   with every rate zero allocates checksum/born planes but delivers
   bitwise the default params (overhead reported, not gated: the toy
   exchange is deliberately tiny, so the checksum's relative cost is
   a worst case, not a regression signal).
3. **Graceful degradation** — under 20% loss + 5% corruption (with
   retransmit budget 2, jitter 1, staleness cutoff 8) the group still
   learns: curve AUC ≤ 2× the fault-free AUC, final error ≤
   max(4× fault-free, 1e-5), every trajectory finite.

Rows land in ``BENCH_fault_transport.json`` (override ``--json``);
any violated gate exits non-zero, so CI's fault lane fails loudly.

    PYTHONPATH=src python benchmarks/bench_fault_transport.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GroupSpec
from repro.core import DDAL


def _default_json() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_fault_transport.json")


def write_json(path: str, rows: list) -> None:
    payload = {"bench": "fault_transport",
               "backend": jax.default_backend(), "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"wrote {path}")


def _time_min(thunk, epochs: int, repeats: int = 5) -> float:
    """Best-of-``repeats`` per-epoch wall time in ms."""
    jax.block_until_ready(thunk())             # compile + warm-up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(thunk())
        best = min(best, time.time() - t0)
    return best / epochs * 1e3


def _time_pair(ta, tb, epochs: int, repeats: int = 11
               ) -> tuple[float, float]:
    """Interleaved best-of timing of two thunks (A B A B …), so both
    see the same thermal/scheduler window — the only way a 2% gate on
    jaxpr-identical programs is noise-free."""
    jax.block_until_ready(ta())
    jax.block_until_ready(tb())
    best_a = best_b = float("inf")
    for r in range(repeats):
        # alternate pair order so neither thunk always runs cold/hot
        for which in ((0, 1) if r % 2 == 0 else (1, 0)):
            t0 = time.time()
            jax.block_until_ready((ta if which == 0 else tb)())
            dt = time.time() - t0
            if which == 0:
                best_a = min(best_a, dt)
            else:
                best_b = min(best_b, dt)
    return best_a / epochs * 1e3, best_b / epochs * 1e3


TARGET = 1.0   # homogeneous: eq. 4 averaging cannot move the optimum


def make_group(spec: GroupSpec, n_params: int):
    def gen(state, key):
        del key
        return {"w": state["w"] - state["t"]}, {}, state

    def app(state, g):
        return {"w": state["w"] - 0.2 * g["w"], "t": state["t"]}

    ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]})
    n = spec.n_agents
    gs = ddal.init({
        "w": jnp.zeros((n, n_params), jnp.float32),
        "t": jnp.full((n, n_params), TARGET, jnp.float32),
    })
    return ddal, gs


def learning_curve(spec: GroupSpec, n_params: int, epochs: int
                   ) -> tuple[np.ndarray, "jax.Array"]:
    """Per-epoch mean |w − target| plus the final params."""
    ddal, gs = make_group(spec, n_params)
    step = jax.jit(ddal.epoch_step)
    n = spec.n_agents
    errs = []
    for e in range(epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
        errs.append(float(jnp.mean(jnp.abs(
            gs.agent_states["w"] - TARGET))))
    return np.asarray(errs), gs.agent_states["w"]


def epoch_thunk(spec: GroupSpec, n_params: int, epochs: int):
    ddal, gs0 = make_group(spec, n_params)
    n = spec.n_agents
    keys = jnp.stack([jax.random.split(jax.random.PRNGKey(e), n)
                      for e in range(epochs)])

    @jax.jit
    def run(gs):
        def body(g, k):
            g, _ = ddal.epoch_step(g, k)
            return g, ()
        return jax.lax.scan(body, gs, keys)[0]

    return ddal, (lambda: run(gs0).agent_states["w"])


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI budget: small group, short curves")
    p.add_argument("--json", default=None)
    args = p.parse_args(argv)

    n, n_params, epochs = (8, 256, 30) if args.smoke else (16, 2048, 60)
    # timing needs a workload well above timer resolution even when
    # the learning curves stay CI-cheap
    t_params, t_epochs = (4096, 500) if args.smoke else (8192, 1000)
    base_kw = dict(n_agents=n, threshold=1, minibatch=2, m_pieces=16,
                   max_delay=1)
    spec_default = GroupSpec(**base_kw)
    spec_none = GroupSpec(**base_kw, exchange_transport="none")
    spec_zero = GroupSpec(**base_kw, exchange_transport="faulty")
    spec_faulty = GroupSpec(**base_kw, transport_loss=0.2,
                            transport_corrupt=0.05,
                            transport_retransmit=2,
                            transport_jitter=1, max_staleness=8,
                            transport_decay=0.95, transport_seed=0)

    failures = []
    rows = []

    # -- gate 1: fault-free structural identity + ≤ 2% overhead -------
    dd, gd = make_group(spec_default, n_params)
    dn, gn = make_group(spec_none, n_params)
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    same_jaxpr = (str(jax.make_jaxpr(dd.epoch_step)(gd, keys))
                  == str(jax.make_jaxpr(dn.epoch_step)(gn, keys)))
    if not same_jaxpr:
        failures.append("fault-free program is no longer structurally "
                        "identical to exchange_transport='none'")
    _, t_default = epoch_thunk(spec_default, t_params, t_epochs)
    _, t_none = epoch_thunk(spec_none, t_params, t_epochs)
    ms_default, ms_none = _time_pair(t_default, t_none, t_epochs)
    overhead = ms_default / ms_none - 1.0
    # while the two programs are jaxpr-identical the true overhead is
    # structurally zero and any measured delta is scheduler noise; the
    # timed 2% bound is the backstop that fires the day the identity
    # above is relaxed and a real fault-free cost could creep in
    if not same_jaxpr and abs(overhead) > 0.02:
        failures.append(
            f"fault-free transport overhead {overhead:+.2%} exceeds "
            f"2% (default {ms_default:.3f} ms vs none "
            f"{ms_none:.3f} ms)")
    rows.append({"row": "structural", "same_jaxpr": same_jaxpr,
                 "ms_default": ms_default, "ms_none": ms_none,
                 "overhead": overhead})
    print(f"[structural] same_jaxpr={same_jaxpr} "
          f"default={ms_default:.3f}ms none={ms_none:.3f}ms "
          f"overhead={overhead:+.2%}")

    # -- gate 2: zero-rate 'faulty' delivers bitwise-default values ---
    curve_free, w_free = learning_curve(spec_default, n_params, epochs)
    curve_zero, w_zero = learning_curve(spec_zero, n_params, epochs)
    bitwise = bool((np.asarray(w_free) == np.asarray(w_zero)).all())
    if not bitwise:
        failures.append("zero-rate 'faulty' transport changed "
                        "delivered values (must be bitwise default)")
    _, t_zero = epoch_thunk(spec_zero, t_params, t_epochs)
    ms_zero = _time_min(t_zero, t_epochs)
    rows.append({"row": "zero_faulty", "bitwise_default": bitwise,
                 "ms": ms_zero,
                 "checksum_overhead": ms_zero / ms_none - 1.0})
    print(f"[zero_faulty] bitwise={bitwise} {ms_zero:.3f}ms "
          f"(checksum machinery {ms_zero / ms_none - 1.0:+.2%}, "
          f"informational)")

    # -- gate 3: survivors learn under 20% loss + 5% corruption -------
    curve_fault, w_fault = learning_curve(spec_faulty, n_params,
                                          epochs)
    finite = bool(np.isfinite(curve_fault).all()
                  and np.isfinite(np.asarray(w_fault)).all())
    auc_free, auc_fault = float(curve_free.sum()), float(
        curve_fault.sum())
    final_free, final_fault = float(curve_free[-1]), float(
        curve_fault[-1])
    auc_ok = auc_fault <= 2.0 * auc_free
    final_ok = final_fault <= max(4.0 * final_free, 1e-5)
    if not finite:
        failures.append("NaN/inf in the faulted run")
    if not auc_ok:
        failures.append(
            f"learning-curve AUC under faults {auc_fault:.4f} exceeds "
            f"2x the fault-free {auc_free:.4f}")
    if not final_ok:
        failures.append(
            f"final error under faults {final_fault:.2e} exceeds "
            f"max(4x fault-free {final_free:.2e}, 1e-5)")
    rows.append({"row": "loss20", "finite": finite,
                 "auc_free": auc_free, "auc_fault": auc_fault,
                 "final_free": final_free, "final_fault": final_fault,
                 "curve_free": curve_free.tolist(),
                 "curve_fault": curve_fault.tolist()})
    print(f"[loss20] finite={finite} auc {auc_fault:.4f} vs "
          f"{auc_free:.4f} (x{auc_fault / max(auc_free, 1e-12):.2f}) "
          f"final {final_fault:.2e} vs {final_free:.2e}")

    write_json(args.json or _default_json(), rows)
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        raise SystemExit("fault-transport gates FAILED")
    print("all fault-transport gates passed")


if __name__ == "__main__":
    main()
