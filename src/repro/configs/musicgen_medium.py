"""MusicGen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284]. Backbone only: the EnCodec audio codec and the T5
text encoder are stubbed (``input_specs`` supplies conditioning
embeddings), per the spec's audio/VLM carve-out. 4 codebooks with
summed embeddings and 4 parallel LM heads; cross-attention to the text
conditioning sequence in every layer."""
from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,           # MHA (kv = heads)
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        rope_mode="none",        # musicgen uses sinusoidal embeddings
        cross_attention=True,
        cond_len=64,             # stubbed T5 conditioning length
        n_codebooks=4,
        citation="arXiv:2306.05284",
    )
