"""Llama-3.2-3B — small llama3 dense GQA [hf:meta-llama/Llama-3.2-1B]."""
from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=5e5,
        tie_embeddings=True,
        citation="hf:meta-llama/Llama-3.2-1B",
    )
