from repro.kernels.ssd_scan import ops, ref  # noqa: F401
from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_bchl  # noqa: F401
