"""DDAL weighting — paper eq. 4.

    ḡ = ½ ( Σ_j T_j/ΣT · g_j  +  Σ_j R_j/ΣR · g_j )

so each piece's effective weight is w_j = ½(T_j/ΣT + R_j/ΣR): a convex
combination of the two normalised weightings. T_j quantifies the
*training experience* of the source when the piece was generated
(paper: number of training epochs); R_j its *relevance* to the
destination (paper §6 sets it uniform for homogeneous groups).
"""
from __future__ import annotations

import jax.numpy as jnp


def eq4_weights(T, R, valid=None, eps: float = 1e-12):
    """Effective per-piece weights w_j = ½(T̂_j + R̂_j).

    T, R: (m,) float arrays; valid: optional (m,) bool mask for ring
    buffers that are not yet full. Invalid pieces get weight 0 and are
    excluded from both normalisations. Returns (m,) weights that sum to
    1 over valid pieces (to 0 if none are valid).
    """
    T = jnp.asarray(T, jnp.float32)
    R = jnp.asarray(R, jnp.float32)
    if valid is not None:
        v = valid.astype(jnp.float32)
        T = T * v
        R = R * v
    t_hat = T / jnp.maximum(jnp.sum(T), eps)
    r_hat = R / jnp.maximum(jnp.sum(R), eps)
    return 0.5 * (t_hat + r_hat)


def training_experience(epoch, mode: str = "epochs"):
    """T_j for a piece generated at ``epoch`` (paper: proportional to
    the number of training epochs so far)."""
    e = jnp.asarray(epoch, jnp.float32)
    if mode == "epochs":
        return jnp.maximum(e, 1.0)
    if mode == "sqrt":
        return jnp.sqrt(jnp.maximum(e, 1.0))
    if mode == "uniform":
        return jnp.ones_like(e)
    raise ValueError(f"unknown T mode {mode!r}")


def combine_relevance(prior, learned):
    """Effective relevance = static prior × learned online estimate,
    elementwise. The prior encodes what is wired (topology support,
    user-supplied R, e.g. ``repro.core.relevance.obs_overlap``); the
    learned factor comes from the exchange protocol's relevance
    estimator (``repro.core.exchange.estimators``, dense matrix via
    ``estimator.matrix(state)``) and adapts it. With the ``uniform``
    estimator the protocol skips this product entirely
    (``ExchangeProtocol.apply_relevance`` is the identity), so the
    static eq. 4 weights are not just numerically but *structurally*
    unchanged — the equivalence oracle the tests pin."""
    return prior * learned


def relevance_matrix(n: int, mode: str = "uniform",
                     adjacency=None) -> jnp.ndarray:
    """R[j, i] = relevance of agent j's knowledge to agent i. The group
    topology is expressed as a mask on R (DESIGN.md §3): a zero entry
    means j's knowledge never reaches i."""
    R = jnp.ones((n, n), jnp.float32)
    if mode == "uniform":
        pass
    elif mode == "ring":
        idx = jnp.arange(n)
        adj = (jnp.abs(idx[:, None] - idx[None, :]) % (n - 1 if n > 1 else 1)
               <= 1) if n > 2 else jnp.ones((n, n), bool)
        ring = (jnp.minimum((idx[:, None] - idx[None, :]) % n,
                            (idx[None, :] - idx[:, None]) % n) <= 1)
        R = R * ring.astype(jnp.float32)
    elif mode == "custom":
        if adjacency is None:
            raise ValueError("custom relevance needs an adjacency matrix")
        R = jnp.asarray(adjacency, jnp.float32)
    else:
        raise ValueError(f"unknown relevance mode {mode!r}")
    return R
