"""String-keyed strategy registries for the exchange-protocol API.

Every strategy family (topology schedules, relevance estimators, delay
models, combiners) is a :class:`Registry`: a name → factory table with
per-strategy CLI parameter metadata. ``build_exchange`` (in
``repro.core.exchange.build``) resolves a ``GroupSpec`` against these
tables; ``repro.launch.train`` derives its ``--exchange key=value``
vocabulary from the same metadata, so registering a new strategy never
requires new argparse plumbing.

Unknown keys fail with the full list of valid choices — the registry
is the single place that knows what exists, so the error message can
always name the alternatives.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, Optional, Tuple


class Registry:
    """Name → factory table for one strategy family.

    ``params`` metadata attached at registration maps a CLI parameter
    name to the ``GroupSpec`` field it sets (plus its type), which is
    what lets ``--exchange key=value`` cover new strategies for free.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._table: Dict[str, Callable] = {}
        self._params: Dict[str, Mapping[str, Tuple[str, type]]] = {}

    # ------------------------------------------------------------------
    def register(self, name: str,
                 params: Optional[Mapping[str, Tuple[str, type]]] = None):
        """Decorator: ``@REGISTRY.register("name", params={cli_key:
        (spec_field, type)})``."""
        def deco(factory):
            if name in self._table:
                raise ValueError(
                    f"duplicate {self.kind} strategy {name!r}")
            self._table[name] = factory
            self._params[name] = dict(params or {})
            return factory
        return deco

    # ------------------------------------------------------------------
    @property
    def choices(self) -> Tuple[str, ...]:
        return tuple(sorted(self._table))

    def __contains__(self, name: str) -> bool:
        return name in self._table

    def get(self, name: str) -> Callable:
        """The factory registered under ``name``; unknown keys raise a
        ``ValueError`` that names every valid choice."""
        try:
            return self._table[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} strategy {name!r}; expected one "
                f"of {self.choices}") from None

    def build(self, name: str, *args: Any, **kw: Any):
        return self.get(name)(*args, **kw)

    def cli_params(self) -> Dict[str, Tuple[str, type]]:
        """Union of every registered strategy's CLI parameters."""
        out: Dict[str, Tuple[str, type]] = {}
        for p in self._params.values():
            out.update(p)
        return out


SCHEDULES = Registry("topology schedule")
ESTIMATORS = Registry("relevance estimator")
DELAYS = Registry("delay model")
COMBINERS = Registry("combiner")
TRANSPORTS = Registry("transport fault model")

REGISTRIES: Dict[str, Registry] = {
    "schedule": SCHEDULES,
    "estimator": ESTIMATORS,
    "delay": DELAYS,
    "combiner": COMBINERS,
    "transport": TRANSPORTS,
}


def validate_choice(family: str, name: str) -> None:
    """Construction-time GroupSpec validation hook: ``"auto"`` or a
    registered key; anything else raises naming the valid choices."""
    if name == "auto":
        return
    reg = REGISTRIES[family]
    if name not in reg:
        raise ValueError(
            f"unknown {reg.kind} strategy {name!r}; expected 'auto' or "
            f"one of {reg.choices}")


def cli_options() -> Dict[str, Tuple[str, type]]:
    """The full ``--exchange key=value`` vocabulary: the four strategy
    selectors plus every registered strategy's declared parameters,
    each mapped to the ``GroupSpec`` field it sets."""
    opts: Dict[str, Tuple[str, type]] = {
        "schedule": ("exchange_schedule", str),
        "estimator": ("exchange_estimator", str),
        "delay": ("exchange_delay", str),
        "combiner": ("exchange_combiner", str),
        "transport": ("exchange_transport", str),
    }
    for reg in REGISTRIES.values():
        opts.update(reg.cli_params())
    return opts
