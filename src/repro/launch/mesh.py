"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips over ("data", "model").
    Multi-pod: 2×16×16 = 512 chips over ("pod", "data", "model") —
    one GARL agent per pod (DESIGN.md §3)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many (CPU) devices exist — tests only."""
    return jax.make_mesh(shape, axes)


def make_pod_mesh(n_pods: int, devices_per_pod: int = None,
                  pod_axis: str = "pod"):
    """Two-level ``(pod_axis, "agent")`` mesh for hierarchical DDAL
    dispatch: the ``"agent"`` axis is the fast intra-pod interconnect
    (ICI on a TPU pod), ``pod_axis`` the slow cross-pod one (DCN).
    Only pod leaders' knowledge planes ever cross ``pod_axis``
    (``repro.core.pod_dispatch``).

    On a single-host simulation rig the devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the same
    mesh the multi-device test lane uses."""
    n_dev = jax.device_count()
    if devices_per_pod is None:
        if n_pods < 1 or n_dev % n_pods:
            raise ValueError(
                f"{n_dev} devices do not split into {n_pods} pods — "
                f"pass devices_per_pod explicitly")
        devices_per_pod = n_dev // n_pods
    return jax.make_mesh((n_pods, devices_per_pod),
                         (pod_axis, "agent"))


def train_rules(mesh, pod_axis: str = "pod") -> dict:
    """Logical→physical sharding rules for training on ``mesh``.
    ``pod_axis`` must name the cross-pod axis when the mesh was built
    with a non-default name (``make_pod_mesh(..., pod_axis=...)``)."""
    has_pod = pod_axis in mesh.axis_names
    # two-level DDAL mesh: the agent axis spreads over pods × the
    # intra-pod agent axis (repro.core.pod_dispatch)
    if has_pod and "agent" in mesh.axis_names:
        agent = (pod_axis, "agent")
    else:
        agent = pod_axis if has_pod else None
    return {
        "agent": agent,
        "batch": "data",
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "qkv_fused": "model",
        "ff": "model",
        "experts": "model",
        "ssm_inner": "model",
        "kv_slots": None,        # training: no decode cache
    }


def serve_rules(mesh, global_batch: int) -> dict:
    """Serving has no agent axis; the batch spreads over every
    non-model axis when divisible (pod×data on the multi-pod mesh)."""
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    n = 1
    for a in batch_axes:
        n *= mesh.shape[a]
    batch = batch_axes if global_batch % n == 0 else None
    if batch is not None and len(batch) == 1:
        batch = batch[0]
    return {
        "agent": None,
        "batch": batch,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "qkv_fused": "model",
        "ff": "model",
        "experts": "model",
        "ssm_inner": "model",
        # decode caches shard their SLOT dim over "model" (32768 and
        # the 8192 sliding window both divide 16) — flash-decoding
        # style distributed KV sweep; kv-head counts (8, 4) don't
        # divide 16, so head-sharding would replicate (§Perf it.5)
        "kv_slots": "model",
    }
