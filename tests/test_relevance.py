"""Learned-relevance subsystem tests: the gradient-cosine estimator's
algebraic properties, the EMA schedule, the observation-overlap prior,
and end-to-end integration — agents with aligned gradients end up
weighting each other above agents with conflicting gradients, in both
the ring-buffer DDAL loop and the streaming trainer."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.configs.base import GroupSpec
from repro.core import DDAL, relevance as REL, topology as T
from repro.core.weighting import combine_relevance


# ----------------------------------------------------------------------
# estimator algebra
# ----------------------------------------------------------------------
def test_grad_cosine_identity_and_opposition():
    g = jnp.asarray([[1.0, 0.0], [1.0, 0.0], [-1.0, 0.0], [0.0, 1.0]])
    c = np.asarray(REL.grad_cosine({"w": g}))
    np.testing.assert_allclose(np.diag(c), 1.0)
    np.testing.assert_allclose(c[0, 1], 1.0, atol=1e-6)   # aligned
    np.testing.assert_allclose(c[0, 2], -1.0, atol=1e-6)  # opposed
    np.testing.assert_allclose(c[0, 3], 0.0, atol=1e-6)   # orthogonal
    np.testing.assert_allclose(c, c.T, atol=1e-6)         # symmetric


def test_grad_cosine_flattens_pytrees_and_zero_grads():
    grads = {"a": jnp.asarray([[1.0], [0.0]]),
             "b": jnp.asarray([[0.0, 2.0], [0.0, 0.0]])}
    c = np.asarray(REL.grad_cosine(grads))
    # agent 1 is all-zero: cosine 0 off-diagonal, 1 on its own slot
    assert c[1, 1] == 1.0
    np.testing.assert_allclose(c[0, 1], 0.0, atol=1e-6)


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8),
       st.integers(1, 9))
def test_grad_cosine_bounded_and_to_relevance_in_range(seed, n, p):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)}
    c = np.asarray(REL.grad_cosine(g))
    assert (c >= -1.0).all() and (c <= 1.0).all()
    r = np.asarray(REL.to_relevance(jnp.asarray(c)))
    assert (r >= 1e-3).all() and (r <= 1.0).all()
    np.testing.assert_allclose(np.diag(r), 1.0)


def test_to_relevance_floor_keeps_conflicting_pieces_alive():
    r = REL.to_relevance(jnp.asarray([-1.0, 0.0, 1.0]))
    np.testing.assert_allclose(np.asarray(r), [1e-3, 0.5, 1.0])


def test_ema_update_schedule_and_gating():
    prev = jnp.ones((2, 2))
    obs = jnp.zeros((2, 2))
    held = REL.ema_update(prev, obs, 0.9, enabled=False)
    np.testing.assert_array_equal(np.asarray(held), np.asarray(prev))
    new = REL.ema_update(prev, obs, 0.9, enabled=True)
    np.testing.assert_allclose(np.asarray(new), 0.9, rtol=1e-6)
    # decay 0 ⇒ jump straight to the observation
    np.testing.assert_allclose(
        np.asarray(REL.ema_update(prev, obs, 0.0)), 0.0)


def test_gather_edges_matches_with_relevance_gather():
    n = 5
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.uniform(0.1, 1.0, (n, n)), jnp.float32)
    topo = T.ring(n)
    via_topo = np.asarray(topo.with_relevance(dense).relevance)
    via_gather = np.asarray(
        jnp.where(topo.mask, REL.gather_edges(dense, topo.nbr), 0.0))
    np.testing.assert_allclose(via_topo, via_gather, rtol=1e-6)


def test_update_relevance_uniform_is_identity():
    rel = jnp.full((3, 3), 0.7)
    out = REL.update_relevance(rel, {"w": jnp.ones((3, 2))},
                               "uniform", 0.9)
    assert out is rel
    with pytest.raises(ValueError, match="unknown relevance mode"):
        REL.update_relevance(rel, {"w": jnp.ones((3, 2))}, "psychic",
                             0.9)


def test_obs_overlap_prior():
    mean = jnp.asarray([[0.0, 0.0], [0.0, 0.0], [10.0, 0.0]])
    scale = jnp.ones((3,))
    R = np.asarray(REL.obs_overlap(mean, scale))
    np.testing.assert_allclose(np.diag(R), 1.0)
    np.testing.assert_allclose(R, R.T, rtol=1e-6)
    np.testing.assert_allclose(R[0, 1], 1.0)       # identical streams
    assert R[0, 2] < 1e-6                          # far-apart streams


def test_combine_relevance_uniform_fixed_point():
    prior = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (4, 4)),
                        jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(combine_relevance(prior, jnp.ones((4, 4)))),
        np.asarray(prior))


# ----------------------------------------------------------------------
# integration: the learned R reaches eq. 4
# ----------------------------------------------------------------------
def _aligned_vs_opposed_group(relevance_mode):
    """4 agents: 0,1 descend +w, 2,3 descend −w. Gradient cosine is +1
    within a pair, −1 across pairs."""
    n = 4
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=8, relevance_mode=relevance_mode,
                     relevance_ema=0.5)

    def gen(state, key):
        del key
        return {"w": state["sign"] * jnp.ones_like(state["w"])}, {}, state

    ddal = DDAL(spec, gen, lambda s, g: s, lambda s: {"w": s["w"]})
    gs = ddal.init({"w": jnp.zeros((n, 3)),
                    "sign": jnp.asarray([1.0, 1.0, -1.0, -1.0]
                                        )[:, None]})
    step = jax.jit(ddal.epoch_step)
    for e in range(6):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
    return gs


def test_grad_cos_relevance_separates_aligned_from_opposed():
    gs = _aligned_vs_opposed_group("grad_cos")
    rel = np.asarray(gs.relevance)
    # learned estimate: ~1 within a pair, driven toward the floor across
    assert rel[0, 1] > 0.9
    assert rel[0, 2] < 0.2
    # and the stores' R metadata (what eq. 4 consumes) reflects it:
    # for dst 0, pieces from {0,1} carry higher R than pieces from {2,3}
    vals = np.asarray(gs.stores.grads["w"])[0, :, 0]   # signed payloads
    R = np.asarray(gs.stores.R)[0]
    valid = np.asarray(gs.stores.valid)[0]
    r_aligned = R[valid & (vals > 0)]
    r_opposed = R[valid & (vals < 0)]
    assert r_aligned.size and r_opposed.size
    assert r_aligned.min() > r_opposed.max()


def test_uniform_relevance_mode_keeps_flat_weights():
    gs = _aligned_vs_opposed_group("uniform")
    np.testing.assert_array_equal(np.asarray(gs.relevance),
                                  np.ones((4, 4), np.float32))
    R = np.asarray(gs.stores.R)
    valid = np.asarray(gs.stores.valid)
    assert set(np.unique(R[valid]).tolist()) <= {1.0}


def test_streaming_grad_cos_with_dynamic_gossip_runs():
    """Streaming trainer end-to-end with resampled gossip + learned
    relevance: finite losses, relevance EMA leaves the all-ones prior
    after the first share, window resets preserve it."""
    from repro import optim
    from repro.core.sharded_ddal import make_group_train_step
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.core import init_train_state
    from repro.data import StreamSpec, make_group_batch

    cfg = get_arch_config("llama3.2-3b").reduced()
    spec = GroupSpec(n_agents=4, threshold=0, minibatch=1,
                     topology="random_k", degree=3, resample_every=2,
                     relevance_mode="grad_cos", relevance_ema=0.5,
                     knowledge_mode="streaming")
    opt = optim.sgd(0.1)
    state = init_train_state(cfg, spec, opt, jax.random.PRNGKey(0))
    assert state.know.rel is not None
    np.testing.assert_array_equal(np.asarray(state.know.rel),
                                  np.ones((4, 4), np.float32))
    shape = ShapeConfig("t", 16, 2, "train")
    step = jax.jit(make_group_train_step(cfg, spec, opt))
    for i in range(3):
        batch = make_group_batch(cfg, shape, StreamSpec(), 4, i)
        state, m = step(state, batch)
        assert bool(jnp.isfinite(m["loss"]).all())
    rel = np.asarray(state.know.rel)
    assert rel.shape == (4, 4)
    assert not np.allclose(rel, 1.0)       # the estimate moved
    assert (rel > 0).all() and (rel <= 1.0 + 1e-6).all()
    # uniform mode keeps rel out of the state entirely
    spec_u = GroupSpec(n_agents=4, threshold=0, minibatch=1,
                       knowledge_mode="streaming")
    state_u = init_train_state(cfg, spec_u, opt, jax.random.PRNGKey(0))
    assert state_u.know.rel is None
