"""Relevance-estimator scaling gate: sketched streaming relevance
must stay O(A·|params|) streaming + O(A²·d) comparisons (ISSUE 4).

The exact ``grad_cos`` estimator costs O(A²·|params|) FLOPs per share
step, and the seed's implementation additionally materialised an
(A, P) fp32 concat of every agent's gradients (an extra HBM copy per
update). This benchmark drives the sketched estimator
(``repro.core.relevance.sketch_cosine`` over
``repro.kernels.grad_sketch``) across growing parameter counts and
FAILS (non-zero exit) unless:

1. **streaming memory** — the sketched estimator's peak jaxpr
   intermediate is bounded by one leaf / one projection block
   (≤ max(max_leaf_bytes, block·d·4B) plus the (A, d)-scale tail),
   i.e. nothing (A, P)-shaped is ever built; the per-leaf exact path
   (``sketch_dim = 0``) obeys the same leaf bound, while the retired
   flatten-based oracle provably trips it (methodology sanity check);
2. **streaming time** — per-parameter estimator time does not grow
   with |params| (the single streaming pass is the only
   parameter-sized work): t(P₂)/t(P₁) ≤ (P₂/P₁) × slack;
3. **accuracy** — sketched-vs-exact cosine max abs error ≤ 0.15 at
   d = 256 on the bench model (pairs spanning aligned → orthogonal
   gradients), with the d-sweep reported alongside;
4. **equivalence** — ``sketch_dim = 0`` stays bit-identical to the
   pre-PR exact estimator on the single-leaf bench model (where the
   contraction order is unchanged) and ≤ 2e-6 from the flatten
   oracle on multi-leaf trees (Σ-over-leaves reassociation only).

Every run writes machine-readable ``BENCH_relevance_sketch.json``
next to this file (override with ``--json``) so the perf trajectory
is tracked across PRs.

    PYTHONPATH=src python benchmarks/bench_relevance_sketch.py \
        [--smoke] [--dim 256] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import relevance as REL
from repro.core.pod_dispatch import relevance_exchange_bytes
from repro.kernels.grad_sketch.ops import DEFAULT_BLOCK

_DEFAULT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_relevance_sketch.json")


# ---------------------------------------------------------------------
# bench model: grouped-agent gradients with realistic cosine structure
# ---------------------------------------------------------------------
def bench_grads(n: int, scale: int, seed: int = 0,
                noise: float = 0.5, single_leaf: bool = False):
    """LLM-shaped stacked gradient pytree in the heterogeneous-agents
    regime the estimator exists for (arXiv 2501.11818, and the
    aligned-vs-opposed integration tests): half the agents descend a
    shared direction, half descend its negation, plus per-agent noise
    — cosines ≈ ±0.8 within/across the split. This is the *decision*
    regime (up-weight aligned, floor conflicting), where sign-JL
    error (1 − ρ²)/√d is also near its realistic size. ``scale``
    multiplies leaf widths so |params| sweeps while shapes stay
    model-like."""
    shapes = {
        "embed": (256 * scale, 128),
        "attn": (128, 256 * scale),
        "mlp": (256 * scale, 128),
        "norm": (128 * scale,),
    }
    if single_leaf:
        shapes = {"w": (512 * scale, 128)}
    rng = np.random.default_rng(seed)
    tree = {}
    for name, shape in shapes.items():
        p = int(np.prod(shape))
        base = rng.normal(size=p)
        g = np.empty((n, p), np.float32)
        for i in range(n):
            sign = 1.0 if i < n // 2 else -1.0
            g[i] = sign * base + noise * rng.normal(size=p)
        tree[name] = jnp.asarray(g.reshape((n,) + shape))
    return tree


def tree_params(tree) -> int:
    n = jax.tree.leaves(tree)[0].shape[0]
    return sum(int(x.size) for x in jax.tree.leaves(tree)) // n


def max_leaf_bytes(tree) -> int:
    return max(int(x.size) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------
# jaxpr peak-intermediate accounting
# ---------------------------------------------------------------------
def peak_intermediate_bytes(fn, *args) -> int:
    """Largest array any equation of ``fn``'s jaxpr produces —
    recursing through nested jaxprs (pjit/scan/cond) but not into
    Pallas kernel bodies (their refs are VMEM tiles, not HBM
    intermediates). Inputs don't count; every eqn output does, so an
    (A, P) concat or astype copy of the full stack is visible."""
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr) -> int:
        peak = 0
        for eqn in jaxpr.eqns:
            if "pallas" in eqn.primitive.name:
                for v in eqn.outvars:
                    peak = max(peak, _aval_bytes(v.aval))
                continue
            for v in eqn.outvars:
                peak = max(peak, _aval_bytes(v.aval))
            for p in eqn.params.values():
                peak = max(peak, _sub(p))
        return peak

    def _sub(p) -> int:
        if hasattr(p, "jaxpr"):           # ClosedJaxpr
            return walk(p.jaxpr)
        if hasattr(p, "eqns"):            # raw Jaxpr
            return walk(p)
        if isinstance(p, (tuple, list)):
            return max((_sub(q) for q in p), default=0)
        return 0

    def _aval_bytes(aval) -> int:
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None or dtype is None:
            return 0
        return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize

    return walk(closed.jaxpr)


# the pre-PR exact estimator (one shared definition: the equivalence
# + memory-methodology oracle here AND the test pin)
_flatten_oracle_cosine = REL.flatten_cosine


# ---------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------
def _time_min(thunk, repeats: int = 5) -> float:
    """Best-of-``repeats`` wall time in ms (min is the noise-robust
    statistic for a deterministic workload)."""
    jax.block_until_ready(thunk())             # compile + warm-up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(thunk())
        best = min(best, time.time() - t0)
    return best * 1e3


def bench_row(n: int, scale: int, dim: int, repeats: int) -> dict:
    """One sweep cell: sketched + exact estimator time and peak
    intermediate at this parameter count."""
    tree = bench_grads(n, scale)
    P = tree_params(tree)
    seed = jnp.int32(0)

    sk_fn = jax.jit(lambda t: REL.sketch_cosine(t, dim, seed))
    ex_fn = jax.jit(REL.grad_cosine)
    row = {
        "n": n, "scale": scale, "params": P, "dim": dim,
        "max_leaf_mb": max_leaf_bytes(tree) / 2**20,
        "sketch_ms": _time_min(lambda: sk_fn(tree), repeats),
        "exact_ms": _time_min(lambda: ex_fn(tree), repeats),
        "sketch_peak_mb":
            peak_intermediate_bytes(sk_fn, tree) / 2**20,
        "exact_peak_mb":
            peak_intermediate_bytes(ex_fn, tree) / 2**20,
        # cross-mesh relevance traffic of each estimator (what the
        # pod-dispatched trainer moves per share step)
        "rel_xchg_sketch_mb":
            relevance_exchange_bytes(n, P, dim) / 2**20,
        "rel_xchg_exact_mb":
            relevance_exchange_bytes(n, P, 0) / 2**20,
    }
    err = np.abs(np.asarray(sk_fn(tree)) - np.asarray(ex_fn(tree)))
    row["cos_err_max"] = float(err[~np.eye(n, dtype=bool)].max())
    return row


# ---------------------------------------------------------------------
# gates
# ---------------------------------------------------------------------
def gate_memory(rows, n: int, dim: int) -> dict:
    """Nothing (n, P)-shaped: sketched and exact peaks stay within one
    leaf / one projection block (+ the (n, d)/(n, n) tails); the
    flatten oracle trips the same bound (so the methodology would
    catch a regression)."""
    tree_big = bench_grads(n, rows[-1]["scale"])
    concat_mb = (n * tree_params(tree_big) * 4) / 2**20
    oracle_peak = peak_intermediate_bytes(
        jax.jit(_flatten_oracle_cosine), tree_big) / 2**20
    ok = True
    for r in rows:
        allow = (max(r["max_leaf_mb"],
                     DEFAULT_BLOCK * dim * 4 / 2**20)
                 + (n * max(dim, n) * 4) / 2**20)
        ok &= r["sketch_peak_mb"] <= allow
        ok &= r["exact_peak_mb"] <= allow
        ok &= r["sketch_peak_mb"] < concat_mb
    sane = oracle_peak >= concat_mb * 0.99
    return {"pass": bool(ok and sane),
            "oracle_concat_mb": concat_mb,
            "oracle_peak_mb": oracle_peak,
            "detail": "peak intermediate ≤ one leaf/projection block; "
                      "flatten oracle ≥ (n, P) concat"}


def exchange_report(rows) -> dict:
    """Cross-mesh relevance traffic (``pod_dispatch.
    relevance_exchange_bytes``), *reported* rather than gated: both
    columns come from the same analytic accounting function, so
    asserting their relationship here would be tautological (the
    formula itself is pinned by a unit test; the real streaming
    behaviour is gated by the jaxpr memory check above)."""
    return {"sketch_mb": sorted({r["rel_xchg_sketch_mb"]
                                 for r in rows}),
            "exact_mb": [r["rel_xchg_exact_mb"] for r in rows]}


def gate_time(rows, slack: float = 2.5) -> dict:
    """Per-parameter sketched-estimator time must not grow with
    |params| beyond the streaming pass. Compared between the two
    *largest* sizes: the smallest sweep cell sits entirely in cache
    and would make any DRAM-resident run look superlinear. A
    quadratic regression (the O(A²·|params|) exact cost, or an
    (A, P)-shaped intermediate getting re-read) shows up as a ≥ 4×
    per-param ratio at the 4× size step — far beyond the slack (set
    to absorb cache-residency transitions and shared-CI timing noise,
    observed up to ~1.7×); the memory gate catches the
    materialisation itself deterministically."""
    lo, hi = rows[-2], rows[-1]
    ratio = (hi["sketch_ms"] / hi["params"]) / \
        (lo["sketch_ms"] / lo["params"])
    return {"pass": bool(ratio <= slack), "per_param_ratio": ratio,
            "slack": slack,
            "detail": f"t/param at {hi['params']:,} vs "
                      f"{lo['params']:,} params"}


def gate_error(n: int, scale: int, dim: int) -> dict:
    """Sketched vs exact cosine max abs error at the gate dim, plus
    the reported d-sweep (deterministic: fixed seeds)."""
    tree = bench_grads(n, scale)
    exact = np.asarray(REL.grad_cosine(tree))
    off = ~np.eye(n, dtype=bool)
    sweep = {}
    for d in (64, dim, 4 * dim):
        sk = np.asarray(REL.sketch_cosine(tree, d, jnp.int32(0)))
        e = np.abs(sk - exact)[off]
        sweep[d] = {"max": float(e.max()), "mean": float(e.mean())}
    return {"pass": bool(sweep[dim]["max"] <= 0.15),
            "bound": 0.15, "dim": dim, "sweep": sweep}


def gate_equivalence(n: int) -> dict:
    """sketch_dim = 0 ≡ the pre-PR exact estimator: bitwise on the
    single-leaf bench model (same op sequence), ≤ 2e-6 on the
    multi-leaf one (Σ-over-leaves reassociation only)."""
    rel0 = REL.init_relevance(n)
    single = bench_grads(n, 2, single_leaf=True)
    multi = bench_grads(n, 2)

    def new(tree):
        return np.asarray(REL.update_relevance(
            rel0, tree, "grad_cos", 0.5, sketch_dim=0))

    def old(tree):
        return np.asarray(REL.ema_update(
            rel0, REL.to_relevance(_flatten_oracle_cosine(tree)), 0.5))

    bitwise = bool(np.array_equal(new(single), old(single)))
    multi_err = float(np.abs(new(multi) - old(multi)).max())
    return {"pass": bool(bitwise and multi_err <= 2e-6),
            "single_leaf_bitwise": bitwise,
            "multi_leaf_max_err": multi_err}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI fast path: smaller parameter sweep")
    p.add_argument("--agents", type=int, default=8)
    p.add_argument("--dim", type=int, default=256,
                   help="sketch dimension d the gates run at")
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--json", default=_DEFAULT_JSON,
                   help="machine-readable results path")
    args = p.parse_args(argv)

    n, dim = args.agents, args.dim
    # the gated pair (last two scales) must sit on the same side of
    # the XLA path's unroll→fori_loop threshold (ops._MAX_UNROLL), or
    # the per-param time gate compares two different code paths:
    # smoke tiles all unroll (8/16/32 ≤ 64), the full sweep's gated
    # pair both roll (128/512 tiles)
    scales = [1, 2, 4] if args.smoke else [4, 16, 64]
    rows = []
    print(f"sketched relevance sweep (n={n}, d={dim}, "
          f"backend={jax.default_backend()}):")
    print(f"{'params':>12} {'sketch ms':>10} {'exact ms':>9} "
          f"{'sk peak MB':>11} {'ex peak MB':>11} {'err max':>8}")
    for s in scales:
        r = bench_row(n, s, dim, args.repeats)
        rows.append(r)
        print(f"{r['params']:12,} {r['sketch_ms']:10.2f} "
              f"{r['exact_ms']:9.2f} {r['sketch_peak_mb']:11.2f} "
              f"{r['exact_peak_mb']:11.2f} {r['cos_err_max']:8.4f}")

    gates = {
        "memory": gate_memory(rows, n, dim),
        "time": gate_time(rows),
        "error": gate_error(n, scales[-1], dim),
        "equivalence": gate_equivalence(n),
    }
    exchange = exchange_report(rows)
    print()
    for name, g in gates.items():
        print(f"gate {name}: {'PASS' if g['pass'] else 'FAIL'} "
              f"({ {k: v for k, v in g.items() if k != 'pass'} })")
    print(f"relevance exchange (analytic, per share step): "
          f"sketch {exchange['sketch_mb']} MB flat vs exact "
          f"{exchange['exact_mb']} MB")

    payload = {"bench": "relevance_sketch", "n_agents": n, "dim": dim,
               "backend": jax.default_backend(), "rows": rows,
               "exchange": exchange, "gates": gates}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"\nwrote {args.json}")

    if not all(g["pass"] for g in gates.values()):
        raise SystemExit("relevance sketch gate FAILED")
    return payload


if __name__ == "__main__":
    main()
