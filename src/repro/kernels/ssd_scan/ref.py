"""Pure-jnp oracle for the SSD intra-chunk kernel — the einsum dual
form from repro.models.ssd (arXiv:2405.21060 §6)."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_intra_chunk(xc, dtc, cs, Bc, Cc) -> jnp.ndarray:
    """Intra-chunk ("diagonal block") output of the SSD dual form.

    xc:  (b, nc, l, h, p);  dtc, cs: (b, nc, l, h) fp32;
    Bc, Cc: (b, nc, l, h, n).  Returns y_diag (b, nc, l, h, p) fp32.
    """
    f32 = jnp.float32
    l = cs.shape[2]
    cs_h = jnp.moveaxis(cs, 3, 2)                       # (b,nc,h,l)
    diff = cs_h[..., :, None] - cs_h[..., None, :]      # (b,nc,h,l,l)
    causal = jnp.tril(jnp.ones((l, l), bool))
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bchij",
                        Cc.astype(f32), Bc.astype(f32))
    scores = scores * L * jnp.moveaxis(dtc, 3, 2)[..., None, :]
    return jnp.einsum("bchij,bcjhp->bcihp", scores, xc.astype(f32))
