"""Serving observability: per-request latency / TTFT / queue-depth
counters (ISSUE 6).

A :class:`ServeMetrics` instance is threaded through an engine's host
loop; the engine reports lifecycle events (enqueue → admitted → first
token → finish) and per-step queue depth, and ``summary()`` folds the
traces into the percentile/throughput numbers the load bench gates on
(``benchmarks/bench_serving.py`` → ``BENCH_serving.json``).

The clock is injected (default ``time.monotonic``) so tests drive a
fake clock and get deterministic traces; the bench passes arrival
timestamps explicitly (``enqueue(..., at=t)``) so open-loop queueing
delay — time between the *scheduled* Poisson arrival and admission —
is part of the measured latency, as a production load test requires.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestTrace:
    """One request's lifecycle timestamps (clock units, usually s)."""
    rid: int
    agent_id: int = 0
    enqueued: float = 0.0
    admitted: Optional[float] = None     # slot assigned (prefill start)
    first_token: Optional[float] = None  # TTFT reference point
    finished: Optional[float] = None
    n_tokens: int = 0
    version: int = 0                     # param-store version served

    @property
    def latency(self) -> Optional[float]:
        return (None if self.finished is None
                else self.finished - self.enqueued)

    @property
    def ttft(self) -> Optional[float]:
        return (None if self.first_token is None
                else self.first_token - self.enqueued)

    @property
    def queue_wait(self) -> Optional[float]:
        return (None if self.admitted is None
                else self.admitted - self.enqueued)


def percentile(xs: List[float], q: float) -> float:
    """numpy linear-interpolation percentile; nan on empty."""
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


class ServeMetrics:
    """Lifecycle counters for one engine run."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.traces: Dict[int, RequestTrace] = {}
        self.queue_depth: List[int] = []     # sampled once per step
        self.live_slots: List[int] = []
        self.decode_steps = 0
        self.swaps = 0                       # param hot-swaps observed

    # -- lifecycle events ----------------------------------------------
    def enqueue(self, rid: int, agent_id: int = 0,
                at: Optional[float] = None) -> None:
        self.traces[rid] = RequestTrace(
            rid=rid, agent_id=agent_id,
            enqueued=self.clock() if at is None else at)

    def admitted(self, rid: int, version: int = 0) -> None:
        t = self.traces[rid]
        t.admitted = self.clock()
        t.version = version

    def first_token(self, rid: int) -> None:
        self.traces[rid].first_token = self.clock()

    def finish(self, rid: int, n_tokens: int) -> None:
        t = self.traces[rid]
        t.finished = self.clock()
        t.n_tokens = n_tokens

    def observe_step(self, queued: int, live: int) -> None:
        self.decode_steps += 1
        self.queue_depth.append(queued)
        self.live_slots.append(live)

    def observe_swap(self) -> None:
        self.swaps += 1

    # -- aggregation ----------------------------------------------------
    def summary(self) -> dict:
        done = [t for t in self.traces.values()
                if t.finished is not None]
        lat = [t.latency for t in done]
        ttft = [t.ttft for t in done if t.ttft is not None]
        wait = [t.queue_wait for t in done if t.queue_wait is not None]
        toks = sum(t.n_tokens for t in done)
        span = (max(t.finished for t in done)
                - min(t.enqueued for t in done)) if done else 0.0
        per_agent: Dict[int, int] = {}
        for t in done:
            per_agent[t.agent_id] = per_agent.get(t.agent_id, 0) + 1
        return {
            "requests": len(self.traces),
            "completed": len(done),
            "tokens": toks,
            "span_s": span,
            "throughput_tok_s": toks / span if span > 0 else 0.0,
            "requests_s": len(done) / span if span > 0 else 0.0,
            "latency_p50": percentile(lat, 50),
            "latency_p99": percentile(lat, 99),
            "ttft_p50": percentile(ttft, 50),
            "ttft_p99": percentile(ttft, 99),
            "queue_wait_p99": percentile(wait, 99),
            "queue_depth_mean": (float(np.mean(self.queue_depth))
                                 if self.queue_depth else 0.0),
            "queue_depth_max": (int(np.max(self.queue_depth))
                                if self.queue_depth else 0),
            "live_slots_mean": (float(np.mean(self.live_slots))
                                if self.live_slots else 0.0),
            "decode_steps": self.decode_steps,
            "swaps": self.swaps,
            "per_agent_completed": per_agent,
        }

    def rows(self) -> List[dict]:
        """Per-request records for the bench's machine-readable JSON."""
        return [{"rid": t.rid, "agent": t.agent_id,
                 "enqueued": t.enqueued, "ttft": t.ttft,
                 "latency": t.latency, "tokens": t.n_tokens,
                 "version": t.version}
                for t in sorted(self.traces.values(),
                                key=lambda t: t.rid)]
