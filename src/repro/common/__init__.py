from repro.common import pytree, sharding  # noqa: F401
