"""Multi-tenant group serving (ISSUE 6): single-tenant bitwise
equivalence with the fixed-batch engine, per-agent routing across one
jitted decode step, publish/acquire hot-swap, and the trainer→store
handoff."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_arch_config
from repro.configs.base import GroupSpec
from repro.core import init_train_state
from repro.models import get_model
from repro.serving import (
    GroupRequest,
    GroupServeEngine,
    ParamStore,
    Router,
    ServeConfig,
    ServeEngine,
    ServeMetrics,
    publish_from_trainer,
)

PAD = 8          # every prompt below fits one pad bucket


def _ref_tokens(cfg, params, serve, prompt):
    """ServeEngine (fixed-batch) greedy reference for one prompt,
    padded to the same bucket the group engine prefills at."""
    eng = ServeEngine(cfg, params, serve)
    toks = np.zeros((1, PAD), np.int32)
    toks[0, :len(prompt)] = prompt
    out = eng.generate(jnp.asarray(toks),
                       jnp.asarray([len(prompt)], jnp.int32))
    return list(np.asarray(out)[0])


def _agent_params(planes, aid):
    return jax.tree.map(lambda p: p[aid], planes)


def _init_planes(cfg, model, n_agents, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n_agents)
    return jax.vmap(lambda k: model.init(cfg, k))(keys)


# ---------------------------------------------------------------------
# single-tenant equivalence oracle
# ---------------------------------------------------------------------
@pytest.mark.parametrize(
    "arch",
    ["llama3.2-3b", "mamba2-780m",
     # MoE decode is the slow cell (~10s); its engine path is also
     # exercised by test_serving_continuous's deepseek oracle, so it
     # rides the slow lane to keep tier-1 on budget
     pytest.param("deepseek-v2-lite-16b", marks=pytest.mark.slow)])
def test_single_tenant_matches_serve_engine(arch):
    """With one agent the group engine is bitwise the fixed-batch
    engine: same prefill/sample/stop pipeline via repro.serving.api."""
    cfg = get_arch_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    planes = jax.tree.map(lambda p: p[None], params)
    serve = ServeConfig(max_len=64, max_new_tokens=5)
    eng = GroupServeEngine(cfg, planes, serve, batch_size=2,
                           prompt_pad=PAD)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
    out = eng.run([GroupRequest(rid, 0, pr)
                   for rid, pr in enumerate(prompts)])
    assert set(out) == {0, 1, 2}
    for rid, pr in enumerate(prompts):
        ref = _ref_tokens(cfg, params, serve, pr)
        assert out[rid] == ref[:5]


# ---------------------------------------------------------------------
# per-agent routing across one jitted decode step
# ---------------------------------------------------------------------
@pytest.mark.slow
def test_four_agents_one_decode_step():
    """≥4 tenants live in the same batch: one jitted step advances all
    of them, and every request decodes under its own agent's params
    (each matches the single-tenant engine on that agent's row)."""
    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    A = 4
    planes = _init_planes(cfg, model, A)
    serve = ServeConfig(max_len=64, max_new_tokens=4)
    eng = GroupServeEngine(cfg, planes, serve, batch_size=A,
                           prompt_pad=PAD)
    prompts = [[10 + a, 20 + a, 30 + a] for a in range(A)]
    for a in range(A):
        eng.submit(GroupRequest(a, a, prompts[a]))
    eng.step()
    assert eng.live == A            # all four tenants in one batch
    out = eng.drain()
    # second wave re-uses the freed slots (continuous refill)
    out2 = eng.run([GroupRequest(A + a, a, prompts[a][::-1])
                    for a in range(A)])
    for a in range(A):
        params_a = _agent_params(planes, a)
        assert out[a] == _ref_tokens(cfg, params_a, serve,
                                     prompts[a])[:4]
        assert out2[A + a] == _ref_tokens(cfg, params_a, serve,
                                          prompts[a][::-1])[:4]


def test_routing_determinism():
    """Same submission order → identical results, fifo and fair."""
    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    planes = _init_planes(cfg, model, 3)
    serve = ServeConfig(max_len=32, max_new_tokens=3)
    reqs = [GroupRequest(rid, rid % 3, [rid + 1, rid + 2])
            for rid in range(7)]
    for policy in ("fifo", "fair"):
        eng = GroupServeEngine(cfg, planes, serve, batch_size=2,
                               prompt_pad=PAD, router=Router(policy))
        out1 = eng.run(reqs)
        eng.reset()
        out2 = eng.run(reqs)
        assert out1 == out2
        assert set(out1) == set(range(7))


def test_agent_id_out_of_range():
    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    planes = _init_planes(cfg, model, 2)
    eng = GroupServeEngine(cfg, planes,
                           ServeConfig(max_len=32, max_new_tokens=2),
                           batch_size=2, prompt_pad=PAD)
    with pytest.raises(ValueError, match="agent_id"):
        eng.submit(GroupRequest(0, 2, [1, 2]))


def test_empty_request_stream():
    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    planes = _init_planes(cfg, model, 1)
    eng = GroupServeEngine(cfg, planes,
                           ServeConfig(max_len=32, max_new_tokens=2),
                           batch_size=2, prompt_pad=PAD)
    assert eng.run([]) == {}


# ---------------------------------------------------------------------
# router unit behaviour (no jax)
# ---------------------------------------------------------------------
def test_fair_router_round_robins_agents():
    r = Router("fair")
    for rid, aid in enumerate([0, 0, 0, 1, 2]):
        r.push(GroupRequest(rid, aid, (1,)))
    order = [r.pop().agent_id for _ in range(5)]
    assert order == [0, 1, 2, 0, 0]      # no starvation by agent 0
    assert r.pop() is None and len(r) == 0


def test_fifo_router_preserves_arrival_order():
    r = Router("fifo")
    for rid, aid in enumerate([0, 0, 1, 0]):
        r.push(GroupRequest(rid, aid, (1,)))
    assert [r.pop().rid for _ in range(4)] == [0, 1, 2, 3]
    assert r.depth(0) == 0


# ---------------------------------------------------------------------
# publish/acquire hot-swap
# ---------------------------------------------------------------------
def test_param_store_publish_acquire_double_buffer():
    planes0 = {"w": jnp.arange(4.0).reshape(2, 2)}
    store = ParamStore(planes0)
    held, v0 = store.acquire()
    assert v0 == 0 and store.n_agents == 2
    planes1 = {"w": planes0["w"] + 1}
    assert store.publish(planes1) == 1
    live, v1 = store.acquire()
    assert v1 == 1
    np.testing.assert_array_equal(np.asarray(live["w"]),
                                  np.asarray(planes1["w"]))
    # the buffer a reader acquired before the swap stays intact
    np.testing.assert_array_equal(np.asarray(held["w"]),
                                  np.asarray(planes0["w"]))


def test_param_store_checkpoint_roundtrip(tmp_path):
    store = ParamStore({"w": jnp.ones((3, 2))})
    store.publish({"w": jnp.full((3, 2), 2.0)})
    path = str(tmp_path / "planes.npz")
    store.save(path)
    loaded = ParamStore.load(path, {"w": jnp.zeros((3, 2))})
    assert loaded.version == 1           # version rides __step__
    live, _ = loaded.acquire()
    np.testing.assert_array_equal(np.asarray(live["w"]), 2.0)


def test_hot_swap_mid_stream():
    """A publish mid-decode drops/corrupts nothing: the in-flight
    request's pre-swap tokens match the old params' reference and it
    runs to completion; a request admitted after the swap is bitwise
    what a fresh engine on the new planes produces from the start."""
    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    planes0 = _init_planes(cfg, model, 2, seed=0)
    planes1 = _init_planes(cfg, model, 2, seed=1)
    serve = ServeConfig(max_len=64, max_new_tokens=8)
    metrics = ServeMetrics()
    store = ParamStore(planes0)
    eng = GroupServeEngine(cfg, store, serve, batch_size=2,
                           prompt_pad=PAD, metrics=metrics)
    pr0, pr1 = [1, 2, 3], [4, 5]
    eng.submit(GroupRequest(0, 0, pr0))
    for _ in range(3):                   # 1 prefill token + 3 decodes
        eng.step()
    store.publish(planes1)
    eng.submit(GroupRequest(1, 1, pr1))
    out = eng.drain()

    assert len(out[0]) == 8              # in-flight ran to completion
    ref0 = _ref_tokens(cfg, _agent_params(planes0, 0), serve, pr0)
    assert out[0][:4] == ref0[:4]        # pre-swap tokens untouched
    # post-swap admission == serving the new planes from the start
    fresh = GroupServeEngine(cfg, planes1, serve, batch_size=2,
                             prompt_pad=PAD)
    assert out[1] == fresh.run([GroupRequest(1, 1, pr1)])[1]
    # observability: each request records the version it was served at
    assert metrics.traces[0].version == 0
    assert metrics.traces[1].version == 1
    assert store.version == 1


def test_publish_from_trainer_into_engine():
    """The train→serve handoff: a DDAL TrainState's stacked params
    publish straight into the serving store, and the engine serves
    each agent's trained row."""
    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    spec = GroupSpec(n_agents=2, threshold=0, minibatch=1,
                     knowledge_mode="streaming")
    state = init_train_state(cfg, spec, optim.adamw(1e-3),
                             jax.random.PRNGKey(0))
    store = ParamStore(_init_planes(cfg, model, 2, seed=7))
    assert publish_from_trainer(store, state) == 1
    assert store.n_agents == 2
    serve = ServeConfig(max_len=32, max_new_tokens=3)
    eng = GroupServeEngine(cfg, store, serve, batch_size=2,
                           prompt_pad=PAD)
    out = eng.run([GroupRequest(0, 0, [1, 2, 3]),
                   GroupRequest(1, 1, [4, 5])])
    assert out[0] == _ref_tokens(cfg, _agent_params(state.params, 0),
                                 serve, [1, 2, 3])[:3]
    assert out[1] == _ref_tokens(cfg, _agent_params(state.params, 1),
                                 serve, [4, 5])[:3]


# ---------------------------------------------------------------------
# metrics (fake clock → exact numbers)
# ---------------------------------------------------------------------
def test_metrics_summary_with_fake_clock():
    t = [0.0]
    m = ServeMetrics(clock=lambda: t[0])
    m.enqueue(0, agent_id=1)
    t[0] = 0.5
    m.admitted(0, version=3)
    m.first_token(0)
    t[0] = 2.5
    m.finish(0, n_tokens=4)
    m.enqueue(1, at=1.0)                 # backdated open-loop arrival
    t[0] = 3.0
    m.admitted(1)
    m.first_token(1)
    t[0] = 5.0
    m.finish(1, n_tokens=4)
    m.observe_step(2, 1)
    m.observe_swap()
    s = m.summary()
    assert s["completed"] == 2 and s["tokens"] == 8
    assert s["span_s"] == pytest.approx(5.0)      # first enqueue → last finish
    assert s["latency_p50"] == pytest.approx((2.5 + 4.0) / 2)
    assert s["ttft_p99"] == pytest.approx(2.0, abs=0.05)
    assert s["queue_wait_p99"] == pytest.approx(2.0, abs=0.05)
    assert s["swaps"] == 1 and s["decode_steps"] == 1
    assert s["per_agent_completed"] == {1: 1, 0: 1}
    assert m.traces[0].version == 3
    rows = m.rows()
    assert [r["rid"] for r in rows] == [0, 1]
    assert rows[1]["enqueued"] == 1.0


# ---------------------------------------------------------------------
# RL policies: the same plane-gather routing
# ---------------------------------------------------------------------
def test_group_policy_act_routes_per_agent():
    from repro.rl.networks import (group_policy_act, init_policy_value,
                                   policy_logits)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    planes = jax.vmap(lambda k: init_policy_value(k, 6, 4))(keys)
    obs = jax.random.normal(jax.random.PRNGKey(1), (5, 6))
    ids = jnp.asarray([2, 0, 1, 2, 0])
    acts, logits = group_policy_act(planes, ids, obs)
    for i in range(5):
        pi = _agent_params(planes, int(ids[i]))
        np.testing.assert_allclose(np.asarray(logits[i]),
                                   np.asarray(policy_logits(pi, obs[i])),
                                   rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(acts),
                                  np.asarray(jnp.argmax(logits, -1)))
    with pytest.raises(ValueError, match="PRNG key"):
        group_policy_act(planes, ids, obs, temperature=1.0)
    a1, _ = group_policy_act(planes, ids, obs,
                             key=jax.random.PRNGKey(2), temperature=1.0)
    a2, _ = group_policy_act(planes, ids, obs,
                             key=jax.random.PRNGKey(2), temperature=1.0)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    assert bool(((a1 >= 0) & (a1 < 4)).all())


# ---------------------------------------------------------------------
# load run (excluded from the CI fast lane; serving-smoke runs the
# bench directly)
# ---------------------------------------------------------------------
@pytest.mark.slow
def test_serving_load_bench_gates(tmp_path):
    """The open-loop load bench completes with every gate green and a
    well-formed BENCH_serving.json."""
    import json
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = str(tmp_path / "bench.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run(
        [sys.executable,
         os.path.join(repo, "benchmarks", "bench_serving.py"),
         "--smoke", "--json", out],
        cwd=repo, env=env, text=True, timeout=600,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    assert res.returncode == 0, res.stdout
    with open(out) as f:
        payload = json.load(f)
    assert all(g["pass"] for g in payload["gates"].values())
    assert payload["open_loop"]["swapped"]
    assert len(payload["rows"]) == payload["requests"]


# ---------------------------------------------------------------------
# mesh placement: serving planes share the trainer's layout
# ---------------------------------------------------------------------
@pytest.mark.multi_device
def test_group_planes_on_pod_mesh(multi_device):
    """On the two-level (pod, agent) mesh the engine's store places
    publishes with dim 0 over both agent axes — the placement
    ``group_plane_partition_specs`` declares and the DDAL trainer
    already keeps — and the group decode runs on the sharded planes."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_pod_mesh
    from repro.launch.shardings import group_plane_partition_specs

    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    mesh = make_pod_mesh(2)              # 8 devices → (pod=2, agent=4)
    A = 8
    planes = _init_planes(cfg, model, A)
    eng = GroupServeEngine(cfg, planes,
                           ServeConfig(max_len=32, max_new_tokens=2),
                           batch_size=2, prompt_pad=PAD, mesh=mesh)
    live, _ = eng.store.acquire()
    leaf = jax.tree.leaves(live)[0]
    assert leaf.sharding.spec[0] == ("pod", "agent")
    specs = group_plane_partition_specs(cfg, mesh)
    assert all(s == P(("pod", "agent"))
               for s in jax.tree.leaves(
                   specs, is_leaf=lambda x: isinstance(x, P)))
    out = eng.run([GroupRequest(a, a, [1 + a, 2 + a])
                   for a in range(A)])
    assert set(out) == set(range(A))
    assert all(len(v) == 2 for v in out.values())
