"""Architecture / shape / group configuration dataclasses.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` module
exporting ``get_config() -> ArchConfig`` with the exact assigned
hyper-parameters (source citations in each file). ``ArchConfig.reduced``
produces the smoke-test variant (≤2 layers, d_model ≤ 512, ≤4 experts)
required to run a real forward/train step on CPU.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int
    n_shared: int = 0          # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3
    aux_loss: float = 1e-2     # load-balance auxiliary loss weight


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128
    q_lora_rank: Optional[int] = None   # V2-Lite: queries not compressed


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    d_conv: int = 4


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: N super-blocks of (mamba_per_block Mamba2 layers +
    one SHARED attention/MLP block) plus tail Mamba2 layers."""
    n_super_blocks: int = 16
    mamba_per_block: int = 4
    tail_mamba: int = 1
    lora_rank: int = 128       # per-call-site LoRA on the shared block


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_mode: str = "standard"         # standard | mrope | none
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    sliding_window: Optional[int] = None
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    first_k_dense: int = 0              # deepseek: leading dense layers
    dense_ff: int = 0                   # d_ff of those dense layers
    # -- modality backbone stubs (per-spec carve-out) -----------------
    cross_attention: bool = False       # musicgen: cross-attn to cond.
    cond_len: int = 0                   # conditioning sequence length
    n_codebooks: int = 1                # musicgen: 4 EnCodec codebooks
    vision_prefix: int = 0              # qwen2-vl: # of patch embeddings
    # -- numerics / execution -----------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    unroll_layers: bool = False         # dry-run: exact HLO cost/collectives
    moe_dispatch: str = "auto"          # auto | dense | expert_parallel
    mla_absorb: bool = True             # MLA decode weight absorption
    attention_scores_dtype: str = "float32"   # float32 | bfloat16 (§Perf)
    attention_impl: str = "xla"         # xla | pallas | pallas_interpret
    ssd_impl: str = "xla"               # xla | pallas_interpret
    max_position: int = 1 << 20
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def q_proj_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_proj_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def dtype(self, which: str = "compute"):
        return jnp.dtype(self.param_dtype if which == "param" else
                         self.compute_dtype)

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        d_model = min(self.d_model, 256)
        head_dim = 32
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # keep the GQA ratio interesting but legal
        while n_heads % n_kv:
            n_kv -= 1
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            cond_len=min(self.cond_len, 8) if self.cross_attention else 0,
            vision_prefix=min(self.vision_prefix, 8),
            max_position=1 << 14,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )
        if self.moe is not None:
            kw["moe"] = replace(self.moe, n_experts=4,
                                top_k=min(self.moe.top_k, 2),
                                expert_ff=128,
                                n_shared=min(self.moe.n_shared, 1))
        if self.mla is not None:
            kw["mla"] = replace(self.mla, kv_lora_rank=64, qk_nope_dim=32,
                                qk_rope_dim=16, v_dim=32)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16,
                                chunk=32)
        if self.hybrid is not None:
            kw["hybrid"] = replace(self.hybrid, n_super_blocks=1,
                                   mamba_per_block=1, tail_mamba=1,
                                   lora_rank=8)
            kw["n_layers"] = 3
        if self.first_k_dense:
            kw["dense_ff"] = 128
        if self.sliding_window is not None:
            kw["sliding_window"] = 16
        if self.rope_mode == "mrope":
            # sections must sum to head_dim/2 = 16
            kw["mrope_sections"] = (4, 6, 6)
        return replace(self, **kw)


# ---------------------------------------------------------------------
# Input shapes (assigned). ``kind`` selects which step function the
# dry-run lowers: train_step / prefill_step / decode_step.
# ---------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",  524_288,    1, "decode"),
}

# Dense (full-attention) archs fall back to a sliding-window variant for
# long_500k (sub-quadratic requirement) — see DESIGN.md §5.
LONG_CONTEXT_WINDOW = 8_192


@dataclass(frozen=True)
class GroupSpec:
    """DDAL group-agent training configuration (paper §5).

    Invalid combinations raise ``ValueError`` at construction (they
    used to surface as shape/index errors deep inside jit): unknown
    ``topology`` / ``relevance_mode`` strings, ``resample_every < 0``,
    and ``degree >= n_agents`` for ``random_k`` (the gossip degree
    counts the self-loop; k = n is spelled ``topology="full"``).
    """
    n_agents: int = 1
    threshold: int = 1_000       # warm-up epochs of independent learning
    minibatch: int = 100         # share/update cadence (paper's name)
    m_pieces: int = 8            # pieces retrieved from K_i ∪ K_-i
    knowledge_mode: str = "buffer"   # buffer | streaming (LLM-scale)
    knowledge_dtype: str = "float32" # streaming accumulators (bf16 halves
                                     # the cross-pod exchange traffic)
    # communication graph (repro.core.topology): full | ring | torus2d
    # | star | random_k | hierarchical
    topology: str = "full"
    degree: int = 4              # k for random_k; pod size for hierarchical
    pods: int = 0                # multi-host dispatch: map hierarchical
                                 # pods onto a two-level mesh (0 = flat
                                 # single-mesh combine; requires
                                 # n_agents == pods * degree)
    pod_axis: str = "pod"        # mesh axis the leader-level (DCN)
                                 # exchange crosses; intra-pod exchange
                                 # stays on the "agent" axis
    topology_seed: int = 0       # seed for random_k gossip sampling
    resample_every: int = 0      # dynamic gossip: resample the random_k
                                 # table every N epochs (0 = static)
    max_delay: int = 0           # async staleness simulation (epochs)
    t_weighting: str = "epochs"  # T_j source
    r_weighting: str = "uniform" # R_j source (paper §6 uses uniform)
    relevance_mode: str = "uniform"  # online R estimator: uniform |
                                     # grad_cos (repro.core.relevance)
    relevance_ema: float = 0.9   # EMA decay of the learned R estimate
    relevance_sketch_dim: int = 0    # grad_cos at LLM scale: stream
                                     # gradients through a seeded ±1
                                     # projection into (n, d) sketches
                                     # and cosine those — O(n·|params|)
                                     # + O(n²·d) instead of
                                     # O(n²·|params|); 0 = exact
                                     # pairwise cosines
    # -- exchange-protocol strategy overrides (repro.core.exchange) ---
    # "auto" derives each strategy from the legacy flags above (the
    # bitwise-pinned mapping); explicit keys select registered
    # strategies directly — e.g. exchange_schedule="relevance_topk"
    # (Gumbel top-k gossip over the learned R) or
    # exchange_estimator="obs_stats" (observation-overlap relevance).
    exchange_schedule: str = "auto"   # auto | static | dynamic |
                                      # relevance_topk
    exchange_estimator: str = "auto"  # auto | uniform | grad_cos |
                                      # grad_cos+sketch | obs_stats
    exchange_delay: str = "auto"      # auto | none | uniform | hops
    exchange_combiner: str = "auto"   # auto | flat | pod | store
    explore_eps: float = 0.1          # relevance_topk: per-destination
                                      # ε-greedy uniform-gossip rate
    elastic: bool = False             # elastic membership: thread a
                                      # per-agent alive mask through
                                      # the exchange (eq. 4 masking,
                                      # delay-line drop on death,
                                      # frozen relevance EMA, gossip
                                      # exclusion). False keeps every
                                      # trainer's jitted program
                                      # structurally unchanged.
    knowledge_quant_block: int = 0    # >0: store/ship knowledge planes
                                      # as int8 with one fp32 scale per
                                      # this many flat elements (~4×
                                      # lighter delay lines and
                                      # cross-pod bytes). Must be a
                                      # multiple of 128 dividing 8192
                                      # (whole sublane row groups of
                                      # the wavg kernel tile). 0 = fp32
                                      # planes, bitwise-legacy.
    # -- transport faults (repro.core.transport) ----------------------
    # Seeded per-edge message faults on the exchange path. All-zero
    # rates keep the exchange structurally identical to the perfect-
    # delivery programs (the same contract elastic=False honors).
    transport_loss: float = 0.0       # per-message per-edge loss prob.
    transport_dup: float = 0.0        # duplicate-delivery probability
    transport_corrupt: float = 0.0    # in-flight payload-garble prob.
                                      # (checksummed + quarantined at
                                      # deliver: exactly-zero eq. 4
                                      # weight)
    transport_jitter: int = 0         # max uniform extra delivery
                                      # delay (epochs) on top of the
                                      # delay model
    transport_retransmit: int = 0     # retry budget per lost message
                                      # (exponential backoff 1,2,4,…
                                      # epochs; resolved at plan time)
    transport_seed: int = 0           # fault-plan seed (numpy RNG —
                                      # never touches trainer PRNG)
    transport_horizon: int = 256      # planned epochs before the
                                      # fault history replays
    transport_decay: float = 1.0      # staleness discount per epoch
                                      # of arrival-slot age on the
                                      # eq. 4 T/R terms (1.0 = none)
    max_staleness: Optional[int] = None   # hard cutoff: arrival slots
                                      # older than this many epochs
                                      # get zero eq. 4 weight; when no
                                      # slot survives the agent falls
                                      # back to its purely-local
                                      # update. None disables age
                                      # tracking (buffer trainer only).
    exchange_transport: str = "auto"  # auto | none | faulty

    def __post_init__(self):
        # deferred imports: repro.core modules import this module for
        # the dataclass, so the name tables must resolve lazily.
        from repro.core.exchange import validate_choice
        from repro.core.relevance import RELEVANCE_MODES
        from repro.core.topology import TOPOLOGIES
        if self.topology not in TOPOLOGIES:
            raise ValueError(
                f"unknown topology {self.topology!r}; expected one of "
                f"{TOPOLOGIES}")
        if self.relevance_mode not in RELEVANCE_MODES:
            raise ValueError(
                f"unknown relevance_mode {self.relevance_mode!r}; "
                f"expected one of {RELEVANCE_MODES}")
        if self.resample_every < 0:
            raise ValueError(
                f"resample_every must be >= 0, got {self.resample_every}")
        if self.resample_every > 0 and self.topology != "random_k":
            raise ValueError(
                f"resample_every > 0 needs topology='random_k', got "
                f"{self.topology!r}")
        validate_choice("schedule", self.exchange_schedule)
        validate_choice("estimator", self.exchange_estimator)
        validate_choice("delay", self.exchange_delay)
        validate_choice("combiner", self.exchange_combiner)
        if self.exchange_schedule == "relevance_topk":
            if self.topology != "random_k" or self.resample_every < 1:
                raise ValueError(
                    "exchange_schedule='relevance_topk' resamples a "
                    "gossip graph and needs topology='random_k' with "
                    "resample_every >= 1, got "
                    f"topology={self.topology!r}, "
                    f"resample_every={self.resample_every}")
        if self.exchange_schedule == "static" and self.resample_every:
            raise ValueError(
                "exchange_schedule='static' pins a fixed graph but "
                f"resample_every={self.resample_every} requests "
                "resampling — drop one of them")
        if not 0.0 <= self.explore_eps <= 1.0:
            raise ValueError(
                f"explore_eps must be in [0, 1], got "
                f"{self.explore_eps}")
        if self.topology == "random_k":
            if not 1 <= self.degree < max(self.n_agents, 2):
                raise ValueError(
                    f"random_k degree must satisfy 1 <= degree < "
                    f"n_agents (self-loop included; use topology="
                    f"'full' for k = n), got degree={self.degree} "
                    f"with n_agents={self.n_agents}")
        if not 0.0 <= self.relevance_ema < 1.0:
            raise ValueError(
                f"relevance_ema must be in [0, 1), got "
                f"{self.relevance_ema}")
        if self.relevance_sketch_dim < 0:
            raise ValueError(
                f"relevance_sketch_dim must be >= 0 (0 = exact "
                f"pairwise cosines), got {self.relevance_sketch_dim}")
        if (self.exchange_estimator not in ("auto", "grad_cos+sketch")
                and self.relevance_sketch_dim > 0):
            raise ValueError(
                f"exchange_estimator={self.exchange_estimator!r} "
                "does not sketch and would silently ignore "
                f"relevance_sketch_dim={self.relevance_sketch_dim} — "
                "use 'grad_cos+sketch' (or drop the dim)")
        if (self.relevance_sketch_dim > 0
                and self.relevance_mode != "grad_cos"
                and self.exchange_estimator != "grad_cos+sketch"):
            raise ValueError(
                f"relevance_sketch_dim > 0 sketches the grad_cos "
                f"estimator and needs relevance_mode='grad_cos' (or "
                f"exchange_estimator='grad_cos+sketch'), got "
                f"{self.relevance_mode!r}")
        if self.pods < 0:
            raise ValueError(f"pods must be >= 0, got {self.pods}")
        if self.pods > 0:
            if self.topology != "hierarchical":
                raise ValueError(
                    f"pods > 0 maps hierarchical pods onto a two-level "
                    f"mesh and needs topology='hierarchical', got "
                    f"{self.topology!r}")
            if self.n_agents != self.pods * self.degree:
                raise ValueError(
                    f"pod dispatch needs n_agents == pods * degree "
                    f"(uniform pods of `degree` agents), got "
                    f"n_agents={self.n_agents}, pods={self.pods}, "
                    f"degree={self.degree}")
            if (not self.pod_axis
                    or not isinstance(self.pod_axis, str)
                    or self.pod_axis == "agent"):
                raise ValueError(
                    f"pod_axis must be a non-empty mesh axis name "
                    f"distinct from the intra-pod 'agent' axis, got "
                    f"{self.pod_axis!r}")
        qb = self.knowledge_quant_block
        if qb < 0:
            raise ValueError(
                f"knowledge_quant_block must be >= 0, got {qb}")
        if qb > 0 and (qb % 128 != 0 or 8192 % qb != 0):
            raise ValueError(
                f"knowledge_quant_block must be a multiple of 128 "
                f"dividing 8192 (one scale per whole sublane row group "
                f"of the wavg kernel tile), got {qb}")
        for name in ("transport_loss", "transport_dup",
                     "transport_corrupt"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(
                    f"{name} is a per-message probability and must be "
                    f"in [0, 1], got {p}")
        if self.transport_jitter < 0:
            raise ValueError(
                f"transport_jitter must be >= 0 (max extra delivery "
                f"delay in epochs), got {self.transport_jitter}")
        if not 0 <= self.transport_retransmit <= 8:
            raise ValueError(
                f"transport_retransmit must be in [0, 8] (the delay "
                f"line grows by the 2^budget - 1 worst-case backoff), "
                f"got {self.transport_retransmit}")
        if self.transport_horizon < 1:
            raise ValueError(
                f"transport_horizon must be >= 1 (planned epochs "
                f"before the fault history replays), got "
                f"{self.transport_horizon}")
        if not 0.0 < self.transport_decay <= 1.0:
            raise ValueError(
                f"transport_decay must be in (0, 1] (per-epoch "
                f"staleness discount; 1.0 = none), got "
                f"{self.transport_decay}")
        if self.max_staleness is not None and self.max_staleness < 1:
            raise ValueError(
                f"max_staleness must be >= 1 (epochs; None disables "
                f"the cutoff), got {self.max_staleness}")
        validate_choice("transport", self.exchange_transport)
        if self.exchange_transport == "none" and (
                self.transport_loss > 0 or self.transport_dup > 0
                or self.transport_corrupt > 0
                or self.transport_jitter > 0):
            raise ValueError(
                "exchange_transport='none' would silently ignore the "
                "nonzero transport fault knobs (loss="
                f"{self.transport_loss}, dup={self.transport_dup}, "
                f"corrupt={self.transport_corrupt}, jitter="
                f"{self.transport_jitter}) — use 'faulty' (or 'auto') "
                "or zero the rates")
