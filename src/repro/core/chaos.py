"""Chaos — a seeded, host-side fault injector for elastic membership.

Faults are *planned*, not sampled on the fly: :func:`chaos_schedule`
rolls the whole kill/revive history up front with a dedicated
``numpy`` generator, so a schedule is a plain ``(n_epochs, n_agents)``
bool matrix that tests, the chaos CI lane and the ``--churn`` bench
row can all share — same seed, same faults, everywhere, regardless of
what else consumes randomness around it.

The injector never touches jax: membership events are host-side
decisions between jitted epochs (``DDAL.kill`` / ``DDAL.revive``,
``sharded_ddal.kill_agents`` / ``revive_agents``), and keeping the
planner in numpy means replaying a schedule can never perturb a
trainer's PRNG stream.

This module injects *membership* faults — whole agents die and
revive. Its sibling ``repro.core.transport`` injects *message* faults
(per-edge loss / duplication / corruption / delay-jitter on the
exchange path) with the same planned-up-front design; the two compose
freely, e.g. the CI chaos lane killing agents over a lossy transport.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def chaos_schedule(seed: int, n_agents: int, n_epochs: int,
                   kill_prob: float = 0.1, revive_after: int = 3,
                   min_alive: int = 1) -> np.ndarray:
    """Plan a deterministic kill/revive history.

    Returns ``alive[e, i]`` — whether agent ``i`` participates in
    epoch ``e``. Per epoch, each live agent dies with ``kill_prob``;
    a dead agent stays down exactly ``revive_after`` epochs, then
    revives. Kills are skipped (in agent order) whenever they would
    leave fewer than ``min_alive`` survivors, so the group never goes
    dark. Epoch 0 is always all-alive.
    """
    if not 0.0 <= kill_prob <= 1.0:
        raise ValueError(f"kill_prob must be in [0, 1], got {kill_prob}")
    if revive_after < 1:
        raise ValueError(f"revive_after must be >= 1, got {revive_after}")
    if not 1 <= min_alive <= n_agents:
        raise ValueError(f"min_alive must be in [1, {n_agents}], "
                         f"got {min_alive}")
    rng = np.random.default_rng(seed)
    down_until = np.zeros(n_agents, np.int64)     # first epoch back up
    alive = np.ones((n_epochs, n_agents), bool)
    for e in range(1, n_epochs):
        cur = down_until <= e                      # alive entering e
        wants = cur & (rng.random(n_agents) < kill_prob)
        budget = int(cur.sum()) - min_alive        # kills we can afford
        for i in np.flatnonzero(wants):
            if budget <= 0:
                break
            down_until[i] = e + revive_after
            budget -= 1
        alive[e] = down_until <= e
    return alive


def membership_events(alive: np.ndarray
                      ) -> Iterator[Tuple[int, np.ndarray, np.ndarray]]:
    """Diff a schedule into per-epoch events.

    Yields ``(epoch, kill_mask, revive_mask)`` for every epoch whose
    membership differs from the previous one — the masks to hand to
    ``kill`` / ``revive`` *before* running that epoch. Epochs with no
    change are skipped.
    """
    alive = np.asarray(alive, bool)
    for e in range(1, alive.shape[0]):
        kill = alive[e - 1] & ~alive[e]
        revive = ~alive[e - 1] & alive[e]
        if kill.any() or revive.any():
            yield e, kill, revive
