"""Training launcher: DDAL group-agent training of any model-zoo arch.

On the CPU rig this runs REDUCED configs end-to-end (real data → real
gradients → eq. 4 knowledge exchange → optimiser); on a TPU pod the
same code path runs the full config over the production mesh
(--mesh prod / prod-multipod).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --agents 2 --steps 30 --batch 4 --seq 128 --threshold 5 \
        --minibatch 5 [--full] [--ckpt out.npz]
"""
from __future__ import annotations

import argparse
import time
import warnings


# Legacy named flags are kept as thin shims over the --exchange
# vocabulary (each still works, but explicit use now emits a
# DeprecationWarning pointing at the docs/exchange.md migration
# table; new strategies never add flags here — they arrive through
# the registry automatically).
_DEPRECATION = " [deprecated spelling of --exchange {key}=N]"

# legacy flag → (GroupSpec field, default applied when unset). Flags
# parse with a None sentinel so only *explicit* use warns.
_LEGACY_FLAGS = {
    "topology": ("topology", "full"),
    "degree": ("degree", 4),
    "topology-seed": ("topology_seed", 0),
    "pods": ("pods", 0),
    "pod-axis": ("pod_axis", "pod"),
    "resample-every": ("resample_every", 0),
    "relevance-mode": ("relevance_mode", "uniform"),
    "relevance-ema": ("relevance_ema", 0.9),
    "relevance-sketch-dim": ("relevance_sketch_dim", 0),
}


def _legacy_spec_kw(args) -> dict:
    """Fold the legacy named flags into GroupSpec kwargs, warning on
    each explicit (non-None) use with its --exchange spelling."""
    kw = {}
    for flag, (field, default) in _LEGACY_FLAGS.items():
        value = getattr(args, field)
        if value is None:
            kw[field] = default
        else:
            warnings.warn(
                f"--{flag} is deprecated: spell it --exchange "
                f"{field}={value} (see docs/exchange.md, 'Migration: "
                f"old GroupSpec flags -> strategies')",
                DeprecationWarning, stacklevel=2)
            kw[field] = value
    return kw


def _exchange_kv(text: str):
    """Parse one ``--exchange key=value`` item against the registry
    vocabulary (``repro.core.exchange.cli_options``): the key names
    either a strategy selector (schedule/estimator/delay/combiner) or
    any registered strategy's declared parameter, and the value is
    coerced to that parameter's type."""
    from repro.core.exchange import cli_options
    opts = cli_options()
    key, sep, value = text.partition("=")
    if not sep:
        raise argparse.ArgumentTypeError(
            f"--exchange wants key=value, got {text!r}")
    if key not in opts:
        raise argparse.ArgumentTypeError(
            f"unknown exchange option {key!r}; valid keys: "
            f"{', '.join(sorted(opts))}")
    field, typ = opts[key]
    try:
        return field, typ(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--exchange {key} wants a {typ.__name__}, got {value!r}")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="llama3.2-3b")
    p.add_argument("--agents", type=int, default=2)
    p.add_argument("--steps", type=int, default=30)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--threshold", type=int, default=5)
    p.add_argument("--minibatch", type=int, default=5)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--exchange", action="append", default=[],
                   type=_exchange_kv, metavar="KEY=VALUE",
                   help="exchange-protocol configuration "
                        "(repro.core.exchange): KEY is a strategy "
                        "selector (schedule= estimator= delay= "
                        "combiner=) or any registered strategy's "
                        "parameter (e.g. resample_every= "
                        "relevance_ema= explore_eps= pods=). "
                        "Repeatable; keys and types come from the "
                        "strategy registry, so newly registered "
                        "strategies need no new flags. Examples: "
                        "--exchange schedule=relevance_topk "
                        "--exchange explore_eps=0.2; faulty-network "
                        "training: --exchange transport=faulty "
                        "--exchange loss=0.2 --exchange corrupt=0.05 "
                        "(repro.core.transport)")
    p.add_argument("--topology", default=None,
                   choices=["full", "ring", "torus2d", "star",
                            "random_k", "hierarchical"],
                   help="communication graph"
                        + _DEPRECATION.format(key="topology"))
    p.add_argument("--degree", type=int, default=None,
                   help="k for random_k; pod size for hierarchical"
                        + _DEPRECATION.format(key="degree"))
    p.add_argument("--topology-seed", type=int, default=None,
                   help="gossip sampling seed"
                        + _DEPRECATION.format(key="topology_seed"))
    p.add_argument("--pods", type=int, default=None,
                   help="multi-host dispatch: map hierarchical pods "
                        "onto a two-level (pod, agent) mesh — "
                        "intra-pod exchange stays on the fast agent "
                        "axis, only pod leaders' planes cross the pod "
                        "axis (requires --topology hierarchical and "
                        "agents == pods * degree; 0 = flat combine)"
                        + _DEPRECATION.format(key="pods"))
    p.add_argument("--pod-axis", default=None,
                   help="mesh axis name the leader-level exchange "
                        "crosses (--pods only)"
                        + _DEPRECATION.format(key="pod_axis"))
    p.add_argument("--resample-every", type=int, default=None,
                   help="dynamic gossip: resample the random_k "
                        "neighbor table every N steps inside the "
                        "jitted loop (0 = static wiring; requires "
                        "--topology random_k)"
                        + _DEPRECATION.format(key="resample_every"))
    p.add_argument("--relevance-mode", default=None,
                   choices=["uniform", "grad_cos"],
                   help="eq. 4 per-edge relevance R: 'uniform' "
                        "(paper §6 static prior) or 'grad_cos' "
                        "(learned online from the cosine similarity "
                        "of the agents' share-window gradients) "
                        "[deprecated spelling of --exchange "
                        "estimator=...]")
    p.add_argument("--relevance-ema", type=float, default=None,
                   help="EMA decay of the learned relevance estimate "
                        "across share steps (grad_cos only)"
                        + _DEPRECATION.format(key="relevance_ema"))
    p.add_argument("--relevance-sketch-dim", type=int, default=None,
                   help="sketched streaming relevance (grad_cos "
                        "only): project each agent's gradients "
                        "through a seeded ±1 random projection into "
                        "an (agents, d) sketch and estimate cosines "
                        "on sketches — O(agents·|params|) streaming "
                        "+ O(agents²·d) comparisons instead of "
                        "O(agents²·|params|); 0 = exact pairwise "
                        "cosines (d ≈ 256 keeps worst-case cosine "
                        "error ≈ 0.06 before EMA averaging)"
                        + _DEPRECATION.format(
                            key="relevance_sketch_dim"))
    p.add_argument("--full", action="store_true",
                   help="full (not reduced) config — TPU pods only")
    p.add_argument("--mesh", default="cpu",
                   choices=["cpu", "prod", "prod-multipod", "pods"],
                   help="'pods' builds the two-level (pod, agent) "
                        "mesh over the visible devices (simulate with "
                        "XLA_FLAGS=--xla_force_host_platform_"
                        "device_count=N) and runs the pod-dispatched "
                        "combine collectives; 'cpu' with --pods runs "
                        "the same decomposition without collectives")
    p.add_argument("--elastic", action="store_true",
                   help="elastic group membership: carry a per-agent "
                        "alive mask through the exchange so agents "
                        "can be killed/revived between steps without "
                        "perturbing survivors (see docs/exchange.md, "
                        "'Membership semantics')")
    p.add_argument("--ckpt", default=None,
                   help="save final params to this .npz")
    p.add_argument("--ckpt-full", default=None,
                   help="save the FULL TrainState — params, optimiser "
                        "state, and the exchange window (Knowledge "
                        "incl. sketches and learned relevance) — so a "
                        "preempted run rejoins mid-stream via "
                        "--restore instead of resetting the group")
    p.add_argument("--restore", default=None,
                   help="restore a --ckpt-full TrainState before "
                        "training (leaves missing from older "
                        "checkpoints, e.g. the elastic alive mask, "
                        "keep their freshly initialised values)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    import jax

    from repro import optim
    from repro.checkpoint import save
    from repro.common.sharding import set_mesh
    from repro.configs import get_arch_config
    from repro.configs.base import GroupSpec, ShapeConfig
    from repro.core import init_train_state, make_group_train_step
    from repro.data import StreamSpec, make_group_batch
    from repro.launch.mesh import make_pod_mesh, make_production_mesh

    cfg = get_arch_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    # legacy named flags first (deprecation-warned when explicit),
    # --exchange key=value pairs layered on top (later spellings win)
    # — both feed the same GroupSpec fields
    spec_kw = _legacy_spec_kw(args)
    for field, value in args.exchange:
        spec_kw[field] = value
    spec = GroupSpec(n_agents=args.agents, threshold=args.threshold,
                     minibatch=args.minibatch,
                     knowledge_mode="streaming", elastic=args.elastic,
                     **spec_kw)
    shape = ShapeConfig("train_cli", args.seq, args.batch, "train")
    opt = optim.adamw(args.lr)
    stream = StreamSpec(seed=args.seed)

    # mesh wiring reads the merged spec, so --exchange pods=N /
    # pod_axis=X and the legacy named flags behave identically
    mesh = None
    if args.mesh == "pods":
        if spec.pods < 1:
            raise SystemExit("--mesh pods needs --pods >= 1 (or "
                             "--exchange pods=N)")
        mesh = make_pod_mesh(spec.pods, pod_axis=spec.pod_axis)
        ctx = set_mesh(mesh)
    elif args.mesh != "cpu":
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multipod")
        ctx = set_mesh(mesh)
    else:
        import contextlib
        ctx = contextlib.nullcontext()

    key = jax.random.PRNGKey(args.seed)
    with ctx:
        # one protocol serves state init and the step: the carried
        # relevance state and the step's estimator can never drift
        from repro.core.exchange import build_exchange
        exchange = build_exchange(spec, mesh, kind="streaming")
        state = init_train_state(cfg, spec, opt, key,
                                 exchange=exchange)
        if args.restore:
            from repro.checkpoint import restore
            state = restore(args.restore, state, strict=False)
            print(f"restored full TrainState from {args.restore} "
                  f"(step {int(state.step)})")
        if mesh is not None:
            from repro.launch.shardings import agent_sharded_state
            state = agent_sharded_state(state, mesh, spec.pod_axis)
        step_fn = jax.jit(make_group_train_step(cfg, spec, opt,
                                                exchange=exchange))
        n_params = sum(int(x.size) for x in
                       jax.tree.leaves(state.params)) // args.agents
        print(f"arch={args.arch} reduced={not args.full} "
              f"params/agent={n_params:,} agents={args.agents}")
        t0 = time.time()
        for i in range(args.steps):
            batch = make_group_batch(cfg, shape, stream, args.agents, i)
            state, m = step_fn(state, batch)
            losses = " ".join(f"{float(l):6.3f}" for l in m["loss"])
            tag = " <shared>" if int(m["shared"]) else ""
            print(f"step {i:4d} losses [{losses}]{tag}")
        dt = time.time() - t0
        toks = args.steps * args.agents * args.batch * args.seq
        print(f"{args.steps} steps in {dt:.1f}s "
              f"({toks / dt:,.0f} tokens/s)")
        if args.ckpt:
            save(args.ckpt, state.params, step=args.steps)
            print(f"saved params to {args.ckpt}")
        if args.ckpt_full:
            save(args.ckpt_full, state, step=int(state.step))
            print(f"saved full TrainState to {args.ckpt_full}")


if __name__ == "__main__":
    main()
