"""Communication topologies for DDAL — neighbor-indexed sparse graphs.

The paper's group is a set of geographically distributed agents that
exchange knowledge over a *communication graph*, not a shared
environment (paper §5; arXiv 2501.11818 and 1912.03821 make the same
point for networked MARL). The seed repo simulated that graph with a
dense all-to-all delay line — O(n²·D·|params|) memory — and used
``GroupSpec.topology`` only as a relevance prior. This module makes the
graph first-class:

A ``Topology`` is a *neighbor index table*: for every destination agent
``i``, ``nbr[i, j]`` names the source agent feeding its ``j``-th
incoming edge slot (``j < k``), with a validity ``mask`` for
non-uniform in-degrees and per-edge ``delay`` / ``relevance``
annotations. All arrays are static (host-built with numpy) so they jit
as constants; knowledge exchange becomes gather/scatter over the table
(``repro.core.knowledge.sparse_send`` / ``sparse_deliver``) with
delay-line memory O(n·k·D) instead of O(n²·D). The dense ``full``
topology is the ``k = n`` special case, so the seed semantics are a
strict subset.

Every constructor includes the self-loop edge (an agent's own pieces
always enter its own store K_i, paper Algorithm 1 line 8) with delay 0
unless overridden.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np


class Topology(NamedTuple):
    """Sparse communication graph over ``n`` agents.

    nbr:       (n, k) int32 — ``nbr[i, j]`` = source agent of dst i's
               j-th incoming edge (arbitrary value where masked out).
    mask:      (n, k) bool — which edge slots are real edges.
    delay:     (n, k) int32 — per-edge delivery delay in epochs.
    relevance: (n, k) float32 — per-edge relevance R[src→dst] fed to
               the eq. 4 weighting on delivery.
    """
    nbr: jnp.ndarray
    mask: jnp.ndarray
    delay: jnp.ndarray
    relevance: jnp.ndarray

    # ------------------------------------------------------------------
    @property
    def n_agents(self) -> int:
        return self.nbr.shape[0]

    @property
    def degree(self) -> int:
        """Max in-degree k (the padded edge-slot count)."""
        return self.nbr.shape[1]

    @property
    def n_edges(self) -> int:
        """Number of real (unmasked) edges, self-loops included."""
        return int(np.asarray(self.mask).sum())

    @property
    def max_delay(self) -> int:
        return int(np.asarray(jnp.max(self.delay * self.mask)))

    # ------------------------------------------------------------------
    def with_delay(self, delay, per_edge: bool = False) -> "Topology":
        """Attach delays: a scalar, an (n, n) src→dst matrix (gathered
        onto the edge table), or an (n, k) per-edge array. When k == n
        the two array forms are shape-ambiguous and the dense src→dst
        reading wins — pass ``per_edge=True`` to force the
        (dst, edge-slot) interpretation (they differ by a transpose on
        the ``full`` topology)."""
        n, k = self.nbr.shape
        d = jnp.asarray(delay, jnp.int32)
        if d.ndim == 0:
            d = jnp.full((n, k), d, jnp.int32)
        elif d.shape == (n, n) and not per_edge:
            dst = jnp.arange(n)[:, None]
            d = d[self.nbr, dst]                      # (n, k)
        elif d.shape != (n, k):
            raise ValueError(f"delay shape {d.shape} != (), ({n},{n}) "
                             f"or ({n},{k})")
        return self._replace(delay=jnp.where(self.mask, d, 0))

    def with_relevance(self, relevance,
                       per_edge: bool = False) -> "Topology":
        """Attach relevance: an (n, n) matrix R[src, dst] (gathered
        onto the edge table) or an (n, k) per-edge array. See
        ``with_delay`` for the k == n ambiguity and ``per_edge``."""
        n, k = self.nbr.shape
        r = jnp.asarray(relevance, jnp.float32)
        if r.shape == (n, n) and not per_edge:
            dst = jnp.arange(n)[:, None]
            r = r[self.nbr, dst]
        elif r.shape != (n, k):
            raise ValueError(f"relevance shape {r.shape} != ({n},{n}) "
                             f"or ({n},{k})")
        return self._replace(
            relevance=jnp.where(self.mask, r, 0.0))

    def dense_relevance(self) -> jnp.ndarray:
        """Scatter the edge relevance back to an (n, n) R[src, dst]
        matrix (zeros off-graph) — for code still wanting the dense
        form (e.g. the streaming trainer's matmul path)."""
        n, k = self.nbr.shape
        R = jnp.zeros((n, n), jnp.float32)
        dst = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
        vals = jnp.where(self.mask, self.relevance, 0.0)
        return R.at[self.nbr, dst].add(vals)

    def delay_line_bytes(self, n_params: int, max_delay: int,
                         dtype_bytes: int = 4) -> int:
        """Static memory of a SparseInFlight over this topology
        (D+1 delivery planes + 1 scratch plane)."""
        n, k = self.nbr.shape
        planes = max_delay + 2
        meta = 3 * n * k * planes * 4        # T, R (+valid ≈ 1B, round)
        return n * k * planes * n_params * dtype_bytes + meta


# ---------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------
def _from_neighbor_lists(nbrs: Sequence[Sequence[int]]) -> Topology:
    """Build a padded (n, k) table from per-dst in-neighbor lists."""
    n = len(nbrs)
    k = max(1, max(len(v) for v in nbrs))
    nbr = np.zeros((n, k), np.int32)
    mask = np.zeros((n, k), bool)
    for i, v in enumerate(nbrs):
        nbr[i, :len(v)] = v
        mask[i, :len(v)] = True
    return Topology(
        nbr=jnp.asarray(nbr),
        mask=jnp.asarray(mask),
        delay=jnp.zeros((n, k), jnp.int32),
        relevance=jnp.asarray(mask, jnp.float32),
    )


def full(n: int) -> Topology:
    """All-to-all: k = n, ``nbr[i, j] = j`` — the dense seed layout as
    a special case (edge slot order == source order, so the sparse
    path is bitwise-identical to the dense reference)."""
    return _from_neighbor_lists([list(range(n)) for _ in range(n)])


def ring(n: int) -> Topology:
    """Bidirectional ring: each agent hears itself and its two ring
    neighbours (matches ``relevance_matrix(n, "ring")``'s support)."""
    return _from_neighbor_lists(
        [sorted({(i - 1) % n, i, (i + 1) % n}) for i in range(n)])


def torus2d(rows: int, cols: int) -> Topology:
    """2-D torus (rows × cols grid, wrap-around): self + the 4-mesh
    neighbourhood — the classic pod-interconnect shape."""
    n = rows * cols
    nbrs = []
    for i in range(n):
        r, c = divmod(i, cols)
        nbrs.append(sorted({
            i,
            ((r - 1) % rows) * cols + c,
            ((r + 1) % rows) * cols + c,
            r * cols + (c - 1) % cols,
            r * cols + (c + 1) % cols,
        }))
    return _from_neighbor_lists(nbrs)


def star(n: int, hub: int = 0) -> Topology:
    """Hub-and-spoke: every leaf exchanges with the hub only. The hub's
    in-degree is n (it hears everyone), so the padded k is n — star is
    inherently centralised; use it for parameter-server-style groups."""
    nbrs = []
    for i in range(n):
        if i == hub:
            nbrs.append(list(range(n)))
        else:
            nbrs.append(sorted({i, hub}))
    return _from_neighbor_lists(nbrs)


def random_k(n: int, k: int, seed: int = 0) -> Topology:
    """Seeded gossip graph: each destination hears itself plus k−1
    distinct uniformly-drawn other agents. Regular in-degree k, so the
    delay line is exactly (n, k, D+1) with no padding waste."""
    if k < 1:
        raise ValueError("random_k needs k >= 1 (the self-loop)")
    k = min(k, n)
    rng = np.random.default_rng(seed)
    nbrs = []
    for i in range(n):
        others = np.delete(np.arange(n), i)
        pick = rng.choice(others, size=k - 1, replace=False)
        nbrs.append(sorted({i, *pick.tolist()}))
    return _from_neighbor_lists(nbrs)


def hierarchical(n: int, pod_size: int = 4) -> Topology:
    """Pods-of-pods: dense all-to-all inside each pod of ``pod_size``
    agents; the first agent of each pod is a *leader* additionally
    connected all-to-all with the other leaders. Knowledge crosses pods
    in two hops (member → leader → member), mirroring ICI-dense /
    DCN-sparse pod fabrics."""
    pod_size = max(1, min(pod_size, n))
    leaders = list(range(0, n, pod_size))
    nbrs = []
    for i in range(n):
        pod = i // pod_size
        members = [j for j in range(pod * pod_size,
                                    min((pod + 1) * pod_size, n))]
        s = set(members) | {i}
        if i in leaders:
            s |= set(leaders)
        nbrs.append(sorted(s))
    return _from_neighbor_lists(nbrs)


# ---------------------------------------------------------------------
# GroupSpec dispatch
# ---------------------------------------------------------------------
TOPOLOGIES = ("full", "ring", "torus2d", "star", "random_k",
              "hierarchical")


def _torus_dims(n: int):
    """Most-square rows × cols factorisation of n."""
    r = int(math.isqrt(n))
    while n % r:
        r -= 1
    return r, n // r


def make_topology(spec, delay=None,
                  relevance=None) -> Topology:
    """Build the topology named by a ``GroupSpec`` (``topology``,
    ``degree``, ``topology_seed``), then attach optional dense or
    per-edge ``delay`` / ``relevance`` overrides."""
    n = spec.n_agents
    name = spec.topology
    if name == "full":
        topo = full(n)
    elif name == "ring":
        topo = ring(n)
    elif name == "torus2d":
        topo = torus2d(*_torus_dims(n))
    elif name == "star":
        topo = star(n)
    elif name == "random_k":
        topo = random_k(n, spec.degree, spec.topology_seed)
    elif name == "hierarchical":
        topo = hierarchical(n, pod_size=spec.degree)
    else:
        raise ValueError(
            f"unknown topology {name!r}; expected one of {TOPOLOGIES}")
    if relevance is not None:
        topo = topo.with_relevance(relevance)
    if delay is not None:
        topo = topo.with_delay(delay)
    return topo
