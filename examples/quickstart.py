"""Quickstart — the paper in 60 seconds.

Two A2C agents play CartPole-v0 in *separate* environments and share
gradient knowledge through DDAL (paper Algorithm 1). Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro import optim
from repro.configs.base import GroupSpec
from repro.core import DDAL
from repro.rl import CartPole, init_a2c, make_a2c_callbacks

EPOCHS = 1_500
THRESHOLD = 600          # epochs of independent warm-up learning

env = CartPole()                               # each agent gets its own
opt = optim.adamw(3e-3)
spec = GroupSpec(n_agents=2, threshold=THRESHOLD, minibatch=100,
                 m_pieces=32)

gen_grads, apply_grads, params_of = make_a2c_callbacks(env, opt)
ddal = DDAL(spec, gen_grads, apply_grads, params_of)

key = jax.random.PRNGKey(0)
agent_states = jax.vmap(lambda k: init_a2c(k, env, opt))(
    jax.random.split(key, spec.n_agents))
group = ddal.init(agent_states)

group, metrics = jax.jit(lambda g, k: ddal.run(g, k, EPOCHS))(
    group, jax.random.PRNGKey(1))
rewards = np.asarray(metrics["return"])        # (EPOCHS, 2)

for a in range(spec.n_agents):
    before = rewards[:THRESHOLD, a].mean()
    after = rewards[-300:, a].mean()
    print(f"agent {a}: mean reward {before:6.1f} (warm-up) -> "
          f"{after:6.1f} (after group sharing)")
print("knowledge sharing starts at epoch", THRESHOLD,
      "- a reward of 100 is the optimum")
