"""Multi-host pod dispatch for hierarchical DDAL (ISSUE 3).

The ``hierarchical`` topology is pods-of-pods: dense exchange inside a
pod, sparse leader-to-leader exchange across pods. On a flat mesh the
streaming combine (``repro.core.sharded_ddal._combine_topo``) contracts
the full (A, A) adjacency over the sharded agent axis, so *every*
agent's accumulator planes cross whatever interconnect the axis is
mapped to — O(n·k·|params|) traffic. This module maps the pod
structure onto a real two-level ``(pod_axis, "agent")`` mesh instead:

* **intra-pod segment** — each destination's sum over its pod members
  runs entirely inside the pod's device row (``all_gather`` over the
  fast ``"agent"`` axis, ICI on a TPU pod), touching no cross-pod
  link;
* **leader-level segment** — only each pod's *leader* planes
  (tg/rg + the tsum/rsum scalars) cross the slow ``pod_axis`` (DCN):
  a ``ppermute`` rotation per leader edge-list shift, or a single
  ``psum`` when the leader clique is complete and unweighted (the
  leader's own plane is subtracted back out — the masked leader
  self-edge; it already entered through the intra-pod sum).

Cross-pod traffic is therefore O(pods · k_leader · |params|) per share
step instead of O(n · k · |params|) — it scales with the number of
pods, not the number of agents (``cross_pod_bytes`` /
``flat_exchange_bytes`` account both sides; the benchmark sweep in
``benchmarks/bench_topology_scaling.py --pods`` reports them).
Learned relevance rides the same placement: with
``GroupSpec.relevance_sketch_dim > 0`` the per-round gradient-cosine
observation is computed on the carried (n, d) window sketches, so
cross-pod relevance exchange is O(pods · n · d) bytes — never the
parameter-sized accumulators the exact Gram would contract
(``relevance_exchange_bytes`` accounts it, reported in
``benchmarks/bench_relevance_sketch.py``'s JSON record; the
no-parameter-sized-intermediate property itself is gated there by
the jaxpr peak-intermediate check).

Trainers reach this module through the exchange protocol's ``pod``
combiner strategy (``repro.core.exchange.combiners`` — selected by
``GroupSpec.pods > 0`` or ``exchange_combiner="pod"``), never
directly: ``make_pod_dispatch`` builds the combine closure once at
protocol-build time.

Equivalence oracle: both paths reuse ``_edge_sums`` /
``_finish_combine`` from ``sharded_ddal``, and with one pod the
cross-pod segment vanishes *statically* — the dispatched combine is
then the same computation as ``_combine_topo``, pinned bitwise in
``tests/test_pod_dispatch.py``. Everything runs on simulated devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), so the tests
exercise the real collectives on CPU rigs and CI alike; true
multi-process ``jax.distributed`` bring-up is the ROADMAP follow-up.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_map
from repro.common.sharding import shard_map
from repro.core.sharded_ddal import (
    Knowledge,
    _edge_sums,
    _finish_combine,
    mask_knowledge,
)
from repro.core.topology import PodLayout, Topology, cross_pod_mask


class PodEdges(NamedTuple):
    """The hierarchical edge set split by the mesh axis it crosses.

    intra_mask:  (n, k) bool — edges local to the destination's pod
                 (same slot layout as ``topo.nbr``).
    leader_mask: (n, k) bool — cross-pod edges; validation guarantees
                 they connect pod leaders only.
    ledge:       (pods, pods) bool — leader adjacency
                 ``ledge[src_pod, dst_pod]``, diagonal False (the
                 leader self-edge is masked: a leader's own plane
                 enters eq. 4 through the intra-pod segment only).
    lslot:       (pods, pods) int32 — edge slot of src pod's leader in
                 dst leader's row (-1 where no edge), for per-edge
                 relevance lookup.
    """
    intra_mask: np.ndarray
    leader_mask: np.ndarray
    ledge: np.ndarray
    lslot: np.ndarray


def split_topology(topo: Topology, layout: PodLayout) -> PodEdges:
    """Partition the edge table into intra-pod and leader-level sets.

    Raises if any cross-pod edge is not leader→leader — such a graph
    has no two-level placement (a member's plane would need to ride
    the DCN axis directly)."""
    n, k = np.asarray(topo.nbr).shape
    if layout.n_agents != n:
        raise ValueError(
            f"layout covers {layout.n_agents} agents, topology has {n}")
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    cross = cross_pod_mask(topo, layout)
    intra = mask & ~cross
    is_leader = np.asarray(layout.leader_mask)
    bad = cross & ~(is_leader[nbr] & is_leader[:, None])
    if bad.any():
        dst, slot = np.argwhere(bad)[0]
        raise ValueError(
            f"cross-pod edge {int(nbr[dst, slot])}→{int(dst)} does not "
            f"connect two pod leaders — the topology cannot be "
            f"pod-dispatched (only leader planes may cross the pod "
            f"axis)")
    pods = layout.n_pods
    pod_id = np.asarray(layout.pod_id)
    ledge = np.zeros((pods, pods), bool)
    lslot = np.full((pods, pods), -1, np.int32)
    for dst, slot in np.argwhere(cross):
        sp, dp = int(pod_id[nbr[dst, slot]]), int(pod_id[dst])
        ledge[sp, dp] = True
        lslot[sp, dp] = slot
    # ledge's diagonal is False by construction: a same-pod leader
    # edge cannot be in `cross`, so the leader self-edge lands in the
    # intra segment and is counted exactly once
    return PodEdges(intra_mask=intra, leader_mask=cross, ledge=ledge,
                    lslot=lslot)


# ---------------------------------------------------------------------
# traffic accounting
# ---------------------------------------------------------------------
def _edge_cost(n_params: int, dtype_bytes: int,
               quant_block: int = 0) -> int:
    """Bytes one directed edge moves per share step: the source's two
    accumulator planes (tg, rg) plus the (tsum, rsum) scalars. With
    ``quant_block > 0`` each plane is int8 wire format — 1 byte per
    element plus one fp32 scale per ``quant_block`` elements — instead
    of ``dtype_bytes`` per element (~4× lighter at fp32)."""
    if quant_block > 0:
        plane = n_params + (-(-n_params // quant_block)) * 4
    else:
        plane = n_params * dtype_bytes
    return 2 * plane + 2 * 4


def cross_pod_bytes(edges: PodEdges, n_params: int,
                    dtype_bytes: int = 4,
                    quant_block: int = 0) -> int:
    """Cross-pod traffic per share step of the *dispatched* combine:
    only the directed leader edges move data over the pod axis —
    O(pods · k_leader · |params|), independent of pod size.
    ``quant_block`` mirrors ``GroupSpec.knowledge_quant_block``: int8
    planes + per-block scales instead of ``dtype_bytes``/element."""
    return int(edges.ledge.sum()) * _edge_cost(n_params, dtype_bytes,
                                               quant_block)


def relevance_exchange_bytes(n_agents: int, n_params: int,
                             sketch_dim: int,
                             dtype_bytes: int = 4) -> int:
    """Bytes the learned-relevance observation moves across the agent
    sharding per share step (ISSUE 4). The exact ``grad_cos`` Gram
    contracts the (A, P) window accumulators against themselves, so
    every agent's parameter-sized ``rg`` rows cross the mesh —
    O(A · |params|). The sketched estimator
    (``GroupSpec.relevance_sketch_dim > 0``) gathers only the carried
    (A, d) window sketches (``Knowledge.sk``) — O(A · d) bytes,
    independent of |params|: at pod scale, O(pods · n · d) instead of
    anything parameter-sized."""
    per_row = n_params if sketch_dim <= 0 else sketch_dim
    return n_agents * per_row * dtype_bytes


def flat_exchange_bytes(topo: Topology, n_params: int,
                        dtype_bytes: int = 4,
                        quant_block: int = 0) -> int:
    """What the single-flat-mesh combine moves between devices: every
    non-self edge's source planes cross a device boundary (a flat
    placement gives pod structure no locality) — O(n · k · |params|),
    growing with agent count. ``quant_block`` as in
    :func:`cross_pod_bytes`."""
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    self_edge = nbr == np.arange(nbr.shape[0])[:, None]
    return int((mask & ~self_edge).sum()) * _edge_cost(
        n_params, dtype_bytes, quant_block)


# ---------------------------------------------------------------------
# the dispatched combine
# ---------------------------------------------------------------------
def _leader_terms_dense(know: Knowledge, topo: Topology,
                        edges: PodEdges, rel):
    """Reference (single-device) leader-level segment: the same
    ``_edge_sums`` restricted to the cross-pod edge list."""
    lm = jnp.asarray(edges.leader_mask)
    return _edge_sums(know, topo.nbr, lm, jnp.where(lm, rel, 0.0))


def make_pod_dispatch(topo: Topology, layout: PodLayout, *,
                      mesh=None, pod_axis: str = "pod",
                      agent_axis: str = "agent"):
    """Build ``combine(know, rel=None, alive=None) -> ḡ`` for a
    hierarchical topology placed on pods.

    With ``mesh`` carrying both ``pod_axis`` and ``agent_axis`` the
    combine runs under ``shard_map``: intra-pod sums gather over the
    agent axis only, and the leader exchange is the only collective on
    the pod axis. Without a mesh (single-device rigs) the identical
    decomposition runs as plain array ops. ``rel`` overrides the
    per-edge relevance table (traced — the learned-R path); ``None``
    uses the topology's static table. ``alive`` ((n,) bool, elastic
    membership) zeroes dead agents' accumulator rows *before* either
    segment runs: a dead leader's cross-pod term is its own (now
    zero) plane, so nothing of its pod crosses the pod axis, and a
    dead member contributes zero to its pod's intra sums — dead
    destinations' output rows are garbage the trainer selects away.
    """
    edges = split_topology(topo, layout)
    if mesh is not None and (pod_axis in mesh.axis_names
                             and agent_axis in mesh.axis_names):
        return _make_sharded_dispatch(topo, layout, edges, mesh,
                                      pod_axis, agent_axis)
    return _make_reference_dispatch(topo, layout, edges)


def _make_reference_dispatch(topo: Topology, layout: PodLayout,
                             edges: PodEdges):
    """The decomposed combine as plain array ops (no mesh): intra-pod
    edge sums plus — statically skipped for one pod — the leader-level
    edge sums. With one pod the intra edge set *is* the full edge set,
    so this is the same computation as ``_combine_topo`` (the bitwise
    1-pod oracle)."""
    intra_mask = jnp.asarray(edges.intra_mask)
    multi_pod = layout.n_pods > 1

    def combine(know: Knowledge, rel: Optional[jnp.ndarray] = None,
                alive=None):
        rel = topo.relevance if rel is None else rel
        know = mask_knowledge(know, alive)
        tnum, tden, rnum, rden = _edge_sums(
            know, topo.nbr, intra_mask, jnp.where(intra_mask, rel, 0.0))
        if multi_pod:
            lt, ltd, lr, lrd = _leader_terms_dense(know, topo, edges,
                                                   rel)
            tnum = tree_map(jnp.add, tnum, lt)
            rnum = tree_map(jnp.add, rnum, lr)
            tden = tden + ltd
            rden = rden + lrd
        return _finish_combine(tnum, tden, rnum, rden)

    return combine


def _make_sharded_dispatch(topo: Topology, layout: PodLayout,
                           edges: PodEdges, mesh, pod_axis: str,
                           agent_axis: str):  # pragma: no cover — runs
    # only with a multi-device mesh: the `multi_device` tests cover it
    # inline in the CI multi-device lane / via subprocess re-exec
    # locally, both invisible to the fast lane's in-process pytest-cov
    """The decomposed combine under ``shard_map`` on a two-level mesh.

    Placement contract (validated): agents shard contiguously over
    ``(pod_axis, agent_axis)``, topology pods align with the mesh's
    pod rows (``layout.n_pods == mesh.shape[pod_axis]``), and the pod
    size divides evenly over the agent axis. Each device gathers its
    pod's accumulators over the agent axis (intra-pod traffic only),
    runs the pod-local ``_edge_sums``, and the leader segment moves
    exactly the leader planes across the pod axis.
    """
    from jax.sharding import PartitionSpec as P

    pods = layout.n_pods
    pod_size = layout.pod_size
    n_pod_dev = mesh.shape[pod_axis]
    n_agent_dev = mesh.shape[agent_axis]
    if pods != n_pod_dev:
        raise ValueError(
            f"topology has {pods} pods but mesh axis "
            f"{pod_axis!r} has {n_pod_dev} devices — pods must map "
            f"1:1 onto the pod axis")
    if pod_size % n_agent_dev:
        raise ValueError(
            f"pod size {pod_size} does not divide over the "
            f"{n_agent_dev}-device {agent_axis!r} axis")
    blk = pod_size // n_agent_dev
    k = topo.degree

    # pod-local intra edge tables: same slot layout as the global
    # table, sources remapped to pod-local indices (gather targets
    # after the all_gather). Stacked (pods, pod_size, k); the device's
    # pod row selects its slice by axis_index at trace time.
    nbr_g = np.asarray(topo.nbr).reshape(pods, pod_size, k)
    pod_lo = np.arange(pods)[:, None, None] * pod_size
    intra_nbr_local = nbr_g - pod_lo
    intra_mask_p = np.asarray(edges.intra_mask).reshape(
        pods, pod_size, k)
    intra_nbr_local = np.where(intra_mask_p, intra_nbr_local, 0)
    if ((intra_nbr_local < 0) | (intra_nbr_local >= pod_size)).any():
        raise ValueError("intra-pod edge escapes its pod — layout and "
                         "topology disagree")
    # leader bookkeeping: local row of the leader inside its pod, and
    # whether the (complete, unweighted) psum fast path applies.
    leader_local = (np.asarray(layout.leaders)
                    - np.arange(pods) * pod_size).astype(np.int32)
    complete = bool(edges.ledge.sum()
                    == pods * (pods - 1)) if pods > 1 else False
    rel_static = np.asarray(topo.relevance)
    uniform_leaders = bool(
        np.all(rel_static[np.asarray(edges.leader_mask)] == 1.0))

    def make_local_combine(fast: bool):
        return lambda *args: local_combine(fast, *args)

    def local_combine(fast, tg, tsum, rg, rsum, rel_rows):
        # gather the pod's accumulators over the fast agent axis —
        # intra-pod traffic only, no cross-pod collective
        gather = lambda x: jax.lax.all_gather(      # noqa: E731
            x, agent_axis, axis=0, tiled=True)
        tg_p = tree_map(gather, tg)                 # (pod_size, *param)
        rg_p = tree_map(gather, rg)
        tsum_p = gather(tsum)                       # (pod_size,)
        rsum_p = gather(rsum)
        rel_p = gather(rel_rows)                    # (pod_size, k)

        p = jax.lax.axis_index(pod_axis)
        nbr_l = jnp.asarray(intra_nbr_local)[p]     # (pod_size, k)
        mask_l = jnp.asarray(intra_mask_p)[p]
        know_p = Knowledge(tg=tg_p, tsum=tsum_p, rg=rg_p, rsum=rsum_p)
        tnum, tden, rnum, rden = _edge_sums(
            know_p, nbr_l, mask_l, jnp.where(mask_l, rel_p, 0.0))

        if pods > 1:
            lidx = jnp.asarray(leader_local)[p]
            take0 = lambda x: jnp.take(x, lidx, axis=0)  # noqa: E731
            own = (tree_map(take0, tg_p), take0(tsum_p),
                   tree_map(take0, rg_p), take0(rsum_p))
            if fast:
                # complete unweighted leader clique: one psum over the
                # pod axis, own plane subtracted back out (the masked
                # leader self-edge)
                tot = jax.tree.map(
                    lambda x: jax.lax.psum(x, pod_axis), own)
                xt, xts, xr, xrs = jax.tree.map(jnp.subtract, tot, own)
            else:
                # sparse / weighted leader edge list: one ppermute
                # rotation per shift, each edge weighted by the
                # destination row's per-edge relevance
                zeros = jax.tree.map(jnp.zeros_like, own)
                xt, xts, xr, xrs = zeros
                ledge_j = jnp.asarray(edges.ledge)
                lslot_j = jnp.asarray(edges.lslot)
                for s in range(1, pods):
                    perm = [(q, (q + s) % pods) for q in range(pods)]
                    rot = lambda x: jax.lax.ppermute(  # noqa: E731
                        x, pod_axis, perm)
                    r_tg, r_ts, r_rg, r_rs = jax.tree.map(rot, own)
                    src_pod = (p - s) % pods
                    e = ledge_j[src_pod, p].astype(jnp.float32)
                    slot = lslot_j[src_pod, p]
                    w = e * rel_p[lidx, jnp.maximum(slot, 0)]
                    xt = tree_map(lambda a, g: a + e * g, xt, r_tg)
                    xr = tree_map(lambda a, g: a + w * g, xr, r_rg)
                    xts = xts + e * r_ts
                    xrs = xrs + w * r_rs
            add_row = lambda acc, x: acc.at[lidx].add(x)  # noqa: E731
            tnum = tree_map(add_row, tnum, xt)
            rnum = tree_map(add_row, rnum, xr)
            tden = tden.at[lidx].add(xts)
            rden = rden.at[lidx].add(xrs)

        out = _finish_combine(tnum, tden, rnum, rden)
        start = jax.lax.axis_index(agent_axis) * blk
        return tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, start, blk, 0),
            out)

    def spec_of(x):
        return P((pod_axis, agent_axis), *([None] * (x.ndim - 1)))

    def combine(know: Knowledge, rel: Optional[jnp.ndarray] = None,
                alive=None):
        # the psum fast path assumes unweighted leader edges — the
        # static table can prove that, a (possibly traced) per-edge
        # override cannot, so any override takes the weighted
        # ppermute chain. Dead agents' rows are zeroed *before* the
        # shard_map, so what a dead leader psums/ppermutes across the
        # pod axis is a zero plane — it carries nothing.
        fast = complete and uniform_leaders and rel is None
        rel = topo.relevance if rel is None else rel
        know = mask_knowledge(know, alive)
        args = (know.tg, know.tsum, know.rg, know.rsum,
                jnp.asarray(rel, jnp.float32))
        in_specs = jax.tree.map(spec_of, args)
        out_specs = jax.tree.map(spec_of, know.tg)
        return shard_map(make_local_combine(fast), mesh, in_specs,
                         out_specs)(*args)

    return combine
