"""Combiners — *how gathered knowledge becomes one update*.

A :class:`Combiner` is the eq. 4 aggregation step, resolved **once at
build time** into a ``combine(knowledge, rel, step)`` closure so the
jitted trainers contain exactly the ops of the chosen strategy — no
runtime dispatch, which is what keeps every pre-redesign
configuration bitwise-reproducible. Three strategies are registered:

``flat``
    The streaming trainer's single-mesh combine. ``full`` + uniform
    keeps the global-sum fast path (:func:`repro.core.sharded_ddal.
    _combine`); any real topology takes the neighbor-local segment-sum
    (:func:`repro.core.sharded_ddal._combine_topo`), re-gathering the
    learned relevance onto the step's edge table.
``pod``
    The two-level multi-host dispatch (:func:`repro.core.pod_dispatch.
    make_pod_dispatch`): intra-pod sums on the fast ``"agent"`` mesh
    axis, only pod leaders' planes crossing ``GroupSpec.pod_axis``.
    Static hierarchical topologies only.
``store``
    The buffer trainer's piece-faithful eq. 4 weighted average over
    each agent's knowledge store (:func:`repro.core.knowledge.
    weighted_average`), optionally through the Pallas wavg kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.exchange.registry import COMBINERS
from repro.core.exchange.schedules import StaticSchedule
from repro.core import relevance as REL
from repro.core.weighting import combine_relevance, relevance_matrix


class Combiner:
    """Interface: ``combine(knowledge, rel, step, alive=None)``.

    ``knowledge`` is trainer-shaped — the streaming
    :class:`~repro.core.sharded_ddal.Knowledge` window for
    ``flat``/``pod`` (returning the per-destination ḡ pytree), the
    vmapped :class:`~repro.core.knowledge.KnowledgeStore` for
    ``store`` (returning ``(ḡ, weight_sum)``). ``rel`` is the dense
    learned relevance matrix (``None`` when nothing is learned);
    ``step`` resolves time-varying topologies. ``alive`` ((n,) bool,
    optional — elastic membership) zeroes dead agents' window rows
    before the aggregation, so a corpse's numerator *and* denominator
    contributions to eq. 4 are exactly zero (dead destinations'
    output rows are garbage by construction — the trainer selects
    them away); ``alive=None`` traces the historical program.
    """

    def __call__(self, knowledge, rel, step, alive=None):
        raise NotImplementedError


def _edge_effective(topo, rel):
    """Per-edge effective relevance: static prior × learned estimate,
    re-gathered onto (a possibly traced) edge table — the shared tail
    both trainers used to duplicate."""
    eff = combine_relevance(topo.relevance,
                            REL.gather_edges(rel, topo.nbr))
    return topo._replace(relevance=jnp.where(topo.mask, eff, 0.0))


@COMBINERS.register("flat",
                    params={"r_weighting": ("r_weighting", str),
                            "quant_block": ("knowledge_quant_block",
                                            int)})
def make_flat_combiner(*, spec, schedule, estimator, dense_R=None,
                       mesh=None, use_wavg_kernel=False,
                       transport=None) -> Combiner:
    """Streaming single-mesh combine. ``schedule=None`` marks the
    topology-free case (``full`` graph, no explicit object): the
    global-sum fast path when nothing weights the edges, the dense
    eq. 4 matmul otherwise. ``knowledge_quant_block > 0`` pushes the
    window's gradient planes through the int8 wire format before the
    aggregation (``quantize_knowledge_roundtrip``); 0 traces the
    historical program bit for bit.

    ``transport`` (a ``repro.core.transport.Transport``) makes each
    share round ride the faulty network: edges whose message this
    round is lost or corrupted are dropped from the round's edge
    table (zero weight in both eq. 4 sums — the streaming equivalent
    of the buffer trainer's hole slots + quarantine), while the
    destination's own window always survives, so the degradation
    limit is exactly the local update. Duplication and jitter are
    no-ops on idempotent window sums with no delay line."""
    del mesh, use_wavg_kernel
    from repro.core.sharded_ddal import (
        _combine,
        _combine_topo,
        mask_knowledge,
        quantize_knowledge_roundtrip,
    )
    A = spec.n_agents
    learns = estimator.learns
    qb = int(getattr(spec, "knowledge_quant_block", 0) or 0)

    def gate(knowledge, alive):
        return quantize_knowledge_roundtrip(
            mask_knowledge(knowledge, alive), qb)

    if schedule is None:
        if transport is not None:
            raise ValueError(
                "the faulty transport drops per-round edges and needs "
                "an edge table — build_exchange keeps a schedule when "
                "transport is enabled, so a None schedule here is a "
                "construction bug")
        uniform = (dense_R is None and spec.r_weighting == "uniform"
                   and not learns)
        R = (dense_R if dense_R is not None
             else relevance_matrix(A, "uniform"))
        if learns:
            def combine(knowledge, rel, step, alive=None):
                del step
                return _combine(gate(knowledge, alive),
                                combine_relevance(R, rel),
                                uniform=False)
        else:
            def combine(knowledge, rel, step, alive=None):
                del rel, step
                return _combine(gate(knowledge, alive), R, uniform)
        return combine

    if transport is not None:
        from repro.core.sharded_ddal import drop_topology_edges

        if learns:
            def combine(knowledge, rel, step, alive=None):
                topo = _edge_effective(
                    schedule.at_step(step, rel, alive), rel)
                keep = transport.deliver_mask(step, topo.nbr)
                return _combine_topo(gate(knowledge, alive),
                                     drop_topology_edges(topo, keep))
        else:
            def combine(knowledge, rel, step, alive=None):
                del rel
                topo = schedule.at_step(step, None, alive)
                keep = transport.deliver_mask(step, topo.nbr)
                return _combine_topo(gate(knowledge, alive),
                                     drop_topology_edges(topo, keep))
        return combine

    if learns:
        def combine(knowledge, rel, step, alive=None):
            topo = _edge_effective(schedule.at_step(step, rel, alive),
                                   rel)
            return _combine_topo(gate(knowledge, alive), topo)
    else:
        def combine(knowledge, rel, step, alive=None):
            del rel
            return _combine_topo(gate(knowledge, alive),
                                 schedule.at_step(step, None, alive))
    return combine


@COMBINERS.register("pod",
                    params={"pods": ("pods", int),
                            "pod_axis": ("pod_axis", str)})
def make_pod_combiner(*, spec, schedule, estimator, dense_R=None,
                      mesh=None, use_wavg_kernel=False,
                      transport=None) -> Combiner:
    """Two-level pod dispatch over a static hierarchical topology.
    ``knowledge_quant_block > 0`` quantizes the window's planes to the
    int8 wire format before anything crosses the pod axis — the
    byte saving ``pod_dispatch.cross_pod_bytes`` accounts for."""
    del dense_R, use_wavg_kernel
    if transport is not None:
        raise ValueError(
            "the 'pod' combiner lowers a static two-level collective "
            "and cannot drop per-round faulty edges — use the 'flat' "
            "combiner with transport faults, or zero the transport_* "
            "rates for pod dispatch")
    from repro.core.pod_dispatch import make_pod_dispatch
    from repro.core.sharded_ddal import quantize_knowledge_roundtrip
    from repro.core.topology import hierarchical_layout
    if schedule is None or not isinstance(schedule, StaticSchedule):
        raise ValueError(
            "the 'pod' combiner needs a static hierarchical topology "
            f"(got schedule "
            f"{type(schedule).__name__ if schedule else None}) — "
            "resampling schedules cannot be pod-dispatched: a swapped "
            "edge could cross pods without touching a leader")
    topology = schedule.base
    layout = hierarchical_layout(spec.n_agents, spec.degree)
    pod_combine = make_pod_dispatch(topology, layout, mesh=mesh,
                                    pod_axis=spec.pod_axis)
    qb = int(getattr(spec, "knowledge_quant_block", 0) or 0)
    if estimator.learns:
        def combine(knowledge, rel, step, alive=None):
            del step
            topo = _edge_effective(topology, rel)
            return pod_combine(
                quantize_knowledge_roundtrip(knowledge, qb),
                topo.relevance, alive=alive)
    else:
        def combine(knowledge, rel, step, alive=None):
            del rel, step
            return pod_combine(
                quantize_knowledge_roundtrip(knowledge, qb),
                alive=alive)
    return combine


@COMBINERS.register("store",
                    params={"quant_block": ("knowledge_quant_block",
                                            int)})
def make_store_combiner(*, spec, schedule, estimator, dense_R=None,
                        mesh=None, use_wavg_kernel=False,
                        transport=None) -> Combiner:
    """Buffer-trainer eq. 4 weighted average over the (n,) vmapped
    knowledge stores; relevance already rode in on each piece's R
    metadata at delivery time, so ``rel`` is unused here.

    The default path is the *fused* share-step entry
    (``weighted_average(fused=True)``): one pass over the ring's
    planes, (ḡ, Σw) out — on CPU/GPU its tiled XLA form is bitwise
    the historical two-op path; on TPU it lowers to the Pallas
    kernel. ``use_wavg_kernel=True`` keeps the legacy per-leaf wavg
    kernel (weights precomputed outside). Quantized stores
    (``knowledge_quant_block > 0``) always take the fused quantized
    entry.

    **Staleness-aware weighting** (``max_staleness`` set, or a faulty
    ``transport`` with ``transport_decay < 1``): each piece's age at
    combine time is ``step - born`` (the send epoch rides with the
    piece). Pieces older than ``max_staleness`` epochs get their
    ``valid`` bit cut — exactly zero eq. 4 weight — and the surviving
    T and R terms are discounted by ``decay**age`` before the
    normalised eq. 4 weights are formed, so fresher knowledge
    dominates. When every cross piece ages out, the weight sum hits
    zero and the trainer degrades to its purely-local update."""
    del schedule, estimator, dense_R, mesh
    from repro.core import knowledge as K
    qb = int(getattr(spec, "knowledge_quant_block", 0) or 0)
    ms = getattr(spec, "max_staleness", None)
    decay = (float(getattr(spec, "transport_decay", 1.0))
             if transport is not None else 1.0)
    stale_gate = ms is not None or decay < 1.0

    def age_gate(stores, step):
        if stores.born is None:
            raise ValueError(
                "staleness-aware combine needs born-tracked stores "
                "(make_store(..., track_born=True)) — the trainer's "
                "init() was built against a different spec")
        age = jnp.asarray(step, jnp.int32) - stores.born   # (n, m)
        valid = stores.valid
        if ms is not None:
            valid = valid & (age <= ms)
        T, R = stores.T, stores.R
        if decay < 1.0:
            d = decay ** jnp.maximum(age, 0).astype(jnp.float32)
            T, R = T * d, R * d
        return stores._replace(T=T, R=R, valid=valid)

    def combine(stores, rel, step, alive=None):
        # store contents are already membership-gated: the buffer
        # trainer's send/deliver path never lets a dead agent's piece
        # into a survivor's ring, and a dead destination's own row is
        # selected away upstream — nothing to mask here
        del rel, alive
        if stale_gate:
            stores = age_gate(stores, step)
        if qb:
            return jax.vmap(lambda st: K.weighted_average(
                st, quant_block=qb))(stores)
        if use_wavg_kernel:
            return jax.vmap(lambda st: K.weighted_average(
                st, use_wavg_kernel))(stores)
        return jax.vmap(lambda st: K.weighted_average(
            st, fused=True))(stores)

    return combine
