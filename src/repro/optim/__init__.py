from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    make_optimizer,
    momentum,
    sgd,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule,
    cosine_schedule,
    warmup_cosine,
)
