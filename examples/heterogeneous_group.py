"""The general group MDP: heterogeneous agents, ring topology,
relevance weighting.

The paper's experiments use the homogeneous special case (§6); its
formulation (§4) is more general — agents with *different*
environments, coupled only by the relevance matrix R[j, i]. Here three
GridWorld agents of different sizes learn together over a ring
topology: each agent's knowledge flows only to its ring neighbours,
and R weights down knowledge from dissimilar worlds.

The hand-built R below is the *static* way to encode that coupling.
The exchange API (docs/exchange.md) can maintain it online instead —
``GroupSpec(exchange_estimator="obs_stats")`` streams each agent's
observation moments from the rollouts into the same Gaussian-overlap
relevance (``repro.core.relevance.obs_overlap``), and
``exchange_schedule="relevance_topk"`` even rewires the gossip graph
toward high-R edges; see the closing demo at the bottom.

    PYTHONPATH=src python examples/heterogeneous_group.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import GroupSpec
from repro.core import DDAL, GroupMDP, AgentEnv
from repro.rl import GridWorld, init_a2c, make_a2c_callbacks

# three agents in different-size worlds — same state/action *types*
# (one-hot obs padded to the largest world) so knowledge is exchangeable
SIZE = 5
envs = [GridWorld(size=SIZE), GridWorld(size=SIZE),
        GridWorld(size=SIZE, max_steps=30)]
group_mdp = GroupMDP(
    agents=tuple(AgentEnv(e, gamma=0.95) for e in envs),
    spec=GroupSpec(n_agents=3, threshold=300, minibatch=50,
                   m_pieces=16, topology="ring"),
    relevance=jnp.asarray([[1.0, 0.8, 0.5],
                           [0.8, 1.0, 0.8],
                           [0.5, 0.8, 1.0]]),
)

env = envs[0]
opt = optim.adamw(3e-3)
gen, app, pof = make_a2c_callbacks(env, opt, gamma=0.95)
ddal = DDAL(group_mdp.spec, gen, app, pof,
            relevance=group_mdp.relevance)

key = jax.random.PRNGKey(0)
astates = jax.vmap(lambda k: init_a2c(k, env, opt))(
    jax.random.split(key, 3))
group = ddal.init(astates)
group, metrics = jax.jit(lambda g, k: ddal.run(g, k, 1_200))(
    group, jax.random.PRNGKey(1))
rewards = np.asarray(metrics["return"])

print("GridWorld group (ring topology, graded relevance):")
for a in range(3):
    print(f"  agent {a}: warm-up mean={rewards[:300, a].mean():6.2f}  "
          f"final mean={rewards[-200:, a].mean():6.2f} "
          f"(optimum ≈ {1.0 - 0.01 * (2 * (SIZE - 1)):.2f})")

# -- the online alternative: let the obs_stats estimator maintain R --
from repro.rl import make_a2c_group  # noqa: E402

spec_online = GroupSpec(n_agents=3, threshold=50, minibatch=10,
                        m_pieces=16, topology="ring",
                        exchange_estimator="obs_stats",
                        relevance_ema=0.8)
ddal2, group2 = make_a2c_group(env, opt, spec_online,
                               jax.random.PRNGKey(2), gamma=0.95)
group2, _ = jax.jit(lambda g, k: ddal2.run(g, k, 200))(
    group2, jax.random.PRNGKey(3))
R_learned = np.asarray(group2.relevance.rel)
print("\nobs_stats estimator after 200 epochs (same env ⇒ high "
      "overlap):")
print(np.array_str(R_learned, precision=3))
