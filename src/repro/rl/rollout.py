"""Episode rollout via ``lax.scan`` (Algorithm 1 line 2: "generate k
experiences"). One epoch = one episode capped at ``env.max_steps``;
post-terminal steps are masked out, matching the paper's §6 setup."""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Trajectory(NamedTuple):
    obs: jnp.ndarray        # (T, obs_dim)
    actions: jnp.ndarray    # (T,) int32
    rewards: jnp.ndarray    # (T,)
    next_obs: jnp.ndarray   # (T, obs_dim)
    dones: jnp.ndarray      # (T,) bool — episode over AFTER this step
    mask: jnp.ndarray       # (T,) fp32 — 1 for real steps


def run_episode(env, select_action: Callable, key) -> Trajectory:
    """select_action(obs, key) -> action. Scans ``env.max_steps``."""
    k_reset, k_steps = jax.random.split(key)
    s0 = env.reset(k_reset)

    def body(carry, k):
        s = carry
        o = env.obs(s)
        live = jnp.logical_not(s.done)
        a = select_action(o, k)
        ns, no, r, d = env.step(s, a)
        step = (o, a, r, no, d, live.astype(jnp.float32))
        return ns, step

    keys = jax.random.split(k_steps, env.max_steps)
    _, (obs, actions, rewards, next_obs, dones, mask) = jax.lax.scan(
        body, s0, keys)
    return Trajectory(obs, actions, rewards * mask, next_obs, dones,
                      mask)


def episode_return(traj: Trajectory) -> jnp.ndarray:
    return jnp.sum(traj.rewards)


def obs_moments(traj: Trajectory) -> Tuple[jnp.ndarray, jnp.ndarray,
                                           jnp.ndarray]:
    """Masked running-moment contributions of one episode's
    observation stream: ``(obs_sum (d,), sq_sum (), count ())``.

    The ``obs_stats`` relevance estimator
    (``repro.core.exchange.estimators.ObsStatsEstimator``) merges
    these into per-agent running obs mean/variance and refreshes the
    ``repro.core.relevance.obs_overlap`` prior from them — the agent
    callbacks attach the triple as ``metrics["obs_moments"]`` and the
    DDAL loop forwards it. Post-terminal steps are masked out, so the
    moments cover exactly the steps the agent really saw.
    """
    m = traj.mask[:, None]
    obs_sum = jnp.sum(traj.obs * m, axis=0)
    sq_sum = jnp.sum(jnp.square(traj.obs) * m)
    return obs_sum, sq_sum, jnp.sum(traj.mask)
