"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (arXiv:2409.12191): the head_dim/2 rotary frequencies are split
into three contiguous sections (t, h, w); each section takes its angle
from the corresponding component of a (3,)-vector position. For pure
text all three components are equal and M-RoPE degenerates to RoPE.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def _angles(positions, dim: int, theta: float):
    """positions (..., S) → (..., S, dim/2) angles."""
    half = dim // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    return positions[..., None].astype(jnp.float32) * freq


def _apply_rotary(x, cos, sin):
    """x (..., D) with rotate-half pairing (x1, x2 = split halves)."""
    d = x.shape[-1] // 2
    x1, x2 = x[..., :d], x[..., d:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def rope(x, positions, theta: float):
    """Standard RoPE. x: (B, S, H, D); positions: (B, S)."""
    ang = _angles(positions, x.shape[-1], theta)      # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _apply_rotary(x, cos, sin)


def mrope(x, positions3, theta: float, sections: Tuple[int, int, int]):
    """M-RoPE. x: (B, S, H, D); positions3: (B, 3, S); sections sum to D/2."""
    assert sum(sections) == x.shape[-1] // 2, (sections, x.shape)
    ang_parts = []
    off = 0
    for i, sec in enumerate(sections):
        half = x.shape[-1] // 2
        freq = theta ** (-(jnp.arange(off, off + sec, dtype=jnp.float32))
                         / half)
        ang_parts.append(positions3[:, i, :, None].astype(jnp.float32)
                         * freq)
        off += sec
    ang = jnp.concatenate(ang_parts, axis=-1)         # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _apply_rotary(x, cos, sin)


def apply_rope(cfg, x, positions):
    """Dispatch on cfg.rope_mode; positions is (B,S) or (B,3,S)."""
    if cfg.rope_mode == "none":
        return x
    if cfg.rope_mode == "mrope":
        return mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return rope(x, positions, cfg.rope_theta)
