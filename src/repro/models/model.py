"""Public model API: one namespace per architecture family.

    model = get_model(cfg)
    params = model.init(cfg, key)
    loss   = model.loss(cfg, params, batch)            # train
    logits, cache = model.prefill(cfg, params, batch)  # prefill
    logits, cache = model.decode(cfg, params, batch, cache)

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every
model input of the given assigned input shape (weak-type-correct, no
device allocation) — the multi-pod dry-run lowers against these.
``param_logical_axes`` gives the logical sharding of every parameter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import hybrid as hy
from repro.models import ssm_model as ssm
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class Model:
    init: Callable
    loss: Callable
    forward: Callable            # full-seq: (cfg, params, batch, cache)
    decode: Callable             # (cfg, params, batch, cache)
    make_cache: Callable         # (cfg, batch_size, max_len)


def _tf_prefill(cfg, params, batch, cache):
    logits, _, new_cache = tf.transformer_forward(cfg, params, batch,
                                                  cache=cache)
    return logits, new_cache


def _ssm_prefill(cfg, params, batch, cache):
    logits, _, new_cache = ssm.ssm_forward(cfg, params, batch, cache=cache)
    return logits, new_cache


def _hy_prefill(cfg, params, batch, cache):
    logits, _, new_cache = hy.hybrid_forward(cfg, params, batch, cache=cache)
    return logits, new_cache


_FAMILIES: Dict[str, Model] = {
    "transformer": Model(
        init=tf.init_transformer,
        loss=tf.transformer_loss,
        forward=_tf_prefill,
        decode=tf.transformer_decode,
        make_cache=tf.make_transformer_cache,
    ),
    "ssm": Model(
        init=ssm.init_ssm_model,
        loss=ssm.ssm_loss,
        forward=_ssm_prefill,
        decode=ssm.ssm_decode,
        make_cache=lambda cfg, b, m: ssm.make_ssm_cache(cfg, b, m),
    ),
    "hybrid": Model(
        init=hy.init_hybrid,
        loss=hy.hybrid_loss,
        forward=_hy_prefill,
        decode=hy.hybrid_decode,
        make_cache=hy.make_hybrid_cache,
    ),
}


def get_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return _FAMILIES["transformer"]
    return _FAMILIES[cfg.family]


# ----------------------------------------------------------------------
# input specs (dry-run stand-ins and data-pipeline shape contracts)
# ----------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the batch of ``shape.kind``. For decode the
    batch is a single new token; the cache spec comes separately from
    ``cache_specs``."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cdt = cfg.dtype("compute")
    E = cfg.d_model

    if shape.kind in ("train", "prefill"):
        specs = {"positions": _sds((B, S), i32)}
        if cfg.family == "audio":
            specs["tokens"] = _sds((B, cfg.n_codebooks, S), i32)
            specs["cond"] = _sds((B, cfg.cond_len, E), cdt)
            if shape.kind == "train":
                specs["labels"] = _sds((B, cfg.n_codebooks, S), i32)
        elif cfg.family == "vlm":
            vp = cfg.vision_prefix
            specs["tokens"] = _sds((B, S - vp), i32)
            specs["vision"] = _sds((B, vp, E), cdt)
            specs["positions"] = _sds((B, 3, S), i32)
            if shape.kind == "train":
                specs["labels"] = _sds((B, S), i32)
        else:
            specs["tokens"] = _sds((B, S), i32)
            if shape.kind == "train":
                specs["labels"] = _sds((B, S), i32)
        return specs

    # decode: ONE new token at position S-1, cache holds the prefix
    if cfg.family == "audio":
        tok = {"tokens": _sds((B, cfg.n_codebooks, 1), i32),
               "positions": _sds((B, 1), i32)}
    elif cfg.family == "vlm":
        tok = {"tokens": _sds((B, 1), i32),
               "positions": _sds((B, 3, 1), i32)}
    else:
        tok = {"tokens": _sds((B, 1), i32),
               "positions": _sds((B, 1), i32)}
    return tok


def cache_specs(cfg: ArchConfig, shape: ShapeConfig) -> Any:
    """ShapeDtypeStructs for the decode cache of ``shape``."""
    model = get_model(cfg)
    cache = jax.eval_shape(
        lambda: model.make_cache(cfg, shape.global_batch, shape.seq_len))
    return cache


def param_specs(cfg: ArchConfig) -> Any:
    """ShapeDtypeStructs of the parameter pytree (no allocation)."""
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: model.init(cfg, k), key)


def make_batch(cfg: ArchConfig, shape: ShapeConfig, key) -> Dict[str, Any]:
    """Concrete random batch matching ``input_specs`` (for smoke tests
    and CPU examples; never used by the dry-run)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if s.dtype == jnp.int32 and name in ("tokens", "labels"):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size,
                                           jnp.int32)
        elif name == "positions":
            if cfg.family == "vlm" and s.shape[1] == 3:
                pos = jnp.arange(s.shape[-1], dtype=jnp.int32)
                out[name] = jnp.broadcast_to(pos, s.shape)
            else:
                pos = jnp.arange(s.shape[-1], dtype=jnp.int32)
                out[name] = jnp.broadcast_to(pos, s.shape)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32
                                          ).astype(s.dtype) * 0.02
    if cfg.family == "vlm" and "labels" in out:
        # vision prefix carries no LM loss
        vp = cfg.vision_prefix
        out["labels"] = out["labels"].at[:, :vp].set(-100)
    return out


# ----------------------------------------------------------------------
# parameter sharding rules (logical axes; see repro.common.sharding)
# ----------------------------------------------------------------------
_COLUMN = {"wq", "wk", "wv", "w_gate", "w_up", "w1", "w_uk", "w_uv",
           "w_z", "w_x"}
_ROW = {"wo", "w_down", "w2", "out_proj"}
_COLUMN_BIAS = {"bq", "bk", "bv", "b1"}
_VEC_SHARDED = {"norm_w", "conv_x"}


def param_logical_axes(cfg: ArchConfig, params_shape) -> Any:
    """Pytree (matching params) of logical PartitionSpec name tuples."""
    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) or
                 str(getattr(p, "idx", "")) for p in path]
        last = names[-1]
        parent = names[-2] if len(names) > 1 else ""
        ndim = len(leaf.shape)
        lead = ndim - 2  # stacked-layer / expert leading axes

        def spec(*tail):
            return tuple([None] * (ndim - len(tail)) + list(tail))

        if parent == "experts":
            # (Ne, E, F) / (Ne, F, E): expert-parallel on axis -3
            return tuple([None] * (ndim - 3) + ["experts", None, None])
        if last == "embed":
            if cfg.family == "audio":
                return spec("vocab", None)
            return spec("vocab", None)
        if last == "lm_head":
            return spec(None, "vocab")
        if last in _COLUMN:
            return spec(None, "ff")
        if last in _ROW:
            return spec("ff", None)
        if last in _COLUMN_BIAS:
            return spec("ff")
        if last == "norm_w":
            return spec("ssm_inner")
        if parent == "conv_x" and last == "w":
            return spec(None, "ssm_inner")
        if parent == "conv_x" and last == "b":
            return spec("ssm_inner")
        if parent in ("a", "b") or last in ("a", "b"):
            # LoRA factors: a (din, r) row-ish, b (r, dout) column-ish —
            # both small; replicate.
            return spec(None, None) if ndim >= 2 else spec(None)
        return tuple([None] * ndim)

    return jax.tree_util.tree_map_with_path(rule, params_shape)
