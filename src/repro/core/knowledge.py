"""Knowledge stores (K_i ∪ K_-i) for DDAL — functional jnp structures.

A ``KnowledgeStore`` is a ring buffer of the last ``m`` gradient pieces
an agent holds, each with its (T, R) weighting metadata (paper §5:
every piece travels with its training-experience and relevance
weights). The paper's multiprocessing queues become delay lines
(``InFlight``): a piece sent by agent j at epoch t is delivered into
agent i's store at epoch t + delay[j, i] — deterministic asynchrony
(DESIGN.md §3).

All structures carry a leading agent axis when used by the vmapped
group loop in ``repro.core.ddal``.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_map, tree_weighted_sum, tree_zeros_like
from repro.core.weighting import eq4_weights


class KnowledgeStore(NamedTuple):
    grads: Any           # pytree, leaves (m, *param_shape)
    T: jnp.ndarray       # (m,) training-experience weights
    R: jnp.ndarray       # (m,) relevance weights
    valid: jnp.ndarray   # (m,) bool
    ptr: jnp.ndarray     # () int32 — next write slot


def make_store(params_like, m: int) -> KnowledgeStore:
    grads = tree_map(
        lambda x: jnp.zeros((m,) + x.shape, jnp.float32), params_like)
    return KnowledgeStore(
        grads=grads,
        T=jnp.zeros((m,), jnp.float32),
        R=jnp.zeros((m,), jnp.float32),
        valid=jnp.zeros((m,), bool),
        ptr=jnp.zeros((), jnp.int32),
    )


def append(store: KnowledgeStore, piece, T, R,
           enabled=True) -> KnowledgeStore:
    """Append one piece (overwrites the oldest when full). ``enabled``
    may be a traced bool — when False the store is returned unchanged
    (used to mask delivery before the sharing threshold)."""
    slot = store.ptr % store.T.shape[0]
    en = jnp.asarray(enabled)

    def write(buf, x):
        new = buf.at[slot].set(x.astype(buf.dtype))
        return jnp.where(en, new, buf) if new.ndim == 0 else \
            jnp.where(jnp.reshape(en, (1,) * new.ndim), new, buf)

    grads = tree_map(lambda b, x: write(b, x), store.grads, piece)
    return KnowledgeStore(
        grads=grads,
        T=write(store.T, jnp.broadcast_to(T, ())),
        R=write(store.R, jnp.broadcast_to(R, ())),
        valid=write(store.valid, jnp.asarray(True)),
        ptr=store.ptr + en.astype(jnp.int32),
    )


def append_many(store: KnowledgeStore, pieces, T, R,
                deliver) -> KnowledgeStore:
    """Append up to n pieces at once (one scan step per piece so ring
    semantics — oldest first overwritten — are preserved).

    pieces: pytree with leading axis n; T, R, deliver: (n,).
    """
    n = T.shape[0]

    def body(st, idx):
        piece = tree_map(lambda x: x[idx], pieces)
        return append(st, piece, T[idx], R[idx], deliver[idx]), None

    store, _ = jax.lax.scan(body, store, jnp.arange(n))
    return store


def weighted_average(store: KnowledgeStore, use_kernel: bool = False):
    """eq. 4 over the store's valid pieces → (ḡ, total_weight)."""
    w = eq4_weights(store.T, store.R, store.valid)
    if use_kernel:
        from repro.kernels.ddal_wavg import ops as wavg_ops
        g = wavg_ops.tree_wavg(store.grads, w, interpret=True)
    else:
        g = tree_weighted_sum(store.grads, w)
    return g, jnp.sum(w)


class InFlight(NamedTuple):
    """Delay-line simulating asynchronous delivery. Slot layout:
    (dst, delay_slot, src, *piece); a piece from src→dst sent at epoch
    t sits in slot (t + delay[src, dst]) % (D+1) until epoch
    t + delay[src, dst] pops it."""
    grads: Any            # leaves (n_dst, D+1, n_src, *param_shape)
    T: jnp.ndarray        # (n_dst, D+1, n_src)
    R: jnp.ndarray
    valid: jnp.ndarray    # bool


def make_inflight(params_like, n: int, max_delay: int) -> InFlight:
    D1 = max_delay + 1
    grads = tree_map(
        lambda x: jnp.zeros((n, D1, n) + x.shape, jnp.float32),
        params_like)
    z = jnp.zeros((n, D1, n), jnp.float32)
    return InFlight(grads=grads, T=z, R=z, valid=z.astype(bool))


def send(flight: InFlight, pieces, T, R, delay, epoch,
         enabled) -> InFlight:
    """Every agent broadcasts its piece to every destination.

    pieces: pytree leaves (n_src, ...); T: (n_src,); R: (n_src, n_dst)
    relevance of src's knowledge to dst; delay: (n_src, n_dst) int;
    enabled: scalar bool (sharing started).
    """
    n, D1 = flight.T.shape[0], flight.T.shape[1]
    slot = (epoch + delay) % D1                     # (n_src, n_dst)
    en = jnp.asarray(enabled)
    src = jnp.arange(n)[:, None] * jnp.ones((1, n), jnp.int32)
    dst = jnp.arange(n)[None, :] * jnp.ones((n, 1), jnp.int32)

    def put(buf, xs):
        # buf: (n_dst, D1, n_src, ...); xs: (n_src, ...)
        upd = jnp.broadcast_to(
            xs[:, None, ...], (n, n) + xs.shape[1:])  # (src, dst, ...)
        new = buf.at[dst.T, slot.T, src.T].set(
            jnp.swapaxes(upd, 0, 1).astype(buf.dtype))
        return jnp.where(jnp.reshape(en, (1,) * new.ndim), new, buf)

    grads = tree_map(lambda b, x: put(b, x), flight.grads, pieces)
    Tb = jnp.broadcast_to(T[:, None], (n, n))
    new_T = flight.T.at[dst.T, slot.T, src.T].set(Tb.T)
    new_R = flight.R.at[dst.T, slot.T, src.T].set(R.T)
    new_valid = flight.valid.at[dst.T, slot.T, src.T].set(True)
    pick = lambda new, old: jnp.where(  # noqa: E731
        jnp.reshape(en, (1,) * new.ndim), new, old)
    return InFlight(grads=grads, T=pick(new_T, flight.T),
                    R=pick(new_R, flight.R),
                    valid=pick(new_valid, flight.valid))


def deliver(flight: InFlight, stores: KnowledgeStore, epoch
            ) -> Tuple[InFlight, KnowledgeStore]:
    """Pop epoch's arrival slot for every destination and append the
    valid pieces into the (vmapped) knowledge stores."""
    n, D1 = flight.T.shape[0], flight.T.shape[1]
    slot = epoch % D1

    def pop(dst_store, dst_idx):
        pieces = tree_map(lambda b: b[dst_idx, slot], flight.grads)
        return append_many(
            dst_store, pieces,
            flight.T[dst_idx, slot], flight.R[dst_idx, slot],
            flight.valid[dst_idx, slot])

    new_stores = jax.vmap(pop)(stores, jnp.arange(n))
    cleared = InFlight(
        grads=flight.grads,  # stale slots overwritten by next send
        T=flight.T,
        R=flight.R,
        valid=flight.valid.at[:, slot, :].set(False),
    )
    return cleared, new_stores
