import os
import subprocess
import sys

import jax
import pytest

# CPU tests run in fp32 (reduced configs set this too); keep x64 off.
jax.config.update("jax_enable_x64", False)

# ---------------------------------------------------------------------
# multi-device simulation rig: tests marked ``multi_device`` need >= 8
# devices, which on CPU only exist if XLA_FLAGS carried
# --xla_force_host_platform_device_count *before jax was imported*.
# When the current process is already multi-device (the CI
# multi-device lane, or a dev running with the flag set) the fixture
# is a no-op and the test runs inline. Otherwise the fixture re-execs
# just that test in a subprocess with the flag set — the only way to
# get the flag in front of the jax import — and reports the child's
# verdict. Plain subprocess + pytest: no hypothesis / pytest-cov
# needed on local rigs.
# ---------------------------------------------------------------------
MULTI_DEVICE_COUNT = 8
_CHILD_ENV = "REPRO_MULTI_DEVICE_CHILD"
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def multi_device(request):
    """Devices of the >= 8-device (simulated) platform; re-execs the
    test under XLA_FLAGS when the current process is single-device."""
    if jax.device_count() >= MULTI_DEVICE_COUNT:
        return jax.devices()
    if os.environ.get(_CHILD_ENV):
        pytest.fail(
            f"re-exec child still sees {jax.device_count()} device(s) "
            f"— XLA_FLAGS did not land before the jax import")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={MULTI_DEVICE_COUNT}"
    ).strip()
    env[_CHILD_ENV] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO_ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         "-p", "no:cacheprovider", request.node.nodeid],
        cwd=_REPO_ROOT, env=env, text=True, timeout=900,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    if res.returncode != 0:
        pytest.fail(
            f"multi-device re-exec failed "
            f"(XLA_FLAGS={env['XLA_FLAGS']!r}):\n{res.stdout}",
            pytrace=False)
    pytest.skip(f"passed under re-exec with {MULTI_DEVICE_COUNT} "
                f"simulated devices")

# ---------------------------------------------------------------------
# hypothesis fallback: CI installs the real package (pyproject.toml
# [dev] extra); on bare rigs without it we register a minimal shim so
# the property tests still run — deterministic seeded random sampling
# instead of real shrinking/coverage. Must happen before test modules
# import `hypothesis`, which is why it lives in conftest.
# ---------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401

    # Bounded CI profile: per-test @settings(max_examples=...) caps are
    # tuned for thoroughness; the CI fast lane trades examples for wall
    # time so the whole lane stays inside its ~5 min budget. deadline
    # is off in both profiles — first-call jit compilation blows any
    # per-example deadline.
    hypothesis.settings.register_profile(
        "ci", max_examples=10, deadline=None, derandomize=True)
    hypothesis.settings.register_profile(
        "dev", max_examples=40, deadline=None)
    hypothesis.settings.load_profile(
        "ci" if os.environ.get("CI") else "dev")
except ImportError:
    import functools
    import inspect
    import random
    import sys
    import types
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, allow_nan=False,
                allow_infinity=False, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(size)]
        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def _settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    # profile API used by this conftest's real-hypothesis branch;
    # harmless no-ops under the shim
    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    def _given(*strats, **kwstrats):
        def deco(fn):
            @functools.wraps(fn)
            def runner():
                # mirror the real profiles: bounded on CI, fuller on dev
                default_n = 15 if os.environ.get("CI") else 40
                n = getattr(fn, "_shim_max_examples", default_n)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    args = [s.draw(rng) for s in strats]
                    kwargs = {k: s.draw(rng)
                              for k, s in kwstrats.items()}
                    fn(*args, **kwargs)
            # hide the wrapped signature so pytest doesn't mistake the
            # strategy parameters for fixtures
            runner.__signature__ = inspect.Signature()
            del runner.__wrapped__
            return runner
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _st.tuples = _tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
