"""Mamba2-780M — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060]. d_inner = expand * d_model = 3072, head_dim 64 →
48 SSD heads, d_state=128, chunk 256, conv4.

Arch-applicability note (DESIGN.md): DDAL consumes gradient pytrees and
is agnostic to the sequence-mixing operator, so the paper's technique
applies unchanged; there is simply no attention to shard."""
from repro.configs.base import ArchConfig, SSMConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,               # attention-free
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,                  # no MLP: Mamba2 blocks only
        vocab_size=50280,
        rope_mode="none",
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                      chunk=256, d_conv=4),
        citation="arXiv:2405.21060",
    )
