from repro.kernels.ddal_wavg import ops, ref  # noqa: F401
from repro.kernels.ddal_wavg.kernel import wavg_flat  # noqa: F401
