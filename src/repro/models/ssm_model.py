"""Mamba2 language model (attention-free SSM stack)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.sharding import shard
from repro.models.common import cross_entropy, dense_init, embed_init, rms_norm
from repro.models.mamba2 import (init_mamba2, make_mamba_state,
                                 mamba2_decode, mamba2_forward)


def init_ssm_model(cfg, key):
    k_e, k_l, k_h = jax.random.split(key, 3)
    dt = cfg.dtype("param")
    params = {
        "embed": embed_init(k_e, (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_h, (cfg.d_model, cfg.vocab_size), dt)
    keys = jax.random.split(k_l, cfg.n_layers)

    def one(k):
        return {"ln": jnp.ones((cfg.d_model,), dt),
                "mamba": init_mamba2(cfg, k)}
    params["layers"] = jax.vmap(one)(keys)
    return params


def _head(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(cfg.dtype("compute"))
    return shard(x @ w, "batch", None, "vocab")


def ssm_forward(cfg, params, batch, cache=None):
    """Full-sequence pass; returns (logits, aux=0, decode_state)."""
    cdt = cfg.dtype("compute")
    x = params["embed"].astype(cdt)[batch["tokens"]]
    x = shard(x, "batch", None, None)
    want_state = cache is not None

    def body(xc, per_layer):
        lp, lstate = per_layer
        h = rms_norm(xc, lp["ln"], cfg.norm_eps)
        o, new_state = mamba2_forward(cfg, lp["mamba"], h, lstate)
        return xc + o, (new_state if want_state else None)

    body_fn = body
    if cfg.remat and not want_state:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    if want_state:
        x, states = jax.lax.scan(body_fn, x,
                                 (params["layers"], cache),
                                 unroll=cfg.unroll_layers)
    else:
        x, _ = jax.lax.scan(lambda c, lp: body_fn(c, (lp, None)),
                            x, params["layers"],
                            unroll=cfg.unroll_layers)
        states = None
    return _head(cfg, params, x), jnp.float32(0.0), states


def ssm_decode(cfg, params, batch, cache):
    cdt = cfg.dtype("compute")
    x = params["embed"].astype(cdt)[batch["tokens"]]

    def body(xc, per_layer):
        lp, lstate = per_layer
        h = rms_norm(xc, lp["ln"], cfg.norm_eps)
        o, new_state = mamba2_decode(cfg, lp["mamba"], h, lstate)
        return xc + o, new_state

    x, states = jax.lax.scan(body, x, (params["layers"], cache),
                             unroll=cfg.unroll_layers)
    return _head(cfg, params, x), states


def ssm_loss(cfg, params, batch):
    logits, aux, _ = ssm_forward(cfg, params, batch)
    return cross_entropy(logits, batch["labels"]) + aux


def make_ssm_cache(cfg, batch: int, max_len: int = 0):
    return make_mamba_state(cfg, batch, cfg.n_layers)
