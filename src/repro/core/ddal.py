"""DDAL — Decentralised Distributed Asynchronous Learning (paper §5,
Algorithm 1), as a vmapped group loop over n agents.

The agent is abstracted behind two pure callbacks so DDAL "is not
restricted by agent type" (paper §5) — DQN, A2C and the LLM trainers
all plug in the same way:

    gen_grads(agent_state, key)   -> (grads, metrics, agent_state')
        Algorithm 1 lines 2–4: generate k experiences, compute the
        average loss, compute gradients.
    apply_grads(agent_state, g)   -> agent_state'
        one model update with gradients (or ḡ).

Per epoch (Algorithm 1):
    epoch < threshold : independent learning — update with own grads.
    epoch ≥ threshold : broadcast the piece (with T, R metadata)
        through the delay lines into every store; every ``minibatch``
        epochs retrieve m pieces from K_i ∪ K_-i and update with the
        eq. 4 weighted average.

Asynchrony is simulated by the per-edge delay matrix (DESIGN.md §3);
delay 0 reproduces the paper's same-epoch queue delivery.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_map
from repro.configs.base import GroupSpec
from repro.core import knowledge as K
from repro.core.weighting import (eq4_weights, relevance_matrix,
                                  training_experience)


class GroupState(NamedTuple):
    agent_states: Any          # leaves with leading (n,) agent axis
    stores: K.KnowledgeStore   # leading (n,)
    flight: K.InFlight
    epoch: jnp.ndarray         # () int32


def _tree_select(pred, a, b):
    """Leafwise where(pred, a, b); pred may be (n,) for vmapped trees."""
    def sel(x, y):
        p = jnp.reshape(pred, pred.shape + (1,) * (x.ndim - pred.ndim))
        return jnp.where(p, x, y)
    return tree_map(sel, a, b)


class DDAL:
    """Group-agent learning loop. Construct once, then either call
    ``epoch_step`` inside your own loop or ``run`` to scan N epochs."""

    def __init__(self, spec: GroupSpec, gen_grads: Callable,
                 apply_grads: Callable, params_of: Callable,
                 relevance: Optional[jnp.ndarray] = None,
                 delay: Optional[jnp.ndarray] = None,
                 use_wavg_kernel: bool = False):
        self.spec = spec
        self.gen_grads = gen_grads
        self.apply_grads = apply_grads
        self.params_of = params_of       # agent_state -> params pytree
        n = spec.n_agents
        self.relevance = (relevance if relevance is not None else
                          relevance_matrix(n, "ring" if
                                           spec.topology == "ring"
                                           else "uniform"))
        if delay is None:
            delay = jnp.zeros((n, n), jnp.int32)
        self.delay = delay
        self.max_delay = max(int(jnp.max(delay)), spec.max_delay)
        self.use_wavg_kernel = use_wavg_kernel

    # ------------------------------------------------------------------
    def init(self, agent_states) -> GroupState:
        """agent_states: pytree with leading (n,) axis."""
        n = self.spec.n_agents
        params0 = self.params_of(tree_map(lambda x: x[0], agent_states))
        stores = jax.vmap(lambda _: K.make_store(params0,
                                                 self.spec.m_pieces))(
            jnp.arange(n))
        flight = K.make_inflight(params0, n, self.max_delay)
        return GroupState(agent_states=agent_states, stores=stores,
                          flight=flight,
                          epoch=jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------------
    def epoch_step(self, gs: GroupState, keys) -> Tuple[GroupState, Any]:
        """One epoch for the whole group. keys: (n,) PRNG keys."""
        spec = self.spec
        n = spec.n_agents
        epoch = gs.epoch
        grads, metrics, astates = jax.vmap(self.gen_grads)(
            gs.agent_states, keys)

        warmup = epoch < spec.threshold
        sharing = jnp.logical_not(warmup)

        # --- lines 5–6: independent learning during warm-up -----------
        updated_local = jax.vmap(self.apply_grads)(astates, grads)
        astates = _tree_select(
            jnp.broadcast_to(warmup, (n,)), updated_local, astates)

        # --- lines 8–10: append + asynchronous broadcast ---------------
        T = jnp.broadcast_to(training_experience(epoch, spec.t_weighting),
                             (n,))
        flight = K.send(gs.flight, grads, T, self.relevance, self.delay,
                        epoch, sharing)
        flight, stores = K.deliver(flight, gs.stores, epoch)

        # --- lines 11–14: eq. 4 update every ``minibatch`` epochs ------
        is_update = sharing & (epoch % spec.minibatch == 0)
        gbar, wsum = jax.vmap(
            lambda st: K.weighted_average(st, self.use_wavg_kernel))(
            stores)
        updated_group = jax.vmap(self.apply_grads)(astates, gbar)
        # only update agents whose store has at least one valid piece
        do = jnp.broadcast_to(is_update, (n,)) & (wsum > 0)
        astates = _tree_select(do, updated_group, astates)

        new_gs = GroupState(agent_states=astates, stores=stores,
                            flight=flight, epoch=epoch + 1)
        return new_gs, metrics

    # ------------------------------------------------------------------
    def run(self, gs: GroupState, key, n_epochs: int
            ) -> Tuple[GroupState, Any]:
        """Scan ``n_epochs`` epochs; returns stacked per-epoch metrics."""
        def body(carry, k):
            keys = jax.random.split(k, self.spec.n_agents)
            return self.epoch_step(carry, keys)

        keys = jax.random.split(key, n_epochs)
        return jax.lax.scan(body, gs, keys)
