"""DDAL — Decentralised Distributed Asynchronous Learning (paper §5,
Algorithm 1), as a vmapped group loop over n agents.

The agent is abstracted behind two pure callbacks so DDAL "is not
restricted by agent type" (paper §5) — DQN, A2C and the LLM trainers
all plug in the same way:

    gen_grads(agent_state, key)   -> (grads, metrics, agent_state')
        Algorithm 1 lines 2–4: generate k experiences, compute the
        average loss, compute gradients.
    apply_grads(agent_state, g)   -> agent_state'
        one model update with gradients (or ḡ).

Per epoch (Algorithm 1):
    epoch < threshold : independent learning — update with own grads.
    epoch ≥ threshold : broadcast the piece (with T, R metadata)
        through the delay lines into every store; every ``minibatch``
        epochs retrieve m pieces from K_i ∪ K_-i and update with the
        eq. 4 weighted average.

Asynchrony is simulated by per-edge delays (DESIGN.md §3); delay 0
reproduces the paper's same-epoch queue delivery. Knowledge moves over
the group's communication graph (``repro.core.topology.Topology``):
each destination gathers pieces from its in-neighbors through a
neighbor-indexed ``SparseInFlight`` delay line — O(n·k·D) memory — and
the dense all-to-all of the seed is recovered exactly by the ``full``
topology (k = n).

The graph itself can be adaptive (ISSUE 2): with
``spec.resample_every > 0`` the gossip table is a
``repro.core.topology.DynamicTopology`` resampled inside the jitted
epoch loop, and with ``spec.relevance_mode="grad_cos"`` the per-edge
relevance fed to eq. 4 is learned online from gradient cosine
similarity (``repro.core.relevance``), EMA-smoothed over share steps —
exact pairwise cosines, or the streaming sketched estimate when
``spec.relevance_sketch_dim > 0`` (ISSUE 4: O(n·|params|) streaming +
O(n²·d) comparisons instead of O(n²·|params|), re-seeded per epoch so
replay stays deterministic). Both default off, in which case the
epoch step is bitwise-identical to the static path.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_map
from repro.configs.base import GroupSpec
from repro.core import knowledge as K
from repro.core import relevance as REL
from repro.core.topology import (
    DynamicTopology,
    Topology,
    make_topology,
)
from repro.core.weighting import combine_relevance, training_experience


class GroupState(NamedTuple):
    agent_states: Any          # leaves with leading (n,) agent axis
    stores: K.KnowledgeStore   # leading (n,)
    flight: K.SparseInFlight
    epoch: jnp.ndarray         # () int32
    relevance: jnp.ndarray     # (n, n) learned R EMA (ones = uniform)
    nbr: jnp.ndarray           # (n, k) current gossip table (static
                               # topologies carry it untouched)


def _tree_select(pred, a, b):
    """Leafwise where(pred, a, b); pred may be (n,) for vmapped trees."""
    def sel(x, y):
        p = jnp.reshape(pred, pred.shape + (1,) * (x.ndim - pred.ndim))
        return jnp.where(p, x, y)
    return tree_map(sel, a, b)


class DDAL:
    """Group-agent learning loop. Construct once, then either call
    ``epoch_step`` inside your own loop or ``run`` to scan N epochs."""

    def __init__(self, spec: GroupSpec, gen_grads: Callable,
                 apply_grads: Callable, params_of: Callable,
                 relevance: Optional[jnp.ndarray] = None,
                 delay: Optional[jnp.ndarray] = None,
                 topology: Optional[Union[Topology,
                                          DynamicTopology]] = None,
                 use_wavg_kernel: bool = False):
        """``topology`` overrides the graph named by ``spec.topology``
        (a ``DynamicTopology`` makes the gossip table time-varying);
        ``relevance`` / ``delay`` accept either dense (n, n) src→dst
        matrices (seed-compatible) or per-edge (n, k) arrays and are
        attached onto the topology's edge table — dynamic topologies
        accept only the dense (or scalar delay) forms, which are
        re-gathered after every resample."""
        self.spec = spec
        self.gen_grads = gen_grads
        self.apply_grads = apply_grads
        self.params_of = params_of       # agent_state -> params pytree
        if topology is None:
            topology = make_topology(spec, delay=delay,
                                     relevance=relevance)
            relevance = delay = None     # consumed by make_topology
        if isinstance(topology, DynamicTopology):
            topology = topology.with_dense(delay=delay,
                                           relevance=relevance)
            if topology.dense_delay is None:
                topology._uniform_base_delay()   # validate early, not in jit
            self.static_topology = topology.base
        else:
            if relevance is not None:
                topology = topology.with_relevance(relevance)
            if delay is not None:
                topology = topology.with_delay(delay)
            self.static_topology = topology
        self.topology = topology
        self.dynamic = isinstance(topology, DynamicTopology)
        self.max_delay = max(topology.max_delay, spec.max_delay)
        self.use_wavg_kernel = use_wavg_kernel

    # ------------------------------------------------------------------
    def _topology_at(self, epoch, nbr):
        """(topology in force at ``epoch``, carried gossip table).
        Dynamic topologies refresh the table only at resample-round
        boundaries (a ``lax.cond`` over the tiny (n, k) table — the
        O(n² log n) sampler is skipped on off-boundary epochs)."""
        if not self.dynamic or self.topology.resample_every <= 0:
            return self.static_topology if self.dynamic \
                else self.topology, nbr
        nbr = self.topology.refresh_table(epoch, nbr)
        return self.topology.with_table(nbr), nbr

    # ------------------------------------------------------------------
    def init(self, agent_states) -> GroupState:
        """agent_states: pytree with leading (n,) axis."""
        n = self.spec.n_agents
        params0 = self.params_of(tree_map(lambda x: x[0], agent_states))
        stores = jax.vmap(lambda _: K.make_store(params0,
                                                 self.spec.m_pieces))(
            jnp.arange(n))
        flight = K.make_sparse_inflight(params0, self.static_topology,
                                        self.max_delay)
        return GroupState(agent_states=agent_states, stores=stores,
                          flight=flight,
                          epoch=jnp.zeros((), jnp.int32),
                          relevance=REL.init_relevance(n),
                          nbr=jnp.asarray(self.static_topology.nbr,
                                          jnp.int32))

    # ------------------------------------------------------------------
    def epoch_step(self, gs: GroupState, keys) -> Tuple[GroupState, Any]:
        """One epoch for the whole group. keys: (n,) PRNG keys."""
        spec = self.spec
        n = spec.n_agents
        epoch = gs.epoch
        grads, metrics, astates = jax.vmap(self.gen_grads)(
            gs.agent_states, keys)

        warmup = epoch < spec.threshold
        sharing = jnp.logical_not(warmup)

        # --- adaptive wiring: resample gossip, learn relevance --------
        topo, nbr = self._topology_at(epoch, gs.nbr)
        learned = gs.relevance
        if spec.relevance_mode != "uniform":
            # EMA over share steps only (warm-up holds the prior);
            # effective R = static edge prior × learned estimate.
            # With spec.relevance_sketch_dim > 0 the observation is
            # the streaming sketched cosine, re-seeded every epoch
            # (rnd=epoch): replay with the same topology_seed is
            # bit-deterministic, while the EMA averages the
            # independent per-round projection errors away.
            learned = REL.update_relevance(
                learned, grads, spec.relevance_mode,
                spec.relevance_ema, sharing,
                sketch_dim=spec.relevance_sketch_dim,
                seed=spec.topology_seed, rnd=epoch)
            eff = combine_relevance(topo.relevance,
                                    REL.gather_edges(learned, topo.nbr))
            topo = topo._replace(
                relevance=jnp.where(topo.mask, eff, 0.0))

        # --- lines 8–10: append + async exchange over the graph -------
        T = jnp.broadcast_to(training_experience(epoch, spec.t_weighting),
                             (n,))
        flight = K.sparse_send(gs.flight, topo, grads, T,
                               epoch, sharing)
        # the delivery fast-path hint needs only static facts (mask,
        # delay, m % k) — valid whatever the traced nbr table says
        flight, stores = K.sparse_deliver(flight, gs.stores, epoch,
                                          self.static_topology)

        # --- lines 5–6 / 11–14: one update per epoch ------------------
        # warm-up: own grads every epoch; sharing: the eq. 4 average
        # every ``minibatch`` epochs (for agents with ≥1 valid piece).
        # The branches are mutually exclusive, so a single switch runs
        # exactly one of them — off-cadence sharing epochs do no
        # update work at all (the seed computed and discarded both).
        is_update = sharing & (epoch % spec.minibatch == 0)

        def hold(states):
            return states

        def independent(states):
            return jax.vmap(self.apply_grads)(states, grads)

        def group_update(states):
            gbar, wsum = jax.vmap(
                lambda st: K.weighted_average(st, self.use_wavg_kernel))(
                stores)
            updated = jax.vmap(self.apply_grads)(states, gbar)
            # only update agents with ≥1 valid piece in store
            return _tree_select(wsum > 0, updated, states)

        branch = (warmup.astype(jnp.int32)
                  + 2 * is_update.astype(jnp.int32))
        astates = jax.lax.switch(
            branch, (hold, independent, group_update), astates)

        new_gs = GroupState(agent_states=astates, stores=stores,
                            flight=flight, epoch=epoch + 1,
                            relevance=learned, nbr=nbr)
        return new_gs, metrics

    # ------------------------------------------------------------------
    def run(self, gs: GroupState, key, n_epochs: int
            ) -> Tuple[GroupState, Any]:
        """Scan ``n_epochs`` epochs; returns stacked per-epoch metrics."""
        def body(carry, k):
            keys = jax.random.split(k, self.spec.n_agents)
            return self.epoch_step(carry, keys)

        keys = jax.random.split(key, n_epochs)
        return jax.lax.scan(body, gs, keys)
