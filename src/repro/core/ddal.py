"""DDAL — Decentralised Distributed Asynchronous Learning (paper §5,
Algorithm 1), as a vmapped group loop over n agents.

The agent is abstracted behind two pure callbacks so DDAL "is not
restricted by agent type" (paper §5) — DQN, A2C and the LLM trainers
all plug in the same way:

    gen_grads(agent_state, key)   -> (grads, metrics, agent_state')
        Algorithm 1 lines 2–4: generate k experiences, compute the
        average loss, compute gradients.
    apply_grads(agent_state, g)   -> agent_state'
        one model update with gradients (or ḡ).

Per epoch (Algorithm 1):
    epoch < threshold : independent learning — update with own grads.
    epoch ≥ threshold : broadcast the piece (with T, R metadata)
        through the delay lines into every store; every ``minibatch``
        epochs retrieve m pieces from K_i ∪ K_-i and update with the
        eq. 4 weighted average.

Asynchrony is simulated by per-edge delays (DESIGN.md §3); delay 0
reproduces the paper's same-epoch queue delivery. Knowledge moves over
the group's communication graph (``repro.core.topology.Topology``):
each destination gathers pieces from its in-neighbors through a
neighbor-indexed ``SparseInFlight`` delay line — O(n·k·D) memory — and
the dense all-to-all of the seed is recovered exactly by the ``full``
topology (k = n).

Everything configurable about the exchange — which graph is in force,
how per-edge relevance is estimated, how stale knowledge is on
arrival, how gathered knowledge becomes one update — lives in one
:class:`repro.core.exchange.ExchangeProtocol` (ISSUE 5), assembled
from the spec by ``build_exchange``. ``epoch_step`` is a thin loop
over it: ``topology_at`` → ``observe`` → ``apply_relevance`` → the
delay lines → ``combine``. The default (``"auto"``) strategies
reproduce every legacy ``GroupSpec`` flag spelling bitwise; new
scenarios (relevance-aware ``relevance_topk`` resampling,
``obs_stats`` relevance) are registered strategies, not new trainer
branches.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_map
from repro.configs.base import GroupSpec
from repro.core import knowledge as K
from repro.core.exchange import ExchangeProtocol, build_exchange
from repro.core.topology import DynamicTopology, Topology
from repro.core.weighting import training_experience


class GroupState(NamedTuple):
    agent_states: Any          # leaves with leading (n,) agent axis
    stores: K.KnowledgeStore   # leading (n,)
    flight: K.SparseInFlight
    epoch: jnp.ndarray         # () int32
    relevance: Any             # estimator state — the (n, n) learned R
                               # EMA for the gradient estimators (ones
                               # = uniform), a moments pytree for
                               # obs_stats
    nbr: jnp.ndarray           # (n, k) current gossip table (static
                               # topologies carry it untouched)
    alive: Any = None          # (n,) bool elastic-membership mask;
                               # None (the default — filtered out of
                               # the pytree) keeps non-elastic
                               # programs, shardings and existing
                               # checkpoints structurally unchanged


def _tree_select(pred, a, b):
    """Leafwise where(pred, a, b); pred may be (n,) for vmapped trees."""
    def sel(x, y):
        p = jnp.reshape(pred, pred.shape + (1,) * (x.ndim - pred.ndim))
        return jnp.where(p, x, y)
    return tree_map(sel, a, b)


class DDAL:
    """Group-agent learning loop. Construct once, then either call
    ``epoch_step`` inside your own loop or ``run`` to scan N epochs."""

    def __init__(self, spec: GroupSpec, gen_grads: Callable,
                 apply_grads: Callable, params_of: Callable,
                 relevance: Optional[jnp.ndarray] = None,
                 delay: Optional[jnp.ndarray] = None,
                 topology: Optional[Union[Topology,
                                          DynamicTopology]] = None,
                 use_wavg_kernel: bool = False,
                 exchange: Optional[ExchangeProtocol] = None,
                 obs_dim: Optional[int] = None):
        """``exchange`` supplies a prebuilt protocol; otherwise one is
        assembled from ``spec`` with ``topology`` overriding the graph
        named by ``spec.topology`` (a ``DynamicTopology`` makes the
        gossip table time-varying) and ``relevance`` / ``delay``
        accepting either dense (n, n) src→dst matrices
        (seed-compatible) or per-edge (n, k) arrays attached onto the
        topology's edge table — dynamic topologies accept only the
        dense (or scalar delay) forms, which are re-gathered after
        every resample. ``obs_dim`` is needed only by the
        ``obs_stats`` estimator (the rl entry points forward it)."""
        self.spec = spec
        self.gen_grads = gen_grads
        self.apply_grads = apply_grads
        self.params_of = params_of       # agent_state -> params pytree
        if exchange is None:
            exchange = build_exchange(
                spec, kind="buffer", topology=topology,
                relevance=relevance, delay=delay, obs_dim=obs_dim,
                use_wavg_kernel=use_wavg_kernel)
        elif exchange.kind != "buffer":
            raise ValueError(
                f"DDAL needs a 'buffer' exchange protocol, got "
                f"{exchange.kind!r}")
        else:
            stale = [name for name, v in
                     [("topology", topology), ("relevance", relevance),
                      ("delay", delay), ("obs_dim", obs_dim),
                      ("use_wavg_kernel", use_wavg_kernel or None)]
                     if v is not None]
            if stale:
                raise ValueError(
                    f"{', '.join(stale)} would be silently ignored: "
                    f"these are baked into the protocol at build time "
                    f"— pass them to build_exchange(...) instead of "
                    f"to DDAL when supplying a prebuilt exchange")
        self.exchange = exchange
        # introspection back-compat (benchmarks, tests)
        self.topology = exchange.schedule.topology
        self.static_topology = exchange.static_topology
        self.dynamic = isinstance(self.topology, DynamicTopology)
        self.max_delay = exchange.max_delay
        self.use_wavg_kernel = use_wavg_kernel
        self.elastic = bool(getattr(spec, "elastic", False))
        self.quant_block = int(getattr(spec, "knowledge_quant_block",
                                       0) or 0)
        # faulty transport / staleness cutoff: when either can starve
        # an agent of fresh knowledge on an update epoch, the empty-
        # store branch degrades to the purely-local update instead of
        # holding (the paper's independent-learning fallback)
        self.transport = getattr(exchange, "transport", None)
        self.track_born = bool(getattr(exchange, "track_born", False))
        self.local_fallback = (
            self.transport is not None
            or getattr(spec, "max_staleness", None) is not None)

    # ------------------------------------------------------------------
    def init(self, agent_states) -> GroupState:
        """agent_states: pytree with leading (n,) axis."""
        n = self.spec.n_agents
        params0 = self.params_of(tree_map(lambda x: x[0], agent_states))
        stores = jax.vmap(lambda _: K.make_store(params0,
                                                 self.spec.m_pieces,
                                                 self.quant_block,
                                                 self.track_born))(
            jnp.arange(n))
        flight = K.make_sparse_inflight(
            params0, self.static_topology, self.max_delay,
            self.quant_block, transport=self.transport is not None,
            track_born=self.track_born)
        alive = jnp.ones((n,), bool) if self.elastic else None
        return GroupState(agent_states=agent_states, stores=stores,
                          flight=flight,
                          epoch=jnp.zeros((), jnp.int32),
                          relevance=self.exchange.init_relevance(),
                          nbr=self.exchange.init_table(),
                          alive=alive)

    # ------------------------------------------------------------------
    def epoch_step(self, gs: GroupState, keys) -> Tuple[GroupState, Any]:
        """One epoch for the whole group. keys: (n,) PRNG keys."""
        spec = self.spec
        ex = self.exchange
        n = spec.n_agents
        epoch = gs.epoch
        alive = gs.alive if self.elastic else None
        if self.elastic and alive is None:
            raise ValueError(
                "spec.elastic=True but GroupState.alive is None — the "
                "state was built by a non-elastic init(); rebuild it "
                "with this trainer's init()")
        grads, metrics, astates = jax.vmap(self.gen_grads)(
            gs.agent_states, keys)

        warmup = epoch < spec.threshold
        sharing = jnp.logical_not(warmup)

        # --- the exchange protocol: graph, relevance, staleness ------
        # (all strategy decisions were resolved at build time — the
        # default strategies trace exactly the legacy ops)
        topo, nbr = ex.topology_at(epoch, gs.nbr, gs.relevance, alive)
        aux = (metrics.get("obs_moments")
               if ex.wants_obs and isinstance(metrics, dict) else None)
        learned = ex.observe(gs.relevance, grads=grads, aux=aux,
                             rnd=epoch, enabled=sharing, alive=alive)
        topo = ex.apply_relevance(topo, learned)

        # --- lines 8–10: append + async exchange over the graph -------
        T = jnp.broadcast_to(training_experience(epoch, spec.t_weighting),
                             (n,))
        faults = (None if self.transport is None
                  else self.transport.at(epoch))
        flight = K.sparse_send(gs.flight, topo, grads, T,
                               epoch, sharing, alive,
                               quant_block=self.quant_block,
                               faults=faults)
        # the delivery fast-path hint needs only static facts (mask,
        # delay, m % k) — valid whatever the traced nbr table says
        flight, stores = K.sparse_deliver(flight, gs.stores, epoch,
                                          self.static_topology, alive)

        # --- lines 5–6 / 11–14: one update per epoch ------------------
        # warm-up: own grads every epoch; sharing: the eq. 4 average
        # every ``minibatch`` epochs (for agents with ≥1 valid piece).
        # The branches are mutually exclusive, so a single switch runs
        # exactly one of them — off-cadence sharing epochs do no
        # update work at all (the seed computed and discarded both).
        is_update = sharing & (epoch % spec.minibatch == 0)

        def hold(states):
            return states

        def independent(states):
            return jax.vmap(self.apply_grads)(states, grads)

        def group_update(states):
            gbar, wsum = ex.combine(stores, learned, epoch)
            updated = jax.vmap(self.apply_grads)(states, gbar)
            # only update agents with ≥1 valid piece in store; under a
            # faulty transport / staleness cutoff an empty store means
            # every neighbor's knowledge was lost, quarantined or too
            # stale — degrade to the purely-local update rather than
            # stalling (fault-free specs keep the historical hold)
            empty = (jax.vmap(self.apply_grads)(states, grads)
                     if self.local_fallback else states)
            return _tree_select(wsum > 0, updated, empty)

        branch = (warmup.astype(jnp.int32)
                  + 2 * is_update.astype(jnp.int32))
        astates = jax.lax.switch(
            branch, (hold, independent, group_update), astates)

        if self.elastic:
            # a dead agent is frozen in amber: whatever gen_grads or
            # the update branch did to its row is discarded, restoring
            # its pre-epoch state (params, env, replay — everything)
            astates = _tree_select(alive, astates, gs.agent_states)

        new_gs = GroupState(agent_states=astates, stores=stores,
                            flight=flight, epoch=epoch + 1,
                            relevance=learned, nbr=nbr,
                            alive=gs.alive)
        return new_gs, metrics

    # ------------------------------------------------------------------
    # elastic membership — host-side events between epochs
    # ------------------------------------------------------------------
    def kill(self, gs: GroupState, dead) -> GroupState:
        """Mark agents dead (``dead``: (n,) bool, True = kill now).

        Beyond flipping ``alive``, death scrubs the exchange of every
        trace of the victims so survivors' streams are as if the dead
        had simply stopped participating: their queued in-flight
        planes are dropped (any plane addressed *to* them, and any
        plane *from* them still riding a delay line — identified
        through the current gossip table, exact for static topologies
        and for dynamic ones whose table did not resample within the
        last ``max_delay`` epochs), and their own knowledge stores are
        emptied so a later revival replays nothing stale."""
        if gs.alive is None:
            raise ValueError("kill() needs an elastic GroupState "
                             "(spec.elastic=True)")
        dead = jnp.asarray(dead, bool)
        alive = gs.alive & jnp.logical_not(dead)
        # planes to a dead dst, or from a dead src (src of dst-row i,
        # edge-slot j is nbr[i, j]) — every delay slot
        drop = dead[gs.nbr] | dead[:, None]              # (n, k)
        flight = gs.flight._replace(
            valid=jnp.where(drop[:, :, None], False, gs.flight.valid))

        def clear_rows(x):
            m = jnp.reshape(dead, (-1,) + (1,) * (x.ndim - 1))
            return jnp.where(m, jnp.zeros_like(x), x)

        stores = gs.stores._replace(
            grads=tree_map(clear_rows, gs.stores.grads),
            T=clear_rows(gs.stores.T), R=clear_rows(gs.stores.R),
            valid=clear_rows(gs.stores.valid),
            ptr=jnp.where(dead, 0, gs.stores.ptr),
            scale=(None if gs.stores.scale is None else
                   tree_map(clear_rows, gs.stores.scale)),
            born=(None if gs.stores.born is None else
                  clear_rows(gs.stores.born)))
        return gs._replace(stores=stores, flight=flight, alive=alive)

    def revive(self, gs: GroupState, mask,
               restore: Optional[GroupState] = None) -> GroupState:
        """Bring agents back (``mask``: (n,) bool, True = revive).

        Without ``restore`` the agent resumes from its frozen
        pre-death state (params, env, replay untouched since
        ``kill``). With ``restore`` — a checkpointed ``GroupState``,
        e.g. through ``repro.checkpoint.npz`` — the revived rows'
        ``agent_states`` and knowledge stores are spliced from the
        checkpoint instead, so a preempted agent rejoins mid-stream at
        its last published version without resetting any survivor.
        Either way its delay-line rows stay cleared: fresh planes
        start flowing at the next sharing epoch."""
        if gs.alive is None:
            raise ValueError("revive() needs an elastic GroupState "
                             "(spec.elastic=True)")
        m = jnp.asarray(mask, bool)
        out = gs._replace(alive=gs.alive | m)
        if restore is not None:
            out = out._replace(
                agent_states=_tree_select(m, restore.agent_states,
                                          gs.agent_states),
                stores=_tree_select(m, restore.stores, gs.stores))
        return out

    # ------------------------------------------------------------------
    def run(self, gs: GroupState, key, n_epochs: int
            ) -> Tuple[GroupState, Any]:
        """Scan ``n_epochs`` epochs; returns stacked per-epoch metrics."""
        def body(carry, k):
            keys = jax.random.split(k, self.spec.n_agents)
            return self.epoch_step(carry, keys)

        keys = jax.random.split(key, n_epochs)
        return jax.lax.scan(body, gs, keys)
