"""Pallas-TPU kernel for DDAL's eq. 4 contraction: ḡ = Σ_j w_j·G[j].

The op is a streaming m-way weighted reduction over the full gradient
vector — at LLM scale it is HBM-bandwidth-bound (arithmetic intensity
≈ 0.5 FLOP/byte). XLA typically emits m separate scaled adds (reading
the fp32 accumulator m times); this kernel streams each (m, TILE) slab
through VMEM once and keeps one fp32 accumulator tile, so HBM traffic
is exactly one pass over G plus one write of ḡ — the roofline floor.

Tiling: the flat parameter vector is viewed as (tiles, ROWS, 128)
— 128 lanes, ROWS sublane-multiples — and the grid walks tiles. The
m-loop is unrolled inside the block (the paper's store holds ≤ tens of
pieces). Weights ride along as a tiny VMEM block replicated per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_ROWS = 64                  # tile = 64·128 = 8192 elements


def _wavg_kernel(w_ref, g_ref, o_ref):
    """w_ref: (m, 1); g_ref: (m, 1, ROWS, LANES); o_ref: (1, ROWS, LANES)."""
    m = g_ref.shape[0]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for j in range(m):                       # m is static & small
        acc = acc + w_ref[j, 0] * g_ref[j].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def wavg_flat(G: jnp.ndarray, w: jnp.ndarray,
              rows: int = DEFAULT_ROWS,
              interpret: bool = False) -> jnp.ndarray:
    """G: (m, N) float, w: (m,) → (N,) fp32 = Σ_j w[j]·G[j]."""
    m, n = G.shape
    tile = rows * LANES
    n_pad = max(tile, ((n + tile - 1) // tile) * tile)
    if n_pad != n:
        G = jnp.pad(G, ((0, 0), (0, n_pad - n)))
    tiles = n_pad // tile
    G4 = G.reshape(m, tiles, rows, LANES)
    w2 = w.astype(jnp.float32).reshape(m, 1)

    out = pl.pallas_call(
        _wavg_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, 1, rows, LANES), lambda i: (0, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rows, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, rows, LANES),
                                       jnp.float32),
        interpret=interpret,
    )(w2, G4)
    return out.reshape(n_pad)[:n]
