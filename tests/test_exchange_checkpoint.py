"""Checkpoint/restore of the *full* exchange state (ISSUE 7).

``checkpoint/npz.py`` already round-trips arbitrary pytrees; what
elastic membership adds is the requirement that the whole exchange —
``Knowledge`` planes incl. ``sk`` sketches and the learned ``rel``,
the ``SparseInFlight`` delay-line rings, the gossip table and the
step counter — survives a kill/restore/continue boundary **bitwise**,
so a preempted agent rejoins mid-stream at its last published version
without resetting the group. Also pinned here: bf16 leaves through
the f32 npz detour, non-strict restore of pre-elastic checkpoints,
and the serving ``ParamStore``'s ``__step__`` version.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import npz, restore, save
from repro.configs.base import GroupSpec
from repro.core import DDAL


def _toy_ddal(spec, delay=None):
    def gen_grads(state, key):
        del key
        g = {"w": state["w"] - state["target"]}
        return g, {"w": state["w"]}, state

    def apply_grads(state, g):
        return {"w": state["w"] - 0.5 * g["w"],
                "target": state["target"]}

    return DDAL(spec, gen_grads, apply_grads,
                lambda s: {"w": s["w"]}, delay=delay)


def _toy_states(n):
    return {"w": jnp.zeros((n,)),
            "target": jnp.arange(n, dtype=jnp.float32)}


def _run(ddal, gs, epochs, start=0):
    step = jax.jit(ddal.epoch_step)
    for e in range(start, start + epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e),
                                          ddal.spec.n_agents))
    return gs


def _assert_trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


# ----------------------------------------------------------------------
# buffer trainer: GroupState (stores + delay lines + gossip table)
# ----------------------------------------------------------------------
def test_groupstate_roundtrip_is_bitwise(tmp_path):
    """Save mid-run, restore into an eval_shape template, continue:
    the continued trajectory is bitwise the uninterrupted one —
    delay-line rings, gossip table, stores, epoch and alive included."""
    n = 4
    delay = jnp.asarray(np.random.default_rng(0).integers(
        0, 3, (n, n)), jnp.int32)
    spec = GroupSpec(n_agents=n, threshold=2, minibatch=2, m_pieces=6,
                     elastic=True, topology="random_k", degree=2,
                     resample_every=3)
    ddal = _toy_ddal(spec, delay=delay)
    gs = _run(ddal, ddal.init(_toy_states(n)), 7)

    path = os.path.join(tmp_path, "group.npz")
    save(path, gs, step=7)
    assert npz.restore_step(path) == 7

    template = jax.eval_shape(lambda: gs)
    back = restore(path, template)
    _assert_trees_equal(back, gs)

    # continuing from the restored state is bitwise the straight run
    cont = _run(ddal, back, 6, start=7)
    straight = _run(ddal, gs, 6, start=7)
    _assert_trees_equal(cont, straight)


def test_kill_restore_continue_boundary(tmp_path):
    """The ISSUE's boundary: checkpoint, kill an agent, continue,
    then splice the victim back from the checkpoint — its restored
    rows (params, store rings, T/R metadata) are bitwise the saved
    ones even though the group kept moving underneath."""
    n = 3
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1, m_pieces=8,
                     elastic=True)
    ddal = _toy_ddal(spec, delay=jnp.ones((n, n), jnp.int32))
    gs = _run(ddal, ddal.init(_toy_states(n)), 5)
    path = os.path.join(tmp_path, "pre_kill.npz")
    save(path, gs, step=5)

    dead = jnp.asarray([True, False, False])
    gs = ddal.kill(gs, dead)
    gs = _run(ddal, gs, 4, start=5)

    ckpt = restore(path, jax.eval_shape(lambda: gs))
    rejoined = ddal.revive(gs, dead, restore=ckpt)
    d = np.asarray(dead)
    np.testing.assert_array_equal(
        np.asarray(rejoined.agent_states["w"])[d],
        np.asarray(ckpt.agent_states["w"])[d])
    for field in ("T", "R", "valid", "ptr"):
        np.testing.assert_array_equal(
            np.asarray(getattr(rejoined.stores, field))[d],
            np.asarray(getattr(ckpt.stores, field))[d])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a)[d], np.asarray(b)[d]),
        rejoined.stores.grads, ckpt.stores.grads)
    # and the group can keep training through the splice
    out = _run(ddal, rejoined, 3, start=9)
    assert np.isfinite(np.asarray(out.agent_states["w"])).all()


# ----------------------------------------------------------------------
# streaming trainer: TrainState (Knowledge incl. sk + rel + step)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_streaming_trainstate_roundtrip_with_sketch_and_rel(tmp_path):
    """Full streaming TrainState — window accumulators, the learned
    relevance EMA, the gradient sketch and the step counter — is
    bitwise across save/restore, and a restored run continues
    bitwise. Slow lane: it runs a reduced llama twice end to end; the
    toy-sized roundtrips in this file pin the same leaf-for-leaf
    save/restore guarantee in tier-1."""
    from repro import optim
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.core import init_train_state, make_group_train_step
    from repro.data import StreamSpec, make_group_batch

    cfg = get_arch_config("llama3.2-3b").reduced()
    opt = optim.sgd(0.1)
    shape = ShapeConfig("ckpt", 32, 2, "train")
    spec = GroupSpec(n_agents=2, threshold=1, minibatch=2,
                     knowledge_mode="streaming", elastic=True,
                     relevance_mode="grad_cos",
                     relevance_sketch_dim=16)
    state = init_train_state(cfg, spec, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_group_train_step(cfg, spec, opt))

    def batch(i):
        return make_group_batch(cfg, shape, StreamSpec(), 2, i)

    for i in range(3):
        state, _ = step(state, batch(i))
    assert state.know.sk is not None and state.know.rel is not None

    path = os.path.join(tmp_path, "train.npz")
    save(path, state, step=int(state.step))
    back = restore(path, jax.eval_shape(lambda: state))
    _assert_trees_equal(back, state)
    assert npz.restore_step(path) == 3

    s1, _ = step(state, batch(3))
    s2, _ = step(back, batch(3))
    _assert_trees_equal(s1, s2)


def test_restore_non_strict_fills_missing_leaves(tmp_path):
    """A pre-elastic checkpoint (no ``alive`` leaf) restores into an
    elastic template with ``strict=False``: present leaves load,
    missing ones keep the template's value; ``strict=True`` raises."""
    saved = {"w": jnp.arange(4.0)}
    path = os.path.join(tmp_path, "old.npz")
    save(path, saved)
    template = {"w": jnp.zeros((4,)), "alive": jnp.ones((4,), bool)}
    with pytest.raises(ValueError, match="missing leaf.*alive"):
        restore(path, template)
    got = restore(path, template, strict=False)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.arange(4.0))
    np.testing.assert_array_equal(np.asarray(got["alive"]),
                                  np.ones(4, bool))


def test_bf16_leaves_roundtrip_bitwise(tmp_path):
    """bf16 exchange planes take the f32 detour inside npz (np.savez
    can't serialise ml_dtypes) — lossless, bitwise back in bf16."""
    rng = np.random.default_rng(3)
    tree = {
        "planes": jnp.asarray(rng.normal(size=(4, 33)),
                              jnp.bfloat16),
        "tsum": jnp.asarray(rng.uniform(1, 3, 4), jnp.float32),
        "alive": jnp.asarray([True, False, True, True]),
        "step": jnp.asarray(17, jnp.int32),
    }
    path = os.path.join(tmp_path, "bf16.npz")
    save(path, tree, step=17)
    back = restore(path, jax.eval_shape(lambda: tree))
    assert back["planes"].dtype == jnp.bfloat16
    assert back["alive"].dtype == bool
    _assert_trees_equal(back, tree)


# ----------------------------------------------------------------------
# damaged checkpoints fail up front with one descriptive ValueError
# ----------------------------------------------------------------------
def test_restore_truncated_file_raises_valueerror(tmp_path):
    """A checkpoint cut off mid-write (preemption during save) is
    detected before any leaf is touched: ValueError naming the file,
    not a zipfile.BadZipFile / KeyError from inside np.load."""
    tree = {"w": jnp.arange(64.0), "b": jnp.ones((8, 8))}
    path = os.path.join(tmp_path, "full.npz")
    save(path, tree)
    with open(path, "rb") as f:
        blob = f.read()
    cut = os.path.join(tmp_path, "cut.npz")
    with open(cut, "wb") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.raises(ValueError, match="unreadable|truncated"):
        restore(cut, jax.eval_shape(lambda: tree))
    garbage = os.path.join(tmp_path, "garbage.npz")
    with open(garbage, "wb") as f:
        f.write(b"not an npz archive at all")
    with pytest.raises(ValueError, match="unreadable|truncated"):
        restore(garbage, jax.eval_shape(lambda: tree))


def test_restore_shape_mismatch_names_leaf_and_shapes(tmp_path):
    """Restoring into a template with a different group size names
    the offending leaf path and both shapes — and reports *every*
    mismatch at once, not just the first."""
    saved = {"stores": {"T": jnp.zeros((4, 8)), "R": jnp.zeros((4, 8))},
             "epoch": jnp.zeros((), jnp.int32)}
    path = os.path.join(tmp_path, "n4.npz")
    save(path, saved)
    template = jax.eval_shape(lambda: {
        "stores": {"T": jnp.zeros((6, 8)), "R": jnp.zeros((6, 8))},
        "epoch": jnp.zeros((), jnp.int32)})
    with pytest.raises(ValueError) as ei:
        restore(path, template)
    msg = str(ei.value)
    assert "shape mismatch" in msg
    assert "'T'" in msg and "'R'" in msg
    assert "(4, 8)" in msg and "(6, 8)" in msg


def test_restore_transport_groupstate_roundtrip(tmp_path):
    """A transport-enabled GroupState (checksum + born planes in the
    delay line, born column in the stores) checkpoints and continues
    bitwise — the fault plan is host-side config, so a restored run
    replays the same fault history from the same epoch."""
    n = 3
    spec = GroupSpec(n_agents=n, threshold=1, minibatch=2, m_pieces=6,
                     transport_loss=0.3, transport_corrupt=0.1,
                     transport_seed=7, max_staleness=5, max_delay=1)
    ddal = _toy_ddal(spec)
    gs = _run(ddal, ddal.init(_toy_states(n)), 6)
    assert gs.flight.chk is not None and gs.stores.born is not None

    path = os.path.join(tmp_path, "transport.npz")
    save(path, gs, step=6)
    back = restore(path, jax.eval_shape(lambda: gs))
    _assert_trees_equal(back, gs)
    cont = _run(ddal, back, 5, start=6)
    straight = _run(ddal, gs, 5, start=6)
    _assert_trees_equal(cont, straight)


# ----------------------------------------------------------------------
# serving ParamStore version
# ----------------------------------------------------------------------
def test_param_store_checkpoint_carries_version(tmp_path):
    """ParamStore.save stamps its publish version into ``__step__``;
    load resumes at that version so serving hot-swap monotonicity
    survives a restart."""
    from repro.serving.group import ParamStore

    planes = {"w": jnp.arange(6.0).reshape(2, 3)}
    store = ParamStore(planes)
    for v in range(3):
        store.publish(jax.tree.map(lambda x: x + 1.0,
                                   store.acquire()[0]))
    assert store.version == 3
    path = os.path.join(tmp_path, "store.npz")
    store.save(path)
    assert npz.restore_step(path) == 3

    back = ParamStore.load(path, jax.eval_shape(lambda: planes))
    assert back.version == 3
    _assert_trees_equal(back.acquire()[0], store.acquire()[0])
