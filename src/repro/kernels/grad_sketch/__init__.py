"""Streaming gradient-sketch projection (sign-JL) for sketched
relevance estimation — kernel (Pallas TPU), tiled XLA path, and jnp
oracle. See ``repro.core.relevance.sketch_cosine`` for the consumer."""
from repro.kernels.grad_sketch.kernel import sign_block  # noqa: F401
from repro.kernels.grad_sketch.ops import (  # noqa: F401
    sketch_leaf,
    sketch_pytree,
)
