"""Pure-JAX environments for the paper's experiments.

``CartPole`` reproduces OpenAI Gym's CartPole-v0 dynamics exactly
(Barto-Sutton-Anderson cart-pole, Euler integration, the same
constants as gym.envs.classic_control.CartPoleEnv). The paper's §6
evaluation caps episodes at 100 steps, so a total reward of 100 is the
optimum. ``GridWorld`` is a second, *different* environment used to
exercise the general group-MDP case (heterogeneous tasks, R_j ≠
uniform) that the paper formulates but does not evaluate.

Both follow the AgentEnv protocol (repro.core.group_mdp):

    env.reset(key)              -> state
    env.step(state, action)     -> (state, obs, reward, done)
    env.obs(state)              -> observation
    env.obs_dim / env.n_actions
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CartPoleState(NamedTuple):
    x: jnp.ndarray          # () fp32 — cart position
    x_dot: jnp.ndarray
    theta: jnp.ndarray      # pole angle (rad)
    theta_dot: jnp.ndarray
    t: jnp.ndarray          # () int32 — step count
    done: jnp.ndarray       # () bool


@dataclasses.dataclass(frozen=True)
class CartPole:
    """CartPole-v0 (gym classic_control constants)."""
    gravity: float = 9.8
    masscart: float = 1.0
    masspole: float = 0.1
    length: float = 0.5            # half pole length
    force_mag: float = 10.0
    tau: float = 0.02
    theta_threshold: float = 12 * 2 * jnp.pi / 360
    x_threshold: float = 2.4
    max_steps: int = 100           # paper §6: max 100 steps per episode

    obs_dim: int = 4
    n_actions: int = 2

    def reset(self, key) -> CartPoleState:
        vals = jax.random.uniform(key, (4,), jnp.float32, -0.05, 0.05)
        return CartPoleState(vals[0], vals[1], vals[2], vals[3],
                             jnp.zeros((), jnp.int32),
                             jnp.zeros((), bool))

    def obs(self, s: CartPoleState) -> jnp.ndarray:
        return jnp.stack([s.x, s.x_dot, s.theta, s.theta_dot])

    def step(self, s: CartPoleState, action
             ) -> Tuple[CartPoleState, jnp.ndarray, jnp.ndarray,
                        jnp.ndarray]:
        total_mass = self.masscart + self.masspole
        polemass_length = self.masspole * self.length
        force = jnp.where(action == 1, self.force_mag, -self.force_mag)
        costh = jnp.cos(s.theta)
        sinth = jnp.sin(s.theta)
        temp = (force + polemass_length * s.theta_dot ** 2 * sinth
                ) / total_mass
        thetaacc = (self.gravity * sinth - costh * temp) / (
            self.length * (4.0 / 3.0 - self.masspole * costh ** 2 /
                           total_mass))
        xacc = temp - polemass_length * thetaacc * costh / total_mass
        x = s.x + self.tau * s.x_dot
        x_dot = s.x_dot + self.tau * xacc
        theta = s.theta + self.tau * s.theta_dot
        theta_dot = s.theta_dot + self.tau * thetaacc
        t = s.t + 1
        fell = ((jnp.abs(x) > self.x_threshold) |
                (jnp.abs(theta) > self.theta_threshold))
        done = fell | (t >= self.max_steps) | s.done
        # gym gives +1 for every step taken, including the failing one;
        # but once an episode was already done, further steps score 0.
        reward = jnp.where(s.done, 0.0, 1.0)
        ns = CartPoleState(x, x_dot, theta, theta_dot, t, done)
        return ns, self.obs(ns), reward, done


class GridState(NamedTuple):
    pos: jnp.ndarray        # () int32 — flattened cell index
    t: jnp.ndarray
    done: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class GridWorld:
    """N×N gridworld: start top-left, goal bottom-right, step cost
    -0.01, goal +1. Observation is the one-hot cell. Used for the
    heterogeneous-group tests (each agent can get a different size)."""
    size: int = 5
    max_steps: int = 50

    @property
    def obs_dim(self) -> int:
        return self.size * self.size

    n_actions: int = 4      # up / down / left / right

    def reset(self, key) -> GridState:
        del key
        return GridState(jnp.zeros((), jnp.int32),
                         jnp.zeros((), jnp.int32), jnp.zeros((), bool))

    def obs(self, s: GridState) -> jnp.ndarray:
        return jax.nn.one_hot(s.pos, self.obs_dim, dtype=jnp.float32)

    def step(self, s: GridState, action):
        n = self.size
        r, c = s.pos // n, s.pos % n
        dr = jnp.array([-1, 1, 0, 0], jnp.int32)[action]
        dc = jnp.array([0, 0, -1, 1], jnp.int32)[action]
        r = jnp.clip(r + dr, 0, n - 1)
        c = jnp.clip(c + dc, 0, n - 1)
        pos = r * n + c
        t = s.t + 1
        at_goal = pos == (n * n - 1)
        done = at_goal | (t >= self.max_steps) | s.done
        reward = jnp.where(s.done, 0.0,
                           jnp.where(at_goal, 1.0, -0.01))
        ns = GridState(pos, t, done)
        return ns, self.obs(ns), reward, done
