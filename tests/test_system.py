"""End-to-end behaviour tests for the paper's system: group training
improves every agent, knowledge sharing beats no-sharing on identical
budgets, and the full train → checkpoint → serve loop closes."""
from __future__ import annotations

import os
import tempfile

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import restore, save
from repro.configs import get_arch_config
from repro.configs.base import GroupSpec, ShapeConfig
from repro.core import init_train_state, make_group_train_step
from repro.data import StreamSpec, make_group_batch
from repro.serving import ServeConfig, ServeEngine

# end-to-end train → checkpoint → serve loops (~40 s): excluded from
# the CI tier-1 fast lane, still part of the full local tier-1 run
pytestmark = pytest.mark.slow


def _train(cfg, spec, steps, seed=0, lr=1e-3):
    opt = optim.adamw(lr)
    state = init_train_state(cfg, spec, opt, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_group_train_step(cfg, spec, opt))
    shape = ShapeConfig("sys", 64, 2, "train")
    stream = StreamSpec(seed=seed, similarity=0.7)
    losses = []
    for i in range(steps):
        batch = make_group_batch(cfg, shape, stream, spec.n_agents, i)
        state, m = step_fn(state, batch)
        losses.append(np.asarray(m["loss"]))
    return state, np.stack(losses)


def test_group_training_reduces_loss_for_every_agent():
    cfg = get_arch_config("llama3.2-3b").reduced()
    spec = GroupSpec(n_agents=2, threshold=5, minibatch=3,
                     knowledge_mode="streaming")
    _, losses = _train(cfg, spec, 30)
    first = losses[:5].mean(axis=0)
    last = losses[-5:].mean(axis=0)
    assert (last < first - 0.3).all(), (first, last)


def test_end_to_end_train_checkpoint_serve():
    cfg = get_arch_config("granite-3-8b").reduced()
    spec = GroupSpec(n_agents=2, threshold=3, minibatch=3,
                     knowledge_mode="streaming")
    state, _ = _train(cfg, spec, 10)
    # checkpoint round-trip of agent 0's params
    p0 = jax.tree.map(lambda x: x[0], state.params)
    path = os.path.join(tempfile.mkdtemp(), "m.npz")
    save(path, p0, step=10)
    back = restore(path, jax.eval_shape(lambda: p0))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), p0, back)
    # serve with the restored params
    eng = ServeEngine(cfg, back, ServeConfig(max_len=32,
                                             max_new_tokens=4))
    out = eng.generate(jnp.asarray([[1, 2, 3]], jnp.int32),
                       jnp.asarray([3], jnp.int32))
    assert out.shape == (1, 4)
    assert np.isfinite(np.asarray(out)).all()


def test_bf16_knowledge_matches_fp32_closely():
    """The bf16 exchange-traffic option stays close to fp32 training."""
    cfg = get_arch_config("llama3.2-3b").reduced()
    base = dict(n_agents=2, threshold=2, minibatch=2,
                knowledge_mode="streaming")
    _, l32 = _train(cfg, GroupSpec(**base, knowledge_dtype="float32"),
                    12)
    _, l16 = _train(cfg, GroupSpec(**base, knowledge_dtype="bfloat16"),
                    12)
    np.testing.assert_allclose(l32[-3:].mean(), l16[-3:].mean(),
                               rtol=0.05)
