"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles, executed in interpret mode on CPU (deliverable c)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ----------------------------------------------------------------------
# ddal_wavg — the paper's eq. 4 contraction
# ----------------------------------------------------------------------
from repro.kernels.ddal_wavg import ops as wavg_ops
from repro.kernels.ddal_wavg import ref as wavg_ref


@pytest.mark.parametrize("m,n", [(1, 128), (3, 100), (8, 8192),
                                 (5, 20_000), (16, 4_097)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wavg_flat(m, n, dtype):
    key = jax.random.PRNGKey(m * 1000 + n)
    G = jax.random.normal(key, (m, n), jnp.float32).astype(dtype)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (m,))
    got = wavg_ops.wavg(G, w, interpret=True)
    want = wavg_ref.wavg(G, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_wavg_tree():
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (4, 17, 33)),
            "b": jax.random.normal(key, (4, 12_000)),
            "c": {"d": jax.random.normal(key, (4, 8))}}
    w = jax.random.uniform(key, (4,))
    got = wavg_ops.tree_wavg(tree, w, interpret=True)
    want = wavg_ref.tree_wavg(tree, w)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5), got, want)


def test_wavg_zero_weights():
    G = jnp.ones((3, 256))
    w = jnp.zeros((3,))
    got = wavg_ops.wavg(G, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(256))


# ----------------------------------------------------------------------
# ddal_wavg — fused eq. 4 share step (+ int8-quantized planes)
# ----------------------------------------------------------------------
from repro.core.weighting import eq4_weights
from repro.common.pytree import tree_weighted_sum


def _share_meta(m, seed=0):
    kT, kR = jax.random.split(jax.random.PRNGKey(seed))
    T = jnp.abs(jax.random.normal(kT, (m,))) + 0.1
    R = jnp.abs(jax.random.normal(kR, (m,))) + 0.1
    valid = (jnp.arange(m) != 1) if m > 1 else jnp.ones((m,), bool)
    return T, R, valid


def _legacy_share(G, T, R, valid):
    w = eq4_weights(T, R, valid)
    return tree_weighted_sum(G, w), jnp.sum(w)


def _count_pallas_calls(fn, *args):
    hits = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if "pallas" in eqn.primitive.name:
                hits.append(eqn)
            for p in eqn.params.values():
                sub = getattr(p, "jaxpr", p if hasattr(p, "eqns")
                              else None)
                if sub is not None:
                    walk(sub)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return len(hits)


@pytest.mark.parametrize("m,n", [(1, 256), (4, 8192), (6, 100_000),
                                 (8, 262_144), (3, 8_193)])
def test_fused_wavg_xla_bitwise_vs_multi_op(m, n):
    """The fused XLA entry — what CPU/GPU trainers compile — must be
    bit-identical to the historical eq4_weights + tree_weighted_sum
    path at quantization-off."""
    G = jax.random.normal(jax.random.PRNGKey(n), (m, n), jnp.float32)
    T, R, valid = _share_meta(m, seed=n)
    want_g, want_w = _legacy_share(G, T, R, valid)
    got_g, got_w = wavg_ops.fused_wavg(G, T, R, valid, impl="xla")
    np.testing.assert_array_equal(np.asarray(got_g),
                                  np.asarray(want_g))
    assert float(got_w) == float(want_w)


def test_tree_fused_wavg_xla_bitwise_vs_multi_op():
    """Tree-wise: mixed small/large leaves, arbitrary ranks — still
    bitwise, including the (ḡ, Σw) pair the store combiner returns."""
    key = jax.random.PRNGKey(7)
    tree = {"emb": jax.random.normal(key, (5, 300, 65)),
            "head": {"w": jax.random.normal(key, (5, 33)),
                     "b": jax.random.normal(key, (5,))}}
    T, R, valid = _share_meta(5)
    want_g, want_w = _legacy_share(tree, T, R, valid)
    got_g, got_w = wavg_ops.tree_fused_wavg(tree, T, R, valid,
                                            impl="xla")
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got_g, want_g)
    assert float(got_w) == float(want_w)


@pytest.mark.parametrize("m,n", [(4, 8192), (6, 100_000)])
def test_fused_wavg_pallas_interpret_matches_oracle(m, n):
    G = jax.random.normal(jax.random.PRNGKey(m), (m, n), jnp.float32)
    T, R, valid = _share_meta(m)
    want_g, want_w = _legacy_share(G, T, R, valid)
    got_g, got_w = wavg_ops.fused_wavg(G, T, R, valid, impl="pallas",
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(got_g), np.asarray(want_g),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(got_w), float(want_w), rtol=1e-6)


@pytest.mark.parametrize("qb", [128, 512, 2048, 8192])
def test_quantize_roundtrip_error_bound(qb):
    """int8 block quantization: the roundtrip error of every element
    is ≤ half its block's scale (the analytic bound the eq. 4
    accuracy gate builds on), and the wire dtypes/shapes hold."""
    n = 20_000
    G = jax.random.normal(jax.random.PRNGKey(qb), (3, n), jnp.float32)
    G = G * jnp.exp(jax.random.normal(jax.random.PRNGKey(1),
                                      (3, n)))     # mixed magnitudes
    Q, S = wavg_ref.quantize_flat(G, qb)
    assert Q.dtype == jnp.int8 and Q.shape == G.shape
    assert S.shape == (3, -(-n // qb))
    back = wavg_ref.dequantize_flat(Q, S, qb)
    err = jnp.abs(back - G)
    bound = jnp.repeat(S / 2.0, qb, axis=-1)[:, :n] + 1e-9
    assert bool(jnp.all(err <= bound)), (
        f"max excess {float(jnp.max(err - bound))}"
    )


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_fused_wavg_q_matches_dequantized_oracle(impl):
    """Both quantized entries compute eq. 4 over the *dequantized*
    planes — bitwise for XLA, kernel tolerance for Pallas."""
    m, n, qb = 5, 100_000, 512
    G = jax.random.normal(jax.random.PRNGKey(3), (m, n), jnp.float32)
    T, R, valid = _share_meta(m, seed=3)
    Q, S = wavg_ref.quantize_flat(G, qb)
    want_g, want_w = wavg_ref.fused_wavg_q(Q, S, T, R, valid, qb)
    got_g, got_w = wavg_ops.fused_wavg_q(Q, S, T, R, valid, qb,
                                         impl=impl, interpret=True)
    if impl == "xla":
        np.testing.assert_array_equal(np.asarray(got_g),
                                      np.asarray(want_g))
    else:
        np.testing.assert_allclose(np.asarray(got_g),
                                   np.asarray(want_g),
                                   rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(got_w), float(want_w), rtol=1e-6)


def test_fused_wavg_q_rejects_bad_block():
    Q = jnp.zeros((2, 256), jnp.int8)
    S = jnp.zeros((2, 2), jnp.float32)
    T, R, valid = _share_meta(2)
    with pytest.raises(ValueError, match="q_block"):
        from repro.kernels.ddal_wavg.kernel import fused_wavg_q_flat
        fused_wavg_q_flat(Q, S, T, R, valid, q_block=100,
                          interpret=True)


def test_small_leaf_oracle_fallback():
    """Leaves under one tile never pay a kernel launch: the pallas
    tree entry routes them through the jnp contraction (zero
    pallas_call eqns), while a tile-sized leaf gets exactly one."""
    T, R, valid = _share_meta(3)
    small = {"b": jnp.ones((3, 64)), "w": jnp.ones((3, 10, 12))}
    big = {"emb": jnp.ones((3, 16_384))}

    def run(tree):
        return lambda: wavg_ops.tree_fused_wavg(
            tree, T, R, valid, impl="pallas", interpret=True)

    assert _count_pallas_calls(run(small)) == 0
    assert _count_pallas_calls(run(big)) == 1
    got_g, got_w = run(small)()
    want_g, want_w = _legacy_share(small, T, R, valid)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got_g, want_g)


def test_resolve_impl_auto_selection():
    """`auto` resolves by backend (xla off-TPU), explicit choices pass
    through, and unknown names fail loudly on every new entry point."""
    expect = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert wavg_ops.resolve_impl("auto") == expect
    assert wavg_ops.resolve_impl(None) == expect
    assert wavg_ops.resolve_impl("pallas") == "pallas"
    assert wavg_ops.resolve_impl("xla") == "xla"
    with pytest.raises(ValueError, match="impl"):
        wavg_ops.resolve_impl("cuda")
    G = jnp.ones((2, 256))
    T, R, valid = _share_meta(2)
    with pytest.raises(ValueError, match="impl"):
        wavg_ops.fused_wavg(G, T, R, valid, impl="nope")


def test_store_weighted_average_fused_is_bitwise():
    """The store combiner's new default (`fused=True`) reproduces the
    legacy multi-op weighted_average bit for bit on a populated ring,
    and a quantized store stays within the analytic eq. 4 bound."""
    from repro.core import knowledge as K
    params_like = {"w": jnp.zeros((24, 7)), "b": jnp.zeros((13,))}
    key = jax.random.PRNGKey(0)

    def fill(store, qb=0):
        for i in range(5):
            piece = jax.tree.map(
                lambda x: jax.random.normal(
                    jax.random.fold_in(key, i), x.shape), params_like)
            scale = None
            if qb:
                piece, scale = wavg_ops.quantize_tree(piece, qb,
                                                      lead=0)
            store = K.append(store, piece, T=float(i + 1),
                             R=0.5 + 0.1 * i, scale=scale)
        return store

    st = fill(K.make_store(params_like, m=8))
    legacy_g, legacy_w = K.weighted_average(st)
    fused_g, fused_w = K.weighted_average(st, fused=True)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), fused_g, legacy_g)
    assert float(fused_w) == float(legacy_w)

    qb = 128
    stq = fill(K.make_store(params_like, m=8, quant_block=qb), qb=qb)
    quant_g, quant_w = K.weighted_average(stq, quant_block=qb)
    max_scale = max(float(jnp.max(s))
                    for s in jax.tree.leaves(stq.scale))
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(quant_g), jax.tree.leaves(legacy_g)))
    assert err <= max_scale / 2 + 1e-7
    np.testing.assert_allclose(float(quant_w), float(legacy_w),
                               rtol=1e-6)


def test_flat_pod_quant_gate_is_identity_at_zero():
    """flat/pod combiners push window planes through
    quantize_knowledge_roundtrip before aggregation; quant-off must be
    the *same object* (no tracer-level perturbation), and quantized
    planes must respect the per-block bound."""
    from repro.core.sharded_ddal import (Knowledge,
                                         quantize_knowledge_roundtrip)
    key = jax.random.PRNGKey(4)
    tg = {"w": jax.random.normal(key, (4, 1000))}
    know = Knowledge(tg=tg,
                     tsum=jnp.ones((4,)),
                     rg=jax.tree.map(lambda x: 0.5 * x, tg),
                     rsum=jnp.ones((4,)))
    assert quantize_knowledge_roundtrip(know, 0) is know
    rt = quantize_knowledge_roundtrip(know, 128)
    _, S = wavg_ref.quantize_flat(tg["w"].reshape(4, -1), 128)
    err = jnp.abs(rt.tg["w"] - know.tg["w"]).reshape(4, -1)
    bound = jnp.repeat(S / 2.0, 128, axis=-1)[:, :1000] + 1e-9
    assert bool(jnp.all(err <= bound))
    np.testing.assert_array_equal(np.asarray(rt.tsum),
                                  np.asarray(know.tsum))


# ----------------------------------------------------------------------
# flash_attention
# ----------------------------------------------------------------------
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref


@pytest.mark.parametrize(
    "B,S,H,K,D,win,blk",
    [(2, 128, 4, 2, 32, None, 64),
     (1, 256, 4, 4, 64, None, 128),
     (2, 96, 8, 2, 32, None, 32),
     (1, 256, 4, 2, 32, 64, 64),
     (1, 64, 2, 1, 16, 16, 32),     # MQA + window
     (2, 80, 4, 4, 32, None, 32)])  # padded seq (80 % 32 != 0)
def test_flash_attention(B, S, H, K, D, win, blk):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, K, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, K, D), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, window=win, block_q=blk,
                                 block_k=blk, interpret=True)
    want = fa_ref.attention(q, k, v, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 128, 4, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(key, (1, 128, 2, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(key, (1, 128, 2, 32)).astype(jnp.bfloat16)
    got = fa_ops.flash_attention(q, k, v, interpret=True)
    want = fa_ref.attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2)


# ----------------------------------------------------------------------
# ssd_scan — Mamba2 intra-chunk dual form
# ----------------------------------------------------------------------
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


def _ssd_inputs(key, b, nc, l, h, n, p):
    ks = jax.random.split(key, 5)
    xc = jax.random.normal(ks[0], (b, nc, l, h, p), jnp.float32)
    dtc = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    cs = jnp.cumsum(dtc * A, axis=2)
    Bc = jax.random.normal(ks[3], (b, nc, l, h, n), jnp.float32)
    Cc = jax.random.normal(ks[4], (b, nc, l, h, n), jnp.float32)
    return xc, dtc, cs, Bc, Cc


@pytest.mark.parametrize("b,nc,l,h,p,n",
                         [(2, 2, 32, 3, 16, 16),
                          (1, 4, 64, 2, 32, 64),
                          (2, 1, 128, 4, 64, 128)])
def test_ssd_intra_chunk(b, nc, l, h, p, n):
    xc, dtc, cs, Bc, Cc = _ssd_inputs(jax.random.PRNGKey(0),
                                      b, nc, l, h, n, p)
    got = ssd_ops.ssd_intra_chunk(xc, dtc, cs, Bc, Cc, interpret=True)
    want = ssd_ref.ssd_intra_chunk(xc, dtc, cs, Bc, Cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ssd_chunked_end_to_end():
    """Full ssd_chunked with the Pallas intra-chunk path == XLA path."""
    from repro.models.ssd import ssd_chunked
    key = jax.random.PRNGKey(0)
    b, s, h, p, n, chunk = 1, 128, 2, 16, 32, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y1, s1 = ssd_chunked(x, dt, A, B, C, chunk, impl="xla")
    y2, s2 = ssd_chunked(x, dt, A, B, C, chunk,
                         impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_model_level_kernel_equivalence():
    """attention_impl / ssd_impl flags do not change model outputs.
    Slow lane: two full reduced-model losses per arch under interpret
    mode; the per-kernel parity sweeps above give tier-1 the same
    oracle coverage at a fraction of the wall time."""
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.models import get_model, make_batch
    for arch, flag in [("llama3.2-3b", "attention_impl"),
                       ("mamba2-780m", "ssd_impl")]:
        cfg = get_arch_config(arch).reduced()
        model = get_model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(cfg, key)
        batch = make_batch(cfg, ShapeConfig("t", 64, 2, "train"), key)
        l1 = model.loss(cfg, params, batch)
        l2 = model.loss(cfg.with_(**{flag: "pallas_interpret"}),
                        params, batch)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
