"""DDAL ablations (beyond paper): the knobs the paper introduces but
never sweeps.

  * asynchrony tolerance — per-edge delivery delay d ∈ {0, 5, 20}
    epochs (the paper's system is async but is evaluated with same-
    epoch queues); DDAL's eq. 4 average should keep learning stable
    under stale knowledge.
  * T-weighting — epochs vs sqrt vs uniform (paper fixes
    T ∝ epochs; eq. 4's point is down-weighting immature knowledge).
  * topology — full vs ring (K_{i,i'} ⊂ K_i: knowledge flows only to
    ring neighbours).

Each cell: 2 agents × 2,500 epochs of DDA3C on CartPole-v0, sharing
from epoch 1,000, tail-mean reward over the last 20%.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import GroupSpec
from repro.core import DDAL
from repro.rl import CartPole, init_a2c, make_a2c_callbacks


def _run(spec: GroupSpec, delay=None, epochs=2_500, seed=0):
    env = CartPole()
    opt = optim.adamw(3e-3)
    gen, app, pof = make_a2c_callbacks(env, opt)
    ddal = DDAL(spec, gen, app, pof, delay=delay)
    key = jax.random.PRNGKey(seed)
    astates = jax.vmap(lambda k: init_a2c(k, env, opt))(
        jax.random.split(key, spec.n_agents))
    gs = ddal.init(astates)
    gs, metrics = jax.jit(lambda g, k: ddal.run(g, k, epochs))(
        gs, jax.random.fold_in(key, 1))
    r = np.asarray(metrics["return"])
    tail = r[-epochs // 5:]
    return tail.mean(), tail.std()


def main(verbose: bool = True):
    base = dict(n_agents=2, threshold=1_000, minibatch=100, m_pieces=32)
    rows = []

    # staleness must EXCEED the share cadence (100) to bite: delayed
    # pieces then miss their own share step and mix into later ones
    for d in (0, 50, 150):
        delay = jnp.full((2, 2), d, jnp.int32) * (
            1 - jnp.eye(2, dtype=jnp.int32))
        mean, std = _run(GroupSpec(**base, max_delay=d), delay=delay)
        rows.append((f"delay={d}", mean, std))

    # T-weighting differentiates pieces of different maturity — pair
    # it with staleness so the window actually mixes epochs
    for tw in ("epochs", "sqrt", "uniform"):
        delay = jnp.asarray([[0, 150], [150, 0]], jnp.int32)
        mean, std = _run(GroupSpec(**base, max_delay=150,
                                   t_weighting=tw), delay=delay)
        rows.append((f"T={tw} (stale)", mean, std))

    # topology needs n > 3 for ring ⊂ full
    base4 = dict(n_agents=4, threshold=1_000, minibatch=100,
                 m_pieces=32)
    for topo in ("full", "ring"):
        mean, std = _run(GroupSpec(**base4, topology=topo))
        rows.append((f"topology={topo} (4 agents)", mean, std))

    if verbose:
        print(f"{'cell':26s} {'tail-mean':>10s} {'tail-std':>9s}")
        for name, mean, std in rows:
            print(f"{name:26s} {mean:10.2f} {std:9.2f}")
        print("(DDA3C CartPole, 2.5k epochs, share@1k; optimum = 100)")
    return rows


if __name__ == "__main__":
    main()
