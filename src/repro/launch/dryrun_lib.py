"""Dry-run library: lower + compile every (arch × shape) on a given
mesh and extract the roofline terms. No jax device-state mutation here
— ``dryrun.py`` (the CLI) sets XLA_FLAGS before importing anything.

Step functions lowered per shape kind:
  train   → the DDAL group train step (repro.core.sharded_ddal)
  prefill → full-sequence forward building a fresh KV cache
  decode  → ONE new token against a seq_len-capacity cache
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.sharding import axis_rules, set_mesh
from repro.configs import arch_for_shape, get_arch_config
from repro.configs.base import INPUT_SHAPES, ArchConfig, GroupSpec, ShapeConfig
from repro.core.sharded_ddal import make_group_train_step, train_state_specs
from repro.launch.mesh import serve_rules, train_rules
from repro.launch.shardings import (batch_partition_specs,
                                    cache_partition_specs,
                                    param_partition_specs,
                                    train_state_partition_specs)
from repro.models import cache_specs, get_model, input_specs
from repro.optim import adamw
from repro.roofline import analyze, model_flops


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _sanitize(mesh, spec: P, shape) -> P:
    """jit in_shardings require divisibility — drop any spec entry
    whose mesh-axis product does not divide that dim (e.g. kv_heads=8
    over model=16, vocab=49155 over 16). Internal sharding constraints
    still apply; only the *input* layout falls back to replicated on
    that dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, entries):
        out.append(axes if axes and dim % _axis_size(mesh, axes) == 0
                   else None)
    return P(*out)


def _named(mesh, spec_tree, shape_tree=None):
    if shape_tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, x: NamedSharding(mesh, _sanitize(mesh, s, x.shape)),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def _with_lead(specs: Dict[str, Any], n: int) -> Dict[str, Any]:
    return {k: jax.ShapeDtypeStruct((n,) + v.shape, v.dtype)
            for k, v in specs.items()}


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh_name: str
    ok: bool
    error: Optional[str] = None
    memory: Optional[dict] = None
    roofline: Optional[dict] = None
    compile_s: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def _mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def _memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0))
    return out


def lower_train(cfg: ArchConfig, shape: ShapeConfig, mesh,
                spec: GroupSpec, lr: float = 3e-4):
    """Lower the DDAL group train step on ``mesh``."""
    opt = adamw(lr)
    rules = train_rules(mesh)
    agent_axis = rules["agent"]
    # one protocol serves both the step and the partition specs: the
    # estimator decides what relevance state the TrainState carries,
    # so explicit exchange_estimator overrides shard correctly too
    from repro.core.exchange import build_exchange
    exchange = build_exchange(spec, kind="streaming")
    step_fn = make_group_train_step(cfg, spec, opt, exchange=exchange)

    state_shapes = train_state_specs(cfg, spec, opt)
    state_specs = train_state_partition_specs(
        cfg, rules, agent_axis,
        learn_relevance=exchange.estimator.learns,
        sketch_dim=exchange.estimator.sketch_dim)
    batch_shapes = _with_lead(input_specs(cfg, shape), spec.n_agents)
    bspecs = batch_partition_specs(cfg, shape, rules["batch"],
                                   lead=(agent_axis,))

    in_shardings = (_named(mesh, state_specs, state_shapes),
                    _named(mesh, bspecs, batch_shapes))
    with set_mesh(mesh), axis_rules(rules):
        lowered = jax.jit(step_fn, in_shardings=in_shardings).lower(
            state_shapes, batch_shapes)
    return lowered


def lower_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh):
    model = get_model(cfg)
    rules = serve_rules(mesh, shape.global_batch)
    batch_axes = rules["batch"]

    def prefill_step(params, batch):
        cache = model.make_cache(cfg, shape.global_batch, shape.seq_len)
        logits, new_cache = model.forward(cfg, params, batch, cache)
        return logits, new_cache

    from repro.models import param_specs
    pshapes = param_specs(cfg)
    pspecs = param_partition_specs(cfg, rules)
    bshapes = input_specs(cfg, shape)
    bspecs = batch_partition_specs(cfg, shape, batch_axes)
    in_shardings = (_named(mesh, pspecs, pshapes),
                    _named(mesh, bspecs, bshapes))
    with set_mesh(mesh), axis_rules(rules):
        lowered = jax.jit(prefill_step, in_shardings=in_shardings
                          ).lower(pshapes, bshapes)
    return lowered


def lower_decode(cfg: ArchConfig, shape: ShapeConfig, mesh):
    model = get_model(cfg)
    rules = serve_rules(mesh, shape.global_batch)
    batch_axes = rules["batch"]

    def decode_step(params, batch, cache):
        return model.decode(cfg, params, batch, cache)

    from repro.models import param_specs
    pshapes = param_specs(cfg)
    pspecs = param_partition_specs(cfg, rules)
    bshapes = input_specs(cfg, shape)
    bspecs = batch_partition_specs(cfg, shape, batch_axes)
    cshapes = cache_specs(cfg, shape)
    cspecs = cache_partition_specs(cfg, shape, batch_axes)
    in_shardings = (_named(mesh, pspecs, pshapes),
                    _named(mesh, bspecs, bshapes),
                    _named(mesh, cspecs, cshapes))
    with set_mesh(mesh), axis_rules(rules):
        lowered = jax.jit(decode_step, in_shardings=in_shardings
                          ).lower(pshapes, bshapes, cshapes)
    return lowered


def _lower_for(cfg, shape, mesh, group: Optional[GroupSpec]):
    if shape.kind == "train":
        n_agents = mesh.shape.get("pod", 1)
        spec = group or GroupSpec(n_agents=n_agents)
        return lower_train(cfg, shape, mesh, spec), spec
    if shape.kind == "prefill":
        return lower_prefill(cfg, shape, mesh), None
    return lower_decode(cfg, shape, mesh), None


# -- depth extrapolation -------------------------------------------------
# ``cost_analysis`` / the HLO parse see scan bodies ONCE, and fully
# unrolling 60–80-layer models is compile-time-prohibitive. Layer
# stacks are uniform, so every cost metric is affine in depth: compile
# two shallow *unrolled* variants (d1, d2 scanned layers / super-
# blocks), fit the line, evaluate at the full depth. Exact for FLOPs,
# bytes and collective bytes; memory comes from the full scanned
# compile (the artifact that must fit).
_D1, _D2 = 1, 3


def _depth_of(cfg: ArchConfig) -> int:
    if cfg.hybrid is not None:
        return cfg.hybrid.n_super_blocks
    return cfg.n_layers - cfg.first_k_dense


def _with_depth(cfg: ArchConfig, d: int) -> ArchConfig:
    if cfg.hybrid is not None:
        return cfg.with_(hybrid=dataclasses.replace(
            cfg.hybrid, n_super_blocks=d))
    return cfg.with_(n_layers=d + cfg.first_k_dense)


def _cost_metrics(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    from repro.roofline.hlo import collective_bytes
    coll = collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    for k, v in coll.items():
        out[f"coll_{k}"] = float(v)
    return out


def _extrapolate(m1: Dict[str, float], m2: Dict[str, float],
                 d1: int, d2: int, full: int) -> Dict[str, float]:
    out = {}
    for k in m1:
        # per-depth cost is monotone in depth; cost-analysis jitter at
        # tiny shapes (B=1 decode) can give a negative slope — clamp
        # the SLOPE, keeping at least the shallow measurement
        slope = max((m2[k] - m1[k]) / (d2 - d1), 0.0)
        out[k] = m1[k] + slope * (full - d1)
    return out


def dryrun_pair(arch_id: str, shape_name: str, mesh, *,
                group: Optional[GroupSpec] = None,
                cfg_override: Optional[ArchConfig] = None,
                keep_artifacts: bool = False,
                skip_memory: bool = False) -> DryrunResult:
    """Lower + compile one (arch × shape) pair; return roofline record.

    Three compiles: full depth scanned (memory_analysis — the artifact
    that must fit), plus two shallow unrolled (exact per-depth costs,
    extrapolated to full depth)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = cfg_override or arch_for_shape(get_arch_config(arch_id),
                                         shape_name)
    mesh_name = _mesh_name(mesh)
    chips = mesh.size
    t0 = time.time()
    try:
        # 1) full-depth scanned compile → memory + proof it lowers
        lowered, spec = _lower_for(cfg, shape, mesh, group)
        compiled = lowered.compile()
        mem = None if skip_memory else _memory_dict(compiled)

        # 2+3) shallow unrolled compiles → extrapolated exact costs
        full = _depth_of(cfg)
        d1, d2 = min(_D1, full), min(_D2, full)
        if d2 > d1:
            ms = []
            for d in (d1, d2):
                cfg_d = _with_depth(cfg, d).with_(unroll_layers=True)
                low_d, _ = _lower_for(cfg_d, shape, mesh, group)
                ms.append(_cost_metrics(low_d.compile()))
            metrics = _extrapolate(ms[0], ms[1], d1, d2, full)
        else:
            metrics = _cost_metrics(compiled)

        n_agents = spec.n_agents if spec is not None else 1
        mflops = model_flops(cfg, shape, n_agents)
        # cost_analysis & HLO shapes are per-device (post-partition);
        # scale to global so the spec's  X/(chips·BW)  formulas hold.
        cost = {"flops": metrics["flops"] * chips,
                "bytes accessed": metrics["bytes"] * chips}
        coll = {k[len("coll_"):]: v * chips for k, v in metrics.items()
                if k.startswith("coll_")}
        roof = analyze(arch_id, shape, mesh_name, chips, cost, coll,
                       mflops,
                       bytes_per_device=(mem or {}).get(
                           "total_bytes_per_device"))
        res = DryrunResult(arch=arch_id, shape=shape_name,
                           mesh_name=mesh_name, ok=True, memory=mem,
                           roofline=roof.to_dict(),
                           compile_s=time.time() - t0)
        if keep_artifacts:
            res.lowered = lowered        # type: ignore[attr-defined]
            res.compiled = compiled      # type: ignore[attr-defined]
        return res
    except Exception as e:                      # noqa: BLE001
        import traceback
        return DryrunResult(arch=arch_id, shape=shape_name,
                            mesh_name=mesh_name, ok=False,
                            error=f"{type(e).__name__}: {e}\n"
                                  f"{traceback.format_exc(limit=8)}",
                            compile_s=time.time() - t0)
