"""Unified decoder-only transformer covering the dense / moe / vlm /
audio families. Layers are uniform and scanned (``lax.scan`` over
stacked per-layer parameters) so HLO size and compile time are flat in
depth; DeepSeek's leading dense layer runs outside the scan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.sharding import shard
from repro.models import attention as attn
from repro.models.common import (cross_entropy, dense_init, embed_init,
                                 rms_norm, sinusoidal_positions)
from repro.models.mlp import gelu_mlp, init_gelu_mlp, init_swiglu, swiglu
from repro.models.moe import init_moe, moe_apply


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def _init_layer(cfg, key, *, dense_ff: Optional[int] = None):
    """One decoder layer. dense_ff overrides MoE with a dense FF."""
    ka, kc, kf = jax.random.split(key, 3)
    dt = cfg.dtype("param")
    p = {"ln1": jnp.ones((cfg.d_model,), dt),
         "ln2": jnp.ones((cfg.d_model,), dt)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(cfg, ka)
    else:
        p["attn"] = attn.init_self_attention(cfg, ka)
    if cfg.cross_attention:
        p["ln_x"] = jnp.ones((cfg.d_model,), dt)
        p["xattn"] = attn.init_cross_attention(cfg, kc)
    if dense_ff is not None:
        p["mlp"] = (init_gelu_mlp(kf, cfg.d_model, dense_ff, dt)
                    if cfg.family == "audio"
                    else init_swiglu(kf, cfg.d_model, dense_ff, dt))
    else:
        p["moe"] = init_moe(cfg, kf)
    return p


def init_transformer(cfg, key):
    k_embed, k_layers, k_head, k_l0 = jax.random.split(key, 4)
    dt = cfg.dtype("param")
    V, E = cfg.vocab_size, cfg.d_model
    params = {}
    if cfg.family == "audio":
        params["embed"] = embed_init(k_embed, (cfg.n_codebooks, V, E), dt)
        params["lm_head"] = dense_init(k_head, (cfg.n_codebooks, E, V), dt)
    else:
        params["embed"] = embed_init(k_embed, (V, E), dt)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, (E, V), dt)
    params["final_norm"] = jnp.ones((E,), dt)

    dense_ff = cfg.d_ff if cfg.moe is None else None
    n_scan = cfg.n_layers - cfg.first_k_dense
    keys = jax.random.split(k_layers, n_scan)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(cfg, k, dense_ff=dense_ff))(keys)
    if cfg.first_k_dense:
        params["layer0"] = _init_layer(cfg, k_l0, dense_ff=cfg.dense_ff)
    return params


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------
def _layer_apply(cfg, p, x, positions, cond, layer_cache, *,
                 dense_ff: bool):
    cdt = cfg.dtype("compute")
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = attn.mla_attention(cfg, p["attn"], h, positions,
                                          layer_cache and
                                          layer_cache.get("kv"))
    else:
        a, new_cache = attn.self_attention(cfg, p["attn"], h, positions,
                                           layer_cache=layer_cache and
                                           layer_cache.get("kv"))
    x = x + a
    new_xcache = None
    if cfg.cross_attention:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        cx, new_xcache = attn.cross_attention(
            cfg, p["xattn"], hx, cond,
            layer_cache and layer_cache.get("xkv"))
        x = x + cx
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if dense_ff:
        f = (gelu_mlp(p["mlp"], h2, cdt) if cfg.family == "audio"
             else swiglu(p["mlp"], h2, cdt))
    else:
        f, aux = moe_apply(cfg, p["moe"], h2)
    x = x + f
    out_cache = None
    if layer_cache is not None:
        out_cache = {}
        if new_cache is not None:
            out_cache["kv"] = new_cache
        if new_xcache is not None:
            out_cache["xkv"] = new_xcache
    return x, aux, out_cache


def _embed(cfg, params, tokens, positions, vision=None):
    cdt = cfg.dtype("compute")
    emb = params["embed"].astype(cdt)
    if cfg.family == "audio":
        # tokens: (B, n_codebooks, S) — summed codebook embeddings
        x = sum(emb[i][tokens[:, i]] for i in range(cfg.n_codebooks))
        flat_pos = positions
        x = x + sinusoidal_positions(flat_pos, cfg.d_model).astype(cdt)
        return x
    x = emb[tokens]
    if cfg.family == "vlm" and vision is not None:
        # pre-projected patch embeddings prepended to the text tokens
        x = jnp.concatenate([vision.astype(cdt), x], axis=1)
    return x


def transformer_forward(cfg, params, batch, cache=None):
    """Full-sequence pass (train / prefill).

    batch: tokens, positions [, labels, vision, cond].
    Returns (logits, aux_loss, new_cache).
    """
    cdt = cfg.dtype("compute")
    cond = batch.get("cond")
    if cond is not None:
        cond = cond.astype(cdt)
    x = _embed(cfg, params, batch["tokens"], batch["positions"],
               batch.get("vision"))
    x = shard(x, "batch", None, None)
    positions = batch["positions"]
    dense_ff = cfg.moe is None

    l0_cache = None
    if cfg.first_k_dense:
        lc = None if cache is None else jax.tree.map(
            lambda c: c[0], cache["layer0"])
        x, _, l0_cache = _layer_apply(cfg, params["layer0"], x, positions,
                                      cond, lc, dense_ff=True)
        if l0_cache is not None:
            l0_cache = jax.tree.map(lambda c: c[None], l0_cache)

    def body(carry, per_layer):
        xc, aux_sum = carry
        lp, lcache = per_layer
        xo, aux, new_cache = _layer_apply(cfg, lp, xc, positions, cond,
                                          lcache, dense_ff=dense_ff)
        return (xo, aux_sum + aux), new_cache

    body_fn = body
    if cfg.remat and cache is None:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    scan_cache = None if cache is None else cache["layers"]
    n_scan = cfg.n_layers - cfg.first_k_dense
    unroll = cfg.unroll_layers
    if scan_cache is None:
        # scan still needs a per-layer xs structure: params only
        (x, aux_sum), _ = jax.lax.scan(
            lambda c, lp: body_fn(c, (lp, None)),
            (x, jnp.float32(0.0)), params["layers"], unroll=unroll)
        new_cache = None
    else:
        (x, aux_sum), new_layer_caches = jax.lax.scan(
            body_fn, (x, jnp.float32(0.0)),
            (params["layers"], scan_cache), unroll=unroll)
        new_cache = {"layers": new_layer_caches}
        if l0_cache is not None:
            new_cache["layer0"] = l0_cache

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_head(cfg, params, x)
    return logits, aux_sum, new_cache


def _lm_head(cfg, params, x):
    cdt = cfg.dtype("compute")
    if cfg.family == "audio":
        heads = params["lm_head"].astype(cdt)         # (4, E, V)
        logits = jnp.einsum("bsd,kdv->bksv", x, heads)
        return shard(logits, "batch", None, None, "vocab")
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(cdt)
    return shard(x @ w, "batch", None, "vocab")


def transformer_decode(cfg, params, batch, cache):
    """One-token decode. batch: tokens (B,1) or (B,K,1) for audio,
    positions (B,1) / (B,3,1); cache from make_cache/prefill."""
    logits, _, new_cache = transformer_forward(cfg, params, batch,
                                               cache=cache)
    return logits, new_cache


def transformer_loss(cfg, params, batch):
    logits, aux, _ = transformer_forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # labels cover the full (vision_prefix + text) sequence; the
        # data pipeline marks vision positions with -100.
        pass
    return cross_entropy(logits, labels) + aux


def make_transformer_cache(cfg, batch: int, max_len: int):
    n_scan = cfg.n_layers - cfg.first_k_dense
    def one(n):
        entry = {}
        if cfg.mla is not None:
            entry["kv"] = attn.make_mla_cache(cfg, batch, max_len, n)
        else:
            entry["kv"] = attn.make_kv_cache(cfg, batch, max_len, n)
        if cfg.cross_attention:
            H, D = cfg.n_heads, cfg.head_dim
            entry["xkv"] = {
                "ck": jnp.zeros((n, batch, cfg.cond_len, H, D),
                                cfg.dtype("compute")),
                "cv": jnp.zeros((n, batch, cfg.cond_len, H, D),
                                cfg.dtype("compute")),
            }
        return entry
    cache = {"layers": one(n_scan)}
    if cfg.first_k_dense:
        # layer0 is dense FF but same attention type
        cache["layer0"] = one(1)
    return cache
