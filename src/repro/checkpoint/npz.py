"""Checkpointing: flatten a pytree to keyed numpy arrays in one .npz.

Path keys are serialised with ``jax.tree_util.keystr`` so arbitrary
dict/list/NamedTuple nests round-trip; restore takes a *template*
pytree (e.g. from ``jax.eval_shape``) and refills its leaves, casting
back to the template dtype. Atomic via write-to-temp + rename.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                              "float8_e5m2"):
            # np.savez cannot serialise ml_dtypes; f32 is lossless for
            # bf16 and restore() casts back to the template dtype.
            arr = arr.astype(np.float32)
        out[jax.tree_util.keystr(path)] = arr
    return out


def save(path: str, tree: Any, step: int | None = None) -> None:
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(path: str, template: Any, strict: bool = True) -> Any:
    """Refill ``template``'s leaves from ``path`` (dtypes follow the
    template; shapes must match exactly). ``strict=False`` keeps the
    template's value for leaves absent from the checkpoint — e.g.
    restoring a pre-elastic checkpoint into an elastic state whose
    ``alive`` mask the checkpoint never saw."""
    with np.load(path) as data:
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
            template)
        new_leaves = []
        for kpath, leaf in paths_leaves:
            key = jax.tree_util.keystr(kpath)
            if key not in data:
                if not strict:
                    new_leaves.append(leaf)
                    continue
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch at {key}: checkpoint "
                    f"{arr.shape} vs template {leaf.shape}")
            new_leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_step(path: str) -> int | None:
    with np.load(path) as data:
        return int(data["__step__"]) if "__step__" in data else None
