"""Logical-axis sharding helpers.

Model code annotates tensors with *logical* axis names ("batch",
"seq", "model_in", "experts", ...). A rule table, installed by the
launcher (or left empty for single-device smoke tests), maps logical
names to physical mesh axes. When no rules are installed every
annotation is the identity, so the same model code runs on one CPU
device and on the 512-chip production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

# Default logical→physical rules for the production ("data", "model")
# mesh (the "pod" axis is handled separately: it only ever shards the
# leading agent axis, see repro.core.sharded_ddal).
DEFAULT_RULES = {
    "batch": "data",
    "agent": "pod",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv_fused": "model",
    "ff": "model",
    "experts": "model",
    "ssm_inner": "model",
    "embed": None,
    "seq": None,
}


def get_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[dict]):
    """Install logical→physical sharding rules for the enclosed scope."""
    prev = get_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def logical_spec(*names: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules."""
    rules = get_rules()
    if rules is None:
        return P()
    return P(*[rules.get(n) if n is not None else None for n in names])


def shard(x, *names: Optional[str]):
    """Apply a logical sharding constraint (identity w/o rules)."""
    rules = get_rules()
    if rules is None:
        return x
    if all(rules.get(n) is None for n in names if n is not None):
        return x
    return jax.lax.with_sharding_constraint(x, logical_spec(*names))


def named_sharding(mesh, *names: Optional[str]):
    """A NamedSharding for jit in_/out_shardings from logical names."""
    return jax.sharding.NamedSharding(mesh, logical_spec(*names))


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` only exists on newer jax; on older releases the
    Mesh object itself is the context manager. Use as
    ``with set_mesh(mesh): ...``.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: new releases export it at
    the top level (with ``check_vma``), 0.4.x under
    ``jax.experimental.shard_map`` (with ``check_rep``). Replication
    checking is disabled on both — the DDAL pod dispatch returns
    per-device slices whose replication the checker cannot see through
    the ``axis_index``-driven gathers."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)
