"""Mamba2 SSD (state-space duality) sequence mixing — pure-jnp version.

Chunked algorithm from arXiv:2405.21060 §6: within a chunk the SSM is
computed in its "quadratic attention" dual form (MXU-friendly block
matmuls); across chunks a first-order recurrence on the (H, P, N)
states is evaluated with ``lax.associative_scan``. All decay factors
are exp of non-positive numbers (A < 0, dt > 0) so the math is
overflow-free by construction.

The Pallas kernel in ``repro.kernels.ssd_scan`` implements the
intra-chunk dual form; this module is also its ``ref`` oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _segsum_mask(dA_cs):
    """L[i, j] = exp(cs[i] - cs[j]) for j <= i else 0.

    dA_cs: (..., L) inclusive cumsum of dt·A over the chunk.
    Returns (..., L, L).
    """
    L = dA_cs.shape[-1]
    diff = dA_cs[..., :, None] - dA_cs[..., None, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(causal, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, B, C, chunk: int,
                initial_state: Optional[jnp.ndarray] = None,
                impl: str = "xla") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence SSD.

    x:  (b, s, h, p)   per-head inputs
    dt: (b, s, h)      positive step sizes (already softplus'd)
    A:  (h,)           negative decay rates
    B:  (b, s, g, n)   input projections (g groups broadcast onto heads)
    C:  (b, s, g, n)   output projections
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if s % chunk:
        # pad to a chunk multiple with dt = 0 steps: exp(0·A) = 1 and
        # the state update dt·x·B = 0, so padding is an exact no-op on
        # the recurrence (outputs at padded positions are discarded).
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, fs = ssd_chunked(x, dt, A, B, C, chunk,
                            initial_state=initial_state, impl=impl)
        return y[:, :s], fs
    nc = s // chunk
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)                     # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2)

    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bc = Bh.reshape(b, nc, chunk, h, n)
    Cc = Ch.reshape(b, nc, chunk, h, n)

    dA = dtc * A.astype(f32)                            # (b,nc,l,h) ≤ 0
    cs = jnp.cumsum(dA, axis=2)                         # inclusive

    # ---- intra-chunk (dual quadratic form) ---------------------------
    if impl == "pallas_interpret":
        from repro.kernels.ssd_scan import ops as ssd_ops
        y_diag = ssd_ops.ssd_intra_chunk(xc, dtc, cs, Bc, Cc,
                                         interpret=True)
    else:
        Lmask = _segsum_mask(jnp.moveaxis(cs, 3, 2))    # (b,nc,h,l,l)
        scores = jnp.einsum("bcihn,bcjhn->bchij",
                            Cc.astype(f32), Bc.astype(f32))
        scores = scores * Lmask * jnp.moveaxis(dtc, 3, 2)[..., None, :]
        y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores,
                            xc.astype(f32))

    # ---- chunk states -------------------------------------------------
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)       # (b,nc,l,h)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn",
                        Bc.astype(f32), decay_to_end * dtc,
                        xc.astype(f32))                 # (b,nc,h,p,n)
    chunk_decay = jnp.exp(cs[:, :, -1, :])              # (b,nc,h)

    # ---- inter-chunk associative scan ---------------------------------
    if initial_state is not None:
        s0 = initial_state.astype(f32)[:, None]         # (b,1,h,p,n)
        d0 = jnp.ones((b, 1, h), f32)
        states = jnp.concatenate([s0, states], axis=1)
        chunk_decay = jnp.concatenate([d0, chunk_decay], axis=1)

    def combine(a, bb):
        d1, s1 = a
        d2, s2 = bb
        return d1 * d2, s1 * d2[..., None, None] + s2

    decays, states_cum = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    final_state = states_cum[:, -1]                     # (b,h,p,n)
    # state *entering* each (original) chunk:
    if initial_state is not None:
        states_in = states_cum[:, :nc]
    else:
        zeros = jnp.zeros_like(states_cum[:, :1])
        states_in = jnp.concatenate([zeros, states_cum[:, :-1]], axis=1)

    # ---- inter-chunk output contribution ------------------------------
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Cc.astype(f32), states_in, jnp.exp(cs))
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token recurrent update.

    state: (b, h, p, n); x: (b, h, p); dt: (b, h); B, C: (b, g, n).
    Returns (y (b,h,p), new_state).
    """
    f32 = jnp.float32
    g = B.shape[1]
    h = x.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1).astype(f32)         # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1).astype(f32)
    dtf = dt.astype(f32)
    dA = jnp.exp(dtf * A.astype(f32))                   # (b,h)
    upd = (dtf[..., None] * x.astype(f32))[..., None] * Bh[:, :, None, :]
    new_state = state * dA[..., None, None] + upd       # (b,h,p,n)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state
