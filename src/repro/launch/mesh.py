"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips over ("data", "model").
    Multi-pod: 2×16×16 = 512 chips over ("pod", "data", "model") —
    one GARL agent per pod (DESIGN.md §3)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many (CPU) devices exist — tests only."""
    return jax.make_mesh(shape, axes)


def train_rules(mesh) -> dict:
    """Logical→physical sharding rules for training on ``mesh``."""
    has_pod = "pod" in mesh.axis_names
    return {
        "agent": "pod" if has_pod else None,
        "batch": "data",
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "qkv_fused": "model",
        "ff": "model",
        "experts": "model",
        "ssm_inner": "model",
        "kv_slots": None,        # training: no decode cache
    }


def serve_rules(mesh, global_batch: int) -> dict:
    """Serving has no agent axis; the batch spreads over every
    non-model axis when divisible (pod×data on the multi-pod mesh)."""
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    n = 1
    for a in batch_axes:
        n *= mesh.shape[a]
    batch = batch_axes if global_batch % n == 0 else None
    if batch is not None and len(batch) == 1:
        batch = batch[0]
    return {
        "agent": None,
        "batch": batch,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "qkv_fused": "model",
        "ff": "model",
        "experts": "model",
        "ssm_inner": "model",
        # decode caches shard their SLOT dim over "model" (32768 and
        # the 8192 sliding window both divide 16) — flash-decoding
        # style distributed KV sweep; kv-head counts (8, 4) don't
        # divide 16, so head-sharding would replicate (§Perf it.5)
        "kv_slots": "model",
    }
