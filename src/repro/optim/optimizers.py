"""Optimisers as pure (init, update) pairs over parameter pytrees.

No optax in this environment — these are self-contained and used both
by the RL agents (paper repro) and the LLM-scale training loop. The
``update`` signature takes the *gradient source* produced by DDAL
(local gradients during warm-up, the eq. 4 weighted average after
sharing starts) so the optimiser is agnostic to group-agent learning.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.common.pytree import global_norm_clip, tree_map, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple]
    # update(grads, opt_state, params, step) -> (new_params, new_state)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr, clip: Optional[float] = None) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, step):
        if clip is not None:
            grads, _ = global_norm_clip(grads, clip)
        lr_t = _lr_at(lr, step)
        new_params = tree_map(
            lambda p, g: p - lr_t.astype(p.dtype) * g.astype(p.dtype),
            params, grads)
        return new_params, state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, clip: Optional[float] = None
             ) -> Optimizer:
    def init(params):
        return {"m": tree_zeros_like(params)}

    def update(grads, state, params, step):
        if clip is not None:
            grads, _ = global_norm_clip(grads, clip)
        m = tree_map(lambda mm, g: beta * mm + g.astype(mm.dtype),
                     state["m"], grads)
        lr_t = _lr_at(lr, step)
        new_params = tree_map(
            lambda p, mm: p - lr_t.astype(p.dtype) * mm.astype(p.dtype),
            params, m)
        return new_params, {"m": m}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, clip: Optional[float] = 1.0
          ) -> Optimizer:
    """AdamW with fp32 moments (regardless of param dtype)."""
    def init(params):
        f32 = lambda t: tree_map(  # noqa: E731
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        return {"m": f32(params), "v": f32(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step):
        if clip is not None:
            grads, _ = global_norm_clip(grads, clip)
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        m = tree_map(lambda mm, g: b1 * mm + (1 - b1) *
                     g.astype(jnp.float32), state["m"], grads)
        v = tree_map(lambda vv, g: b2 * vv + (1 - b2) *
                     jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1.0 - b1 ** cf
        bc2 = 1.0 - b2 ** cf
        lr_t = _lr_at(lr, step)

        def upd(p, mm, vv):
            mh = mm / bc1
            vh = vv / bc2
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)

        new_params = tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "momentum":
        return momentum(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
