"""Transport faults — a seeded, planned-up-front message-fault
injector for the knowledge exchange (ISSUE 9).

``repro.core.chaos`` injects *membership* faults (whole agents die);
this module injects *message* faults: an individual knowledge piece
travelling one edge of the gossip graph can be **lost**, **duplicated**,
**corrupted** in flight, or arrive **late** (delay jitter). The design
mirrors ``chaos_schedule``: the whole fault history is rolled up front
with a dedicated ``numpy`` generator into plain ``(horizon, n, k)``
arrays — tests, the CI fault lane and ``bench_fault_transport.py`` all
replay identical fault histories from the same seed, and planning in
numpy means a fault schedule can never perturb a trainer's jax PRNG
stream.

Per-edge semantics (edge = destination row i, neighbor slot j of the
``Topology`` table; the **self-loop is exempt** — an agent's own piece
rides a local queue, not the network):

loss / retransmit
    A lost message with retransmit budget ``b`` is retried with
    exponential backoff (1, 2, 4, … epochs). Each retry is an
    independent loss draw; the first success converts the drop into
    *extra delay* (the cumulative backoff — the original payload
    eventually delivered late), exhausting the budget leaves it
    dropped. All resolved at plan time: the jitted path sees only the
    final ``drop`` / ``extra`` arrays.
jitter
    Uniform extra delivery delay in ``[0, transport_jitter]`` epochs,
    added on top of the delay model's per-edge delay.
duplication
    A delivered message is re-delivered one epoch later (the delay
    line re-arms a second arrival slot). Idempotent for the streaming
    trainer's window sums, so it is a buffer-trainer fault only.
corruption
    The payload planes are garbled in flight (finite garbage — sign/
    offset flips, never NaN). The position-weighted checksum computed
    at send rides the clean payload, so ``sparse_deliver`` detects the
    damage and **quarantines** the piece: payload zeroed, ``valid``
    cleared — exactly zero eq. 4 weight, in both the T and R terms.

The fault-free configuration (every knob zero ⇒ the ``"none"``
strategy) allocates no checksum/birth planes and traces every program
bit-identically to the pre-transport exchange — the same structural
contract ``elastic=False`` honors.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exchange.registry import TRANSPORTS

#: additive garbage for fp32 payload corruption — huge against any
#: gradient scale, and finite (0·garbage = 0, never NaN)
CORRUPT_BIAS = 1e6
#: checksum verification tolerance: absolute + relative slack for the
#: send-side vs deliver-side fp32 reduction (identical shapes, so in
#: practice bitwise; int8 payload sums are exact in fp32)
CHK_ABS_TOL = 1e-4
CHK_REL_TOL = 1e-5
#: period of the position-dependent checksum weights (1 + pos % 13) —
#: position weighting is what makes the int8 NOT-flip detectable even
#: on planes whose value multiset is symmetric under q → -1-q
_CHK_PERIOD = 13


class TransportPlan(NamedTuple):
    """One planned fault history — plain numpy, shape (horizon, n, k).

    ``drop``: lost after the retransmit budget (never delivered).
    ``extra``: extra delivery delay (jitter + retransmit backoff).
    ``dup``: a second copy arrives one epoch after the first.
    ``corrupt``: payload garbled in flight (checksum will catch it).
    """
    drop: np.ndarray      # bool
    extra: np.ndarray     # int32
    dup: np.ndarray       # bool
    corrupt: np.ndarray   # bool

    @property
    def horizon(self) -> int:
        return self.drop.shape[0]


def transport_schedule(seed: int, n: int, k: int, horizon: int, *,
                       loss: float = 0.0, dup: float = 0.0,
                       corrupt: float = 0.0, jitter: int = 0,
                       retransmit: int = 0) -> TransportPlan:
    """Plan a deterministic per-edge fault history (see module doc).

    The plan replays cyclically: epoch ``e`` uses row ``e % horizon``.
    Probabilities are per message per edge; ``jitter`` is the maximum
    uniform extra delay; ``retransmit`` is the per-message retry
    budget (backoff 1, 2, 4, … epochs, resolved here into either a
    late delivery or a final drop).
    """
    for name, p in (("loss", loss), ("dup", dup), ("corrupt", corrupt)):
        if not 0.0 <= p <= 1.0:
            raise ValueError(
                f"transport {name} probability must be in [0, 1], "
                f"got {p}")
    if jitter < 0:
        raise ValueError(f"transport jitter must be >= 0, got {jitter}")
    if retransmit < 0:
        raise ValueError(
            f"retransmit budget must be >= 0, got {retransmit}")
    if horizon < 1:
        raise ValueError(f"transport horizon must be >= 1, got {horizon}")
    rng = np.random.default_rng(seed)
    shape = (horizon, n, k)
    drop = rng.random(shape) < loss
    dup_m = rng.random(shape) < dup
    corrupt_m = rng.random(shape) < corrupt
    extra = (rng.integers(0, jitter + 1, shape).astype(np.int32)
             if jitter > 0 else np.zeros(shape, np.int32))
    if retransmit > 0 and loss > 0:
        backoff = 0
        for attempt in range(1, retransmit + 1):
            backoff += 1 << (attempt - 1)
            saved = drop & (rng.random(shape) >= loss)
            extra = np.where(saved, extra + backoff, extra)
            drop &= ~saved
    return TransportPlan(drop=drop, extra=extra, dup=dup_m,
                         corrupt=corrupt_m)


class TransportFaults(NamedTuple):
    """One epoch's fault slice — jnp (n, k) arrays, consumed by
    ``repro.core.knowledge.sparse_send``."""
    drop: jnp.ndarray
    extra: jnp.ndarray
    dup: jnp.ndarray
    corrupt: jnp.ndarray


class Transport:
    """Jit-side view of a :class:`TransportPlan`: the plan arrays as
    jnp constants plus the knob-derived delay-line headroom (static
    regardless of which faults the seed realised, so the compiled
    program shape never depends on the draw)."""

    def __init__(self, plan: TransportPlan, *, extra_delay: int):
        self.plan = plan
        self.drop = jnp.asarray(plan.drop)
        self.extra = jnp.asarray(plan.extra)
        self.dup = jnp.asarray(plan.dup)
        self.corrupt = jnp.asarray(plan.corrupt)
        self.horizon = plan.horizon
        #: worst-case extra delivery planes the line must hold:
        #: jitter + full retransmit backoff + the duplicate's +1
        self.extra_delay = int(extra_delay)

    def at(self, epoch) -> TransportFaults:
        """The (n, k) fault slice in force at ``epoch`` (traced ok)."""
        e = jnp.asarray(epoch, jnp.int32) % self.horizon
        return TransportFaults(
            drop=jnp.take(self.drop, e, axis=0),
            extra=jnp.take(self.extra, e, axis=0),
            dup=jnp.take(self.dup, e, axis=0),
            corrupt=jnp.take(self.corrupt, e, axis=0))

    def deliver_mask(self, step, nbr) -> jnp.ndarray:
        """Streaming-trainer view: (n, k) bool, True where this share
        round's message survives. Lost and corrupted messages are
        equivalent there — a quarantined window contributes exactly
        zero — while dup/jitter are no-ops on idempotent window sums
        with no delay line. Self-loops always survive (local queue)."""
        f = self.at(step)
        n = nbr.shape[0]
        self_edge = nbr == jnp.arange(n)[:, None]
        return self_edge | ~(f.drop | f.corrupt)


# ---------------------------------------------------------------------
# wire integrity: position-weighted payload checksums
# ---------------------------------------------------------------------
def _leaf_checksum(leaf) -> jnp.ndarray:
    """(n, k) fp32 checksum of one (n, k, *param) payload leaf:
    Σ_p w_p·x_p with position weights w_p = 1 + (p % 13). Position
    weighting keeps the int8 NOT-flip (q → -1-q) visible even when a
    plane's value multiset is symmetric; int8 products stay ≤ 13·127,
    so the fp32 sum is exact and order-independent."""
    nk = leaf.shape[:2]
    x = jnp.reshape(leaf, nk + (-1,)).astype(jnp.float32)
    w = (jnp.arange(x.shape[-1]) % _CHK_PERIOD + 1).astype(jnp.float32)
    return x @ w


def plane_checksum(pieces, scales=None) -> jnp.ndarray:
    """Per-edge payload checksum over a (n, k, ...)-shaped pytree
    (plus its quantization ``scales``, when present). Called with the
    *same* shapes at send (the gathered update) and at deliver (the
    popped arrival slice), so both reductions are the same computation
    — any residual fp32 slack is covered by ``checksum_ok``."""
    parts = list(jax.tree.leaves(pieces))
    if scales is not None:
        parts += list(jax.tree.leaves(scales))
    total = _leaf_checksum(parts[0])
    for leaf in parts[1:]:
        total = total + _leaf_checksum(leaf)
    return total


def checksum_ok(carried, recomputed) -> jnp.ndarray:
    """Elementwise integrity verdict (True = intact)."""
    return (jnp.abs(recomputed - carried)
            <= CHK_ABS_TOL + CHK_REL_TOL * jnp.abs(carried))


def corrupt_planes(pieces, mask):
    """Garble the payload wherever ``mask`` ((n, k) bool) is set:
    fp32 leaves take a huge finite offset flip (``CORRUPT_BIAS - x``),
    int8 leaves a bitwise NOT (``-1 - x``, always in range). Both are
    finite — a quarantine miss could bias the average but can never
    manufacture a NaN."""
    def garble(x):
        m = jnp.reshape(mask, mask.shape + (1,) * (x.ndim - 2))
        if x.dtype == jnp.int8:
            return jnp.where(m, (-1 - x).astype(jnp.int8), x)
        return jnp.where(m, (CORRUPT_BIAS - x).astype(x.dtype), x)
    return jax.tree.map(garble, pieces)


# ---------------------------------------------------------------------
# registry strategies + spec resolution
# ---------------------------------------------------------------------
def _any_fault_knob(spec) -> bool:
    return (getattr(spec, "transport_loss", 0.0) > 0
            or getattr(spec, "transport_dup", 0.0) > 0
            or getattr(spec, "transport_corrupt", 0.0) > 0
            or getattr(spec, "transport_jitter", 0) > 0)


def transport_key(spec) -> str:
    """Resolve the spec's transport strategy key (``"auto"`` derives
    it from the fault knobs — any nonzero rate means ``"faulty"``)."""
    key = getattr(spec, "exchange_transport", "auto")
    if key != "auto":
        return key
    return "faulty" if _any_fault_knob(spec) else "none"


def transport_enabled(spec) -> bool:
    """True when the spec's exchange runs over the faulty transport."""
    return transport_key(spec) == "faulty"


@TRANSPORTS.register("none")
def _make_none_transport(*, spec, shape) -> None:
    """Perfect delivery — the structural fixed point: ``None`` means
    no checksum/birth planes, no fault ops, the pre-transport program
    bit for bit."""
    del spec, shape
    return None


@TRANSPORTS.register(
    "faulty",
    params={"loss": ("transport_loss", float),
            "dup": ("transport_dup", float),
            "corrupt": ("transport_corrupt", float),
            "jitter": ("transport_jitter", int),
            "retransmit": ("transport_retransmit", int),
            "transport_seed": ("transport_seed", int),
            "transport_horizon": ("transport_horizon", int),
            "max_staleness": ("max_staleness", int),
            "staleness_decay": ("transport_decay", float)})
def _make_faulty_transport(*, spec, shape) -> Transport:
    """The seeded planned injector over the ``transport_*`` knobs;
    ``shape`` is the base topology's (n, k) edge table shape."""
    n, k = shape
    jitter = int(getattr(spec, "transport_jitter", 0))
    retransmit = int(getattr(spec, "transport_retransmit", 0))
    dup = float(getattr(spec, "transport_dup", 0.0))
    plan = transport_schedule(
        int(getattr(spec, "transport_seed", 0)), n, k,
        int(getattr(spec, "transport_horizon", 256)),
        loss=float(getattr(spec, "transport_loss", 0.0)),
        dup=dup,
        corrupt=float(getattr(spec, "transport_corrupt", 0.0)),
        jitter=jitter, retransmit=retransmit)
    extra = jitter + ((1 << retransmit) - 1) + (1 if dup > 0 else 0)
    return Transport(plan, extra_delay=extra)


def make_transport(spec, shape) -> "Transport | None":
    """Build the spec's transport model for an (n, k) edge table —
    ``None`` for perfect delivery (the ``"none"`` strategy)."""
    return TRANSPORTS.get(transport_key(spec))(spec=spec, shape=shape)
