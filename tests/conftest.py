import jax

# CPU tests run in fp32 (reduced configs set this too); keep x64 off.
jax.config.update("jax_enable_x64", False)
