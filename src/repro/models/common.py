"""Shared layer primitives: norms, linear init, embeddings, masks."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.sharding import shard


def rms_norm(x, weight, eps: float = 1e-5):
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def sinusoidal_positions(positions, dim: int, max_timescale: float = 1e4):
    """Classic sinusoidal embeddings; positions (..., S) int → (..., S, dim)."""
    half = dim // 2
    freq = jnp.exp(-math.log(max_timescale) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def causal_mask_bias(q_pos, k_pos, window: Optional[int] = None,
                     k_valid=None):
    """Additive attention bias from position comparisons.

    q_pos: (B, Sq) absolute positions of the queries.
    k_pos: (B, Sk) absolute positions of the keys.
    window: sliding-window width (None = full causal).
    k_valid: optional (B, Sk) bool — marks live cache slots.
    Returns (B, 1, Sq, Sk) bias of 0 / -inf (broadcast over heads).
    """
    ok = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        ok &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if k_valid is not None:
        ok &= k_valid[:, None, :]
    neg = jnp.asarray(-1e30, jnp.float32)
    return jnp.where(ok, 0.0, neg)[:, None, :, :]


def softmax_attention(q, k, v, bias, scale: float,
                      scores_dtype=jnp.float32):
    """Reference attention. q: (B,Sq,H,Dk), k: (B,Sk,K,Dk), v:
    (B,Sk,K,Dv) with H = G·K (GQA — query heads grouped onto kv heads;
    Dv may differ from Dk, e.g. MLA). bias: (B,1,Sq,Sk).

    GQA is expressed by EXPANDING k/v to the H query heads with a
    static head gather rather than reshaping q to (K, G, D): reshaping
    a head-sharded dim whose size doesn't divide the mesh axis (56H or
    24H over model=16) forces GSPMD into "involuntary full
    rematerialization" copies — replicating multi-GiB score tensors
    (EXPERIMENTS.md §Perf, yi-34b iteration 1). With the gather, every
    attention tensor keeps one uniformly-(padded-)sharded head dim and
    the only cross-device movement is the small K-head k/v gather.
    """
    B, Sq, H, Dk = q.shape
    K = k.shape[2]
    Dv = v.shape[3]
    if H != K:
        idx = jnp.arange(H) // (H // K)
        k = jnp.take(k, idx, axis=2)          # (B,Sk,H,Dk)
        v = jnp.take(v, idx, axis=2)
        k = shard(k, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
    # scores_dtype=bfloat16 halves the HBM footprint of the (B,H,S,S)
    # score/prob pipeline — the dominant memory term at 4k+ context
    # (EXPERIMENTS.md §Perf, yi-34b iteration 2). The matmuls still
    # accumulate in fp32 (preferred_element_type); only the
    # materialised scores/probs are narrow. fp32 remains the default.
    sdt = jnp.dtype(scores_dtype)
    # the dot must EMIT sdt directly — casting an f32 dot output still
    # materialises the f32 (B,H,S,S) tensor (§Perf iteration 3a,
    # refuted); max-subtraction keeps bf16 softmax well-conditioned.
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(sdt),
                        k.astype(sdt),
                        preferred_element_type=sdt)
    scores = scores * jnp.asarray(scale, sdt) + bias.astype(sdt)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(sdt),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def cross_entropy(logits, labels, ignore=-100):
    """Token-mean CE with ignore mask; logits (..., V) fp-any, fp32 math."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def shard_activations(x):
    """Canonical activation sharding: batch over 'data'."""
    names = ["batch"] + [None] * (x.ndim - 1)
    return shard(x, *names)
