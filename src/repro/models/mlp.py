"""Feed-forward blocks: SwiGLU (llama-family) and GELU MLP (MusicGen)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.sharding import shard
from repro.models.common import dense_init


def init_swiglu(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def swiglu(p, x, compute_dtype):
    g = x @ p["w_gate"].astype(compute_dtype)
    u = x @ p["w_up"].astype(compute_dtype)
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", None, "ff")
    return h @ p["w_down"].astype(compute_dtype)


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2 = jax.random.split(key, 2)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": dense_init(k2, (d_ff, d_model), dtype),
        "b2": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p, x, compute_dtype):
    h = x @ p["w1"].astype(compute_dtype) + p["b1"].astype(compute_dtype)
    h = jax.nn.gelu(h)
    h = shard(h, "batch", None, "ff")
    return h @ p["w2"].astype(compute_dtype) + p["b2"].astype(compute_dtype)
