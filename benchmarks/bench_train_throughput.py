"""DDAL cadence vs communication (beyond-paper table).

The paper never measures throughput; this bench quantifies DDAL's
communication saving over lockstep data parallelism on the CPU rig
(reduced config, real steps, wall clock) and analytically for the
production pod (collective bytes per step × cadence).

DDAL with share cadence k exchanges gradients once every k steps —
cross-agent traffic is 1/k of lockstep DP by construction; the bench
confirms the wall-clock effect of the cadence on CPU and reports the
measured t_collective scaling from the dry-run records if present.
"""
from __future__ import annotations

import time

import jax

from repro import optim
from repro.configs import get_arch_config
from repro.configs.base import GroupSpec, ShapeConfig
from repro.core import init_train_state, make_group_train_step
from repro.data import StreamSpec, make_group_batch


def main(arch: str = "llama3.2-3b", steps: int = 12,
         verbose: bool = True):
    cfg = get_arch_config(arch).reduced()
    shape = ShapeConfig("bench", 128, 4, "train")
    rows = []
    for cadence in (1, 4, 16):
        spec = GroupSpec(n_agents=2, threshold=0, minibatch=cadence,
                         knowledge_mode="streaming")
        opt = optim.adamw(1e-3)
        key = jax.random.PRNGKey(0)
        state = init_train_state(cfg, spec, opt, key)
        step = jax.jit(make_group_train_step(cfg, spec, opt))
        batch = make_group_batch(cfg, shape, StreamSpec(), 2, 0)
        state, _ = step(state, batch)          # compile
        t0 = time.time()
        for i in range(steps):
            state, m = step(state, batch)
        jax.block_until_ready(state.params)
        dt = time.time() - t0
        toks = steps * 2 * shape.global_batch * shape.seq_len
        rows.append({"cadence": cadence, "tokens_per_s": toks / dt,
                     "exchanges_per_step": 1.0 / cadence})
    if verbose:
        print(f"{'cadence':>8} {'tokens/s':>10} {'grad-exchanges/step':>20}")
        for r in rows:
            print(f"{r['cadence']:8d} {r['tokens_per_s']:10,.0f} "
                  f"{r['exchanges_per_step']:20.3f}")
        print("cross-agent gradient traffic scales as 1/cadence "
              "(collective bytes move only at share steps)")
    return rows


if __name__ == "__main__":
    main()
