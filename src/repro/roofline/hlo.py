"""HLO text parsing: per-collective byte counts.

``compiled.cost_analysis()`` has no collective-traffic entry, so we
parse the compiled HLO module text and sum the operand sizes of every

    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute (+ their -start async forms)

op. Post-optimisation HLO does not print operand types inline, so the
parse is two-pass: (1) map every instruction name to its result byte
size, (2) for each collective, sum the sizes of its named operands.

NOTE: scan-generated ``while`` loops would be counted once, not
trip-count times — the dry-run therefore lowers with
``ArchConfig.unroll_layers=True`` so every layer's collectives (and
FLOPs) appear explicitly in the module.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

# instruction definition:  %name = <result types> opcode(...).
# Result tuples may contain /*index=N*/ comments (with '='), so the
# result-type capture is a lazy any-char match bounded to the line.
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s"
                  r"([\w\-]+)\(", re.M)

_OPERAND = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(result_types: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE.findall(result_types))


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of operand bytes per collective kind over the whole module
    (one execution). ``-done`` ops are skipped (their operand is the
    async handle — counting both would double-count)."""
    sizes: Dict[str, int] = {}
    instrs = []
    for m in _DEF.finditer(hlo_text):
        name, rtypes, opcode = m.group(1), m.group(2), m.group(3)
        sizes[name] = _result_bytes(rtypes)
        base = None
        for c in _COLLECTIVES:
            if opcode == c or opcode == c + "-start":
                base = c
                break
        if base is not None:
            # operand list: up to the matching close paren of this line
            line_end = hlo_text.find("\n", m.end())
            args = hlo_text[m.end():line_end]
            args = args.split("),")[0]
            instrs.append((base, _OPERAND.findall(args)))

    out = {k: 0 for k in _COLLECTIVES}
    out["total"] = 0
    for kind, operands in instrs:
        nbytes = sum(sizes.get(o, 0) for o in operands)
        out[kind] += nbytes
        out["total"] += nbytes
    return out


def count_ops(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"\b{re.escape(opcode)}\b", hlo_text))
