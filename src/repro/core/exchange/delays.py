"""Delay models — *how stale is knowledge on arrival*.

A :class:`DelayModel` attaches per-edge delivery delays onto the
schedule's topology at build time (delays are placement facts, static
at trace time). Three strategies are registered:

``none``
    Same-epoch queue delivery (the paper's setup): every edge delay 0;
    ``GroupSpec.max_delay`` still sizes the delay line so explicit
    per-edge ``delay=`` overrides passed to the trainers fit.
``uniform``
    Every edge delayed by ``GroupSpec.max_delay`` epochs — the
    simplest asynchrony simulation, and the only non-trivial model a
    resampling schedule can carry (a scalar survives a table swap).
``hops``
    Graph-distance staleness (:func:`repro.core.topology.
    delay_from_hops`): an edge from a distance-d source delivers
    d·latency epochs late, latency = ``max(GroupSpec.max_delay, 1)``.
    Static schedules only — hop counts are properties of a fixed
    graph.

Transport *jitter* (``repro.core.transport`` — per-message random
extra delay, plus retransmit backoff) composes on top of whichever
model is attached: the model gives the edge's deterministic base
delay, the fault plan adds its per-epoch extra, and
``build_exchange`` sizes the delay line for the sum (the knob-derived
worst case, so the program shape never depends on the fault draw).
"""
from __future__ import annotations

from typing import Optional

from repro.core.exchange.registry import DELAYS
from repro.core.topology import Topology, delay_from_hops


class DelayModel:
    """Interface: per-edge delay attachment.

    attach(topo)
        The topology with this model's delays on its edge table
        (static schedules).
    dense_scalar()
        The uniform delay (or ``None``) a resampling schedule carries
        across table swaps; models without one raise there instead of
        silently dropping delays.
    """

    def attach(self, topo: Topology) -> Topology:
        raise NotImplementedError

    def dense_scalar(self) -> Optional[int]:
        raise NotImplementedError


@DELAYS.register("none")
class NoDelay(DelayModel):
    def attach(self, topo: Topology) -> Topology:
        return topo

    def dense_scalar(self) -> Optional[int]:
        return None


@DELAYS.register("uniform", params={"max_delay": ("max_delay", int)})
class UniformDelay(DelayModel):
    def __init__(self, delay: int):
        if delay < 0:
            raise ValueError(f"uniform delay must be >= 0, got {delay}")
        self.delay = int(delay)

    def attach(self, topo: Topology) -> Topology:
        return topo.with_delay(self.delay)

    def dense_scalar(self) -> int:
        return self.delay


@DELAYS.register("hops")
class HopDelay(DelayModel):
    def __init__(self, latency: int, graph: Optional[Topology] = None):
        self.latency = max(int(latency), 1)
        self.graph = graph

    def attach(self, topo: Topology) -> Topology:
        return delay_from_hops(topo, self.latency, graph=self.graph)

    def dense_scalar(self) -> Optional[int]:
        raise ValueError(
            "the 'hops' delay model measures distances on a fixed "
            "graph and cannot follow a resampling schedule — use "
            "delay='uniform' (or 'none') with dynamic/relevance_topk")
