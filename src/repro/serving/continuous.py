"""Continuous batching: a fixed-slot decode batch whose finished slots
are refilled from a request queue without stopping the other slots —
the vLLM-style serving loop, on top of the functional caches.

Static shapes throughout (one compile per engine): prompts prefill at
B=1 into a slot-shaped cache, the result is spliced into the batch
cache at the freed slot index, and a single jitted decode step advances
every live slot each iteration.

Per-leaf batch dims differ across cache families (transformer caches
are (L, B, ...), zamba2's mamba states (nb, mpb, B, ...)) — they are
discovered once by diffing ``eval_shape`` at two batch sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import get_model
from repro.serving.engine import ServeConfig, _decode_batch, _last_logits


def _batch_dims(cfg: ArchConfig, max_len: int) -> Any:
    """Pytree (matching the cache) of each leaf's batch-dim index."""
    model = get_model(cfg)
    s1 = jax.eval_shape(lambda: model.make_cache(cfg, 1, max_len))
    s2 = jax.eval_shape(lambda: model.make_cache(cfg, 2, max_len))

    def dim(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch dim in {a.shape}")

    return jax.tree.map(dim, s1, s2)


@dataclasses.dataclass
class _Slot:
    request_id: Optional[int] = None
    tokens: Optional[list] = None          # generated so far
    done: bool = True


class ContinuousBatcher:
    """Serve a request stream through ``batch_size`` persistent slots.

    engine-level API:
        batcher = ContinuousBatcher(cfg, params, serve, batch_size=4)
        results = batcher.run(requests)     # {req_id: [tokens...]}
    """

    def __init__(self, cfg: ArchConfig, params, serve: ServeConfig,
                 batch_size: int, prompt_pad: int = 32):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.B = batch_size
        self.prompt_pad = prompt_pad
        self.model = get_model(cfg)
        self._bdims = _batch_dims(cfg, serve.max_len)
        self._prefill1 = jax.jit(self._prefill1_impl)
        self._decode = jax.jit(self._decode_impl)
        self._splice = jax.jit(self._splice_impl,
                               static_argnames=("slot",))

    # -- jitted pieces ---------------------------------------------------
    def _prefill1_impl(self, params, tokens, length):
        """B=1 prefill into a fresh 1-slot cache → (next_logits, cache)."""
        cfg = self.cfg
        P = tokens.shape[1]
        pos = jnp.arange(P, dtype=jnp.int32)[None]
        cache = self.model.make_cache(cfg, 1, self.serve.max_len)
        if cfg.family == "audio":
            batch = {"tokens": jnp.broadcast_to(
                        tokens[:, None, :], (1, cfg.n_codebooks, P)),
                     "positions": pos,
                     "cond": jnp.zeros((1, cfg.cond_len, cfg.d_model),
                                       cfg.dtype("compute"))}
        elif cfg.family == "vlm":
            batch = {"tokens": tokens,
                     "vision": jnp.zeros((1, cfg.vision_prefix,
                                          cfg.d_model),
                                         cfg.dtype("compute")),
                     "positions": jnp.broadcast_to(
                         jnp.arange(P + cfg.vision_prefix,
                                    dtype=jnp.int32),
                         (1, 3, P + cfg.vision_prefix))}
        else:
            batch = {"tokens": tokens, "positions": pos}
        logits, cache = self.model.forward(cfg, params, batch, cache)
        idx = jnp.maximum(length - 1, 0)
        nxt = (logits[0, 0, idx] if cfg.family == "audio"
               else logits[0, idx])
        return nxt, cache

    def _splice_impl(self, batch_cache, one_cache, slot: int):
        """Insert a B=1 cache into batch slot ``slot``."""
        def put(buf, one, d):
            idx = [slice(None)] * buf.ndim
            idx[d] = slot
            one_idx = [slice(None)] * one.ndim
            one_idx[d] = 0
            return buf.at[tuple(idx)].set(one[tuple(one_idx)])

        return jax.tree.map(put, batch_cache, one_cache, self._bdims)

    def _decode_impl(self, params, cache, tokens, pos, done, key):
        batch = _decode_batch(self.cfg, tokens, pos[:, None])
        logits, cache = self.model.decode(self.cfg, params, batch,
                                          cache)
        nl = _last_logits(self.cfg, logits)
        if self.serve.temperature <= 0.0:
            nxt = jnp.argmax(nl, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                key, nl / self.serve.temperature).astype(jnp.int32)
        nxt = jnp.where(done, tokens[:, 0], nxt)
        return cache, nxt

    # -- host loop --------------------------------------------------------
    def run(self, requests: Sequence[Sequence[int]],
            key=None) -> Dict[int, List[int]]:
        key = key if key is not None else jax.random.PRNGKey(0)
        queue = list(enumerate(requests))
        slots = [_Slot() for _ in range(self.B)]
        cache = self.model.make_cache(self.cfg, self.B,
                                      self.serve.max_len)
        tokens = jnp.zeros((self.B, 1), jnp.int32)
        pos = jnp.zeros((self.B,), jnp.int32)
        done = jnp.ones((self.B,), bool)
        results: Dict[int, List[int]] = {}

        def pad_to(r):
            p = self.prompt_pad
            while p < len(r):
                p *= 2
            return p

        step = 0
        while queue or any(not s.done for s in slots):
            # refill finished slots
            for i, s in enumerate(slots):
                if s.done and queue:
                    rid, req = queue.pop(0)
                    P = pad_to(req)
                    toks = np.zeros((1, P), np.int32)
                    toks[0, :len(req)] = req
                    key, k = jax.random.split(key)
                    nl, one = self._prefill1(
                        self.params, jnp.asarray(toks),
                        jnp.int32(len(req)))
                    first = (int(jnp.argmax(nl))
                             if self.serve.temperature <= 0 else
                             int(jax.random.categorical(
                                 k, nl / self.serve.temperature)))
                    cache = self._splice(cache, one, slot=i)
                    tokens = tokens.at[i, 0].set(first)
                    pos = pos.at[i].set(len(req))
                    done = done.at[i].set(False)
                    slots[i] = _Slot(request_id=rid, tokens=[first],
                                     done=False)

            # one decode step for every live slot
            key, k = jax.random.split(key)
            cache, nxt = self._decode(self.params, cache, tokens, pos,
                                      done, k)
            tokens = nxt[:, None]
            pos = pos + 1
            for i, s in enumerate(slots):
                if s.done:
                    continue
                t = int(nxt[i])
                s.tokens.append(t)
                hit_eos = t == self.serve.eos_id
                full = len(s.tokens) >= self.serve.max_new_tokens
                out_of_cache = int(pos[i]) >= self.serve.max_len - 1
                if hit_eos or full or out_of_cache:
                    results[s.request_id] = s.tokens
                    s.done = True
                    done = done.at[i].set(True)
            step += 1
        return results
