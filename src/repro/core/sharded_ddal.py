"""DDAL at pod scale — group-agent training of the model zoo.

Mapping (DESIGN.md §3): one GARL agent per **pod**. Parameters,
optimiser state and knowledge accumulators carry a leading
``(n_agents,)`` axis sharded ``P("pod")``; each agent consumes its own
data stream (its own "environment"). Cross-agent knowledge exchange is
expressed as reductions over the agent axis, which GSPMD lowers to
collectives over the pod interconnect — **only at share steps**, which
is DDAL's communication saving over lockstep data parallelism.

Knowledge is held in *streaming* form: per-agent accumulators
    tg = Σ_j T_j·g_j,  tsum = Σ_j T_j,  rg = Σ_j g_j,  rsum = Σ_j 1
over the pieces generated since the last share step. The eq. 4 average
over the union of all agents' windows is then

    ḡ(dst) = ½ ( Σ_src tg_src / Σ_src tsum_src
               + Σ_src R[src,dst]·rg_src / Σ_src R[src,dst]·rsum_src )

— mathematically identical to materialising every piece (the weighted
sum is linear), but O(1) memory instead of m copies of a 34B-parameter
gradient. This matches the paper's own experiment ("gradients generated
by and received during its previous 1000 epochs"). The ring-buffer
(piece-faithful) form lives in ``repro.core.ddal`` for agent-scale use.

Sparse topologies: with a ``repro.core.topology.Topology`` the share
step reduces over each destination's **in-neighbors** via a
segment-sum on the static edge list instead of a global all-reduce —
O(|E|) cross-pod traffic instead of O(A²) — and both eq. 4
normalisations (T and R) become neighbor-local. The ``full`` + uniform
case keeps the cheaper global-sum fast path.

Multi-host pod dispatch (ISSUE 3): with ``spec.pods > 0`` the
hierarchical combine splits into an intra-pod segment (local to the
fast ``"agent"`` mesh axis) and a leader-level segment in which only
each pod's leader planes cross ``spec.pod_axis`` —
``repro.core.pod_dispatch``; cross-pod traffic drops from
O(n·k·|params|) to O(pods·k_leader·|params|) per share step. The
1-pod case is bitwise the flat ``_combine_topo`` (both run the same
``_edge_sums`` / ``_finish_combine``).

Adaptive wiring (ISSUE 2): a ``DynamicTopology``
(``spec.resample_every > 0``) resamples the gossip edge list inside
the jitted step — the segment-sum consumes the traced table directly
— and ``spec.relevance_mode="grad_cos"`` learns per-edge relevance
from the cosine similarity of the agents' *window-accumulated*
gradients (``Knowledge.rg``, already a temporal average over the
share window), EMA-smoothed across share steps in ``Knowledge.rel``
(``repro.core.relevance``). Both default off; the static path is
untouched.

Exchange protocol (ISSUE 5): the train step no longer interprets any
of those flags itself — ``repro.core.exchange.build_exchange``
resolves them into strategy objects once, and the jitted step calls
``protocol.sketch_step`` (window accumulation), ``protocol.observe``
(the relevance update) and ``protocol.combine`` (flat segment-sum,
global fast path, or pod dispatch — decided at build time). The
``"auto"`` strategies trace exactly the ops the inline ladders used
to emit, so every pre-redesign configuration is bitwise-reproduced.

Sketched relevance (ISSUE 4): with ``spec.relevance_sketch_dim > 0``
the window additionally carries an (A, d) **gradient sketch**
(``Knowledge.sk``): every accumulation step also streams that epoch's
gradients through the seeded ±1 projection
(``repro.kernels.grad_sketch``) and adds the tiny (A, d) result —
the projection is linear and seeded per share round, so at share
time ``sk`` *is* the sketch of ``rg`` (up to the knowledge-dtype
cast) and the relevance observation is just ``cosine_rows(sk)``:
O(A²·d) instead of the exact O(A²·|params|) Gram. Under the pod
dispatch this is also what crosses the mesh for relevance — the (A, d)
sketch rows (O(pods·A·d) bytes), never anything parameter-sized
(``repro.core.pod_dispatch.relevance_exchange_bytes`` accounts it).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_map, tree_zeros_like
from repro.configs.base import ArchConfig, GroupSpec
from repro.core.weighting import training_experience
from repro.models import get_model
from repro.optim import Optimizer


class Knowledge(NamedTuple):
    tg: Any               # pytree, leaves (A, *param) fp32
    tsum: jnp.ndarray     # (A,)
    rg: Any
    rsum: jnp.ndarray     # (A,)
    rel: Any = None       # relevance-estimator state, persisted across
                          # window resets (repro.core.exchange): the
                          # (A, A) learned R EMA for the gradient
                          # estimators, an ObsStatsState pytree for
                          # obs_stats; None = uniform (nothing learned)
    sk: Any = None        # (A, d) window gradient sketch; None unless
                          # the estimator sketches (grad_cos+sketch)
    alive: Any = None     # (A,) bool elastic-membership mask, persisted
                          # across window resets like rel; None (the
                          # default — filtered out of the pytree) keeps
                          # non-elastic programs and existing
                          # checkpoints/shardings structurally unchanged


class TrainState(NamedTuple):
    params: Any           # leaves (A, *param)
    opt_state: Any
    know: Knowledge
    step: jnp.ndarray     # () int32


def init_knowledge(params, dtype=jnp.float32, rel=None,
                   sketch_dim: int = 0, alive=None) -> Knowledge:
    """Fresh (zeroed) share-window accumulators. ``rel`` is the learned
    relevance EMA to carry across the window reset — it persists over
    share steps, unlike the window sums (``sketch_dim > 0`` adds the
    (A, d) window sketch, which resets with them). ``alive`` is the
    elastic-membership mask, carried across resets like ``rel``."""
    A = jax.tree.leaves(params)[0].shape[0]
    acc = tree_map(lambda x: jnp.zeros(x.shape, jnp.dtype(dtype)),
                   params)
    sk = (jnp.zeros((A, sketch_dim), jnp.float32)
          if sketch_dim > 0 else None)
    return Knowledge(tg=acc, tsum=jnp.zeros((A,), jnp.float32),
                     rg=tree_zeros_like(acc),
                     rsum=jnp.zeros((A,), jnp.float32), rel=rel,
                     sk=sk, alive=alive)


def init_train_state(cfg: ArchConfig, spec: GroupSpec, opt: Optimizer,
                     key, exchange=None) -> TrainState:
    """Real initialisation (CPU tests / actual training). The
    relevance-state seed (``Knowledge.rel``) and the sketch width come
    from the spec's exchange estimator — pass the prebuilt
    ``exchange`` protocol if the train step got one, so the carried
    state matches what its estimator expects."""
    from repro.core.exchange import build_exchange
    if exchange is None:
        exchange = build_exchange(spec, kind="streaming")
    model = get_model(cfg)
    keys = jax.random.split(key, spec.n_agents)
    params = jax.vmap(lambda k: model.init(cfg, k))(keys)
    opt_state = jax.vmap(opt.init)(params)
    alive = (jnp.ones((spec.n_agents,), bool)
             if getattr(spec, "elastic", False) else None)
    return TrainState(params=params, opt_state=opt_state,
                      know=init_knowledge(params,
                                          jnp.dtype(spec.knowledge_dtype),
                                          rel=exchange.streaming_rel_init(),
                                          sketch_dim=exchange.sketch_dim,
                                          alive=alive),
                      step=jnp.zeros((), jnp.int32))


def train_state_specs(cfg: ArchConfig, spec: GroupSpec, opt: Optimizer
                      ) -> TrainState:
    """ShapeDtypeStruct version for the dry-run (no allocation)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(
        lambda k: init_train_state(cfg, spec, opt, k), key)


def _combine(know: Knowledge, R: jnp.ndarray, uniform: bool):
    """eq. 4 over the union of all agents' windows → per-dst ḡ with a
    leading (A,) axis (identical rows when R is uniform)."""
    A = know.tsum.shape[0]
    eps = 1e-12

    if uniform:
        # Σ over the (pod-sharded) agent axis → all-reduce over pods.
        tsum = jnp.maximum(jnp.sum(know.tsum), eps)
        rsum = jnp.maximum(jnp.sum(know.rsum), eps)

        def avg(tg_leaf, rg_leaf):
            t = jnp.sum(tg_leaf, axis=0) / tsum
            r = jnp.sum(rg_leaf, axis=0) / rsum
            g = 0.5 * (t + r)
            return jnp.broadcast_to(g[None], tg_leaf.shape)

        return tree_map(avg, know.tg, know.rg)

    # per-destination relevance: weighted gather over the agent axis
    r_t = jnp.maximum(jnp.sum(know.tsum), eps)             # T̂ is global
    rden = jnp.maximum(know.rsum @ R, eps)                 # (A_dst,)

    def avg(tg_leaf, rg_leaf):
        t = jnp.sum(tg_leaf, axis=0) / r_t                 # (*param,)
        r = jnp.tensordot(R, rg_leaf, axes=(0, 0))         # (A_dst,*param)
        r = r / jnp.reshape(rden, (A,) + (1,) * (r.ndim - 1))
        return 0.5 * (t[None] + r)

    return tree_map(avg, know.tg, know.rg)


def _edge_sums(know: Knowledge, nbr, mask, rel):
    """eq. 4 numerators/denominators over one edge list: for each
    destination, sum the sources' accumulators over its edge slots.
    The scalar sums reduce with a segment-sum over the edge list; the
    gradient leaves reduce with a masked adjacency matmul —
    mathematically the same segment-sum, but it never materialises
    (E, *param) gathered copies of the accumulators (a k-fold
    peak-memory blowup at LLM scale). Shared by the flat single-mesh
    combine and both segments (intra-pod, leader-level) of the pod
    dispatch, so the 1-pod dispatched path is the *same computation*
    as the flat path, not a reimplementation."""
    A, k = nbr.shape
    src = jnp.reshape(nbr, (-1,))                    # (E,) sources
    seg = jnp.repeat(jnp.arange(A), k)               # (E,) destinations
    m = jnp.reshape(mask, (-1,)).astype(jnp.float32)
    relf = jnp.reshape(jnp.where(mask, rel, 0.0), (-1,))

    def seg_sum(x):
        return jax.ops.segment_sum(x, seg, num_segments=A)

    tden = seg_sum(m * know.tsum[src])               # (A,)
    rden = seg_sum(relf * know.rsum[src])

    # dense (A, A) src→dst weights, zero off-graph (A = pods, small)
    Rd = jnp.zeros((A, A)).at[src, seg].add(relf)
    M = jnp.zeros((A, A)).at[src, seg].add(m)
    tnum = tree_map(lambda g: jnp.tensordot(M, g, axes=(0, 0)), know.tg)
    rnum = tree_map(lambda g: jnp.tensordot(Rd, g, axes=(0, 0)), know.rg)
    return tnum, tden, rnum, rden


def _finish_combine(tnum, tden, rnum, rden):
    """ḡ = ½(t/T̂ + r/R̂) with the eps clamp applied once, after every
    segment's contribution has been accumulated into the sums."""
    eps = 1e-12
    tden = jnp.maximum(tden, eps)
    rden = jnp.maximum(rden, eps)

    def avg(t, r):
        ex = (-1,) + (1,) * (t.ndim - 1)
        return 0.5 * (t / jnp.reshape(tden, ex)
                      + r / jnp.reshape(rden, ex))

    return tree_map(avg, tnum, rnum)


def _combine_topo(know: Knowledge, topo: Topology):
    """eq. 4 with neighbor-local normalisation: for each destination,
    both the T and R terms sum over its in-neighbors only. GSPMD
    lowers the contraction over the sharded agent axis to collectives
    that move only the masked edges' worth of data."""
    return _finish_combine(
        *_edge_sums(know, topo.nbr, topo.mask, topo.relevance))


def drop_topology_edges(topo: Topology, keep) -> Topology:
    """Cut edges whose message did not survive this share round
    (``keep``: (n, k) bool from ``Transport.deliver_mask``): the mask
    bit goes False and the edge relevance to exactly zero, so both
    eq. 4 sums in ``_edge_sums`` exclude the edge entirely — the
    streaming trainer's equivalent of the buffer trainer's hole slots
    and corruption quarantine. ``deliver_mask`` always keeps the
    self-loop, and ``_finish_combine``'s eps clamp covers even a
    destination with *no* surviving edge, so a faulty round degrades
    toward the local window, never toward NaN. An all-True ``keep``
    is a numerical identity (``mask & True``, ``where(True, rel,
    0)``) — but note the op is still traced, so zero-rate faulty
    streaming programs are equal in value, not in jaxpr."""
    k = jnp.asarray(keep, bool)
    return topo._replace(mask=topo.mask & k,
                         relevance=jnp.where(k, topo.relevance, 0.0))


# ---------------------------------------------------------------------
# elastic membership (alive-masked exchange)
# ---------------------------------------------------------------------
def _select_rows(mask, new, old):
    """Per-agent row select over matching pytrees: rows where ``mask``
    is True come from ``new``, the rest hold ``old`` — the elastic
    trainer's way of freezing dead agents' params/optimizer rows
    without multiply-masking live ones."""
    m = jnp.asarray(mask, bool)

    def sel(n_, o_):
        mm = jnp.reshape(m, (-1,) + (1,) * (n_.ndim - 1))
        return jnp.where(mm, n_, o_)

    return tree_map(sel, new, old)


def mask_knowledge(know: Knowledge, alive) -> Knowledge:
    """Zero dead agents' window rows (tg/rg leaves, tsum/rsum scalars,
    the sk sketch rows) so their eq. 4 numerator *and* denominator
    contributions are exactly zero in every combiner path — the flat
    global sum, the dense-R matmul, the ``_edge_sums`` segment-sum and
    the pod dispatch (a dead leader's planes are zero before anything
    crosses the pod axis). ``rel`` and ``alive`` ride through
    untouched; ``alive=None`` returns ``know`` unchanged (the
    non-elastic structural fixed point)."""
    if alive is None:
        return know
    a = jnp.asarray(alive, bool)

    def rows(x):
        m = jnp.reshape(a, (-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, jnp.zeros_like(x))

    return know._replace(
        tg=tree_map(rows, know.tg),
        rg=tree_map(rows, know.rg),
        tsum=jnp.where(a, know.tsum, 0.0),
        rsum=jnp.where(a, know.rsum, 0.0),
        sk=None if know.sk is None else rows(know.sk))


def quantize_knowledge_roundtrip(know: Knowledge,
                                 q_block: int) -> Knowledge:
    """Push the window's gradient planes (tg/rg leaves) through the
    int8 block-quantized wire format (``repro.kernels.ddal_wavg``) —
    what every cross-agent hop carries when
    ``GroupSpec.knowledge_quant_block > 0``. The streaming combiners
    apply this at combine time, so the ḡ the group consumes matches
    the buffer trainer's quantized-delay-line semantics while the
    window accumulators themselves stay fp32 (they never leave the
    agent's shard). ``q_block <= 0`` is the identity — the historical
    program, bit for bit."""
    if q_block <= 0:
        return know
    from repro.kernels.ddal_wavg import ops as wavg_ops

    def rt(tree):
        q, s = wavg_ops.quantize_tree(tree, q_block, lead=1)
        return wavg_ops.dequantize_tree(q, s, q_block)

    return know._replace(tg=rt(know.tg), rg=rt(know.rg))


def kill_agents(state: TrainState, dead) -> TrainState:
    """Host-side elastic transition: mark ``dead`` ((A,) bool) agents
    as gone. Their partial share window is zeroed — a half-window must
    never leak into a later share step — while their params/optimizer
    rows freeze in place and ``Knowledge.rel`` holds its last live
    estimate (the estimator's alive-gated EMA keeps it frozen from
    here). Checkpoint the state *before* killing to splice the agent
    back in later (``revive_agents``)."""
    know = state.know
    if know.alive is None:
        raise ValueError(
            "kill_agents needs an elastic TrainState — build the spec "
            "with GroupSpec(elastic=True) so Knowledge.alive exists")
    alive = know.alive & ~jnp.asarray(dead, bool)
    return state._replace(
        know=mask_knowledge(know, alive)._replace(alive=alive))


def revive_agents(state: TrainState, mask,
                  restore: Optional[TrainState] = None) -> TrainState:
    """Flip ``mask`` ((A,) bool) agents back alive. Their window rows
    are (re)zeroed — a revival starts from an empty window, never a
    stale one — and with ``restore`` (a checkpointed ``TrainState``)
    the revived agents' params/optimizer rows splice back from the
    checkpoint while every survivor's row is untouched."""
    know = state.know
    if know.alive is None:
        raise ValueError(
            "revive_agents needs an elastic TrainState — build the "
            "spec with GroupSpec(elastic=True) so Knowledge.alive "
            "exists")
    m = jnp.asarray(mask, bool)
    know = mask_knowledge(know, ~m)._replace(alive=know.alive | m)
    params, opt_state = state.params, state.opt_state
    if restore is not None:
        params = _select_rows(m, restore.params, params)
        opt_state = _select_rows(m, restore.opt_state, opt_state)
    return state._replace(params=params, opt_state=opt_state,
                          know=know)


def make_group_train_step(cfg: ArchConfig, spec: GroupSpec,
                          opt: Optimizer,
                          relevance: Optional[jnp.ndarray] = None,
                          loss_fn: Optional[Callable] = None,
                          topology=None,
                          mesh=None,
                          exchange=None):
    """Build the jittable DDAL train step.

    Returns step(state, batch) -> (state', metrics); ``batch`` leaves
    carry a leading (n_agents,) axis (each agent's own data stream).
    The model is resolved lazily from ``cfg`` only when no ``loss_fn``
    is supplied, so toy losses need no ArchConfig (pass ``cfg=None``).

    Exchange decisions live in the ``repro.core.exchange`` protocol
    (built from ``spec`` unless a prebuilt ``exchange`` is passed):
    the combiner strategy picks the global-sum fast path, the
    neighbor-local segment-sum, or — with ``spec.pods > 0`` — the
    two-level pod dispatch (``repro.core.pod_dispatch``), where the
    intra-pod segment stays local to the fast ``"agent"`` mesh axis
    and only the pod leaders' planes cross the ``spec.pod_axis`` axis.
    Pass the two-level ``mesh`` (``repro.launch.mesh.make_pod_mesh``)
    to run the real collective path; without a mesh the mathematically
    identical single-device decomposition runs instead, so the flag is
    meaningful on a 1-CPU rig too.
    """
    if loss_fn is None:
        model = get_model(cfg)

        def loss_fn(params, batch):        # noqa: F811
            return model.loss(cfg, params, batch)
    if exchange is None:
        from repro.core.exchange import build_exchange
        exchange = build_exchange(spec, mesh, kind="streaming",
                                  topology=topology,
                                  relevance=relevance)
    elif exchange.kind != "streaming":
        raise ValueError(
            f"the streaming train step needs a 'streaming' exchange "
            f"protocol, got {exchange.kind!r}")
    elif (topology is not None or relevance is not None
          or mesh is not None):
        raise ValueError(
            "topology/relevance/mesh would be silently ignored: they "
            "are baked into the protocol at build time — pass them to "
            "build_exchange(...) instead when supplying a prebuilt "
            "exchange")
    learn_rel = exchange.learns
    sketch_dim = exchange.sketch_dim
    # elastic membership is a *static* build fact: non-elastic specs
    # trace exactly the historical program (no alive ops anywhere)
    elastic = bool(getattr(spec, "elastic", False))

    vopt = jax.vmap(opt.update, in_axes=(0, 0, 0, None))

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Any]:
        step = state.step
        losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(
            state.params, batch)
        know = state.know
        alive = know.alive if elastic else None
        if elastic and alive is None:
            raise ValueError(
                "GroupSpec.elastic=True but Knowledge.alive is None — "
                "init the state through init_train_state / "
                "init_knowledge(..., alive=...) so the mask exists")

        warmup = step < spec.threshold
        is_share = jnp.logical_not(warmup) & (step % spec.minibatch == 0)

        def warmup_branch(_):
            p2, o2 = vopt(grads, state.opt_state, state.params, step)
            if elastic:
                p2 = _select_rows(alive, p2, state.params)
                o2 = _select_rows(alive, o2, state.opt_state)
            return p2, o2, know

        def sharing_branch(_):
            # accumulate this epoch's piece into the local window
            kdt = jnp.dtype(spec.knowledge_dtype)
            T_t = training_experience(step, spec.t_weighting)
            if elastic:
                # dead agents' gradients are garbage (their data still
                # flows): hold their rows instead of accumulating
                def row_gate(x):
                    return jnp.reshape(alive,
                                       (-1,) + (1,) * (x.ndim - 1))
                tg = tree_map(
                    lambda a, g: jnp.where(
                        row_gate(a),
                        a + (T_t * g.astype(jnp.float32)).astype(kdt),
                        a),
                    know.tg, grads)
                rg = tree_map(
                    lambda a, g: jnp.where(row_gate(a),
                                           a + g.astype(kdt), a),
                    know.rg, grads)
                tsum = know.tsum + jnp.where(alive, T_t, 0.0)
                rsum = know.rsum + jnp.where(alive, 1.0, 0.0)
            else:
                tg = tree_map(
                    lambda a, g: a + (T_t * g.astype(jnp.float32)
                                      ).astype(kdt),
                    know.tg, grads)
                rg = tree_map(lambda a, g: a + g.astype(kdt),
                              know.rg, grads)
                tsum = know.tsum + T_t
                rsum = know.rsum + 1.0
            sk = know.sk
            if sketch_dim > 0:
                # carry the window sketch: one streaming projection of
                # this epoch's grads, added to the (A, d) running sum.
                # The projection is linear and every step of the window
                # ending at share step t folds the same round index
                # ((step + mb − 1) // mb), so at share time sk IS the
                # sketch of rg — nothing parameter-sized is re-read.
                rnd = (step + spec.minibatch - 1) // spec.minibatch
                contrib = exchange.sketch_step(grads, rnd)
                if elastic:
                    contrib = jnp.where(alive[:, None], contrib, 0.0)
                sk = know.sk + contrib
            k2 = Knowledge(tg=tg, tsum=tsum, rg=rg, rsum=rsum,
                           rel=know.rel, sk=sk, alive=know.alive)

            def do_share(_):
                # window-accumulated grads are already a temporal
                # average over the share window — the estimator
                # observes them (or the carried (A, d) sketch, so only
                # sketch rows — never parameter planes — cross the
                # mesh for relevance), then the combiner strategy runs
                # eq. 4.
                rel = exchange.observe(
                    k2.rel, grads=k2.rg, sketch=k2.sk,
                    rnd=(step + spec.minibatch - 1) // spec.minibatch,
                    alive=alive)
                gbar = exchange.combine(k2, rel, step, alive=alive)
                p2, o2 = vopt(gbar, state.opt_state, state.params, step)
                if elastic:
                    p2 = _select_rows(alive, p2, state.params)
                    o2 = _select_rows(alive, o2, state.opt_state)
                return p2, o2, init_knowledge(state.params, kdt,
                                              rel=rel,
                                              sketch_dim=sketch_dim,
                                              alive=know.alive)

            def hold(_):
                return state.params, state.opt_state, k2

            return jax.lax.cond(is_share, do_share, hold, None)

        params, opt_state, know = jax.lax.cond(
            warmup, warmup_branch, sharing_branch, None)
        metrics = {"loss": losses, "step": step,
                   "shared": is_share.astype(jnp.int32)}
        new_state = TrainState(params=params, opt_state=opt_state,
                               know=know, step=step + 1)
        return new_state, metrics

    return train_step
