"""repro — Group-Agent Reinforcement Learning (GARL) + DDAL as a
production multi-pod JAX framework. See DESIGN.md."""

__version__ = "0.1.0"
