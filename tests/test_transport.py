"""Transport faults (ISSUE 9): seeded message-fault injection on the
exchange path, integrity-checked delivery, staleness-aware degradation.

Pins the contract in layers:

* the **plan** (``transport_schedule``) — determinism, realised rates,
  retransmit's loss^(b+1) survival math, knob validation;
* the **wire** (``plane_checksum`` / ``corrupt_planes``) — corruption
  is always detected, incl. the int8 NOT-flip on value-symmetric
  planes, and always finite;
* the **delay line** (``sparse_send`` / ``sparse_deliver``) — jitter
  postpones arrival, duplication re-arms a second slot, corruption
  quarantines (exactly zero eq. 4 weight);
* the **trainers** — the fault-free config is *structurally identical*
  (same jaxpr, same pytree) and bitwise-equal in both trainers; total
  loss + staleness cutoff degrades cleanly to purely-local learning,
  never NaN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import optim
from repro.configs.base import GroupSpec
from repro.core import DDAL
from repro.core import knowledge as K
from repro.core import topology as tp
from repro.core.exchange import build_exchange
from repro.core.sharded_ddal import (TrainState, init_knowledge,
                                     make_group_train_step)
from repro.core.transport import (CORRUPT_BIAS, TransportFaults,
                                  checksum_ok, corrupt_planes,
                                  plane_checksum, transport_schedule)


# ---------------------------------------------------------------------
# toy fixtures (same quadratic family as the checkpoint/chaos tests)
# ---------------------------------------------------------------------
def _toy_ddal(spec, delay=None):
    def gen(state, key):
        del key
        return {"w": state["w"] - state["t"]}, {"w": state["w"]}, state

    def app(state, g):
        return {"w": state["w"] - 0.5 * g["w"], "t": state["t"]}

    return DDAL(spec, gen, app, lambda s: {"w": s["w"]}, delay=delay)


def _toy_states(n):
    return {"w": jnp.zeros((n,)),
            "t": jnp.arange(n, dtype=jnp.float32)}


def _run(ddal, gs, epochs, start=0):
    step = jax.jit(ddal.epoch_step)
    for e in range(start, start + epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e),
                                          ddal.spec.n_agents))
    return gs


def _buffer_final_w(spec, epochs=8, delay=None):
    ddal = _toy_ddal(spec, delay=delay)
    gs = _run(ddal, ddal.init(_toy_states(spec.n_agents)), epochs)
    return np.asarray(gs.agent_states["w"])


def _streaming_run(spec, steps=6):
    opt = optim.sgd(0.1)

    def loss_fn(params, batch):
        return jnp.mean((params["w"] - batch["x"]) ** 2)

    exchange = build_exchange(spec, kind="streaming")
    step = jax.jit(make_group_train_step(None, spec, opt,
                                         loss_fn=loss_fn,
                                         exchange=exchange))
    rng = np.random.default_rng(0)
    n = spec.n_agents
    params = {"w": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)}
    state = TrainState(
        params=params, opt_state=jax.vmap(opt.init)(params),
        know=init_knowledge(params, rel=exchange.streaming_rel_init(),
                            sketch_dim=exchange.sketch_dim),
        step=jnp.zeros((), jnp.int32))
    for i in range(steps):
        batch = {"x": jnp.asarray(rng.normal(size=(n, 5)),
                                  jnp.float32)}
        state, m = step(state, batch)
        assert bool(jnp.isfinite(m["loss"]).all())
    return np.asarray(state.params["w"])


# ---------------------------------------------------------------------
# the plan: deterministic, right rates, retransmit math, validation
# ---------------------------------------------------------------------
def test_plan_is_deterministic_in_seed():
    a = transport_schedule(3, 4, 4, 64, loss=0.3, dup=0.2,
                           corrupt=0.1, jitter=2, retransmit=1)
    b = transport_schedule(3, 4, 4, 64, loss=0.3, dup=0.2,
                           corrupt=0.1, jitter=2, retransmit=1)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = transport_schedule(4, 4, 4, 64, loss=0.3, dup=0.2,
                           corrupt=0.1, jitter=2, retransmit=1)
    assert any((np.asarray(x) != np.asarray(y)).any()
               for x, y in zip(a, c))


@given(st.integers(0, 2 ** 31 - 1),
       st.floats(0.05, 0.95, allow_nan=False))
@settings(max_examples=15, deadline=None)
def test_plan_realises_requested_rates(seed, loss):
    plan = transport_schedule(seed, 8, 8, 400, loss=loss, dup=loss,
                              corrupt=loss)
    for field in ("drop", "dup", "corrupt"):
        rate = float(np.mean(getattr(plan, field)))
        assert abs(rate - loss) < 0.02, (field, rate, loss)
    assert (plan.extra == 0).all()          # no jitter, no retransmit


def test_retransmit_converts_drops_into_backoff_delay():
    """With budget b, a message survives unless all 1 + b draws lose:
    realised drop rate ≈ loss^(b+1); every save carries the cumulative
    backoff (1, 3, 7, … epochs) as extra delay, bounded by 2^b - 1."""
    loss = 0.5
    base = transport_schedule(0, 8, 8, 600, loss=loss)
    for b in (1, 2, 3):
        plan = transport_schedule(0, 8, 8, 600, loss=loss,
                                  retransmit=b)
        rate = float(np.mean(plan.drop))
        assert abs(rate - loss ** (b + 1)) < 0.03, (b, rate)
        assert float(np.mean(base.drop)) > rate
        saved = ~plan.drop & (plan.extra > 0)
        assert saved.any()
        assert int(plan.extra.max()) <= (1 << b) - 1
        assert (plan.extra[plan.drop] == 0).all()


def test_jitter_bounds_extra_delay():
    plan = transport_schedule(1, 4, 4, 200, jitter=3)
    assert int(plan.extra.min()) >= 0
    assert int(plan.extra.max()) <= 3
    assert len(np.unique(plan.extra)) == 4   # uniform over 0..3
    assert not plan.drop.any() and not plan.corrupt.any()


@pytest.mark.parametrize("kw,msg", [
    (dict(loss=1.5), r"loss probability must be in \[0, 1\]"),
    (dict(dup=-0.1), r"dup probability must be in \[0, 1\]"),
    (dict(corrupt=2.0), r"corrupt probability must be in \[0, 1\]"),
    (dict(jitter=-1), "jitter must be >= 0"),
    (dict(retransmit=-2), "retransmit budget must be >= 0"),
])
def test_schedule_validates_knobs(kw, msg):
    with pytest.raises(ValueError, match=msg):
        transport_schedule(0, 4, 4, 16, **kw)


def test_schedule_validates_horizon():
    with pytest.raises(ValueError, match="horizon must be >= 1"):
        transport_schedule(0, 4, 4, 0)


# ---------------------------------------------------------------------
# GroupSpec knob validation (satellite: construction-time, named ranges)
# ---------------------------------------------------------------------
@pytest.mark.parametrize("kw,msg", [
    (dict(transport_loss=1.5), r"in \[0, 1\]"),
    (dict(transport_dup=-0.2), r"in \[0, 1\]"),
    (dict(transport_corrupt=7.0), r"in \[0, 1\]"),
    (dict(transport_jitter=-1), "transport_jitter must be >= 0"),
    (dict(transport_retransmit=9), r"transport_retransmit must be in"),
    (dict(transport_horizon=0), "transport_horizon must be >= 1"),
    (dict(transport_decay=0.0), r"transport_decay must be in \(0, 1\]"),
    (dict(transport_decay=1.1), r"transport_decay must be in \(0, 1\]"),
    (dict(max_staleness=0), "max_staleness must be >= 1"),
    (dict(exchange_transport="bogus"), "unknown transport"),
    (dict(exchange_transport="none", transport_loss=0.2),
     "silently ignore"),
])
def test_groupspec_validates_transport_knobs(kw, msg):
    with pytest.raises(ValueError, match=msg):
        GroupSpec(n_agents=4, threshold=1, minibatch=2, **kw)


def test_exchange_cli_speaks_transport():
    from repro.launch.train import _exchange_kv
    assert _exchange_kv("transport=faulty") == ("exchange_transport",
                                                "faulty")
    assert _exchange_kv("loss=0.2") == ("transport_loss", 0.2)
    assert _exchange_kv("max_staleness=4") == ("max_staleness", 4)


# ---------------------------------------------------------------------
# the wire: checksums catch corruption; corruption is always finite
# ---------------------------------------------------------------------
def test_checksum_catches_f32_corruption_per_edge():
    rng = np.random.default_rng(0)
    pieces = {"w": jnp.asarray(rng.normal(size=(3, 2, 5)),
                               jnp.float32)}
    chk = plane_checksum(pieces)
    mask = jnp.asarray([[True, False], [False, True], [False, False]])
    garbled = corrupt_planes(pieces, mask)
    ok = checksum_ok(chk, plane_checksum(garbled))
    np.testing.assert_array_equal(np.asarray(ok), ~np.asarray(mask))
    assert np.isfinite(np.asarray(garbled["w"])).all()


def test_checksum_catches_int8_not_flip_on_symmetric_plane():
    """The value multiset {3, -4} is invariant under q -> -1 - q; a
    plain sum checksum would miss the flip. Position weighting doesn't."""
    plane = {"q": jnp.asarray([[[3, -4]]], jnp.int8)}
    chk = plane_checksum(plane)
    flipped = corrupt_planes(plane, jnp.asarray([[True]]))
    np.testing.assert_array_equal(
        np.asarray(flipped["q"]), np.asarray([[[-4, 3]]], np.int8))
    assert not bool(checksum_ok(chk, plane_checksum(flipped))[0, 0])
    intact = corrupt_planes(plane, jnp.asarray([[False]]))
    assert bool(checksum_ok(chk, plane_checksum(intact))[0, 0])


def test_corrupt_planes_finite_and_in_range():
    pieces = {"f": jnp.ones((2, 2, 3), jnp.float32) * 7.0,
              "q": jnp.full((2, 2, 3), 127, jnp.int8)}
    out = corrupt_planes(pieces, jnp.ones((2, 2), bool))
    assert np.isfinite(np.asarray(out["f"])).all()
    assert float(np.max(np.abs(np.asarray(out["f"])))) <= CORRUPT_BIAS
    assert np.asarray(out["q"]).dtype == np.int8
    np.testing.assert_array_equal(np.asarray(out["q"]), -128)


# ---------------------------------------------------------------------
# the delay line: jitter postpones, duplication re-arms, corruption
# quarantines — pinned on the raw primitives
# ---------------------------------------------------------------------
def _line_rig(n=2, max_delay=3):
    topo = tp.full(n)
    params0 = {"w": jnp.zeros((3,))}
    flight = K.make_sparse_inflight(params0, topo, max_delay,
                                    transport=True, track_born=True)
    stores = jax.vmap(lambda _: K.make_store(params0, 8,
                                             track_born=True))(
        jnp.arange(n))
    pieces = {"w": jnp.arange(n * 3, dtype=jnp.float32).reshape(n, 3)}
    T = jnp.ones((n,))
    return topo, flight, stores, pieces, T


def _faults(n, k, *, drop=False, extra=0, dup=False, corrupt=False):
    return TransportFaults(
        drop=jnp.full((n, k), drop),
        extra=jnp.full((n, k), extra, jnp.int32),
        dup=jnp.full((n, k), dup),
        corrupt=jnp.full((n, k), corrupt))


def _valid_count(stores):
    return np.asarray(stores.valid).sum(axis=1)


def test_jitter_postpones_foreign_arrivals():
    topo, flight, stores, pieces, T = _line_rig()
    n, k = topo.nbr.shape
    flight = K.sparse_send(flight, topo, pieces, T, 0, True,
                           faults=_faults(n, k, extra=2))
    flight, stores = K.sparse_deliver(flight, stores, 0)
    np.testing.assert_array_equal(_valid_count(stores), 1)  # self only
    flight, stores = K.sparse_deliver(flight, stores, 1)
    np.testing.assert_array_equal(_valid_count(stores), 1)  # in flight
    flight, stores = K.sparse_deliver(flight, stores, 2)
    np.testing.assert_array_equal(_valid_count(stores), 2)  # arrived


def test_duplication_rearms_a_second_arrival():
    topo, flight, stores, pieces, T = _line_rig()
    n, k = topo.nbr.shape
    flight = K.sparse_send(flight, topo, pieces, T, 0, True,
                           faults=_faults(n, k, dup=True))
    flight, stores = K.sparse_deliver(flight, stores, 0)
    np.testing.assert_array_equal(_valid_count(stores), 2)
    flight, stores = K.sparse_deliver(flight, stores, 1)
    # the foreign piece arrives again one epoch later, same payload
    np.testing.assert_array_equal(_valid_count(stores), 3)
    g = np.asarray(stores.grads["w"])
    v = np.asarray(stores.valid)
    for i in range(n):
        rows = g[i][v[i]]
        assert len(np.unique(rows.round(6), axis=0)) == 2  # self + dup'd


def test_drop_loses_foreign_pieces():
    topo, flight, stores, pieces, T = _line_rig()
    n, k = topo.nbr.shape
    flight = K.sparse_send(flight, topo, pieces, T, 0, True,
                           faults=_faults(n, k, drop=True))
    for e in range(4):
        flight, stores = K.sparse_deliver(flight, stores, e)
    np.testing.assert_array_equal(_valid_count(stores), 1)  # self only


def test_corruption_is_quarantined_with_zero_weight():
    """A corrupted piece fails its checksum at deliver: it is never
    appended as valid, and no CORRUPT_BIAS garbage reaches the stores
    — exactly zero eq. 4 weight, in both the T and R terms."""
    topo, flight, stores, pieces, T = _line_rig()
    n, k = topo.nbr.shape
    flight = K.sparse_send(flight, topo, pieces, T, 0, True,
                           faults=_faults(n, k, corrupt=True))
    flight, stores = K.sparse_deliver(flight, stores, 0)
    np.testing.assert_array_equal(_valid_count(stores), 1)  # self only
    g = np.asarray(stores.grads["w"])
    v = np.asarray(stores.valid)
    assert (np.abs(g[v]) < CORRUPT_BIAS / 2).all()
    Tcol = np.asarray(stores.T)
    from repro.core.weighting import eq4_weights
    w = np.asarray(jax.vmap(
        lambda T, R, vv: eq4_weights(T, R, valid=vv))(
            stores.T, stores.R, stores.valid))
    assert (w[~v] == 0.0).all()
    assert np.isfinite(w).all() and np.isfinite(Tcol).all()


def test_self_loop_is_exempt_from_all_faults():
    topo, flight, stores, pieces, T = _line_rig()
    n, k = topo.nbr.shape
    flight = K.sparse_send(
        flight, topo, pieces, T, 0, True,
        faults=_faults(n, k, drop=True, corrupt=True, extra=3))
    flight, stores = K.sparse_deliver(flight, stores, 0)
    # own piece arrives on time, intact, despite every fault being set
    cnt = _valid_count(stores)
    np.testing.assert_array_equal(cnt, 1)
    g = np.asarray(stores.grads["w"])
    v = np.asarray(stores.valid)
    for i in range(n):
        np.testing.assert_allclose(g[i][v[i]][0],
                                   np.asarray(pieces["w"])[i])


# ---------------------------------------------------------------------
# trainers: fault-free structural identity + bitwise equality
# ---------------------------------------------------------------------
def test_fault_free_buffer_is_structurally_identical():
    """Default spec vs explicit transport='none': same pytree
    structure, same jaxpr — the elastic=False contract, honored by
    transport too."""
    n = 4
    base = GroupSpec(n_agents=n, threshold=1, minibatch=2, m_pieces=6)
    none = GroupSpec(n_agents=n, threshold=1, minibatch=2, m_pieces=6,
                     exchange_transport="none")
    da, dn = _toy_ddal(base), _toy_ddal(none)
    ga, gn = da.init(_toy_states(n)), dn.init(_toy_states(n))
    assert (jax.tree_util.tree_structure(ga)
            == jax.tree_util.tree_structure(gn))
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    ja = jax.make_jaxpr(da.epoch_step)(ga, keys)
    jn = jax.make_jaxpr(dn.epoch_step)(gn, keys)
    assert str(ja) == str(jn)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_zero_rate_faulty_buffer_is_bitwise_default(seed):
    """Forcing the 'faulty' strategy with every rate zero allocates
    the checksum planes but changes no delivered value: final params
    are bitwise the default run's, whatever the plan seed."""
    n = 4
    kw = dict(n_agents=n, threshold=1, minibatch=2, m_pieces=6,
              topology="ring")
    ref = _buffer_final_w(GroupSpec(**kw))
    out = _buffer_final_w(GroupSpec(**kw, exchange_transport="faulty",
                                    transport_seed=seed))
    np.testing.assert_array_equal(out, ref)


def test_zero_rate_faulty_streaming_is_bitwise_default():
    kw = dict(n_agents=4, threshold=1, minibatch=2,
              knowledge_mode="streaming", topology="ring")
    ref = _streaming_run(GroupSpec(**kw))
    out = _streaming_run(GroupSpec(**kw, exchange_transport="faulty"))
    np.testing.assert_array_equal(out, ref)


def test_corrupt_everything_equals_lose_everything():
    """Quarantine (corrupt=1) and loss (loss=1) must leave bitwise
    identical agent params: a quarantined piece is a hole, exactly."""
    kw = dict(n_agents=3, threshold=1, minibatch=2, m_pieces=6)
    lost = _buffer_final_w(GroupSpec(**kw, transport_loss=1.0))
    quar = _buffer_final_w(GroupSpec(**kw, transport_corrupt=1.0))
    np.testing.assert_array_equal(lost, quar)


# ---------------------------------------------------------------------
# graceful degradation: staleness cutoff, local fallback, no NaN
# ---------------------------------------------------------------------
def test_total_loss_plus_staleness_degrades_to_local_learning():
    """loss=1 with a uniform 2-epoch delay and max_staleness=1 cuts
    every piece (even the agent's own arrives too old): eq. 4 goes
    empty, the trainer falls back to the purely-local update, and
    every agent still converges to its own target — no NaN, no stall."""
    n = 3
    spec = GroupSpec(n_agents=n, threshold=1, minibatch=2, m_pieces=6,
                     transport_loss=1.0, max_staleness=1, max_delay=2)
    delay = jnp.full((n, n), 2, jnp.int32)
    w = _buffer_final_w(spec, epochs=16, delay=delay)
    t = np.arange(n, dtype=np.float32)
    assert np.isfinite(w).all()
    assert (np.abs(w - t) < 0.1).all(), w


def test_staleness_decay_discounts_late_pieces():
    n = 4
    kw = dict(n_agents=n, threshold=1, minibatch=2, m_pieces=6,
              transport_loss=0.3, transport_seed=5, max_delay=1)
    delay = jnp.ones((n, n), jnp.int32)
    full = _buffer_final_w(GroupSpec(**kw), epochs=10, delay=delay)
    disc = _buffer_final_w(GroupSpec(**kw, transport_decay=0.5),
                           epochs=10, delay=delay)
    assert np.isfinite(full).all() and np.isfinite(disc).all()
    assert (full != disc).any()     # the discount is live


def test_mixed_faults_buffer_stays_finite_and_learns():
    n = 4
    spec = GroupSpec(n_agents=n, threshold=1, minibatch=2, m_pieces=8,
                     transport_loss=0.2, transport_corrupt=0.1,
                     transport_dup=0.1, transport_jitter=1,
                     transport_retransmit=2, max_staleness=6,
                     transport_decay=0.9, max_delay=1,
                     transport_seed=11)
    w = _buffer_final_w(spec, epochs=14)
    t = np.arange(n, dtype=np.float32)
    assert np.isfinite(w).all()
    # group averaging pulls toward the group mean; faults only slow it
    assert (np.abs(w - t.mean()) < np.abs(np.zeros(n) - t.mean())
            + 0.5).all()


def test_lossy_streaming_stays_finite():
    spec = GroupSpec(n_agents=4, threshold=1, minibatch=2,
                     knowledge_mode="streaming", topology="ring",
                     transport_loss=0.5, transport_corrupt=0.2,
                     transport_seed=3)
    w = _streaming_run(spec, steps=8)
    assert np.isfinite(w).all()


# ---------------------------------------------------------------------
# build-time composition rules
# ---------------------------------------------------------------------
def test_delay_line_headroom_is_knob_derived():
    """jitter + full retransmit backoff + the duplicate's +1 — static
    whatever the seed realises, so program shape never depends on it."""
    spec = GroupSpec(n_agents=4, threshold=1, minibatch=2,
                     max_delay=1, transport_loss=0.1,
                     transport_jitter=2, transport_retransmit=2,
                     transport_dup=0.1)
    ex = build_exchange(spec, kind="buffer")
    assert ex.max_delay == 1 + 2 + 3 + 1
    base = build_exchange(GroupSpec(n_agents=4, threshold=1,
                                    minibatch=2, max_delay=1),
                          kind="buffer")
    assert base.max_delay == 1


def test_streaming_rejects_delay_line_knobs():
    with pytest.raises(ValueError, match="max_staleness"):
        build_exchange(GroupSpec(n_agents=4, threshold=1, minibatch=2,
                                 knowledge_mode="streaming",
                                 max_staleness=3), kind="streaming")
    with pytest.raises(ValueError, match="jitter"):
        build_exchange(GroupSpec(n_agents=4, threshold=1, minibatch=2,
                                 knowledge_mode="streaming",
                                 transport_loss=0.1,
                                 transport_jitter=1),
                       kind="streaming")


def test_pod_combiner_rejects_transport():
    spec = GroupSpec(n_agents=4, threshold=1, minibatch=2,
                     knowledge_mode="streaming",
                     topology="hierarchical", degree=2, pods=2,
                     exchange_combiner="pod", transport_loss=0.1)
    with pytest.raises(ValueError, match="pod"):
        build_exchange(spec, kind="streaming")


def test_transport_composes_with_elastic_membership():
    n = 4
    spec = GroupSpec(n_agents=n, threshold=1, minibatch=2, m_pieces=8,
                     elastic=True, transport_loss=0.2,
                     transport_corrupt=0.1, transport_seed=2,
                     max_staleness=6, max_delay=1)
    ddal = _toy_ddal(spec)
    gs = _run(ddal, ddal.init(_toy_states(n)), 4)
    dead = jnp.asarray([True, False, False, False])
    gs = ddal.kill(gs, dead)
    gs = _run(ddal, gs, 4, start=4)
    gs = ddal.revive(gs, dead)
    gs = _run(ddal, gs, 4, start=8)
    assert np.isfinite(np.asarray(gs.agent_states["w"])).all()


def test_transport_composes_with_quantized_line():
    n = 3
    spec = GroupSpec(n_agents=n, threshold=1, minibatch=2, m_pieces=6,
                     knowledge_quant_block=128, transport_loss=0.2,
                     transport_corrupt=0.2, transport_seed=9,
                     max_delay=1)
    w = _buffer_final_w(spec, epochs=10)
    assert np.isfinite(w).all()


# ---------------------------------------------------------------------
# slow lane: long mixed-fault sweep with membership chaos on top
# ---------------------------------------------------------------------
@pytest.mark.slow
def test_long_mixed_fault_sweep_with_chaos():
    from repro.core.chaos import chaos_schedule, membership_events

    n = 6
    spec = GroupSpec(n_agents=n, threshold=1, minibatch=2,
                     m_pieces=12, elastic=True, transport_loss=0.25,
                     transport_corrupt=0.1, transport_dup=0.1,
                     transport_jitter=2, transport_retransmit=2,
                     max_staleness=8, transport_decay=0.95,
                     max_delay=1, transport_seed=21)
    ddal = _toy_ddal(spec)
    gs = ddal.init(_toy_states(n))
    step = jax.jit(ddal.epoch_step)
    epochs = 40
    alive = chaos_schedule(13, n, epochs, kill_prob=0.08,
                           revive_after=4, min_alive=2)
    events = {e: (k, r) for e, k, r in membership_events(alive)}
    for e in range(epochs):
        if e in events:
            kill, revive = events[e]
            if kill.any():
                gs = ddal.kill(gs, jnp.asarray(kill))
            if revive.any():
                gs = ddal.revive(gs, jnp.asarray(revive))
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
        assert np.isfinite(np.asarray(gs.agent_states["w"])).all(), e
    w = np.asarray(gs.agent_states["w"])
    t = np.arange(n, dtype=np.float32)
    assert (np.abs(w - t.mean()) < np.abs(t - t.mean()) + 0.5).all()
