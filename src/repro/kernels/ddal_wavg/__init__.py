from repro.kernels.ddal_wavg import ops, ref  # noqa: F401
from repro.kernels.ddal_wavg.kernel import (  # noqa: F401
    fused_wavg_flat, fused_wavg_q_flat, wavg_flat)
