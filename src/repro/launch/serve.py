"""Serving launcher: batched / continuous / multi-tenant group serving
for any model-zoo arch.

Serving configuration rides one generic ``--serve key=value`` escape
hatch whose vocabulary derives from ``repro.serving.cli_options()``
(every ``ServeConfig`` field plus the engine-level knobs) — the same
registry-derived pattern as ``launch/train.py``'s ``--exchange``, so
new serving knobs never grow new argparse flags here.

    # fixed-batch (the seed behaviour)
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --requests 6 --serve engine=batch --serve slots=2

    # multi-tenant: 4 agents' policies from one mesh
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --requests 12 --serve engine=group --serve agents=4 \
        --serve slots=4 --serve max_new_tokens=16
"""
from __future__ import annotations

import argparse
import time


def _serve_kv(text: str):
    """Parse one ``--serve key=value`` item against the serving
    vocabulary (``repro.serving.cli_options``): ServeConfig fields and
    engine-level knobs, values coerced to the declared type."""
    from repro.serving import cli_options
    opts = cli_options()
    key, sep, value = text.partition("=")
    if not sep:
        raise argparse.ArgumentTypeError(
            f"--serve wants key=value, got {text!r}")
    if key not in opts:
        raise argparse.ArgumentTypeError(
            f"unknown serve option {key!r}; valid keys: "
            f"{', '.join(sorted(opts))}")
    field, typ = opts[key]
    try:
        return field, typ(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--serve {key} wants a {typ.__name__}, got {value!r}")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="llama3.2-3b")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--serve", action="append", default=[],
                   type=_serve_kv, metavar="KEY=VALUE",
                   help="serving configuration "
                        "(repro.serving.cli_options): any ServeConfig "
                        "field (max_len= max_new_tokens= temperature= "
                        "eos_id=) or engine knob (engine=batch|"
                        "continuous|group, slots=, prompt_pad=, "
                        "agents=, router=fifo|fair). Repeatable; "
                        "later spellings win. Example: --serve "
                        "engine=group --serve agents=4 --serve "
                        "max_new_tokens=16")
    p.add_argument("--ckpt", default=None,
                   help="group engine: restore the published param "
                        "planes from a ParamStore checkpoint instead "
                        "of random init")
    p.add_argument("--full", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_arch_config
    from repro.models import get_model
    from repro.serving import (
        ContinuousBatcher,
        GroupRequest,
        GroupServeEngine,
        ParamStore,
        Router,
        ServeConfig,
        ServeEngine,
        ServeMetrics,
        serve_batches,
    )

    # defaults, then --serve pairs layered on top (later spellings win)
    knobs = {"engine": "batch", "slots": 2, "prompt_pad": 16,
             "agents": 1, "router": "fifo"}
    serve_kw = {}
    import dataclasses
    serve_fields = {f.name for f in dataclasses.fields(ServeConfig)}
    for field, value in args.serve:
        (serve_kw if field in serve_fields else knobs)[field] = value
    serve = ServeConfig(**{"max_len": 128, "max_new_tokens": 16,
                           **serve_kw})

    cfg = get_arch_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = get_model(cfg)

    rng = np.random.default_rng(args.seed)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 rng.integers(2, args.prompt_len)))
               for _ in range(args.requests)]

    t0 = time.time()
    n_out = 0
    if knobs["engine"] == "group":
        A = knobs["agents"]
        if args.ckpt:
            template = jax.eval_shape(
                lambda ks: jax.vmap(lambda k: model.init(cfg, k))(ks),
                jax.random.split(jax.random.PRNGKey(0), A))
            store = ParamStore.load(args.ckpt, template)
            print(f"restored planes v{store.version} from {args.ckpt}")
        else:
            keys = jax.random.split(jax.random.PRNGKey(args.seed), A)
            store = ParamStore(
                jax.vmap(lambda k: model.init(cfg, k))(keys))
        metrics = ServeMetrics()
        engine = GroupServeEngine(cfg, store, serve,
                                  batch_size=knobs["slots"],
                                  prompt_pad=knobs["prompt_pad"],
                                  router=Router(knobs["router"]),
                                  metrics=metrics, seed=args.seed)
        reqs = [GroupRequest(rid, rid % A, pr)
                for rid, pr in enumerate(prompts)]
        out = engine.run(reqs)
        for req in reqs:
            toks = out[req.rid]
            n_out += len(toks)
            print(f"req {req.rid} agent {req.agent_id}: "
                  f"prompt={np.asarray(req.prompt)} "
                  f"-> {np.asarray(toks)}")
        s = metrics.summary()
        print(f"agents={A} slots={knobs['slots']} "
              f"p50={s['latency_p50'] * 1e3:.0f}ms "
              f"p99={s['latency_p99'] * 1e3:.0f}ms "
              f"queue_depth_mean={s['queue_depth_mean']:.1f}")
    elif knobs["engine"] == "continuous":
        params = model.init(cfg, jax.random.PRNGKey(args.seed))
        batcher = ContinuousBatcher(cfg, params, serve,
                                    batch_size=knobs["slots"],
                                    prompt_pad=knobs["prompt_pad"])
        out = batcher.run(prompts)
        for rid, pr in enumerate(prompts):
            n_out += len(out[rid])
            print(f"req {rid}: prompt={np.asarray(pr)} "
                  f"-> {np.asarray(out[rid])}")
    else:
        params = model.init(cfg, jax.random.PRNGKey(args.seed))
        engine = ServeEngine(cfg, params, serve)
        for bi, (toks, lens) in enumerate(
                serve_batches(prompts, knobs["slots"])):
            out = engine.generate(toks, lens, jax.random.PRNGKey(bi))
            n_out += out.shape[0] * out.shape[1]
            for row in range(out.shape[0]):
                print(f"batch {bi} slot {row}: "
                      f"prompt={np.asarray(toks[row][:int(lens[row])])} "
                      f"-> {np.asarray(out[row])}")
    dt = time.time() - t0
    print(f"{n_out} tokens in {dt:.1f}s ({n_out / dt:,.0f} tok/s, "
          f"incl. compile)")


if __name__ == "__main__":
    main()
