"""jit'd wrappers for the eq. 4 weighted-average kernel.

``tree_wavg`` applies the kernel leaf-wise over a stacked gradient
pytree (leaves (m, *param_shape)) — the exact contraction DDAL's
knowledge stores perform at every share step. Small leaves (< one
tile) fall back to the jnp oracle: kernel launch overhead would
dominate and XLA already fuses them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ddal_wavg import ref
from repro.kernels.ddal_wavg.kernel import DEFAULT_ROWS, LANES, wavg_flat

_MIN_KERNEL_SIZE = DEFAULT_ROWS * LANES


def wavg(G: jnp.ndarray, w: jnp.ndarray, *,
         interpret: bool = False) -> jnp.ndarray:
    """Σ_j w_j·G[j] for G: (m, N) → (N,) fp32."""
    return wavg_flat(G, w, interpret=interpret)


def tree_wavg(grads_stacked, w, *, interpret: bool = False):
    """Kernel-backed version of pytree eq. 4 contraction."""
    def leaf(x):
        m = x.shape[0]
        size = int(x.size) // m
        if size < _MIN_KERNEL_SIZE:
            return ref.wavg(x.reshape(m, -1), w).reshape(x.shape[1:])
        flat = x.reshape(m, size)
        return wavg_flat(flat, w, interpret=interpret
                         ).reshape(x.shape[1:])
    return jax.tree.map(leaf, grads_stacked)
