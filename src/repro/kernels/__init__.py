"""Pallas-TPU kernels for the framework's compute hot-spots.

* ``ddal_wavg`` — the paper's eq. 4 share step. Alongside the original
  m-way weighted reduction it carries the *fused* entries
  (``fused_wavg`` / ``tree_fused_wavg`` and their int8-quantized
  ``_q`` twins): one pass over the arrival-slot knowledge planes that
  regenerates the eq. 4 weights in-kernel and emits (ḡ, Σw) directly.
  Used by the knowledge stores and the ``store`` combiner.
* ``flash_attention`` — blocked online-softmax causal GQA attention
  (optional sliding window) for the model-zoo hot path.
* ``ssd_scan`` — Mamba2 SSD intra-chunk dual form (MXU block matmuls);
  the inter-chunk recurrence runs as ``lax.associative_scan`` outside.

Each subpackage has ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd wrapper at the model-layer interface) and ``ref.py``
(pure-jnp oracle). Validation is per entry point: every kernel runs
on CPU under ``interpret=True`` against its oracle, and the fused
``ddal_wavg`` entries additionally ship a tiled pure-XLA form that is
*bitwise* the historical multi-op path — that form is what CPU/GPU
sessions compile (``ops.resolve_impl``: ``auto`` → Pallas on TPU,
XLA elsewhere), so interpret mode is a test vehicle, not the
deployment path. On-TPU lowering for the model kernels is selected
via ``ArchConfig.attention_impl`` / ``ssd_impl`` flags;
``benchmarks/bench_wavg_kernel.py`` gates the share-step kernel
(bitwise parity, one-pass jaxpr shape, quantization error) in CI.
"""
