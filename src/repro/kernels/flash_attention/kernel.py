"""Pallas-TPU blocked online-softmax (flash) attention.

TPU adaptation (DESIGN.md §3): instead of a CUDA warp-level kernel we
tile for the MXU and VMEM — (BQ, D)·(D, BK) block matmuls with the
online-softmax recurrence carried across the innermost grid dimension
in VMEM scratch. The grid is (B, H, nQ, nK); TPU grids execute
sequentially with the last axis innermost, so the kernel initialises
its scratch at j == 0, accumulates over j, and writes the output tile
at the last *visited* j. Causal and sliding-window structure is
exploited two ways:

* blocks entirely above the diagonal (or entirely outside the window)
  are skipped via ``pl.when`` — with a causal mask this halves the
  work, and with a window of w it bounds it by O(S·w);
* partially-masked blocks apply the mask inside the block.

GQA is handled in the BlockSpec index maps (kv head = h·K//H) — no
repeated K/V materialisation in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int,
               seq_len: int, window: Optional[int], n_k: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    q_start = i * block_q
    k_start = j * block_k

    @pl.when(j == 0)
    def init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    # causal: this k block intersects rows only if k_start <= q_end
    relevant = k_start <= q_start + block_q - 1
    if window is not None:
        # and only if the block is not entirely left of every row's window
        relevant &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(relevant)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)

        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = (kpos <= qpos) & (kpos < seq_len)
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # (BQ, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                        # (BQ, BK)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: Optional[int] = None,
                         scale: Optional[float] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, S, D); k, v: (B, K, S, D). Returns (B, H, S, D)."""
    assert causal, "only the causal variant is used by the framework"
    B, H, S, D = q.shape
    K = k.shape[1]
    assert H % K == 0, (H, K)
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, max(8, S))
    block_k = min(block_k, max(8, S))
    s_pad = ((S + max(block_q, block_k) - 1)
             // max(block_q, block_k)) * max(block_q, block_k)
    if s_pad != S:
        pad = ((0, 0), (0, 0), (0, s_pad - S), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    n_q = s_pad // block_q
    n_k = s_pad // block_k
    group = H // K

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=S, window=window, n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, s_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
