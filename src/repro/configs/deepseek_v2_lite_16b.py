"""DeepSeek-V2-Lite (16B) — MoE with Multi-head Latent Attention
[arXiv:2405.04434]. MLA kv_lora=512; 2 shared + 64 routed experts,
top-6 (the assignment's per-arch note says "160 routed" which is
DeepSeek-V2-*full*; the config line's 64e matches V2-Lite and the cited
paper, so we use 64 — recorded in DESIGN.md §5). Layer 0 is dense with
d_ff 10944 per the model card."""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,            # v head dim; MLA dims below
        d_ff=1408,               # routed-expert FF width
        vocab_size=102400,
        rope_theta=1e4,
        moe=MoEConfig(n_experts=64, top_k=6, expert_ff=1408, n_shared=2),
        mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                      v_dim=128, q_lora_rank=None),
        first_k_dense=1,
        dense_ff=10944,
        citation="arXiv:2405.04434",
    )
