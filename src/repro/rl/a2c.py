"""A2C agent for DDA3C (paper §5.2).

One epoch (Algorithm 1 lines 2–4): run one episode, compute the
one-step advantage loss and its gradients:

    Q(s_t, a_t) = r                      (terminal s_{t+1})
                = r + γ V(s_{t+1})       (non-terminal)   [paper eq. 9]
    ∇θ log π_θ(a_t|s_t) · (Q(s_t,a_t) − V(s_t))           [paper eq. 8]

plus the value-network MSE on the same one-step target. Exposed as the
``gen_grads`` / ``apply_grads`` / ``params_of`` callbacks DDAL consumes
("DDAL should not be restricted by agent type", paper §5).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.rl import networks as nets
from repro.rl.rollout import (
    Trajectory,
    episode_return,
    obs_moments,
    run_episode,
)


class A2CState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray       # () int32 — optimiser step counter


def init_a2c(key, env, opt: Optimizer, hidden: int = 64) -> A2CState:
    params = nets.init_policy_value(key, env.obs_dim, env.n_actions,
                                    hidden)
    return A2CState(params=params, opt_state=opt.init(params),
                    step=jnp.zeros((), jnp.int32))


def a2c_loss(params, traj: Trajectory, gamma: float,
             value_coef: float = 0.5, entropy_coef: float = 0.01):
    logits = nets.policy_logits(params, traj.obs)           # (T, A)
    v = nets.state_value(params, traj.obs)                  # (T,)
    v_next = nets.state_value(params, traj.next_obs)        # (T,)
    q = traj.rewards + gamma * jnp.where(traj.dones, 0.0,
                                         jax.lax.stop_gradient(v_next))
    adv = q - v
    logp = jax.nn.log_softmax(logits)
    logp_a = jnp.take_along_axis(logp, traj.actions[:, None],
                                 axis=-1)[:, 0]
    pg = -logp_a * jax.lax.stop_gradient(adv)
    value = 0.5 * jnp.square(adv)
    probs = jax.nn.softmax(logits)
    entropy = -jnp.sum(probs * logp, axis=-1)
    per_step = pg + value_coef * value - entropy_coef * entropy
    denom = jnp.maximum(jnp.sum(traj.mask), 1.0)
    return jnp.sum(per_step * traj.mask) / denom            # average loss


def make_a2c_callbacks(env, opt: Optimizer, gamma: float = 0.99,
                       entropy_coef: float = 0.01,
                       track_obs: bool = False):
    """(gen_grads, apply_grads, params_of) for repro.core.ddal.DDAL.

    With ``track_obs`` the metrics carry the episode's observation
    moments (``repro.rl.rollout.obs_moments``) — the side channel the
    ``obs_stats`` relevance estimator consumes."""

    def gen_grads(state: A2CState, key) -> Tuple[Any, Any, A2CState]:
        def select(obs, k):
            logits = nets.policy_logits(state.params, obs)
            return jax.random.categorical(k, logits)

        traj = run_episode(env, select, key)
        loss, grads = jax.value_and_grad(a2c_loss)(
            state.params, traj, gamma, entropy_coef=entropy_coef)
        metrics = {"loss": loss, "return": episode_return(traj)}
        if track_obs:
            metrics["obs_moments"] = obs_moments(traj)
        return grads, metrics, state

    def apply_grads(state: A2CState, grads) -> A2CState:
        params, opt_state = opt.update(grads, state.opt_state,
                                       state.params, state.step)
        return A2CState(params=params, opt_state=opt_state,
                        step=state.step + 1)

    def params_of(state: A2CState):
        return state.params

    return gen_grads, apply_grads, params_of


def make_a2c_group(env, opt: Optimizer, spec, key,
                   topology=None, gamma: float = 0.99,
                   entropy_coef: float = 0.01,
                   hidden: int = 64,
                   relevance: Optional[jnp.ndarray] = None,
                   delay: Optional[jnp.ndarray] = None):
    """Entry point for a DDA3C group: builds the exchange protocol
    for ``spec`` (``repro.core.exchange.build_exchange`` — schedule,
    relevance estimator, delay model and combiner strategies; an
    explicit ``Topology`` / ``DynamicTopology`` overrides the graph),
    the DDAL loop over it, and the initial GroupState. A static
    relevance prior (e.g. ``repro.core.relevance.obs_overlap``) can
    be passed as a dense ``relevance`` matrix; with
    ``spec.exchange_estimator="obs_stats"`` the callbacks stream each
    episode's observation moments so that prior maintains itself.
    Returns (ddal, group_state)."""
    from repro.core import DDAL
    from repro.core.exchange import build_exchange
    exchange = build_exchange(spec, kind="buffer", topology=topology,
                              relevance=relevance, delay=delay,
                              obs_dim=env.obs_dim)
    gen, app, pof = make_a2c_callbacks(env, opt, gamma=gamma,
                                       entropy_coef=entropy_coef,
                                       track_obs=exchange.wants_obs)
    ddal = DDAL(spec, gen, app, pof, exchange=exchange)
    astates = jax.vmap(lambda k: init_a2c(k, env, opt, hidden))(
        jax.random.split(key, spec.n_agents))
    return ddal, ddal.init(astates)
