"""RL substrate tests: environment dynamics, rollout masking, A2C/DQN
learning on CartPole (short-budget sanity, not paper-scale)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import GroupSpec
from repro.core import DDAL
from repro.rl import (CartPole, DQNConfig, GridWorld, episode_return,
                      init_a2c, init_dqn, make_a2c_callbacks,
                      make_dqn_callbacks, run_episode)


def test_cartpole_dynamics_match_gym_constants():
    """One hand-checked Euler step from a known state."""
    env = CartPole()
    s = env.reset(jax.random.PRNGKey(0))
    s = s._replace(x=jnp.float32(0.0), x_dot=jnp.float32(0.0),
                   theta=jnp.float32(0.05), theta_dot=jnp.float32(0.0))
    ns, obs, r, d = env.step(s, jnp.int32(1))
    # gym formulas with force=+10, theta=0.05
    costh, sinth = np.cos(0.05), np.sin(0.05)
    temp = 10.0 / 1.1
    thetaacc = (9.8 * sinth - costh * temp) / (
        0.5 * (4.0 / 3.0 - 0.1 * costh ** 2 / 1.1))
    xacc = temp - 0.05 * thetaacc * costh / 1.1
    np.testing.assert_allclose(float(ns.x_dot), 0.02 * xacc, rtol=1e-5)
    np.testing.assert_allclose(float(ns.theta_dot), 0.02 * thetaacc,
                               rtol=1e-5)
    assert float(r) == 1.0 and not bool(d)


def test_cartpole_episode_terminates():
    env = CartPole(max_steps=100)

    def always_left(obs, key):
        return jnp.int32(0)

    traj = run_episode(env, always_left, jax.random.PRNGKey(0))
    ret = float(episode_return(traj))
    assert 1 <= ret < 100           # pushing left only falls quickly
    # rewards stop after done
    m = np.asarray(traj.mask)
    assert m.sum() == ret
    first_zero = int(np.argmin(m)) if (m == 0).any() else len(m)
    assert not m[first_zero:].any()


def test_gridworld_optimal_path():
    env = GridWorld(size=3, max_steps=20)

    def policy(obs, key):
        pos = jnp.argmax(obs)
        r = pos // 3
        return jnp.where(r < 2, 1, 3).astype(jnp.int32)  # down, then right

    traj = run_episode(env, policy, jax.random.PRNGKey(0))
    ret = float(episode_return(traj))
    np.testing.assert_allclose(ret, 1.0 - 0.01 * 3, rtol=1e-5)


def test_a2c_single_agent_learns():
    env = CartPole()
    opt = optim.adamw(3e-3)
    spec = GroupSpec(n_agents=1, threshold=10_000, minibatch=100,
                     m_pieces=4)
    gen, app, pof = make_a2c_callbacks(env, opt)
    ddal = DDAL(spec, gen, app, pof)
    astates = jax.vmap(lambda k: init_a2c(k, env, opt))(
        jax.random.split(jax.random.PRNGKey(0), 1))
    gs = ddal.init(astates)
    gs, metrics = jax.jit(lambda g, k: ddal.run(g, k, 800))(
        gs, jax.random.PRNGKey(1))
    rets = np.asarray(metrics["return"])[:, 0]
    assert rets[-100:].mean() > rets[:100].mean() + 5


def test_dqn_replay_and_target_sync():
    env = CartPole()
    opt = optim.adamw(1e-3)
    cfg = DQNConfig(capacity=500, target_period=3, batch=8)
    gen, app, pof = make_dqn_callbacks(env, opt, cfg)
    key = jax.random.PRNGKey(0)
    state = init_dqn(key, env, opt, cfg)
    for i in range(5):
        g, m, state = gen(state, jax.random.fold_in(key, i))
        state = app(state, g)
    assert int(state.replay.size) > 0
    assert int(state.step) == 5
    # after a sync step target == online
    t = jax.tree.leaves(state.target_params)
    p = jax.tree.leaves(state.params)
    if int(state.step) % cfg.target_period == 0:
        for a, b in zip(t, p):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_group_mdp_validation():
    import pytest
    from repro.core import AgentEnv, GroupMDP
    env = CartPole()
    with pytest.raises(ValueError):
        GroupMDP(agents=(AgentEnv(env),),
                 spec=GroupSpec(n_agents=2))
    g = GroupMDP.homogeneous(env, 3)
    assert g.n == 3 and g.spec.n_agents == 3
