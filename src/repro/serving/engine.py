"""Batched serving engine: prefill + token-by-token decode over the
model zoo's functional KV caches (full / sliding-window ring / MLA
latent / SSM state — whichever ``model.make_cache`` builds for the
arch).

The decode loop is a single jitted ``lax.scan`` over new tokens with
per-slot done masking; the host-side ``serve_batches`` helper packs a
request list into fixed-size batches (static shapes → one compilation).
Decode-shape dry-runs lower exactly ``decode_step`` (one token + cache).

All shape-generic pieces (prefill batch construction, sampling, stop
logic) come from ``repro.serving.api`` — shared with the continuous
batcher and the multi-tenant group engine.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import get_model
from repro.serving.api import (
    Sampler,
    ServeConfig,
    StopCriteria,
    decode_batch as _decode_batch,
    last_logits as _last_logits,
    prefill,
)

__all__ = ["DecodeState", "ServeConfig", "ServeEngine", "serve_batches",
           "_decode_batch", "_last_logits"]


class DecodeState(NamedTuple):
    cache: Any
    tokens: jnp.ndarray          # (B, 1) last emitted token
    pos: jnp.ndarray             # (B,) next absolute position
    done: jnp.ndarray            # (B,) bool


class ServeEngine:
    """One arch, one batch size, one cache capacity → compiled once."""

    def __init__(self, cfg: ArchConfig, params, serve: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.model = get_model(cfg)
        self.sampler = Sampler(serve.temperature)
        self.stop = StopCriteria.from_serve(serve)
        self._prefill = jax.jit(self._prefill_impl)
        self._generate = jax.jit(self._generate_impl)

    # -- prefill -------------------------------------------------------
    def _prefill_impl(self, params, tokens, lengths):
        """tokens: (B, P) prompt ids (right-padded); lengths: (B,)."""
        return prefill(self.cfg, self.model, params, tokens, lengths,
                       self.serve.max_len)

    # -- decode loop ---------------------------------------------------
    def _generate_impl(self, params, tokens, lengths, key):
        cfg, serve = self.cfg, self.serve
        first_logits, cache = self._prefill_impl(params, tokens, lengths)
        k0, key = jax.random.split(key)
        tok0 = self.sampler(first_logits, k0)
        state = DecodeState(
            cache=cache,
            tokens=tok0[:, None],
            pos=lengths.astype(jnp.int32),
            done=self.stop.eos_done(tok0),
        )

        def step(st: DecodeState, k):
            batch = _decode_batch(cfg, st.tokens, st.pos[:, None])
            logits, cache = self.model.decode(cfg, params, batch,
                                              st.cache)
            nxt = self.sampler(_last_logits(cfg, logits), k)
            nxt = jnp.where(st.done, st.tokens[:, 0], nxt)
            done = st.done | self.stop.eos_done(nxt)
            new = DecodeState(cache=cache, tokens=nxt[:, None],
                              pos=st.pos + 1, done=done)
            return new, nxt

        keys = jax.random.split(key, serve.max_new_tokens - 1)
        state, rest = jax.lax.scan(step, state, keys)
        out = jnp.concatenate([tok0[:, None], rest.T], axis=1)
        return out                                  # (B, max_new_tokens)

    # -- public --------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, lengths: jnp.ndarray,
                 key=None) -> jnp.ndarray:
        """prompts: (B, P) right-padded int32; lengths: (B,)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return self._generate(self.params, prompts, lengths, key)


def serve_batches(requests: Sequence[Sequence[int]], batch_size: int,
                  pad_id: int = 0) -> List[Tuple[Any, Any]]:
    """Pack a request list into fixed-(B, P) numpy batches (static
    shapes → single compilation); returns [(tokens, lengths), ...]."""
    import numpy as np
    out = []
    for i in range(0, len(requests), batch_size):
        chunk = list(requests[i:i + batch_size])
        while len(chunk) < batch_size:          # pad the tail batch
            chunk.append([pad_id])
        P = max(len(r) for r in chunk)
        toks = np.full((batch_size, P), pad_id, np.int32)
        lens = np.zeros((batch_size,), np.int32)
        for j, r in enumerate(chunk):
            toks[j, :len(r)] = r
            lens[j] = len(r)
        out.append((jnp.asarray(toks), jnp.asarray(lens)))
    return out
