"""Mixture-of-Experts layer with capacity-based scatter dispatch.

Dispatch is computed *per batch row* so that the position-in-expert
cumsum never crosses the data-parallel sharding boundary (no implicit
cross-device scan); experts are sharded over the "model" mesh axis
(expert parallelism) so GSPMD turns the dispatch scatter / combine
gather into the MoE all-to-all pattern.

Top-k routing with normalised gates (Qwen3 / DeepSeek style), capacity
factor with token dropping, load-balance auxiliary loss and router
z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import shard
from repro.models.common import dense_init
from repro.models.mlp import init_swiglu, swiglu


def init_moe(cfg, key):
    moe = cfg.moe
    kr, ke, ks = jax.random.split(key, 3)
    E, F, Ne = cfg.d_model, moe.expert_ff, moe.n_experts
    dt = cfg.dtype("param")
    kg, ku, kd = jax.random.split(ke, 3)
    p = {
        "router": dense_init(kr, (E, Ne), dt),
        "experts": {
            "w_gate": dense_init(kg, (Ne, E, F), dt),
            "w_up": dense_init(ku, (Ne, E, F), dt),
            "w_down": dense_init(kd, (Ne, F, E), dt),
        },
    }
    if moe.n_shared:
        # shared (always-on) experts fused into one wider SwiGLU
        p["shared"] = init_swiglu(ks, E, F * moe.n_shared, dt)
    return p


def _expert_swiglu(experts, buf, cdt):
    """buf: (B, Ne, C, E) → (B, Ne, C, E) through per-expert SwiGLU."""
    wg = experts["w_gate"].astype(cdt)
    wu = experts["w_up"].astype(cdt)
    wd = experts["w_down"].astype(cdt)
    g = jnp.einsum("bxcd,xdf->bxcf", buf, wg)
    u = jnp.einsum("bxcd,xdf->bxcf", buf, wu)
    h = jax.nn.silu(g) * u
    return jnp.einsum("bxcf,xfd->bxcd", h, wd)


def _dispatch_indices(e_flat, gate_flat, Ne: int, C: int, k: int):
    """Sort-based capacity dispatch (per batch row).

    e_flat: (B, T=S·k) expert ids; gate_flat: (B, T) gate weights.
    Returns token_idx (B, Ne, C) int32 — the flat-token index occupying
    each (expert, capacity-slot) — plus w (B, Ne, C) gate weights
    (0 where the slot is empty) and src (B, Ne, C) source positions
    (token_idx // k). Slot order is the token's rank within its expert
    in original flat order (identical to the cumsum-scatter semantics:
    overflow beyond C is dropped).
    """
    B, T = e_flat.shape
    order = jnp.argsort(e_flat, axis=1, stable=True)     # (B, T)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=1)
    start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(Ne), side="left")
    )(sorted_e)                                          # (B, Ne)
    end = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(Ne), side="right")
    )(sorted_e)
    pos = start[:, :, None] + jnp.arange(C)[None, None, :]
    valid = pos < end[:, :, None]                        # (B, Ne, C)
    token_idx = jnp.take_along_axis(
        order, jnp.minimum(pos, T - 1).reshape(B, Ne * C),
        axis=1).reshape(B, Ne, C)
    w = jnp.take_along_axis(
        gate_flat, token_idx.reshape(B, Ne * C),
        axis=1).reshape(B, Ne, C) * valid
    return token_idx, w.astype(gate_flat.dtype), token_idx // k, valid


def _moe_expert_parallel(cfg, p, x, gate_flat, e_flat, model_axis: str):
    """Expert-parallel MoE under shard_map over ``model_axis``.

    Dispatch is a LOCAL gather (each device pulls the tokens its
    experts own — x is replicated over the model axis, so no
    collective); combine is a local scatter-add into a (B, S, E)
    partial followed by ONE psum over the model axis — the minimal
    GSPMD-expressible combine (vs. all-reducing the (B, Ne, C, E)
    dispatch buffer, which is what the dense scatter formulation
    lowers to).
    """
    moe = cfg.moe
    B, S, E = x.shape
    Ne, k = moe.n_experts, moe.top_k
    C = max(1, int(moe.capacity_factor * S * k / Ne))
    cdt = cfg.dtype("compute")
    token_idx, w, src, _ = _dispatch_indices(e_flat, gate_flat, Ne, C, k)

    from jax.sharding import PartitionSpec as P

    def local(x_l, experts_l, idx_l, w_l, src_l):
        # x_l: (B, S, E) [replicated over model]; experts_l leaves
        # (Ne/m, E, F); idx_l/w_l/src_l: (B, Ne/m, C). The "data" axis
        # is auto inside this manual-on-model region — constrain the
        # batch dim explicitly so GSPMD keeps the expert compute
        # data-sharded instead of replicating it per device.
        nloc = idx_l.shape[1]
        bidx = jnp.arange(B)[:, None, None]
        buf = x_l[bidx, src_l].astype(cdt)               # (B,nloc,C,E)
        buf = shard(buf, "batch", None, None, None)
        buf = buf * (w_l[..., None] != 0).astype(cdt)
        y = _expert_swiglu(experts_l, buf, cdt)          # (B,nloc,C,E)
        y = shard(y, "batch", None, None, None)
        contrib = y.astype(jnp.float32) * w_l[..., None].astype(
            jnp.float32)
        # fp32 combine: exact cross-expert accumulation, and bf16
        # psum crashes XLA:CPU ("invalid binary instruction copy")
        out_l = jnp.zeros((B, S, E), jnp.float32)
        out_l = out_l.at[bidx, src_l].add(contrib)
        out_l = shard(out_l, "batch", None, None)
        return jax.lax.psum(out_l, model_axis)

    # fp32 across the shard_map boundary: XLA:CPU CHECK-crashes on
    # bf16 psum, and shard_map's transpose of the replicated-x input /
    # psum'd output inserts psums of their COTANGENTS — keeping both
    # sides fp32 keeps every fwd+bwd psum fp32 (and exact).
    out = jax.shard_map(
        local,
        in_specs=(P(), jax.tree.map(lambda _: P(model_axis),
                                    p["experts"]),
                  P(None, model_axis, None), P(None, model_axis, None),
                  P(None, model_axis, None)),
        out_specs=P(),
        axis_names={model_axis},
        check_vma=False,   # jax 0.8: psum-invariant VMA check chokes
    )(x.astype(jnp.float32), p["experts"], token_idx,
      w.astype(jnp.float32), src)
    return out.astype(cdt)


def _moe_dense(cfg, p, x, gate_flat, e_flat):
    """Reference dense scatter dispatch (single-device / no-mesh)."""
    moe = cfg.moe
    B, S, E = x.shape
    Ne, k = moe.n_experts, moe.top_k
    cdt = cfg.dtype("compute")
    C = max(1, int(moe.capacity_factor * S * k / Ne))
    onehot = jax.nn.one_hot(e_flat, Ne, dtype=jnp.int32)
    pos_all = jnp.cumsum(onehot, axis=1) - 1             # (B, S·k, Ne)
    pos = jnp.take_along_axis(pos_all, e_flat[..., None], axis=2)[..., 0]
    keep = pos < C
    slot = jnp.where(keep, pos, C)                       # overflow slot C

    x_rep = jnp.repeat(x, k, axis=1)                     # (B, S·k, E)
    bidx = jnp.arange(B)[:, None] * jnp.ones_like(e_flat)
    buf = jnp.zeros((B, Ne, C + 1, E), cdt)
    buf = buf.at[bidx, e_flat, slot].set(x_rep.astype(cdt))
    buf = shard(buf, "batch", "experts", None, None)
    y_buf = _expert_swiglu(p["experts"], buf[:, :, :C], cdt)
    y_buf = jnp.pad(y_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))
    out_rep = y_buf[bidx, e_flat, slot]                  # (B, S·k, E)
    w = (gate_flat * keep).astype(cdt)
    return jnp.sum((out_rep * w[..., None]).reshape(B, S, k, E), axis=2)


def _expert_axis():
    """The physical mesh axis experts shard over, if model code is
    running under installed sharding rules + a mesh context."""
    from repro.common.sharding import get_rules
    rules = get_rules()
    if not rules:
        return None
    axis = rules.get("experts")
    if axis is None:
        return None
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:                                    # noqa: BLE001
        return None
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return None
    return axis


def moe_apply(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, E) → (out, aux_loss).

    Two dispatch engines with identical drop semantics (tested):
      * dense scatter (reference) — single-device/no-mesh path;
      * expert-parallel shard_map (gather dispatch + psum combine) —
        selected automatically under a mesh whose rules shard
        "experts"; cuts the MoE collective term ~500× (EXPERIMENTS.md
        §Perf).
    """
    moe = cfg.moe
    B, S, E = x.shape
    Ne, k = moe.n_experts, moe.top_k
    cdt = cfg.dtype("compute")

    logits = (x @ p["router"].astype(jnp.float32).astype(cdt)
              ).astype(jnp.float32)                      # (B,S,Ne)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, gate_idx = jax.lax.top_k(probs, k)             # (B,S,k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)  # normalised top-k

    e_flat = gate_idx.reshape(B, S * k)                  # (B, S·k)
    gate_flat = gate.reshape(B, S * k)

    axis = None if cfg.moe_dispatch == "dense" else _expert_axis()
    if axis is not None and Ne % jax.sharding.get_abstract_mesh(
            ).shape[axis] == 0:
        out = _moe_expert_parallel(cfg, p, x, gate_flat, e_flat, axis)
    else:
        out = _moe_dense(cfg, p, x, gate_flat, e_flat)

    if moe.n_shared:
        out = out + swiglu(p["shared"], x, cdt)

    # ---- auxiliary losses --------------------------------------------
    # load balance: Ne * Σ_e (fraction dispatched)·(mean router prob)
    frac = jnp.mean(jax.nn.one_hot(gate_idx, Ne, dtype=jnp.float32),
                    axis=(0, 1, 2)) * k
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = moe.aux_loss * Ne * jnp.sum(frac * pmean)
    zloss = moe.router_zloss * jnp.mean(
        jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    return out, aux + zloss
