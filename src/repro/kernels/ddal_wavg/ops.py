"""jit'd wrappers for the eq. 4 weighted-average kernels.

``tree_wavg`` applies the kernel leaf-wise over a stacked gradient
pytree (leaves (m, *param_shape)) — the exact contraction DDAL's
knowledge stores perform at every share step. Small leaves (< one
tile) fall back to the jnp oracle: kernel launch overhead would
dominate and XLA already fuses them — that fallback path compiles on
any backend with no interpreter involved.

``interpret=None`` auto-selects: compiled Pallas on TPU, interpreter
mode elsewhere (Pallas-TPU kernels cannot compile on CPU/GPU). An
explicit bool overrides — tests force ``interpret=True`` off-TPU.

The *fused* entry points (``fused_wavg`` / ``tree_fused_wavg`` and
their ``_q`` quantized twins) take the raw (T, R, valid) metadata and
emit (ḡ, Σw) in one pass. They carry a grad_sketch-style ``impl``
knob:

* ``"auto"``   — Pallas on TPU, tiled XLA elsewhere;
* ``"pallas"`` — the fused kernel (``interpret`` then auto-resolves
  via :func:`resolve_interpret` unless forced);
* ``"xla"``    — portable path. At quantization-off this is literally
  ``eq4_weights`` + the ``tree_weighted_sum`` tensordot, so it is
  **bitwise-equal** to the historical multi-op share step; quantized,
  it dequantises in lane-sized chunks under ``lax.scan`` so no fp32
  copy of the full plane stack ever materialises.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.weighting import eq4_weights
from repro.kernels.ddal_wavg import ref
from repro.kernels.ddal_wavg.kernel import (DEFAULT_ROWS, EQ4_EPS, LANES,
                                            fused_wavg_flat,
                                            fused_wavg_q_flat, wavg_flat)

_MIN_KERNEL_SIZE = DEFAULT_ROWS * LANES
_XLA_Q_CHUNK = 8192        # target elements per scan step (≥ q_block)

IMPLS = ("auto", "pallas", "xla")


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None → interpret off-TPU, compiled on TPU; bool → itself."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def resolve_impl(impl: Optional[str]) -> str:
    """``auto``/None → ``pallas`` on TPU else ``xla``; others →
    themselves."""
    if impl is None:
        impl = "auto"
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def wavg(G: jnp.ndarray, w: jnp.ndarray, *,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    """Σ_j w_j·G[j] for G: (m, N) → (N,) fp32."""
    return wavg_flat(G, w, interpret=resolve_interpret(interpret))


def tree_wavg(grads_stacked, w, *, interpret: Optional[bool] = None):
    """Kernel-backed version of pytree eq. 4 contraction."""
    interp = resolve_interpret(interpret)

    def leaf(x):
        m = x.shape[0]
        size = int(x.size) // m
        if size < _MIN_KERNEL_SIZE:
            return ref.wavg(x.reshape(m, -1), w).reshape(x.shape[1:])
        flat = x.reshape(m, size)
        return wavg_flat(flat, w, interpret=interp
                         ).reshape(x.shape[1:])
    return jax.tree.map(leaf, grads_stacked)


# ---------------------------------------------------------------------
# fused share step: (T, R, valid) in, (ḡ, Σw) out
# ---------------------------------------------------------------------
def fused_wavg(G, T, R, valid, *, impl: str = "auto",
               interpret: Optional[bool] = None, eps: float = EQ4_EPS):
    """Fused eq. 4 on a flat plane stack G: (m, N) → (ḡ: (N,), Σw)."""
    kind = resolve_impl(impl)
    if kind == "xla":
        return ref.fused_wavg(G, T, R, valid, eps=eps)
    return fused_wavg_flat(G, T, R, valid,
                           interpret=resolve_interpret(interpret),
                           eps=eps)


def _xla_fused_wavg_q_flat(Q, scale, w, q_block: int):
    """Streaming-dequant contraction: scan over element chunks so the
    live fp32 intermediate is (m, chunk), never the full (m, N) plane
    stack — the XLA analogue of in-kernel dequantisation."""
    m, n = Q.shape
    chunk = max(q_block, (_XLA_Q_CHUNK // q_block) * q_block)
    n_pad = -(-n // chunk) * chunk
    nb_pad = n_pad // q_block
    if n_pad != n:
        Q = jnp.pad(Q, ((0, 0), (0, n_pad - n)))
    if scale.shape[1] != nb_pad:
        scale = jnp.pad(scale, ((0, 0), (0, nb_pad - scale.shape[1])))
    steps = n_pad // chunk
    nbc = chunk // q_block
    Qc = Q.reshape(m, steps, chunk).transpose(1, 0, 2)
    Sc = scale.reshape(m, steps, nbc).transpose(1, 0, 2)
    wf = w.astype(jnp.float32)

    def step(carry, qs):
        q, s = qs                                # (m, chunk), (m, nbc)
        g = ref.dequantize_flat(q, s, q_block)
        return carry, jnp.tensordot(wf, g, axes=(0, 0))

    _, out = jax.lax.scan(step, 0, (Qc, Sc))
    return out.reshape(n_pad)[:n]


def fused_wavg_q(Q, scale, T, R, valid, q_block: int, *,
                 impl: str = "auto", interpret: Optional[bool] = None,
                 eps: float = EQ4_EPS):
    """Fused eq. 4 over int8 block-quantized planes → (ḡ, Σw)."""
    kind = resolve_impl(impl)
    if kind == "xla":
        w = eq4_weights(T, R, valid, eps=eps)
        return _xla_fused_wavg_q_flat(Q, scale, w, q_block), jnp.sum(w)
    return fused_wavg_q_flat(Q, scale, T, R, valid, q_block,
                             interpret=resolve_interpret(interpret),
                             eps=eps)


def tree_fused_wavg(stacked, T, R, valid, *, impl: str = "auto",
                    interpret: Optional[bool] = None,
                    eps: float = EQ4_EPS):
    """Fused eq. 4 over a stacked pytree (leaves (m, *param)) →
    (ḡ tree, Σw). The ``xla`` path reproduces the multi-op share step
    op-for-op — ``eq4_weights`` then the exact ``tree_weighted_sum``
    contraction on the *unreshaped* leaf — so it is bitwise-equal to
    the historical path; ``pallas`` streams big leaves through the
    fused kernel and keeps small leaves on the oracle contraction."""
    kind = resolve_impl(impl)
    w = eq4_weights(T, R, valid, eps=eps)
    if kind == "xla":
        g = jax.tree.map(
            lambda x: jnp.tensordot(w.astype(x.dtype), x, axes=(0, 0)),
            stacked)
        return g, jnp.sum(w)

    interp = resolve_interpret(interpret)

    def leaf(x):
        m = x.shape[0]
        size = int(x.size) // m
        if size < _MIN_KERNEL_SIZE:
            return jnp.tensordot(w.astype(x.dtype), x, axes=(0, 0))
        g, _ = fused_wavg_flat(x.reshape(m, size), T, R, valid,
                               interpret=interp, eps=eps)
        return g.reshape(x.shape[1:])
    return jax.tree.map(leaf, stacked), jnp.sum(w)


def tree_fused_wavg_q(qtree, stree, T, R, valid, q_block: int, *,
                      impl: str = "auto",
                      interpret: Optional[bool] = None,
                      eps: float = EQ4_EPS):
    """Fused eq. 4 over an int8-quantized stacked pytree → (ḡ, Σw)."""
    kind = resolve_impl(impl)
    w = eq4_weights(T, R, valid, eps=eps)
    interp = resolve_interpret(interpret)

    def leaf(q, s):
        m = q.shape[0]
        size = int(q.size) // m
        qf = q.reshape(m, size)
        sf = s.reshape(m, -1)
        if size < _MIN_KERNEL_SIZE:
            g = jnp.tensordot(w.astype(jnp.float32),
                              ref.dequantize_flat(qf, sf, q_block),
                              axes=(0, 0))
        elif kind == "xla":
            g = _xla_fused_wavg_q_flat(qf, sf, w, q_block)
        else:
            g, _ = fused_wavg_q_flat(qf, sf, T, R, valid, q_block,
                                     interpret=interp, eps=eps)
        return g.reshape(q.shape[1:])
    return jax.tree.map(leaf, qtree, stree), jnp.sum(w)


# ---------------------------------------------------------------------
# int8 block quantization over pytrees (knowledge-plane storage)
# ---------------------------------------------------------------------
def quantize_tree(tree, q_block: int, lead: int = 1):
    """Quantize every leaf's trailing (param) axes into int8 blocks.

    Leaves are viewed as (*lead_shape, P) with ``lead`` leading axes
    kept verbatim (m for stores, (n, k, D+2) for delay lines). Returns
    (qtree, stree): qtree mirrors the input shapes in int8; stree's
    leaves are (*lead_shape, ⌈P/q_block⌉) fp32 scales."""
    leaves, treedef = jax.tree.flatten(tree)
    pairs = [ref.quantize_flat(x.reshape(x.shape[:lead] + (-1,)),
                               q_block) for x in leaves]
    qtree = jax.tree.unflatten(
        treedef, [p[0].reshape(x.shape) for p, x in zip(pairs, leaves)])
    stree = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return qtree, stree


def dequantize_tree(qtree, stree, q_block: int):
    """Inverse of :func:`quantize_tree` → fp32 tree of qtree's shapes.
    The lead-axis split is recovered from each scale leaf's rank."""
    def leaf(q, s):
        lead = s.ndim - 1
        flat = q.reshape(q.shape[:lead] + (-1,))
        return ref.dequantize_flat(flat, s, q_block).reshape(q.shape)
    return jax.tree.map(leaf, qtree, stree)
