"""Qwen3-30B-A3B — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
head_dim=128 is explicit in the model card (q-proj 2048 → 4096)."""
from repro.configs.base import ArchConfig, MoEConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,                # routed-expert FF width
        vocab_size=151936,
        rope_theta=1e6,
        moe=MoEConfig(n_experts=128, top_k=8, expert_ff=768, n_shared=0),
        citation="hf:Qwen/Qwen3-30B-A3B",
    )
