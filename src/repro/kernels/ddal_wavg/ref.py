"""Pure-jnp oracles for the DDAL eq. 4 weighted-average kernels.

``wavg``/``tree_wavg`` mirror the plain contraction; ``fused_wavg``
mirrors the fused share-step (weights from raw (T, R, valid) metadata,
(ḡ, Σw) out) with **exactly** the float ops of the historical multi-op
path — ``repro.core.weighting.eq4_weights`` followed by the
``tree_weighted_sum`` tensordot — so the fused entry points are
bitwise-comparable against it at quantization-off.

``quantize_flat``/``dequantize_flat`` define the int8 block-quantized
knowledge-plane wire format: ``q_block`` consecutive elements of the
flat plane share one fp32 scale ``max|x| / 127``; values quantize by
round-to-nearest-even (jnp.rint) into [-127, 127]. The roundtrip
error is bounded per element by ``scale / 2`` of its block — the
accuracy bound the bench gate pins.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.weighting import eq4_weights


def wavg(G: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Σ_j w_j · G[j]  for G: (m, N), w: (m,) → (N,) fp32."""
    return jnp.einsum("m,mn->n", w.astype(jnp.float32),
                      G.astype(jnp.float32))


def tree_wavg(grads_stacked, w):
    """Reference over a pytree whose leaves have leading axis m."""
    def leaf(x):
        m = x.shape[0]
        flat = x.reshape(m, -1).astype(jnp.float32)
        return wavg(flat, w).reshape(x.shape[1:])
    return jax.tree.map(leaf, grads_stacked)


# ---------------------------------------------------------------------
# fused eq. 4 oracle (the multi-op path, spelled once)
# ---------------------------------------------------------------------
def fused_wavg(G, T, R, valid, eps: float = 1e-12):
    """(ḡ, Σw) from raw metadata — the multi-op bitwise oracle: the
    exact ``eq4_weights`` + ``tensordot`` ops the knowledge stores ran
    before fusion (``tree_weighted_sum`` contracts with the same
    dimension numbers)."""
    w = eq4_weights(T, R, valid, eps=eps)
    g = jnp.tensordot(w.astype(G.dtype), G, axes=(0, 0))
    return g.astype(jnp.float32), jnp.sum(w)


# ---------------------------------------------------------------------
# int8 block quantization (the knowledge-plane wire format)
# ---------------------------------------------------------------------
def _blocks(p: int, q_block: int) -> int:
    return -(-p // q_block)


def quantize_flat(G: jnp.ndarray, q_block: int):
    """G: (..., P) float → (q: (..., P) int8, scale: (..., ⌈P/q_block⌉)
    fp32). A short trailing block is zero-padded only for the scale
    max — ``q`` keeps G's exact shape."""
    p = G.shape[-1]
    nb = _blocks(p, q_block)
    pad = nb * q_block - p
    Gf = jnp.asarray(G, jnp.float32)
    Gp = jnp.pad(Gf, [(0, 0)] * (G.ndim - 1) + [(0, pad)])
    Gb = Gp.reshape(G.shape[:-1] + (nb, q_block))
    scale = jnp.max(jnp.abs(Gb), axis=-1) / 127.0        # (..., nb)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.rint(Gb / safe[..., None]), -127, 127)
    q = q.astype(jnp.int8).reshape(Gp.shape)
    if pad:
        q = q[..., :p]
    return q, scale


def dequantize_flat(q: jnp.ndarray, scale: jnp.ndarray,
                    q_block: int) -> jnp.ndarray:
    """Inverse wire transform: q · scale, block-broadcast → fp32 of
    q's shape."""
    p = q.shape[-1]
    nb = scale.shape[-1]
    pad = nb * q_block - p
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    x = (qp.reshape(q.shape[:-1] + (nb, q_block)).astype(jnp.float32)
         * scale[..., None])
    x = x.reshape(qp.shape)
    return x[..., :p] if pad else x


def fused_wavg_q(Q, scale, T, R, valid, q_block: int,
                 eps: float = 1e-12):
    """Quantized-plane oracle: dequantise, then the fused oracle."""
    return fused_wavg(dequantize_flat(Q, scale, q_block), T, R, valid,
                      eps=eps)
