"""Serving load bench: open-loop Poisson traffic against the
multi-tenant ``GroupServeEngine``, latency/throughput gated (ISSUE 6).

An open-loop arrival process (exponential inter-arrivals at a fixed
offered load — arrivals do NOT wait for the server, the production
regime) drives a group of agents' policies through one engine, with a
param hot-swap published mid-run. Floors derive from a *calibrated*
single-step service time measured on the same machine, so the gates
track engine regressions rather than CI-host speed:

1. **completeness** — every request finishes with a sane token count;
   the mid-run hot-swap drops or corrupts nothing.
2. **throughput** — sustained token throughput ≥ ``thr_frac`` × the
   offered token rate (the open-loop load is set below calibrated
   capacity, so a healthy engine keeps up and the measured rate is
   arrival-bound; an engine that lost its batching falls behind and
   the drain tail collapses the ratio).
3. **latency p50/p99** — request latency percentiles ≤ slack × the
   ideal no-queueing request latency (prefill + max_new_tokens decode
   steps at the calibrated step time). Slacks absorb the queueing
   delay of the offered load plus shared-CI noise; a per-slot host
   sync creeping back into the decode loop or a lost jit cache blows
   straight through them.

Every run writes machine-readable ``BENCH_serving.json`` next to this
file (override with ``--json``) so the serving trajectory is tracked
across PRs.

    PYTHONPATH=src python benchmarks/bench_serving.py [--smoke] \
        [--agents 4] [--slots 4] [--requests 32] [--load 0.6] \
        [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from collections import deque

_DEFAULT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_serving.json")


def build_engine(args, metrics):
    import jax

    from repro.configs import get_arch_config
    from repro.models import get_model
    from repro.serving import (GroupServeEngine, ParamStore, Router,
                               ServeConfig)

    cfg = get_arch_config(args.arch).reduced()
    model = get_model(cfg)
    keys = jax.random.split(jax.random.PRNGKey(args.seed), args.agents)
    planes = jax.vmap(lambda k: model.init(cfg, k))(keys)
    store = ParamStore(planes)
    serve = ServeConfig(max_len=args.max_len,
                        max_new_tokens=args.new_tokens)
    engine = GroupServeEngine(cfg, store, serve, batch_size=args.slots,
                              prompt_pad=args.prompt_pad,
                              router=Router(args.router),
                              metrics=metrics, seed=args.seed)
    return cfg, model, engine


def make_requests(cfg, args, rng):
    """Deterministic request stream: prompts inside ONE pad bucket
    (prefill compiles once), agents round-robin."""
    from repro.serving import GroupRequest
    reqs = []
    for rid in range(args.requests):
        n = int(rng.integers(2, args.prompt_pad))
        prompt = [int(t) for t in
                  rng.integers(0, cfg.vocab_size, n)]
        reqs.append(GroupRequest(rid, rid % args.agents, prompt))
    return reqs


def calibrate(engine, reqs) -> dict:
    """Warm the jit caches on a slot-filling prefix of the request
    stream, then time the steady-state decode step (min over the
    drain: the noise-robust statistic for a deterministic workload)
    and one warm prefill."""
    warm = reqs[:engine.B]
    for r in warm:
        engine.submit(r)
    engine.step()                      # compiles prefill + decode
    step_times = []
    while not engine.idle:
        t0 = time.monotonic()
        engine.step()
        step_times.append(time.monotonic() - t0)
    t_step = min(step_times) if step_times else 1e-3
    # warm prefill+splice timing: one more request through a hot cache
    t0 = time.monotonic()
    engine.submit(warm[0])
    engine.step()
    t_prefill = max(time.monotonic() - t0 - t_step, 0.0)
    while not engine.idle:
        engine.step()
    engine.reset()
    engine.metrics.__init__(clock=engine.metrics.clock)  # fresh traces
    return {"t_step_s": t_step, "t_prefill_s": t_prefill,
            "capacity_tok_s": engine.B / t_step}


def drive_open_loop(engine, reqs, calib, args, swap_planes) -> dict:
    """Open-loop Poisson arrivals at ``args.load`` × calibrated
    capacity; a fresh param version is published once the stream is
    half admitted. Wall-clock driven: arrivals become visible at
    their scheduled times whether or not the engine kept up."""
    import numpy as np
    mnt = args.new_tokens
    cap_req_s = calib["capacity_tok_s"] / mnt     # requests/s capacity
    lam = max(args.load * cap_req_s, 1e-6)
    rng = np.random.default_rng(args.seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, len(reqs)))

    t0 = time.monotonic()
    engine.metrics.clock = lambda: time.monotonic() - t0
    pending = deque(zip(arrivals.tolist(), reqs))
    swap_at = len(reqs) // 2
    submitted = 0
    swapped = False
    while pending or not engine.idle:
        now = time.monotonic() - t0
        while pending and pending[0][0] <= now:
            t_arr, req = pending.popleft()
            engine.submit(req, at=t_arr)
            submitted += 1
        if not swapped and submitted >= swap_at:
            engine.store.publish(swap_planes)
            engine.metrics.observe_swap()
            swapped = True
        if engine.idle and pending:
            time.sleep(max(pending[0][0] - (time.monotonic() - t0),
                           0.0))
            continue
        engine.step()
    return {"offered_req_s": lam, "offered_tok_s": lam * mnt,
            "arrival_span_s": float(arrivals[-1]), "swapped": swapped}


# ---------------------------------------------------------------------
# gates
# ---------------------------------------------------------------------
def gate_completeness(engine, reqs, mnt: int) -> dict:
    ok = True
    bad = []
    for r in reqs:
        toks = engine.results.get(r.rid)
        if toks is None or not 1 <= len(toks) <= mnt:
            ok = False
            bad.append(r.rid)
    return {"pass": ok, "completed": len(engine.results),
            "expected": len(reqs), "bad_rids": bad[:8],
            "detail": "every request finishes with 1..max_new_tokens "
                      "tokens across the mid-run hot-swap"}


def gate_throughput(summary, load_info, thr_frac: float) -> dict:
    offered = load_info["offered_tok_s"]
    got = summary["throughput_tok_s"]
    return {"pass": bool(got >= thr_frac * offered),
            "throughput_tok_s": got, "offered_tok_s": offered,
            "floor_frac": thr_frac,
            "detail": "sustained tokens/s vs the offered open-loop "
                      "rate (load < 1 ⇒ a healthy engine keeps up)"}


def gate_latency(summary, calib, args) -> dict:
    ideal = (calib["t_prefill_s"]
             + args.new_tokens * calib["t_step_s"])
    p50_bound = args.slack_p50 * ideal
    p99_bound = args.slack_p99 * ideal
    return {"pass": bool(summary["latency_p50"] <= p50_bound
                         and summary["latency_p99"] <= p99_bound),
            "ideal_latency_s": ideal,
            "p50": summary["latency_p50"], "p50_bound": p50_bound,
            "p99": summary["latency_p99"], "p99_bound": p99_bound,
            "detail": "request latency vs slack × calibrated "
                      "no-queueing latency"}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI fast path: small stream, loose load")
    p.add_argument("--arch", default="llama3.2-3b")
    p.add_argument("--agents", type=int, default=4)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--requests", type=int, default=None)
    p.add_argument("--new-tokens", type=int, default=None)
    p.add_argument("--max-len", type=int, default=None)
    p.add_argument("--prompt-pad", type=int, default=8)
    p.add_argument("--router", default="fifo",
                   choices=["fifo", "fair"])
    p.add_argument("--load", type=float, default=0.6,
                   help="offered load as a fraction of calibrated "
                        "capacity (open loop: arrivals don't wait)")
    p.add_argument("--slack-p50", type=float, default=6.0)
    p.add_argument("--slack-p99", type=float, default=15.0)
    p.add_argument("--thr-frac", type=float, default=0.4,
                   help="throughput floor as a fraction of the "
                        "offered token rate")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default=_DEFAULT_JSON,
                   help="machine-readable results path")
    args = p.parse_args(argv)

    if args.requests is None:
        args.requests = 12 if args.smoke else 48
    if args.new_tokens is None:
        args.new_tokens = 8 if args.smoke else 16
    if args.max_len is None:
        args.max_len = 64 if args.smoke else 128

    import jax
    import numpy as np

    from repro.serving import ServeMetrics

    metrics = ServeMetrics()
    cfg, model, engine = build_engine(args, metrics)
    rng = np.random.default_rng(args.seed)
    reqs = make_requests(cfg, args, rng)

    print(f"serving load bench: arch={args.arch} "
          f"agents={args.agents} slots={args.slots} "
          f"requests={args.requests} new_tokens={args.new_tokens} "
          f"load={args.load} backend={jax.default_backend()}")
    calib = calibrate(engine, reqs)
    print(f"calibrated: t_step={calib['t_step_s'] * 1e3:.1f}ms "
          f"t_prefill={calib['t_prefill_s'] * 1e3:.1f}ms "
          f"capacity={calib['capacity_tok_s']:.1f} tok/s")

    # the hot-swap payload: a fresh init published mid-run (same
    # shapes — the jitted step keeps its cache)
    keys = jax.random.split(jax.random.PRNGKey(args.seed + 99),
                            args.agents)
    swap_planes = jax.vmap(lambda k: model.init(cfg, k))(keys)

    load_info = drive_open_loop(engine, reqs, calib, args, swap_planes)
    summary = engine.metrics.summary()
    print(f"completed {summary['completed']}/{summary['requests']} "
          f"requests, {summary['tokens']} tokens in "
          f"{summary['span_s']:.2f}s "
          f"({summary['throughput_tok_s']:.1f} tok/s vs "
          f"{load_info['offered_tok_s']:.1f} offered)")
    print(f"latency p50={summary['latency_p50'] * 1e3:.0f}ms "
          f"p99={summary['latency_p99'] * 1e3:.0f}ms  "
          f"ttft p50={summary['ttft_p50'] * 1e3:.0f}ms  "
          f"queue depth mean={summary['queue_depth_mean']:.1f} "
          f"max={summary['queue_depth_max']} swaps={summary['swaps']}")

    gates = {
        "completeness": gate_completeness(engine, reqs,
                                          args.new_tokens),
        "throughput": gate_throughput(summary, load_info,
                                      args.thr_frac),
        "latency": gate_latency(summary, calib, args),
    }
    for name, g in gates.items():
        print(f"gate {name}: {'PASS' if g['pass'] else 'FAIL'} "
              f"({ {k: v for k, v in g.items() if k != 'pass'} })")

    payload = {"bench": "serving", "arch": args.arch,
               "agents": args.agents, "slots": args.slots,
               "requests": args.requests,
               "new_tokens": args.new_tokens, "load": args.load,
               "router": args.router,
               "backend": jax.default_backend(),
               "calibration": calib, "open_loop": load_info,
               "summary": summary, "rows": engine.metrics.rows(),
               "gates": gates}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"\nwrote {args.json}")

    if not all(g["pass"] for g in gates.values()):
        raise SystemExit("serving load gate FAILED")
    return payload


if __name__ == "__main__":
    main()
