"""Pallas-TPU kernels for the framework's compute hot-spots.

* ``ddal_wavg`` — the paper's eq. 4 m-way weighted gradient reduction
  (HBM-bandwidth-bound at LLM scale); used by the knowledge stores.
* ``flash_attention`` — blocked online-softmax causal GQA attention
  (optional sliding window) for the model-zoo hot path.
* ``ssd_scan`` — Mamba2 SSD intra-chunk dual form (MXU block matmuls);
  the inter-chunk recurrence runs as ``lax.associative_scan`` outside.

Each subpackage has ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd wrapper at the model-layer interface) and ``ref.py``
(pure-jnp oracle). All are validated on CPU with ``interpret=True``;
on-TPU lowering is selected via ``ArchConfig.attention_impl`` /
``ssd_impl`` flags.
"""
