"""Continuous batching: a fixed-slot decode batch whose finished slots
are refilled from a request queue without stopping the other slots —
the vLLM-style serving loop, on top of the functional caches.

Static shapes throughout (one compile per engine): prompts prefill at
B=1 into a slot-shaped cache, the result is spliced into the batch
cache at the freed slot index, and a single jitted decode step advances
every live slot each iteration.

Batch construction, sampling, stop logic and the per-leaf cache
batch-dim discovery come from ``repro.serving.api`` (shared with the
fixed-batch engine and the multi-tenant group engine); the host loop
fetches ``nxt``/``pos`` as ONE device→host transfer per decode step
instead of the seed's O(B) per-slot ``int(...)`` syncs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import get_model
from repro.serving.api import (
    Sampler,
    ServeConfig,
    StopCriteria,
    cache_batch_dims,
    decode_batch as _decode_batch,
    last_logits as _last_logits,
    prefill,
    splice_cache,
)


def _batch_dims(cfg: ArchConfig, max_len: int):
    """Back-compat alias of ``repro.serving.api.cache_batch_dims``."""
    return cache_batch_dims(cfg, max_len)


def pad_prompt(prompt_pad: int, n: int) -> int:
    """Smallest power-of-2 multiple of ``prompt_pad`` holding ``n``
    tokens — bounds prefill compilations to O(log max_prompt)."""
    p = prompt_pad
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class _Slot:
    request_id: Optional[int] = None
    tokens: Optional[list] = None          # generated so far
    done: bool = True


class ContinuousBatcher:
    """Serve a request stream through ``batch_size`` persistent slots.

    engine-level API:
        batcher = ContinuousBatcher(cfg, params, serve, batch_size=4)
        results = batcher.run(requests)     # {req_id: [tokens...]}
    """

    def __init__(self, cfg: ArchConfig, params, serve: ServeConfig,
                 batch_size: int, prompt_pad: int = 32):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.B = batch_size
        self.prompt_pad = prompt_pad
        self.model = get_model(cfg)
        self.sampler = Sampler(serve.temperature)
        self.stop = StopCriteria.from_serve(serve)
        self._bdims = cache_batch_dims(cfg, serve.max_len)
        self._prefill1 = jax.jit(self._prefill1_impl)
        self._decode = jax.jit(self._decode_impl)
        self._splice = jax.jit(self._splice_impl,
                               static_argnames=("slot",))

    # -- jitted pieces ---------------------------------------------------
    def _prefill1_impl(self, params, tokens, length):
        """B=1 prefill into a fresh 1-slot cache → (next_logits, cache)."""
        nxt, cache = prefill(self.cfg, self.model, params, tokens,
                             jnp.reshape(length, (1,)),
                             self.serve.max_len)
        return nxt[0], cache

    def _splice_impl(self, batch_cache, one_cache, slot: int):
        """Insert a B=1 cache into batch slot ``slot``."""
        return splice_cache(batch_cache, one_cache, self._bdims, slot)

    def _decode_impl(self, params, cache, tokens, pos, done, key):
        batch = _decode_batch(self.cfg, tokens, pos[:, None])
        logits, cache = self.model.decode(self.cfg, params, batch,
                                          cache)
        nxt = self.sampler(_last_logits(self.cfg, logits), key)
        nxt = jnp.where(done, tokens[:, 0], nxt)
        return cache, nxt

    # -- host loop --------------------------------------------------------
    def run(self, requests: Sequence[Sequence[int]],
            key=None) -> Dict[int, List[int]]:
        key = key if key is not None else jax.random.PRNGKey(0)
        queue = list(enumerate(requests))
        slots = [_Slot() for _ in range(self.B)]
        cache = self.model.make_cache(self.cfg, self.B,
                                      self.serve.max_len)
        tokens = jnp.zeros((self.B, 1), jnp.int32)
        pos = jnp.zeros((self.B,), jnp.int32)
        done = jnp.ones((self.B,), bool)
        results: Dict[int, List[int]] = {}

        while queue or any(not s.done for s in slots):
            # refill finished slots
            for i, s in enumerate(slots):
                if s.done and queue:
                    rid, req = queue.pop(0)
                    P = pad_prompt(self.prompt_pad, len(req))
                    toks = np.zeros((1, P), np.int32)
                    toks[0, :len(req)] = req
                    key, k = jax.random.split(key)
                    nl, one = self._prefill1(
                        self.params, jnp.asarray(toks),
                        jnp.int32(len(req)))
                    first = int(self.sampler(nl, k))
                    # prefill's own token may already end the request
                    # (eos on the first sample, max_new_tokens == 1,
                    # or a prompt that fills the cache)
                    if self.stop.should_stop(1, first, len(req)):
                        results[rid] = [first]
                        continue
                    cache = self._splice(cache, one, slot=i)
                    tokens = tokens.at[i, 0].set(first)
                    pos = pos.at[i].set(len(req))
                    done = done.at[i].set(False)
                    slots[i] = _Slot(request_id=rid, tokens=[first],
                                     done=False)

            if not any(not s.done for s in slots):
                continue        # every refill finished at prefill time

            # one decode step for every live slot
            key, k = jax.random.split(key)
            cache, nxt = self._decode(self.params, cache, tokens, pos,
                                      done, k)
            tokens = nxt[:, None]
            pos = pos + 1
            # ONE device→host transfer per step (not O(B) int() pulls)
            nxt_h, pos_h = jax.device_get((nxt, pos))
            finished = []
            for i, s in enumerate(slots):
                if s.done:
                    continue
                t = int(nxt_h[i])
                s.tokens.append(t)
                if self.stop.should_stop(len(s.tokens), t,
                                         int(pos_h[i])):
                    results[s.request_id] = s.tokens
                    s.done = True
                    finished.append(i)
            if finished:
                done = done.at[np.asarray(finished)].set(True)
        return results
