"""Shared harness for the paper-reproduction benchmarks (Figs 2–5).

Each benchmark builds a DDAL group of A2C/DQN CartPole agents, scans
n_epochs and reports per-agent reward trajectories plus the paper's
qualitative stability metrics:

  * tail-mean   — mean reward over the last 20% of epochs
  * tail-std    — its std (the paper's "fluctuation")
  * frac@100    — fraction of tail epochs at the optimal reward 100

The paper trains 50k epochs; the default budget here is scaled down
(CPU wall-clock) — ``--full`` restores paper scale.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro import optim
from repro.configs.base import GroupSpec
from repro.rl import CartPole, DQNConfig, make_a2c_group, make_dqn_group


@dataclasses.dataclass
class RunResult:
    rewards: np.ndarray          # (epochs, n_agents)
    wall_s: float
    spec: GroupSpec

    def tail(self, frac: float = 0.2) -> np.ndarray:
        n = max(1, int(self.rewards.shape[0] * frac))
        return self.rewards[-n:]

    def summary(self, label: str) -> str:
        t = self.tail()
        lines = [f"{label}: {self.rewards.shape[0]} epochs, "
                 f"{self.rewards.shape[1]} agent(s), "
                 f"{self.wall_s:.1f}s"]
        for a in range(t.shape[1]):
            lines.append(
                f"  agent {a}: tail-mean={t[:, a].mean():6.2f} "
                f"tail-std={t[:, a].std():6.2f} "
                f"frac@100={(t[:, a] >= 100).mean():.2f}")
        return "\n".join(lines)


def run_a2c_group(n_agents: int, epochs: int, threshold: int,
                  minibatch: int = 100, m_pieces: int = 32,
                  lr: float = 3e-3, seed: int = 0,
                  max_steps: int = 100, topology: str = "full",
                  degree: int = 4, topology_seed: int = 0) -> RunResult:
    env = CartPole(max_steps=max_steps)
    opt = optim.adamw(lr)
    spec = GroupSpec(n_agents=n_agents, threshold=threshold,
                     minibatch=minibatch, m_pieces=m_pieces,
                     topology=topology, degree=degree,
                     topology_seed=topology_seed)
    key = jax.random.PRNGKey(seed)
    ddal, gs = make_a2c_group(env, opt, spec, key)
    run = jax.jit(lambda g, k: ddal.run(g, k, epochs))
    t0 = time.time()
    gs, metrics = run(gs, jax.random.fold_in(key, 1))
    rewards = np.asarray(metrics["return"])
    return RunResult(rewards=rewards, wall_s=time.time() - t0,
                     spec=spec)


def run_dqn_group(n_agents: int, epochs: int, threshold: int,
                  minibatch: int = 200, m_pieces: int = 32,
                  lr: float = 1e-3, seed: int = 0,
                  max_steps: int = 100, topology: str = "full",
                  degree: int = 4, topology_seed: int = 0) -> RunResult:
    env = CartPole(max_steps=max_steps)
    opt = optim.adamw(lr)
    cfg = DQNConfig(capacity=10_000, eps_decay=max(500, epochs // 4))
    spec = GroupSpec(n_agents=n_agents, threshold=threshold,
                     minibatch=minibatch, m_pieces=m_pieces,
                     topology=topology, degree=degree,
                     topology_seed=topology_seed)
    key = jax.random.PRNGKey(seed)
    ddal, gs = make_dqn_group(env, opt, spec, key, cfg)
    run = jax.jit(lambda g, k: ddal.run(g, k, epochs))
    t0 = time.time()
    gs, metrics = run(gs, jax.random.fold_in(key, 1))
    rewards = np.asarray(metrics["return"])
    return RunResult(rewards=rewards, wall_s=time.time() - t0,
                     spec=spec)


def sparkline(xs: np.ndarray, width: int = 60) -> str:
    """Terminal mini-plot of a reward trajectory."""
    blocks = " ▁▂▃▄▅▆▇█"
    if len(xs) > width:
        chunk = len(xs) // width
        xs = xs[:chunk * width].reshape(width, chunk).mean(axis=1)
    lo, hi = 0.0, max(float(np.max(xs)), 1.0)
    idx = ((xs - lo) / (hi - lo) * (len(blocks) - 1)).astype(int)
    return "".join(blocks[i] for i in np.clip(idx, 0, len(blocks) - 1))
