"""Multi-host pod dispatch (ISSUE 3): the hierarchical topology's
two-level decomposition — intra-pod segment-sum + sparse leader-level
exchange — against the flat single-mesh ``_combine_topo`` oracle.

Single-device tests pin the layout metadata, the edge split, the
analytic cross-pod traffic accounting, the leader self-edge
regression, and the *reference* decomposition (bitwise for one pod,
numerically for many). Tests marked ``multi_device`` run the real
``shard_map`` collectives (``all_gather`` on the agent axis,
``psum``/``ppermute`` on the pod axis) on 8 simulated devices — the
``multi_device`` fixture re-execs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` when the
session is single-device."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.configs.base import GroupSpec
from repro.core import topology as T
from repro.core.pod_dispatch import (
    cross_pod_bytes,
    flat_exchange_bytes,
    make_pod_dispatch,
    split_topology,
)
from repro.core.sharded_ddal import Knowledge, _combine_topo


def _rand_knowledge(rng, A, p):
    return Knowledge(
        tg={"w": jnp.asarray(rng.normal(size=(A, p)), jnp.float32)},
        tsum=jnp.asarray(rng.uniform(1, 3, A), jnp.float32),
        rg={"w": jnp.asarray(rng.normal(size=(A, p)), jnp.float32)},
        rsum=jnp.asarray(rng.uniform(1, 3, A), jnp.float32),
    )


def _hier(n, pod_size, rel_seed=None):
    topo = T.hierarchical(n, pod_size)
    if rel_seed is not None:
        R = np.random.default_rng(rel_seed).uniform(0.2, 1.0, (n, n))
        topo = topo.with_relevance(jnp.asarray(R, jnp.float32))
    return topo, T.hierarchical_layout(n, pod_size)


# ----------------------------------------------------------------------
# layout + edge metadata
# ----------------------------------------------------------------------
def test_pod_layout_metadata():
    lay = T.hierarchical_layout(12, 4)
    assert lay.n_agents == 12 and lay.n_pods == 3
    np.testing.assert_array_equal(lay.pod_id, np.arange(12) // 4)
    np.testing.assert_array_equal(lay.leaders, [0, 4, 8])
    assert lay.leader_mask.sum() == 3
    assert all(lay.leader_mask[lay.leaders])
    with pytest.raises(ValueError, match="pod_size"):
        T.hierarchical_layout(10, 4)


def test_edge_pod_ids_and_cross_mask():
    topo, lay = _hier(8, 4)
    src_pod = T.edge_pod_ids(topo, lay)
    cross = T.cross_pod_mask(topo, lay)
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    for i in range(8):
        for j in range(topo.degree):
            if not mask[i, j]:
                assert not cross[i, j]
                continue
            assert src_pod[i, j] == nbr[i, j] // 4
            assert cross[i, j] == (nbr[i, j] // 4 != i // 4)
    # the only cross-pod edges are the two leader edges 0↔4
    assert {(int(nbr[i, j]), i) for i, j in np.argwhere(cross)} == \
        {(0, 4), (4, 0)}


def test_split_topology_leader_edges_and_validation():
    topo, lay = _hier(12, 4)
    edges = split_topology(topo, lay)
    # intra ∪ leader == all edges, disjoint
    mask = np.asarray(topo.mask)
    np.testing.assert_array_equal(edges.intra_mask | edges.leader_mask,
                                  mask)
    assert not (edges.intra_mask & edges.leader_mask).any()
    # leader clique complete, self-edge masked off the diagonal
    assert edges.ledge.sum() == 3 * 2
    assert not edges.ledge.diagonal().any()
    # slots point back at the right sources
    nbr = np.asarray(topo.nbr)
    for sp in range(3):
        for dp in range(3):
            if sp == dp:
                continue
            slot = int(edges.lslot[sp, dp])
            assert nbr[lay.leaders[dp], slot] == lay.leaders[sp]
    # a graph with member-level cross-pod edges has no pod placement
    ring = T.ring(8)
    with pytest.raises(ValueError, match="leader"):
        split_topology(ring, T.hierarchical_layout(8, 4))


# ----------------------------------------------------------------------
# leader self-edge regression (ISSUE 3 satellite): a leader belongs to
# both sets it is wired from (pod members ∪ leader clique) — its own
# id must enter its row exactly once, for odd and even pod sizes, or
# its plane is double-counted in every eq. 4 sum.
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,pod_size", [(9, 3), (15, 5), (8, 4),
                                        (12, 3)])
def test_hierarchical_leader_self_edge_counted_once(n, pod_size):
    topo = T.hierarchical(n, pod_size)
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    for i in range(n):
        srcs = nbr[i][mask[i]].tolist()
        assert len(set(srcs)) == len(srcs), \
            f"duplicate source in dst {i}'s neighbor list: {srcs}"
        assert srcs.count(i) == 1
    # the eq. 4 adjacency the combine actually contracts with: every
    # (src, dst) weight is 0 or 1 — a duplicated self-edge would put a
    # 2 on a leader's diagonal
    A, k = nbr.shape
    src = nbr.reshape(-1)
    seg = np.repeat(np.arange(A), k)
    M = np.zeros((A, A))
    np.add.at(M, (src, seg), mask.reshape(-1).astype(float))
    assert M.max() == 1.0
    np.testing.assert_array_equal(np.diag(M), np.ones(A))


def test_duplicate_neighbor_list_is_rejected():
    with pytest.raises(ValueError, match="double-counts"):
        T._from_neighbor_lists([[0, 1, 1], [0, 1]])


# ----------------------------------------------------------------------
# leader reachability property (hypothesis — mirrored by the
# no-hypothesis conftest shim): every agent's knowledge reaches a
# leader in <= 1 intra-pod hop, i.e. each agent is an in-neighbor of
# its pod's leader.
# ----------------------------------------------------------------------
@given(st.integers(1, 6), st.integers(1, 6))
def test_every_agent_reaches_a_leader_in_one_intra_pod_hop(pods,
                                                           pod_size):
    n = pods * pod_size
    topo = T.hierarchical(n, pod_size)
    lay = T.hierarchical_layout(n, pod_size)
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    for i in range(n):
        leader = int(lay.leaders[lay.pod_id[i]])
        in_nbrs = set(nbr[leader][mask[leader]].tolist())
        assert i in in_nbrs, \
            f"agent {i} cannot reach its leader {leader} in one hop"
        assert lay.pod_id[i] == lay.pod_id[leader]


# ----------------------------------------------------------------------
# cross-pod traffic accounting: O(pods · k_leader · |params|), not
# O(n · k · |params|)
# ----------------------------------------------------------------------
def test_cross_pod_bytes_scale_with_pods_not_agents():
    P = 10_000
    # fixed pods, growing pod size: dispatched traffic is constant,
    # flat traffic grows with n · k
    base = cross_pod_bytes(split_topology(*_hier(4 * 4, 4)), P)
    for pod_size in (8, 16):
        topo, lay = _hier(4 * pod_size, pod_size)
        assert cross_pod_bytes(split_topology(topo, lay), P) == base
    assert (flat_exchange_bytes(_hier(4 * 16, 16)[0], P)
            > 3 * flat_exchange_bytes(_hier(4 * 4, 4)[0], P))
    # growing pods at fixed pod size: dispatched traffic is linear in
    # the directed leader edge count pods · (pods − 1)
    got = []
    for pods in (2, 4, 8):
        topo, lay = _hier(pods * 4, 4)
        got.append(cross_pod_bytes(split_topology(topo, lay), P))
    per_edge = got[0] // (2 * 1)
    assert got == [pods * (pods - 1) * per_edge for pods in (2, 4, 8)]
    # and the dispatched path undercuts the flat one
    topo, lay = _hier(32, 4)
    assert cross_pod_bytes(split_topology(topo, lay), P) \
        < flat_exchange_bytes(topo, P)


# ----------------------------------------------------------------------
# reference decomposition vs the flat combine
# ----------------------------------------------------------------------
def test_reference_dispatch_one_pod_is_bitwise_combine_topo():
    """The equivalence oracle that makes the refactor safe: with one
    pod the leader segment vanishes statically and the dispatched
    combine is the *same computation* as ``_combine_topo`` — bitwise,
    not just close."""
    rng = np.random.default_rng(0)
    topo, lay = _hier(8, 8)
    know = _rand_knowledge(rng, 8, 7)
    ref = _combine_topo(know, topo)
    got = make_pod_dispatch(topo, lay)(know)
    np.testing.assert_array_equal(np.asarray(ref["w"]),
                                  np.asarray(got["w"]))


@pytest.mark.parametrize("n,pod_size,rel_seed", [
    (8, 4, None), (12, 4, None), (8, 2, 3), (12, 3, 5),
])
def test_reference_dispatch_matches_combine_topo(n, pod_size,
                                                 rel_seed):
    rng = np.random.default_rng(1)
    topo, lay = _hier(n, pod_size, rel_seed)
    know = _rand_knowledge(rng, n, 6)
    ref = _combine_topo(know, topo)
    got = make_pod_dispatch(topo, lay)(know)
    np.testing.assert_allclose(np.asarray(ref["w"]),
                               np.asarray(got["w"]), rtol=1e-5,
                               atol=1e-6)


def test_reference_dispatch_traced_relevance_override():
    """The learned-R path feeds a *traced* per-edge table — the
    dispatch must accept it as an argument (not a baked constant) and
    match the flat combine with the same override."""
    rng = np.random.default_rng(2)
    topo, lay = _hier(8, 4)
    know = _rand_knowledge(rng, 8, 5)
    rel = jnp.asarray(rng.uniform(0.1, 1.0, (8, topo.degree)),
                      jnp.float32)
    rel = jnp.where(topo.mask, rel, 0.0)
    combine = make_pod_dispatch(topo, lay)
    got = jax.jit(lambda k, r: combine(k, r))(know, rel)
    ref = _combine_topo(know, topo._replace(relevance=rel))
    np.testing.assert_allclose(np.asarray(ref["w"]),
                               np.asarray(got["w"]), rtol=1e-5,
                               atol=1e-6)


# ----------------------------------------------------------------------
# GroupSpec wiring
# ----------------------------------------------------------------------
def test_groupspec_pod_validation():
    GroupSpec(n_agents=8, topology="hierarchical", degree=4, pods=2)
    with pytest.raises(ValueError, match="pods"):
        GroupSpec(n_agents=8, pods=-1)
    with pytest.raises(ValueError, match="hierarchical"):
        GroupSpec(n_agents=8, topology="ring", pods=2)
    with pytest.raises(ValueError, match="pods \\* degree"):
        GroupSpec(n_agents=8, topology="hierarchical", degree=4,
                  pods=3)
    with pytest.raises(ValueError, match="pod_axis"):
        GroupSpec(n_agents=8, topology="hierarchical", degree=4,
                  pods=2, pod_axis="agent")
    with pytest.raises(ValueError, match="pod_axis"):
        GroupSpec(n_agents=8, topology="hierarchical", degree=4,
                  pods=2, pod_axis="")


def _toy_train_state(A, p, opt, seed=0):
    from repro.core.sharded_ddal import TrainState, init_knowledge
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(A, p)), jnp.float32)}
    return TrainState(params=params,
                      opt_state=jax.vmap(opt.init)(params),
                      know=init_knowledge(params),
                      step=jnp.zeros((), jnp.int32))


def _toy_step(spec, opt, mesh=None):
    from repro.core.sharded_ddal import make_group_train_step

    def loss_fn(params, batch):
        return jnp.mean((params["w"] - batch["x"]) ** 2)

    return jax.jit(make_group_train_step(
        None, spec, opt, loss_fn=loss_fn, mesh=mesh))


def _run_toy(spec, opt, steps=6, mesh=None, seed=0):
    step = _toy_step(spec, opt, mesh)
    state = _toy_train_state(spec.n_agents, 5, opt, seed)
    rng = np.random.default_rng(7)
    shared = 0
    for _ in range(steps):
        batch = {"x": jnp.asarray(
            rng.normal(size=(spec.n_agents, 5)), jnp.float32)}
        state, m = step(state, batch)
        shared += int(m["shared"])
    return state, shared


def test_train_step_pod_dispatch_matches_flat_path():
    """The full streaming train step with ``spec.pods > 0`` (reference
    decomposition, no mesh) stays numerically on the flat path's
    trajectory through warm-up and share steps."""
    from repro import optim
    opt = optim.sgd(0.1)
    flat = GroupSpec(n_agents=8, threshold=2, minibatch=2,
                     topology="hierarchical", degree=4)
    pod = GroupSpec(n_agents=8, threshold=2, minibatch=2,
                    topology="hierarchical", degree=4, pods=2)
    s_flat, shared_flat = _run_toy(flat, opt)
    s_pod, shared_pod = _run_toy(pod, opt)
    assert shared_flat == shared_pod and shared_pod >= 1
    np.testing.assert_allclose(np.asarray(s_flat.params["w"]),
                               np.asarray(s_pod.params["w"]),
                               rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------------
# the real collectives, on 8 simulated devices
# ----------------------------------------------------------------------
@pytest.mark.multi_device
def test_sharded_dispatch_one_pod_bitwise_on_mesh(multi_device):
    """Acceptance oracle: on a (1, 8) ``("pod", "agent")`` mesh the
    dispatched path — all_gather over the agent axis, zero pod-axis
    collectives — is bitwise identical to the flat single-mesh
    ``_combine_topo``."""
    from repro.launch.mesh import make_pod_mesh
    rng = np.random.default_rng(0)
    mesh = make_pod_mesh(1)
    assert dict(mesh.shape) == {"pod": 1, "agent": 8}
    topo, lay = _hier(8, 8)
    know = _rand_knowledge(rng, 8, 5)
    ref = _combine_topo(know, topo)
    combine = make_pod_dispatch(topo, lay, mesh=mesh)
    got = jax.jit(combine)(know)
    np.testing.assert_array_equal(np.asarray(ref["w"]),
                                  np.asarray(got["w"]))


@pytest.mark.multi_device
@pytest.mark.parametrize("pods,rel_seed", [(2, None), (2, 11),
                                           (4, None), (4, 13)])
def test_sharded_dispatch_matches_flat_on_mesh(multi_device, pods,
                                               rel_seed):
    """Multi-pod meshes, both leader-exchange lowerings: the psum
    fast path (uniform leader clique, ``rel_seed=None``) and the
    weighted ppermute edge-list chain — against the flat oracle."""
    from repro.launch.mesh import make_pod_mesh
    rng = np.random.default_rng(4)
    mesh = make_pod_mesh(pods)
    topo, lay = _hier(8, 8 // pods, rel_seed)
    know = _rand_knowledge(rng, 8, 6)
    ref = _combine_topo(know, topo)
    combine = make_pod_dispatch(topo, lay, mesh=mesh)
    got = jax.jit(combine)(know)
    np.testing.assert_allclose(np.asarray(ref["w"]),
                               np.asarray(got["w"]), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.multi_device
def test_sharded_dispatch_traced_override_on_mesh(multi_device):
    """Regression: a traced per-edge relevance override must disable
    the psum fast path even when the *static* table is uniform (the
    learned-R path hits exactly this — uniform prior, traced
    override), taking the weighted ppermute chain instead."""
    from repro.launch.mesh import make_pod_mesh
    rng = np.random.default_rng(9)
    mesh = make_pod_mesh(2)
    topo, lay = _hier(8, 4)              # uniform static relevance
    know = _rand_knowledge(rng, 8, 5)
    rel = jnp.asarray(rng.uniform(0.1, 1.0, (8, topo.degree)),
                      jnp.float32)
    rel = jnp.where(topo.mask, rel, 0.0)
    combine = make_pod_dispatch(topo, lay, mesh=mesh)
    got = jax.jit(lambda k, r: combine(k, r))(know, rel)
    ref = _combine_topo(know, topo._replace(relevance=rel))
    np.testing.assert_allclose(np.asarray(ref["w"]),
                               np.asarray(got["w"]), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.multi_device
def test_train_step_pod_dispatch_on_mesh(multi_device):
    """End-to-end: the jitted streaming DDAL step with the shard_map
    combine on a (2, 4) mesh tracks the flat path's trajectory."""
    from repro import optim
    from repro.launch.mesh import make_pod_mesh
    opt = optim.sgd(0.1)
    mesh = make_pod_mesh(2)
    flat = GroupSpec(n_agents=8, threshold=1, minibatch=2,
                     topology="hierarchical", degree=4)
    pod = GroupSpec(n_agents=8, threshold=1, minibatch=2,
                    topology="hierarchical", degree=4, pods=2)
    s_flat, shared_flat = _run_toy(flat, opt)
    s_pod, shared_pod = _run_toy(pod, opt, mesh=mesh)
    assert shared_flat == shared_pod and shared_pod >= 2
    np.testing.assert_allclose(np.asarray(s_flat.params["w"]),
                               np.asarray(s_pod.params["w"]),
                               rtol=1e-5, atol=1e-6)
