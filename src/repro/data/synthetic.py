"""Deterministic per-agent synthetic token streams.

In GARL every agent has its *own* environment; at LLM scale an agent's
environment is its data stream (DESIGN.md §3). Streams are pure
functions of (seed, agent_id, step) so they are reproducible, jit-safe
and shardable from hosts without coordination.

Two generators:

* ``lm_stream`` — structured language-model data: tokens follow a
  per-agent randomly-drawn order-1 Markov chain over the vocab, so
  next-token prediction is genuinely learnable (loss drops well below
  log V) and *different agents see different transition matrices* —
  the heterogeneous-environments setting of the paper. A shared
  ``similarity`` knob interpolates every agent's chain toward a common
  one (the paper's "neighbourhoods of the same city").
* ``uniform_stream`` — i.i.d. uniform tokens (for pure-throughput
  benches where learnability is irrelevant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    seed: int = 0
    kind: str = "markov"         # markov | uniform
    n_states: int = 64           # markov chain order-1 state count
    similarity: float = 0.5      # 0 = fully per-agent, 1 = identical
    branch: int = 4              # out-degree of each markov state


def _agent_key(spec: StreamSpec, agent_id, step):
    key = jax.random.PRNGKey(spec.seed)
    key = jax.random.fold_in(key, agent_id)
    return jax.random.fold_in(key, step)


def _markov_table(spec: StreamSpec, vocab: int, agent_id) -> jnp.ndarray:
    """(n_states, branch) successor table, blended between a shared
    table and a per-agent one by ``similarity``."""
    n = min(spec.n_states, vocab)
    shared = jax.random.randint(
        jax.random.PRNGKey(spec.seed ^ 0x5EED), (n, spec.branch), 0, n)
    local = jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(spec.seed), agent_id),
        (n, spec.branch), 0, n)
    pick_shared = jax.random.bernoulli(
        jax.random.PRNGKey(spec.seed ^ 0xB1E0D), spec.similarity,
        (n, spec.branch))
    return jnp.where(pick_shared, shared, local)


def _markov_tokens(spec: StreamSpec, vocab: int, agent_id, step,
                   batch: int, seq: int) -> jnp.ndarray:
    n = min(spec.n_states, vocab)
    table = _markov_table(spec, vocab, agent_id)        # (n, branch)
    key = _agent_key(spec, agent_id, step)
    k0, kb = jax.random.split(key)
    s0 = jax.random.randint(k0, (batch,), 0, n)
    branches = jax.random.randint(kb, (batch, seq), 0, spec.branch)

    def body(s, br):
        nxt = table[s, br]
        return nxt, nxt

    _, toks = jax.lax.scan(body, s0, branches.T)
    return toks.T.astype(jnp.int32)                     # (batch, seq)


def make_agent_batch(cfg: ArchConfig, shape: ShapeConfig,
                     spec: StreamSpec, agent_id, step
                     ) -> Dict[str, Any]:
    """One training batch for one agent — matches
    ``repro.models.input_specs(cfg, shape)`` exactly."""
    B, S = shape.global_batch, shape.seq_len
    cdt = cfg.dtype("compute")
    E = cfg.d_model
    key = _agent_key(spec, agent_id, step)

    def toks(b, s, sub):
        if spec.kind == "markov":
            return _markov_tokens(spec, cfg.vocab_size, agent_id,
                                  step * 131 + sub, b, s)
        return jax.random.randint(jax.random.fold_in(key, sub),
                                  (b, s), 0, cfg.vocab_size, jnp.int32)

    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.family == "audio":
        # MusicGen delay pattern (arXiv:2306.05284 §2.2): codebook c
        # is shifted right by c frames so step t predicts codebook c
        # of frame t-c — parallel sampling with RVQ causality kept.
        # Token 0 doubles as the delay-pad BOS.
        frames = jnp.stack([toks(B, S, c) % cfg.vocab_size
                            for c in range(cfg.n_codebooks)], axis=1)
        t = jnp.stack(
            [jnp.pad(frames[:, c, :S - c], ((0, 0), (c, 0)))
             for c in range(cfg.n_codebooks)], axis=1)
        # delay-pad positions (t < c) carry no loss
        cb = jnp.arange(cfg.n_codebooks)[None, :, None]
        pidx = jnp.arange(S)[None, None, :]
        labels = jnp.where(pidx < cb, -100, t)
        cond = (jax.random.normal(jax.random.fold_in(key, 7),
                                  (B, cfg.cond_len, E), jnp.float32)
                * 0.02).astype(cdt)
        return {"tokens": t, "labels": labels, "positions": pos,
                "cond": cond}
    if cfg.family == "vlm":
        vp = cfg.vision_prefix
        t = toks(B, S - vp, 0)
        vision = (jax.random.normal(jax.random.fold_in(key, 7),
                                    (B, vp, E), jnp.float32)
                  * 0.02).astype(cdt)
        full = jnp.concatenate(
            [jnp.zeros((B, vp), jnp.int32), t], axis=1)
        labels = full.at[:, :vp].set(-100)
        pos3 = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                (B, 3, S))
        return {"tokens": t, "vision": vision, "labels": labels,
                "positions": pos3}
    t = toks(B, S, 0)
    return {"tokens": t, "labels": t, "positions": pos}


def make_group_batch(cfg: ArchConfig, shape: ShapeConfig,
                     spec: StreamSpec, n_agents: int, step
                     ) -> Dict[str, Any]:
    """Stacked (n_agents, ...) batch — each agent's own stream."""
    batches = [make_agent_batch(cfg, shape, spec, a, step)
               for a in range(n_agents)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *batches)
