"""Data pipeline: deterministic per-agent synthetic streams (each
agent = its own environment) + host-sharded placement."""
from repro.data.sharded import device_put_sharded_batch  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    StreamSpec,
    make_agent_batch,
    make_group_batch,
)
