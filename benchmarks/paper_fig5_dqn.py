"""Paper Fig. 5 — DDADQN: single double-dueling-DQN agent vs 2-agent
group on CartPole-v0.

Paper claims reproduced: the single DQN fluctuates hard early but
eventually converges; the 2-agent group (sharing from 3k of 7k,
minibatch 1000 in the paper — scaled here) converges faster and with
fewer/smaller fluctuations after the first shared update.
"""
from __future__ import annotations


from benchmarks.common import run_dqn_group, sparkline


def main(epochs: int = 4_000, seed: int = 0, verbose: bool = True):
    threshold = int(epochs * 0.43)            # paper: 3k of ~7k
    minibatch = max(50, epochs // 10)         # paper: 1000 of 7k
    single = run_dqn_group(1, epochs, threshold=epochs + 1, seed=seed)
    group = run_dqn_group(2, epochs, threshold=threshold,
                          minibatch=minibatch, seed=seed)

    if verbose:
        print(single.summary("fig5a single-agent DQN"))
        print("  " + sparkline(single.rewards[:, 0]))
        print(group.summary(
            f"fig5bc DDADQN 2-agent (share@{threshold}, "
            f"minibatch={minibatch})"))
        for a in range(2):
            print("  " + sparkline(group.rewards[:, a]))

    s_tail, g_tail = single.tail(), group.tail()
    checks = {
        "group tail-mean >= single tail-mean - 5":
            float(g_tail.mean()) >= float(s_tail.mean()) - 5.0,
        "group tail fluctuation <= single":
            float(g_tail.std(axis=0).mean())
            <= float(s_tail.std(axis=0).mean()) + 1e-6,
    }
    if verbose:
        for k, v in checks.items():
            print(f"  [{'PASS' if v else 'FAIL'}] {k}")
    return {"single": single, "group": group, "checks": checks}


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--epochs", type=int, default=4_000)
    p.add_argument("--full", action="store_true",
                   help="paper scale (7k epochs, minibatch 1000)")
    p.add_argument("--seed", type=int, default=0)
    a = p.parse_args()
    main(7_000 if a.full else a.epochs, a.seed)
