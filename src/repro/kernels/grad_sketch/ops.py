"""jit'd wrappers for the gradient-sketch projection.

``sketch_pytree`` is the production entry point: it streams a stacked
gradient pytree (leaves (n, *param)) leaf-by-leaf into one (n, d)
sketch, with offsets advancing by true leaf size so the result equals
projecting the flat concatenation — which is never materialised.

Implementation selection (``impl``):

* ``"auto"``    — Pallas kernel on TPU (one HBM pass, signs
  regenerated in VMEM), tiled XLA elsewhere. The CPU/GPU tiled path
  is the same algorithm at XLA level: per-leaf chunks of
  ``block`` positions, one (block, d) sign block live at a time.
* ``"pallas"`` / ``"pallas_interpret"`` — force the kernel
  (interpret mode runs it off-TPU; the kernel-vs-oracle tests use
  this).
* ``"xla"``     — force the tiled XLA path.

Small leaves (< one kernel tile) always take the jnp reference — the
launch overhead would dominate and XLA fuses them anyway. Leaves and
sketch dims that don't meet the kernel's lane alignment (d % 128)
fall back to the tiled XLA path rather than failing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.grad_sketch import ref
from repro.kernels.grad_sketch.kernel import (
    DEFAULT_ROWS,
    LANES,
    sign_block_i8,
    sketch_flat,
)

_MIN_KERNEL_SIZE = DEFAULT_ROWS * LANES
# XLA-path chunk: one (block, d) int8 sign block is the only
# projection intermediate ever live — block·d bytes (1 MB at
# d = 256; was 4 MB fp32 before the bit-pack).
DEFAULT_BLOCK = 4096
# beyond this many chunks per leaf, roll the walk into a fori_loop —
# unrolled static slices fuse (and run) better, but jaxpr size must
# stay bounded for LLM-scale leaves
_MAX_UNROLL = 64

IMPLS = ("auto", "pallas", "pallas_interpret", "xla")


def _resolve(impl: str) -> str:
    if impl not in IMPLS:
        raise ValueError(f"unknown sketch impl {impl!r}; expected one "
                         f"of {IMPLS}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _xla_sketch_flat(G: jnp.ndarray, seed, dim: int, offset: int = 0,
                     block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Tiled XLA projection: walk ``block``-position chunks of G so
    only one (block, d) sign block exists at a time — generated as an
    **int8** ±1 matrix (``sign_block_i8``), 1 B/sign instead of 4,
    with the fp32 cast fused into the dot; ±1 is exact either way, so
    the sketch is bitwise the fp32-sign oracle's. Few-tile leaves
    unroll (static slices fuse best); beyond ``_MAX_UNROLL`` tiles
    the loop rolls into a ``fori_loop`` so program size stays O(1)
    however large the leaf (a 4e8-position embedding would otherwise
    unroll ~1e5 dot equations into the jaxpr). The short tail chunk
    is one static trailing step: the sign stream is positional, so no
    padding copy of G is ever made."""
    n, p = G.shape
    tiles, tail = divmod(p, block)
    acc = jnp.zeros((n, dim), jnp.float32)

    def chunk(a, start, width):
        g = jax.lax.slice_in_dim(G, start, start + width, axis=1)
        s = sign_block_i8(seed, offset + start, width, dim)
        return a + jnp.dot(g.astype(jnp.float32),
                           s.astype(jnp.float32),
                           preferred_element_type=jnp.float32)

    if tiles <= _MAX_UNROLL:
        for t in range(tiles):
            acc = chunk(acc, t * block, block)
    else:
        def body(i, a):
            g = jax.lax.dynamic_slice_in_dim(G, i * block, block,
                                             axis=1)
            s = sign_block_i8(seed, offset + i * block, block, dim)
            return a + jnp.dot(g.astype(jnp.float32),
                               s.astype(jnp.float32),
                               preferred_element_type=jnp.float32)
        acc = jax.lax.fori_loop(0, tiles, body, acc)
    if tail:
        acc = chunk(acc, tiles * block, tail)
    return acc


def sketch_leaf(x: jnp.ndarray, seed, dim: int, offset: int = 0, *,
                impl: str = "auto") -> jnp.ndarray:
    """One leaf (n, *param) → its (n, d) sketch contribution."""
    n = x.shape[0]
    p = int(x.size) // n
    G = jnp.reshape(x, (n, p))
    mode = _resolve(impl)
    if p < _MIN_KERNEL_SIZE:
        return ref.sketch_flat(G, seed, dim, offset=offset)
    if mode.startswith("pallas") and dim % LANES == 0:
        return sketch_flat(G, seed, dim, offset=offset,
                           interpret=mode == "pallas_interpret")
    return _xla_sketch_flat(G, seed, dim, offset=offset)


def sketch_pytree(grads, seed, dim: int, *,
                  impl: str = "auto") -> jnp.ndarray:
    """Stream a stacked gradient pytree into its (n, d) sketch in one
    pass — the (n, P) concat is never built. ``seed`` may be traced;
    the sketch is a deterministic pure function of (seed, grads)."""
    leaves = jax.tree.leaves(grads)
    if not leaves:
        raise ValueError("sketch_pytree needs at least one leaf")
    n = leaves[0].shape[0]
    acc = jnp.zeros((n, dim), jnp.float32)
    offset = 0
    for x in leaves:
        acc = acc + sketch_leaf(x, seed, dim, offset, impl=impl)
        offset += int(x.size) // n
    return acc
