"""Unit + property tests for the paper's core contribution: eq. 4
weighting, knowledge stores / delay lines, the DDAL loop semantics
(warm-up, cadence, asynchrony) and the DP-equivalence theorem of
DESIGN.md §3."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from repro.common.pytree import tree_map, tree_weighted_sum
from repro.configs.base import GroupSpec
from repro.core import DDAL, knowledge as K
from repro.core.weighting import (eq4_weights, relevance_matrix,
                                  training_experience)

# ----------------------------------------------------------------------
# eq. 4 weighting — properties
# ----------------------------------------------------------------------
pos_floats = st.floats(min_value=1e-3, max_value=1e3,
                       allow_nan=False, allow_infinity=False)


@given(st.lists(st.tuples(pos_floats, pos_floats), min_size=1,
                max_size=16))
def test_eq4_weights_are_convex(tr):
    """w_j = ½(T̂_j + R̂_j) ≥ 0 and Σw = 1 (a convex combination)."""
    T = jnp.asarray([t for t, _ in tr])
    R = jnp.asarray([r for _, r in tr])
    w = eq4_weights(T, R)
    assert np.all(np.asarray(w) >= 0)
    np.testing.assert_allclose(float(jnp.sum(w)), 1.0, rtol=1e-5)


@given(st.lists(pos_floats, min_size=2, max_size=12), pos_floats)
def test_eq4_scale_invariance(ts, scale):
    """Scaling all T (or all R) leaves the weights unchanged — only
    relative experience/relevance matters."""
    T = jnp.asarray(ts)
    R = jnp.ones_like(T)
    w1 = eq4_weights(T, R)
    w2 = eq4_weights(T * scale, R)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-4, atol=1e-6)


@given(st.integers(2, 10))
def test_eq4_uniform_reduces_to_mean(m):
    """Uniform T and R ⇒ plain average (the DP limit)."""
    T = jnp.ones((m,))
    w = eq4_weights(T, T)
    np.testing.assert_allclose(np.asarray(w), np.full(m, 1.0 / m),
                               rtol=1e-6)


def test_eq4_monotone_in_T():
    """More training experience ⇒ no smaller weight."""
    T = jnp.asarray([1.0, 2.0, 8.0])
    R = jnp.ones((3,))
    w = np.asarray(eq4_weights(T, R))
    assert w[0] < w[1] < w[2]


def test_eq4_invalid_pieces_get_zero():
    T = jnp.asarray([5.0, 3.0, 7.0])
    R = jnp.ones((3,))
    valid = jnp.asarray([True, False, True])
    w = np.asarray(eq4_weights(T, R, valid))
    assert w[1] == 0.0
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)


@given(st.integers(1, 8), st.integers(3, 30))
def test_weighted_sum_matches_manual(m, n):
    key = jax.random.PRNGKey(m * 100 + n)
    G = jax.random.normal(key, (m, n))
    T = jax.random.uniform(jax.random.fold_in(key, 1), (m,)) + 0.1
    R = jax.random.uniform(jax.random.fold_in(key, 2), (m,)) + 0.1
    w = eq4_weights(T, R)
    got = tree_weighted_sum({"g": G}, w)["g"]
    Th = T / T.sum()
    Rh = R / R.sum()
    want = 0.5 * (jnp.einsum("m,mn->n", Th, G)
                  + jnp.einsum("m,mn->n", Rh, G))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_training_experience_modes():
    assert float(training_experience(9, "epochs")) == 9.0
    assert float(training_experience(9, "sqrt")) == 3.0
    assert float(training_experience(9, "uniform")) == 1.0
    assert float(training_experience(0, "epochs")) == 1.0  # floor


def test_relevance_matrix_topologies():
    Rf = relevance_matrix(4, "uniform")
    assert np.all(np.asarray(Rf) == 1.0)
    Rr = np.asarray(relevance_matrix(5, "ring"))
    # each agent reaches itself and its two ring neighbours only
    assert Rr.sum() == 5 * 3
    assert np.all(np.diag(Rr) == 1.0)


# ----------------------------------------------------------------------
# knowledge store (ring buffer) semantics
# ----------------------------------------------------------------------
def _store(m):
    return K.make_store({"g": jnp.zeros((3,))}, m)


def test_store_append_and_average():
    st_ = _store(4)
    for i in range(3):
        st_ = K.append(st_, {"g": jnp.full((3,), float(i + 1))},
                       T=float(i + 1), R=1.0)
    g, wsum = K.weighted_average(st_)
    # T weights 1,2,3 → T̂=(1/6,2/6,3/6); R uniform → R̂=1/3 each
    w = 0.5 * (jnp.asarray([1, 2, 3]) / 6.0 + 1.0 / 3.0)
    want = float(jnp.sum(w * jnp.asarray([1.0, 2.0, 3.0])))
    np.testing.assert_allclose(np.asarray(g["g"]), np.full(3, want),
                               rtol=1e-6)
    assert float(wsum) > 0


def test_store_ring_overwrite():
    """m+1 appends overwrite the oldest piece (K_i holds last m)."""
    st_ = _store(2)
    for i in range(3):
        st_ = K.append(st_, {"g": jnp.full((3,), float(i))},
                       T=1.0, R=1.0)
    g, _ = K.weighted_average(st_)
    # slots now hold pieces 1 and 2 → mean = 1.5
    np.testing.assert_allclose(np.asarray(g["g"]), np.full(3, 1.5),
                               rtol=1e-6)


def test_store_disabled_append_is_noop():
    st_ = _store(2)
    st2 = K.append(st_, {"g": jnp.ones((3,))}, T=1.0, R=1.0,
                   enabled=False)
    assert int(st2.ptr) == 0
    assert not bool(st2.valid.any())


def test_empty_store_average_is_zero():
    g, wsum = K.weighted_average(_store(3))
    np.testing.assert_array_equal(np.asarray(g["g"]), np.zeros(3))
    assert float(wsum) == 0.0


# ----------------------------------------------------------------------
# DDAL loop semantics on a toy quadratic "agent"
# ----------------------------------------------------------------------
def _toy_ddal(spec, delay=None):
    """Agent state = scalar params θ; 'gradient' = θ - agent_id (each
    agent pulls toward its own target id), lr = 1."""
    def gen_grads(state, key):
        del key
        g = {"w": state["w"] - state["target"]}
        return g, {"w": state["w"]}, state

    def apply_grads(state, g):
        return {"w": state["w"] - 0.5 * g["w"],
                "target": state["target"]}

    def params_of(state):
        return {"w": state["w"]}

    return DDAL(spec, gen_grads, apply_grads, params_of, delay=delay)


def _toy_states(n):
    return {"w": jnp.zeros((n,)),
            "target": jnp.arange(n, dtype=jnp.float32)}


def test_ddal_warmup_is_independent():
    """Before the threshold no knowledge flows: each agent optimises
    its own objective exactly as a lone learner."""
    spec = GroupSpec(n_agents=3, threshold=100, minibatch=1, m_pieces=4)
    ddal = _toy_ddal(spec)
    gs = ddal.init(_toy_states(3))
    gs, _ = jax.jit(lambda g, k: ddal.run(g, k, 10))(
        gs, jax.random.PRNGKey(0))
    w = np.asarray(gs.agent_states["w"])
    expect = np.arange(3) * (1 - 0.5 ** 10)
    np.testing.assert_allclose(w, expect, rtol=1e-5)
    assert not bool(np.asarray(gs.stores.valid).any())


def test_ddal_sharing_mixes_knowledge():
    """After the threshold, agents' updates blend others' gradients —
    with symmetric targets the group average pulls everyone together."""
    spec = GroupSpec(n_agents=2, threshold=0, minibatch=1, m_pieces=4)
    ddal = _toy_ddal(spec)
    gs = ddal.init(_toy_states(2))
    gs, _ = jax.jit(lambda g, k: ddal.run(g, k, 30))(
        gs, jax.random.PRNGKey(0))
    w = np.asarray(gs.agent_states["w"])
    # both agents see the same averaged gradient ⇒ identical params,
    # converging to the average target 0.5
    np.testing.assert_allclose(w[0], w[1], rtol=1e-5)
    np.testing.assert_allclose(w, [0.5, 0.5], atol=1e-2)


def test_ddal_minibatch_cadence():
    """Group updates happen only every ``minibatch`` epochs (line 11)."""
    spec = GroupSpec(n_agents=2, threshold=0, minibatch=5, m_pieces=8)
    ddal = _toy_ddal(spec)
    gs = ddal.init(_toy_states(2))
    traj = []
    step = jax.jit(ddal.epoch_step)
    for e in range(11):
        keys = jax.random.split(jax.random.PRNGKey(e), 2)
        gs, m = step(gs, keys)
        traj.append(np.asarray(m["w"]))
    traj = np.stack(traj)             # (11, 2) params BEFORE each epoch
    # changed[e] ⇔ an update was applied during epoch e; updates land
    # only at e % 5 == 0
    changed = np.any(np.diff(traj, axis=0) != 0, axis=1)
    assert changed[0] and changed[5]
    assert not np.any(changed[[1, 2, 3, 4, 6, 7, 8, 9]])


def test_ddal_delay_defers_knowledge():
    """A piece sent at epoch t with delay d arrives at t+d — before
    that the receiving store holds only the sender-free view."""
    delay = jnp.asarray([[0, 3], [3, 0]], jnp.int32)
    spec = GroupSpec(n_agents=2, threshold=0, minibatch=1, m_pieces=8)
    ddal = _toy_ddal(spec, delay=delay)
    gs = ddal.init(_toy_states(2))
    step = jax.jit(ddal.epoch_step)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    gs, _ = step(gs, keys)            # epoch 0: own piece arrives now
    # store 0 has exactly 1 valid piece (its own); the peer's is in
    # flight for 3 more epochs
    assert int(gs.stores.valid[0].sum()) == 1
    for e in range(1, 4):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), 2))
    # epoch 3 delivered the piece agent 1 sent at epoch 0
    assert int(gs.stores.valid[0].sum()) >= 2


# ----------------------------------------------------------------------
# DP-equivalence of the pod-scale streaming trainer (DESIGN.md §3)
# ----------------------------------------------------------------------
def test_streaming_ddal_equals_data_parallel():
    """threshold=0, minibatch=1, uniform weights, delay 0 ⇒ the DDAL
    update IS the plain gradient mean — synchronous data parallelism."""
    from repro import optim
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.core import init_train_state, make_group_train_step
    from repro.data import StreamSpec, make_group_batch
    from repro.models import get_model

    cfg = get_arch_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    opt = optim.sgd(0.1)
    shape = ShapeConfig("t", 32, 2, "train")
    spec = GroupSpec(n_agents=2, threshold=0, minibatch=1,
                     t_weighting="uniform", r_weighting="uniform",
                     knowledge_mode="streaming")
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, spec, opt, key)
    # both agents start from identical params
    p0 = tree_map(lambda x: x[0], state.params)
    state = state._replace(
        params=tree_map(lambda x: jnp.stack([x, x]), p0))
    batch = make_group_batch(cfg, shape, StreamSpec(), 2, 0)

    step = jax.jit(make_group_train_step(cfg, spec, opt))
    new_state, metrics = step(state, batch)
    assert int(metrics["shared"]) == 1

    # manual DP step: mean of the two agents' gradients
    g0 = jax.grad(lambda p: model.loss(cfg, p, tree_map(
        lambda x: x[0], batch)))(p0)
    g1 = jax.grad(lambda p: model.loss(cfg, p, tree_map(
        lambda x: x[1], batch)))(p0)
    gmean = tree_map(lambda a, b: 0.5 * (a + b), g0, g1)
    want, _ = opt.update(gmean, opt.init(p0), p0,
                         jnp.zeros((), jnp.int32))
    got = tree_map(lambda x: x[0], new_state.params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5),
        got, want)
    # and both agents ended identical
    jax.tree.map(lambda x: np.testing.assert_allclose(
        np.asarray(x[0]), np.asarray(x[1]), rtol=1e-6),
        new_state.params)
