"""Pallas-TPU kernel for the streaming gradient-sketch projection.

The op projects a stacked per-agent gradient matrix G: (n, P) through
a seeded random ±1 (Rademacher / sign-JL) matrix S: (P, d) into a
small sketch G·S: (n, d). At LLM scale the projection is
HBM-bandwidth-bound exactly like the eq. 4 contraction: the win is
reading G **once**. The kernel walks (n, ROWS·128) slabs of G through
VMEM, *regenerates* the matching (tile, d) sign block from a
counter-based hash — the sign matrix is never stored anywhere, in HBM
or elsewhere — and accumulates the (n, d) sketch tile in place across
the sequential grid. HBM traffic is one pass over G plus one (n, d)
write: the streaming floor.

Signs are a pure function of ``(seed, global position, sketch dim)``
(``sign_block``), so the sketch is independent of tiling, identical
between this kernel, the tiled XLA fallback and the jnp oracle
(``ref.py``), and — because the projection is linear and the signs
depend only on position — sketches of gradient *sums* equal sums of
sketches, which is what lets the streaming trainer carry an (n, d)
window sketch instead of re-deriving it from the accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_ROWS = 8               # tile = 8·128 = 1024 positions per step

# xxhash/murmur-style 32-bit mixing constants (wrap-around uint32
# arithmetic; both the kernel and the jnp reference run these exact
# ops, so every path sees the same sign stream). Single source of
# truth — ``repro.core.relevance.fold_seed`` mixes round indices with
# the same constants.
MIX_CONSTANTS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D)
_P1, _P2, _P3 = MIX_CONSTANTS


def _sign_bits(seed, start, count: int, dim: int) -> jnp.ndarray:
    """The raw sign bits (uint32 ∈ {0, 1}) behind ``sign_block``:
    hash ``(seed, global position, sketch dim)`` and keep the top bit.
    Shared by every width the sign stream is materialised at, so all
    of them agree bit for bit."""
    pos = jax.lax.broadcasted_iota(jnp.int32, (count, dim), 0)
    dimi = jax.lax.broadcasted_iota(jnp.int32, (count, dim), 1)
    s = jnp.asarray(seed).astype(jnp.uint32)
    x = (s
         + (jnp.asarray(start).astype(jnp.uint32)
            + pos.astype(jnp.uint32)) * jnp.uint32(_P1)
         + dimi.astype(jnp.uint32) * jnp.uint32(_P2))
    x = (x ^ (x >> 15)) * jnp.uint32(_P2)
    x = (x ^ (x >> 13)) * jnp.uint32(_P3)
    x = x ^ (x >> 16)
    return x >> 31


def sign_block(seed, start, count: int, dim: int) -> jnp.ndarray:
    """Deterministic ±1 fp32 block ``S[p - start, j]`` for global
    positions p ∈ [start, start + count) and sketch dims j < dim.

    Pure function of ``(seed, p, j)`` — independent of how callers
    tile the position axis — built from 2D iotas (TPU-legal) and a
    xorshift-multiply integer hash. ``seed``/``start`` may be traced
    scalars; ``count``/``dim`` are static.
    """
    return 1.0 - 2.0 * _sign_bits(seed, start, count, dim).astype(
        jnp.float32)


def sign_block_i8(seed, start, count: int, dim: int) -> jnp.ndarray:
    """``sign_block`` bit-packed to int8: the same ±1 stream at one
    byte per sign instead of four (ROADMAP "sign-generation
    bandwidth"). The off-TPU tiled path materialises one (block, d)
    sign block per chunk — int8 cuts that block's memory traffic 4×,
    and the cast back to fp32 fuses into the projection dot (±1 is
    exact in both dtypes, so the sketch is bitwise unchanged; pinned
    against the jnp oracle in ``tests/test_exchange.py``). The Pallas
    kernel keeps fp32: it regenerates signs in VMEM where the MXU
    wants fp32 operands and no sign block ever reaches HBM."""
    bits = _sign_bits(seed, start, count, dim)
    return (jnp.int8(1) - jnp.int8(2) * bits.astype(jnp.int8))


def _sketch_kernel(seed_ref, g_ref, o_ref, *, offset, tile, dim,
                   total):
    """seed_ref: (1, 1); g_ref: (n, TILE); o_ref: (n, d).

    The output block is revisited by every grid step (TPU grids run
    sequentially): step 0 zeroes it, every step accumulates its
    slab's contribution G_tile @ S_tile. When ``total`` is not a
    tile multiple the final block's overhang (whose contents Pallas
    leaves undefined) is masked to zero in-register — G is never
    padded or copied in HBM.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    start = i * tile
    signs = sign_block(seed_ref[0, 0], offset + start, tile, dim)
    g = g_ref[...].astype(jnp.float32)                   # (n, tile)
    if total % tile:
        pos = jax.lax.broadcasted_iota(jnp.int32, g.shape, 1) + start
        g = jnp.where(pos < total, g, 0.0)
    o_ref[...] += jnp.dot(g, signs,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("dim", "offset", "rows",
                                             "interpret"))
def sketch_flat(G: jnp.ndarray, seed, dim: int, offset: int = 0,
                rows: int = DEFAULT_ROWS,
                interpret: bool = False) -> jnp.ndarray:
    """G: (n, P) float, seed: () int → (n, d) fp32 = G @ S where
    ``S[p, j] = sign_block(seed, offset + p, ...)``. The grid walks
    ceil(P / tile) blocks of the position axis directly on the
    unpadded G — the ragged final block is masked inside the kernel,
    so the only HBM traffic is one read of G plus the (n, d) write."""
    n, p = G.shape
    tile = rows * LANES
    tiles = (p + tile - 1) // tile
    seed2 = jnp.reshape(jnp.asarray(seed, jnp.int32), (1, 1))

    return pl.pallas_call(
        functools.partial(_sketch_kernel, offset=offset, tile=tile,
                          dim=dim, total=p),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, dim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dim), jnp.float32),
        interpret=interpret,
    )(seed2, G)
