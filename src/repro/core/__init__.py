"""The paper's primary contribution: GARL formulation + DDAL learning
framework (knowledge stores, eq. 4 weighting, async delay lines, and
the pod-scale sharded variant). Everything configurable about the
knowledge exchange lives behind one strategy API —
``repro.core.exchange`` (``build_exchange`` assembles an
``ExchangeProtocol`` from a ``GroupSpec``); both trainers are thin
loops over it."""
from repro.core.ddal import DDAL, GroupState  # noqa: F401
from repro.core.exchange import (  # noqa: F401
    COMBINERS,
    DELAYS,
    ESTIMATORS,
    SCHEDULES,
    ExchangeProtocol,
    build_exchange,
)
from repro.core.group_mdp import AgentEnv, GroupMDP  # noqa: F401
from repro.core.knowledge import (  # noqa: F401
    InFlight,
    KnowledgeStore,
    SparseInFlight,
    make_inflight,
    make_sparse_inflight,
    make_store,
    weighted_average,
)
from repro.core.pod_dispatch import (  # noqa: F401
    PodEdges,
    cross_pod_bytes,
    flat_exchange_bytes,
    make_pod_dispatch,
    relevance_exchange_bytes,
    split_topology,
)
from repro.core.transport import (  # noqa: F401
    Transport,
    TransportFaults,
    TransportPlan,
    make_transport,
    transport_schedule,
)
from repro.core.sharded_ddal import (  # noqa: F401
    Knowledge,
    TrainState,
    init_train_state,
    kill_agents,
    make_group_train_step,
    mask_knowledge,
    revive_agents,
    train_state_specs,
)
from repro.core.relevance import (  # noqa: F401
    RELEVANCE_MODES,
    cosine_rows,
    fold_seed,
    grad_cosine,
    obs_overlap,
    sketch_cosine,
)
from repro.core.topology import (  # noqa: F401
    TOPOLOGIES,
    DynamicTopology,
    PodLayout,
    Topology,
    cross_pod_mask,
    delay_from_hops,
    edge_pod_ids,
    full,
    hierarchical,
    hierarchical_layout,
    hop_distances,
    make_topology,
    random_k,
    ring,
    sample_gossip,
    star,
    torus2d,
)
from repro.core.weighting import (  # noqa: F401
    combine_relevance,
    eq4_weights,
    relevance_matrix,
    training_experience,
)
