"""Topology subsystem tests: neighbor-table constructors, the sparse
delay line's bitwise equivalence with the dense all-to-all reference on
the ``full`` topology, graph-local delivery (ring/star), eq. 4
invariants over sparsely-populated stores, and the streaming trainer's
segment-sum combine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import GroupSpec
from repro.core import DDAL, knowledge as K, topology as T
from repro.core.sharded_ddal import Knowledge, _combine, _combine_topo
from repro.core.weighting import eq4_weights


# ----------------------------------------------------------------------
# constructors
# ----------------------------------------------------------------------
def _neighbors(topo, i):
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    return {int(s) for s, m in zip(nbr[i], mask[i]) if m}


def test_full_is_dense_layout():
    topo = T.full(5)
    assert topo.nbr.shape == (5, 5)
    # slot j ↔ source j: the invariant the bitwise-equivalence relies on
    np.testing.assert_array_equal(
        np.asarray(topo.nbr), np.tile(np.arange(5), (5, 1)))
    assert bool(np.asarray(topo.mask).all())


@pytest.mark.parametrize("make,n", [
    (lambda: T.full(6), 6),
    (lambda: T.ring(6), 6),
    (lambda: T.torus2d(2, 3), 6),
    (lambda: T.star(6), 6),
    (lambda: T.random_k(6, 3), 6),
    (lambda: T.hierarchical(6, 3), 6),
])
def test_every_topology_has_self_loops(make, n):
    """An agent's own pieces always reach its own store K_i."""
    topo = make()
    assert topo.n_agents == n
    for i in range(n):
        assert i in _neighbors(topo, i)


def test_ring_neighbor_sets():
    topo = T.ring(6)
    for i in range(6):
        assert _neighbors(topo, i) == {(i - 1) % 6, i, (i + 1) % 6}


def test_torus2d_neighbor_sets():
    topo = T.torus2d(3, 3)
    # agent 4 = centre of the 3x3 torus: self + 4-mesh
    assert _neighbors(topo, 4) == {1, 3, 4, 5, 7}


def test_star_hub_and_leaves():
    topo = T.star(5)
    assert _neighbors(topo, 0) == {0, 1, 2, 3, 4}
    for leaf in range(1, 5):
        assert _neighbors(topo, leaf) == {0, leaf}


def test_random_k_is_regular_and_seeded():
    a = T.random_k(16, 4, seed=7)
    b = T.random_k(16, 4, seed=7)
    c = T.random_k(16, 4, seed=8)
    np.testing.assert_array_equal(np.asarray(a.nbr), np.asarray(b.nbr))
    assert not np.array_equal(np.asarray(a.nbr), np.asarray(c.nbr))
    for i in range(16):
        nb = _neighbors(a, i)
        assert len(nb) == 4 and i in nb


def test_hierarchical_pods_and_leaders():
    topo = T.hierarchical(8, pod_size=4)
    # pod member (non-leader): its own pod only
    assert _neighbors(topo, 1) == {0, 1, 2, 3}
    # leader of pod 0: own pod + the other leader
    assert _neighbors(topo, 0) == {0, 1, 2, 3, 4}
    # leader of pod 1
    assert _neighbors(topo, 4) == {0, 4, 5, 6, 7}


def test_make_topology_dispatch_and_errors():
    spec = GroupSpec(n_agents=9, topology="torus2d")
    topo = T.make_topology(spec)
    assert topo.n_agents == 9 and topo.degree == 5
    spec = GroupSpec(n_agents=8, topology="random_k", degree=3,
                     topology_seed=5)
    topo = T.make_topology(spec)
    np.testing.assert_array_equal(
        np.asarray(topo.nbr), np.asarray(T.random_k(8, 3, 5).nbr))
    with pytest.raises(ValueError, match="unknown topology"):
        T.make_topology(GroupSpec(n_agents=4, topology="moebius"))


def test_with_delay_and_relevance_gather_dense_matrices():
    n = 4
    topo = T.ring(n)
    D = jnp.arange(n * n, dtype=jnp.int32).reshape(n, n)   # D[src,dst]
    R = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n) + 1.0
    topo = topo.with_delay(D).with_relevance(R)
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    for i in range(n):
        for j in range(topo.degree):
            if mask[i, j]:
                src = nbr[i, j]
                assert int(topo.delay[i, j]) == int(D[src, i])
                assert float(topo.relevance[i, j]) == float(R[src, i])


def test_dense_relevance_round_trip():
    n = 5
    R = jnp.asarray(np.random.default_rng(0).uniform(0.1, 1, (n, n)),
                    jnp.float32)
    topo = T.ring(n).with_relevance(R)
    Rd = np.asarray(topo.dense_relevance())
    ring_mask = np.zeros((n, n))
    for i in range(n):
        for s in [(i - 1) % n, i, (i + 1) % n]:
            ring_mask[s, i] = 1.0
    np.testing.assert_allclose(Rd, np.asarray(R) * ring_mask, rtol=1e-6)


# ----------------------------------------------------------------------
# dense-vs-sparse delay-line equivalence (full topology ⇒ bitwise)
# ----------------------------------------------------------------------
def _rand_pieces(rng, n, p):
    return {"w": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)}


def test_sparse_full_equals_dense_reference_bitwise():
    """N epochs of send/deliver over random pieces and random per-edge
    delays: the sparse path on the ``full`` topology must reproduce the
    dense all-to-all reference bit for bit."""
    n, D, p, epochs = 3, 2, 5, 7
    rng = np.random.default_rng(0)
    delay = jnp.asarray(rng.integers(0, D + 1, (n, n)), jnp.int32)
    params = {"w": jnp.zeros((p,))}
    topo = T.full(n).with_delay(delay)
    dense = K.make_inflight(params, n, D)
    sparse = K.make_sparse_inflight(params, topo, D)
    stores_d = jax.vmap(lambda _: K.make_store(params, 4))(jnp.arange(n))
    stores_s = jax.vmap(lambda _: K.make_store(params, 4))(jnp.arange(n))
    R = jnp.ones((n, n))
    for e in range(epochs):
        pieces = _rand_pieces(rng, n, p)
        Tw = jnp.asarray(rng.uniform(1, 5, (n,)), jnp.float32)
        dense = K.send(dense, pieces, Tw, R, delay, e, True)
        dense, stores_d = K.deliver(dense, stores_d, e)
        sparse = K.sparse_send(sparse, topo, pieces, Tw, e, True)
        sparse, stores_s = K.sparse_deliver(sparse, stores_s, e)
    for a, b in zip(jax.tree.leaves(stores_d), jax.tree.leaves(stores_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_regular_fast_path_equals_dense_reference_bitwise():
    """The contiguous k-block delivery fast path (full mask, uniform
    nonzero delay, m % k == 0 — see ``_regular_exchange``) must stay
    bitwise-identical to the dense reference, including across the
    warm-up → sharing transition (disabled sends write the scratch
    plane; disabled deliveries hold ptr)."""
    n, d, m, p, epochs = 4, 1, 8, 5, 10
    rng = np.random.default_rng(3)
    topo = T.full(n).with_delay(d)
    assert K._regular_exchange(topo, m, n)
    params = {"w": jnp.zeros((p,))}
    delay = jnp.full((n, n), d, jnp.int32)
    dense = K.make_inflight(params, n, d)
    sparse = K.make_sparse_inflight(params, topo, d)
    stores_d = jax.vmap(lambda _: K.make_store(params, m))(jnp.arange(n))
    stores_s = jax.vmap(lambda _: K.make_store(params, m))(jnp.arange(n))
    R = jnp.ones((n, n))
    for e in range(epochs):
        enabled = e >= 3                    # warm-up, then sharing
        pieces = _rand_pieces(rng, n, p)
        Tw = jnp.asarray(rng.uniform(1, 5, (n,)), jnp.float32)
        dense = K.send(dense, pieces, Tw, R, delay, e, enabled)
        dense, stores_d = K.deliver(dense, stores_d, e)
        sparse = K.sparse_send(sparse, topo, pieces, Tw, e, enabled)
        sparse, stores_s = K.sparse_deliver(sparse, stores_s, e, topo)
    for a, b in zip(jax.tree.leaves(stores_d), jax.tree.leaves(stores_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ddal_full_topology_equals_dense_reference_groupstate():
    """Full DDAL loop vs a reference epoch loop built on the dense
    InFlight: identical agent params and stores after N epochs."""
    n, epochs = 3, 12
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=2, m_pieces=6)
    delay = jnp.asarray([[0, 1, 2], [1, 0, 1], [2, 1, 0]], jnp.int32)

    def gen(state, key):
        del key
        return {"w": state["w"] - state["t"]}, {}, state

    def app(state, g):
        return {"w": state["w"] - 0.5 * g["w"], "t": state["t"]}

    ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]}, delay=delay)
    states0 = {"w": jnp.zeros((n,)),
               "t": jnp.arange(n, dtype=jnp.float32)}
    gs = ddal.init(states0)
    step = jax.jit(ddal.epoch_step)
    for e in range(epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))

    # dense reference: same update schedule over the seed's delay line
    from repro.core.weighting import training_experience
    params0 = {"w": jnp.zeros(())}
    stores = jax.vmap(lambda _: K.make_store(params0, spec.m_pieces))(
        jnp.arange(n))
    flight = K.make_inflight(params0, n, int(delay.max()))
    astates = states0
    R = jnp.ones((n, n))
    for e in range(epochs):
        grads = {"w": astates["w"] - astates["t"]}
        Tw = jnp.broadcast_to(training_experience(e, "epochs"), (n,))
        flight = K.send(flight, grads, Tw, R, delay, e, True)
        flight, stores = K.deliver(flight, stores, e)
        if e % spec.minibatch == 0:
            gbar, wsum = jax.vmap(K.weighted_average)(stores)
            new = jax.vmap(app)(astates, gbar)
            keep = wsum > 0
            astates = {"w": jnp.where(keep, new["w"], astates["w"]),
                       "t": astates["t"]}
    np.testing.assert_array_equal(np.asarray(gs.agent_states["w"]),
                                  np.asarray(astates["w"]))
    for a, b in zip(jax.tree.leaves(gs.stores), jax.tree.leaves(stores)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# graph-local delivery
# ----------------------------------------------------------------------
def _sources_seen(gs, n):
    """Piece payloads encode the source agent id; return per-dst sets."""
    vals = np.asarray(gs.stores.grads["w"])      # (n, m, 1)
    valid = np.asarray(gs.stores.valid)          # (n, m)
    return [{int(v) for v in vals[i, valid[i], 0]} for i in range(n)]


def _run_id_stamped_group(spec, epochs=6):
    """Each agent 'gradient' is its own id ⇒ stores reveal provenance."""
    def gen(state, key):
        del key
        return {"w": state["id"]}, {}, state

    def app(state, g):
        return state                     # params frozen; stores matter

    ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]})
    gs = ddal.init({"w": jnp.zeros((spec.n_agents, 1)),
                    "id": jnp.arange(spec.n_agents,
                                     dtype=jnp.float32)[:, None]})
    step = jax.jit(ddal.epoch_step)
    for e in range(epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e),
                                          spec.n_agents))
    return gs


def test_ring_delivery_reaches_only_graph_neighbors():
    n = 6
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=32, topology="ring")
    gs = _run_id_stamped_group(spec)
    seen = _sources_seen(gs, n)
    for i in range(n):
        assert seen[i] == {(i - 1) % n, i, (i + 1) % n}


def test_star_delivery_is_hub_centric():
    n = 5
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=32, topology="star")
    gs = _run_id_stamped_group(spec)
    seen = _sources_seen(gs, n)
    assert seen[0] == set(range(n))
    for leaf in range(1, n):
        assert seen[leaf] == {0, leaf}


def test_random_k_delivery_matches_neighbor_table():
    n = 8
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=32, topology="random_k", degree=3,
                     topology_seed=11)
    gs = _run_id_stamped_group(spec)
    topo = T.make_topology(spec)
    seen = _sources_seen(gs, n)
    for i in range(n):
        assert seen[i] == _neighbors(topo, i)


def test_warmup_still_blocks_sharing_on_sparse_path():
    spec = GroupSpec(n_agents=4, threshold=100, minibatch=1,
                     m_pieces=8, topology="random_k", degree=2)
    gs = _run_id_stamped_group(spec, epochs=4)
    assert not bool(np.asarray(gs.stores.valid).any())


# ----------------------------------------------------------------------
# eq. 4 over sparse stores (hypothesis)
# ----------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 12),
       st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_eq4_weights_sum_to_one_over_sparse_store(seed, n, k):
    """Deliver over a random_k topology, then eq. 4 over each store's
    (sparsely populated) slots: weights are non-negative, zero on
    invalid slots, and sum to 1 wherever any piece is valid."""
    k = min(k, n)
    topo = T.random_k(n, k, seed=seed % 10_000)
    params = {"w": jnp.zeros((2,))}
    flight = K.make_sparse_inflight(params, topo, max_delay=0)
    stores = jax.vmap(lambda _: K.make_store(params, 4))(jnp.arange(n))
    rng = np.random.default_rng(seed)
    epochs = int(rng.integers(1, 4))
    for e in range(epochs):
        pieces = {"w": jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)}
        Tw = jnp.asarray(rng.uniform(0.5, 9, (n,)), jnp.float32)
        flight = K.sparse_send(flight, topo, pieces, Tw, e, True)
        flight, stores = K.sparse_deliver(flight, stores, e)
    Tm = np.asarray(stores.T)
    Rm = np.asarray(stores.R)
    valid = np.asarray(stores.valid)
    for i in range(n):
        w = np.asarray(eq4_weights(jnp.asarray(Tm[i]), jnp.asarray(Rm[i]),
                                   jnp.asarray(valid[i])))
        assert (w >= 0).all()
        assert (w[~valid[i]] == 0).all()
        if valid[i].any():
            np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
        else:
            assert w.sum() == 0.0


# ----------------------------------------------------------------------
# streaming trainer: segment-sum combine
# ----------------------------------------------------------------------
def _rand_knowledge(rng, A, p):
    return Knowledge(
        tg={"w": jnp.asarray(rng.normal(size=(A, p)), jnp.float32)},
        tsum=jnp.asarray(rng.uniform(1, 3, A), jnp.float32),
        rg={"w": jnp.asarray(rng.normal(size=(A, p)), jnp.float32)},
        rsum=jnp.asarray(rng.uniform(1, 3, A), jnp.float32),
    )


def test_combine_topo_full_matches_global_sum():
    rng = np.random.default_rng(0)
    know = _rand_knowledge(rng, 4, 7)
    g_uniform = _combine(know, jnp.ones((4, 4)), uniform=True)
    g_topo = _combine_topo(know, T.full(4))
    np.testing.assert_allclose(np.asarray(g_uniform["w"]),
                               np.asarray(g_topo["w"]), rtol=1e-5)


def test_combine_topo_is_neighbor_local():
    rng = np.random.default_rng(1)
    A, p = 5, 3
    know = _rand_knowledge(rng, A, p)
    g = np.asarray(_combine_topo(know, T.ring(A))["w"])
    tg = np.asarray(know.tg["w"])
    rg = np.asarray(know.rg["w"])
    for i in range(A):
        nb = sorted({(i - 1) % A, i, (i + 1) % A})
        t = sum(tg[j] for j in nb) / sum(float(know.tsum[j]) for j in nb)
        r = sum(rg[j] for j in nb) / sum(float(know.rsum[j]) for j in nb)
        np.testing.assert_allclose(g[i], 0.5 * (t + r), rtol=1e-5)


@pytest.mark.slow
def test_streaming_ring_topology_trains():
    """End-to-end: the streaming trainer share-steps over a ring
    without NaNs and with per-agent loss movement."""
    from repro import optim
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.core import init_train_state, make_group_train_step
    from repro.data import StreamSpec, make_group_batch

    cfg = get_arch_config("llama3.2-3b").reduced()
    spec = GroupSpec(n_agents=4, threshold=0, minibatch=1,
                     topology="ring", knowledge_mode="streaming")
    opt = optim.sgd(0.1)
    state = init_train_state(cfg, spec, opt, jax.random.PRNGKey(0))
    shape = ShapeConfig("t", 32, 4, "train")
    step = jax.jit(make_group_train_step(cfg, spec, opt))
    losses = []
    for i in range(3):
        batch = make_group_batch(cfg, shape, StreamSpec(), 4, i)
        state, m = step(state, batch)
        assert bool(jnp.isfinite(m["loss"]).all())
        losses.append(np.asarray(m["loss"]))
    assert not np.allclose(losses[0], losses[-1])
