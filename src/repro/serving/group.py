"""Multi-tenant group serving: one mesh serves every agent's policy
(ISSUE 6).

GARL's premise is many separate agents with separate policies (PAPER.md
§3); at serving time the group is a natural multi-tenant batch.
:class:`GroupServeEngine` serves **many agents' policies from one
device mesh**: requests carry an ``agent_id``, a :class:`Router`
assigns them to continuous-batching slots, and each jitted decode step
gathers per-slot parameters from the **stacked per-agent parameter
planes** — the same leading agent axis ``repro.core.sharded_ddal``
trains, placeable over the ``("pod", "agent")`` mesh via
``repro.launch.shardings`` — so one compiled step advances every
tenant at once. Heterogeneous-agent groups (arXiv 2501.11818) make
this per-agent parameter routing, not one shared checkpoint, the
required serving shape.

Train→serve hot-swap: a :class:`ParamStore` holds the published planes
double-buffered with a monotonic version counter. A live DDAL trainer
calls ``store.publish(state.params)`` after a share step; the engine
``acquire()``-s the live buffer at each step boundary, so in-flight
requests never see a torn update (they continue on whichever buffer
their next step acquires — a complete plane set either way) and
requests admitted after the swap serve the new weights from their
first prefill. The store checkpoints through ``repro.checkpoint.npz``
(version in the ``__step__`` slot), so a restarted server resumes at
the published version.

Single-tenant equivalence: with one agent the engine reduces to the
same prefill / sample / stop pipeline as ``ServeEngine`` (everything
shared through ``repro.serving.api``), pinned by the equivalence
oracle in ``tests/test_serving_group.py``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import get_model
from repro.serving.api import (
    Sampler,
    ServeConfig,
    StopCriteria,
    cache_batch_dims,
    decode_batch as _decode_batch,
    last_logits as _last_logits,
    prefill,
    splice_cache,
)
from repro.serving.continuous import pad_prompt
from repro.serving.metrics import ServeMetrics


@dataclasses.dataclass(frozen=True)
class GroupRequest:
    """One tenant request: which agent's policy, and its prompt."""
    rid: int
    agent_id: int
    prompt: Tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "prompt", tuple(self.prompt))


# ---------------------------------------------------------------------
# router: queued requests → freed slots
# ---------------------------------------------------------------------
class Router:
    """Assigns queued requests to freed continuous-batching slots.

    ``fifo`` (default) is strict arrival order — lowest latency when
    tenants are well-behaved. ``fair`` keeps one queue per agent and
    round-robins across non-empty agents, so one chatty tenant cannot
    starve the rest of the group. Both are deterministic in the
    submission order.
    """

    def __init__(self, policy: str = "fifo"):
        if policy not in ("fifo", "fair"):
            raise ValueError(
                f"unknown router policy {policy!r}; expected 'fifo' "
                f"or 'fair'")
        self.policy = policy
        self._fifo: deque = deque()
        self._per_agent: "OrderedDict[int, deque]" = OrderedDict()

    def push(self, req: GroupRequest) -> None:
        if self.policy == "fifo":
            self._fifo.append(req)
        else:
            self._per_agent.setdefault(req.agent_id, deque()).append(req)

    def pop(self) -> Optional[GroupRequest]:
        if self.policy == "fifo":
            return self._fifo.popleft() if self._fifo else None
        for aid in list(self._per_agent):
            q = self._per_agent.pop(aid)
            req = q.popleft()
            if q:       # rotate: agent re-queues at the back
                self._per_agent[aid] = q
            return req
        return None

    def __len__(self) -> int:
        if self.policy == "fifo":
            return len(self._fifo)
        return sum(len(q) for q in self._per_agent.values())

    def depth(self, agent_id: int) -> int:
        """Queued requests for one tenant (observability)."""
        if self.policy == "fifo":
            return sum(1 for r in self._fifo if r.agent_id == agent_id)
        return len(self._per_agent.get(agent_id, ()))


# ---------------------------------------------------------------------
# publish/acquire hot-swap store
# ---------------------------------------------------------------------
class ParamStore:
    """Double-buffered stacked per-agent parameter planes + version.

    ``publish`` writes the incoming planes into the *back* buffer,
    flips the live index and bumps the version — the previous live
    buffer stays intact until the next publish, so a reader that
    acquired it keeps a complete, immutable plane set for as long as
    it needs. ``acquire`` returns ``(planes, version)`` of the live
    buffer. An optional ``placer`` (e.g. a mesh ``device_put``) runs
    once per publish, so serving placement happens at the handoff, not
    per step.
    """

    def __init__(self, planes: Any, placer=None):
        self._placer = placer
        planes = self._place(planes)
        self._buf: List[Any] = [planes, planes]
        self._live = 0
        self._version = 0

    def _place(self, planes):
        return self._placer(planes) if self._placer is not None \
            else planes

    @property
    def version(self) -> int:
        return self._version

    @property
    def n_agents(self) -> int:
        return int(jax.tree.leaves(self._buf[self._live])[0].shape[0])

    def publish(self, planes: Any) -> int:
        """Install fresh planes (e.g. a trainer's post-exchange
        ``state.params``); returns the new version."""
        back = 1 - self._live
        self._buf[back] = self._place(planes)
        self._live = back
        self._version += 1
        return self._version

    def acquire(self) -> Tuple[Any, int]:
        """The live planes and their version (no copy)."""
        return self._buf[self._live], self._version

    # -- checkpointing (repro.checkpoint.npz) --------------------------
    def save(self, path: str) -> None:
        from repro.checkpoint import npz
        planes, version = self.acquire()
        npz.save(path, planes, step=version)

    @classmethod
    def load(cls, path: str, template: Any, placer=None) -> "ParamStore":
        """Rebuild a store from a published checkpoint; ``template`` is
        a matching pytree of ShapeDtypeStructs or arrays (e.g. from
        ``jax.eval_shape`` over the vmapped init)."""
        from repro.checkpoint import npz
        store = cls(npz.restore(path, template), placer=placer)
        store._version = npz.restore_step(path) or 0
        return store


def publish_from_trainer(store: ParamStore, state) -> int:
    """Push a live DDAL trainer's current per-agent parameter planes
    (``TrainState.params``, leading agent axis) into the serving
    store."""
    return store.publish(state.params)


# ---------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------
@dataclasses.dataclass
class _Slot:
    request_id: Optional[int] = None
    agent_id: int = 0
    tokens: Optional[list] = None
    done: bool = True


class GroupServeEngine:
    """Continuous batching across every tenant of a group.

    ``planes`` is either a :class:`ParamStore` or a stacked-params
    pytree (leaves ``(A, *param)``, the DDAL training layout) which is
    wrapped in a fresh store. With a ``mesh``, publishes are placed
    with dim 0 over the mesh's agent axes
    (``repro.launch.shardings.agent_sharded_state``) so serving and
    training share the same parameter placement.

    Incremental API (what the load bench drives)::

        engine.submit(GroupRequest(rid, agent_id, prompt))
        finished = engine.step()     # refill + one jitted decode step
        engine.drain()               # step() until idle → all results

    ``run(requests)`` is the batch convenience wrapper.
    """

    def __init__(self, cfg: ArchConfig, planes, serve: ServeConfig,
                 batch_size: int, prompt_pad: int = 32,
                 router: Optional[Router] = None,
                 metrics: Optional[ServeMetrics] = None,
                 mesh=None, pod_axis: str = "pod", seed: int = 0):
        self.cfg = cfg
        self.serve = serve
        self.B = batch_size
        self.prompt_pad = prompt_pad
        self.model = get_model(cfg)
        self.sampler = Sampler(serve.temperature)
        self.stop = StopCriteria.from_serve(serve)
        self.metrics = metrics
        self.router = router if router is not None else Router()
        self._seed = seed
        placer = None
        if mesh is not None:
            from repro.launch.shardings import agent_sharded_state
            placer = lambda p: agent_sharded_state(p, mesh, pod_axis)  # noqa: E731
        if isinstance(planes, ParamStore):
            self.store = planes
        else:
            self.store = ParamStore(planes, placer=placer)
        self.n_agents = self.store.n_agents
        self._bdims = cache_batch_dims(cfg, serve.max_len)
        self._prefill_a = jax.jit(self._prefill_agent_impl)
        self._decode = jax.jit(self._group_decode_impl)
        self._splice = jax.jit(
            lambda cache, one, slot: splice_cache(cache, one,
                                                  self._bdims, slot),
            static_argnames=("slot",))
        self.reset()

    # -- jitted pieces -------------------------------------------------
    def _prefill_agent_impl(self, planes, agent_id, tokens, length):
        """B=1 prefill under ONE tenant's params, gathered from the
        stacked planes at a traced index (no per-agent recompile)."""
        params = jax.tree.map(lambda p: p[agent_id], planes)
        nxt, cache = prefill(self.cfg, self.model, params, tokens,
                             jnp.reshape(length, (1,)),
                             self.serve.max_len)
        return nxt[0], cache

    def _group_decode_impl(self, planes, slot_agent, cache, tokens,
                           pos, done, key):
        """One decode step for every live slot, each under its own
        tenant's parameters: gather (B, *param) per-slot params from
        the stacked planes, then vmap the single-slot decode over the
        slot axis (cache leaves map over their discovered batch dims).
        One jitted step advances every tenant."""
        cfg, model, bdims = self.cfg, self.model, self._bdims
        params_b = jax.tree.map(lambda p: p[slot_agent], planes)

        def one(p, tok, ps, cache_i):
            # vmap stripped the batch dim from every cache leaf;
            # restore a B=1 batch for the single-slot decode
            cache1 = jax.tree.map(lambda x, d: jnp.expand_dims(x, d),
                                  cache_i, bdims)
            batch = _decode_batch(cfg, tok[None, None], ps[None, None])
            logits, cache1 = model.decode(cfg, p, batch, cache1)
            nl = _last_logits(cfg, logits)[0]
            cache_i = jax.tree.map(lambda x, d: jnp.squeeze(x, d),
                                   cache1, bdims)
            return nl, cache_i

        nl, cache = jax.vmap(
            one, in_axes=(0, 0, 0, bdims),
            out_axes=(0, bdims))(params_b, tokens[:, 0], pos, cache)
        nxt = self.sampler(nl, key)
        nxt = jnp.where(done, tokens[:, 0], nxt)
        return cache, nxt

    # -- host state ----------------------------------------------------
    def reset(self) -> None:
        """Fresh slots/caches/results (the router and store persist)."""
        self._slots = [_Slot() for _ in range(self.B)]
        self._cache = self.model.make_cache(self.cfg, self.B,
                                            self.serve.max_len)
        self._tokens = jnp.zeros((self.B, 1), jnp.int32)
        self._pos = jnp.zeros((self.B,), jnp.int32)
        self._done = jnp.ones((self.B,), bool)
        self._slot_agent = jnp.zeros((self.B,), jnp.int32)
        self._key = jax.random.PRNGKey(self._seed)
        self.results: Dict[int, List[int]] = {}

    # -- public --------------------------------------------------------
    def submit(self, req: GroupRequest, at: Optional[float] = None
               ) -> None:
        """Queue a request; ``at`` backdates its enqueue timestamp to
        the scheduled (open-loop) arrival time, so queueing delay
        between arrival and admission is part of measured latency."""
        if not 0 <= req.agent_id < self.n_agents:
            raise ValueError(
                f"request {req.rid}: agent_id {req.agent_id} outside "
                f"the group (n_agents={self.n_agents})")
        self.router.push(req)
        if self.metrics is not None:
            self.metrics.enqueue(req.rid, req.agent_id, at=at)

    @property
    def live(self) -> int:
        return sum(1 for s in self._slots if not s.done)

    @property
    def idle(self) -> bool:
        return self.live == 0 and len(self.router) == 0

    def _finish(self, rid: int, tokens: List[int]) -> None:
        self.results[rid] = tokens
        if self.metrics is not None:
            self.metrics.finish(rid, len(tokens))

    def _refill(self) -> None:
        for i, s in enumerate(self._slots):
            if not s.done:
                continue
            req = self.router.pop()
            if req is None:
                return
            planes, version = self.store.acquire()
            n = len(req.prompt)
            P = pad_prompt(self.prompt_pad, n)
            toks = np.zeros((1, P), np.int32)
            toks[0, :n] = req.prompt
            self._key, k = jax.random.split(self._key)
            if self.metrics is not None:
                self.metrics.admitted(req.rid, version=version)
            nl, one = self._prefill_a(planes, jnp.int32(req.agent_id),
                                      jnp.asarray(toks), jnp.int32(n))
            first = int(self.sampler(nl, k))
            if self.metrics is not None:
                self.metrics.first_token(req.rid)
            if self.stop.should_stop(1, first, n):
                self._finish(req.rid, [first])
                continue
            self._cache = self._splice(self._cache, one, slot=i)
            self._tokens = self._tokens.at[i, 0].set(first)
            self._pos = self._pos.at[i].set(n)
            self._done = self._done.at[i].set(False)
            self._slot_agent = self._slot_agent.at[i].set(req.agent_id)
            self._slots[i] = _Slot(request_id=req.rid,
                                   agent_id=req.agent_id,
                                   tokens=[first], done=False)

    def step(self) -> Dict[int, List[int]]:
        """Refill freed slots from the router, then advance every live
        slot by one jitted decode step; returns the requests finished
        during this step ({rid: tokens})."""
        before = set(self.results)
        self._refill()
        if self.metrics is not None:
            self.metrics.observe_step(len(self.router), self.live)
        if self.live == 0:
            return {r: self.results[r]
                    for r in set(self.results) - before}

        self._key, k = jax.random.split(self._key)
        planes, _ = self.store.acquire()
        cache, nxt = self._decode(planes, self._slot_agent,
                                  self._cache, self._tokens, self._pos,
                                  self._done, k)
        self._cache = cache
        self._tokens = nxt[:, None]
        self._pos = self._pos + 1
        # single host transfer per step (the continuous-batcher fix)
        nxt_h, pos_h = jax.device_get((nxt, self._pos))
        freed = []
        for i, s in enumerate(self._slots):
            if s.done:
                continue
            t = int(nxt_h[i])
            s.tokens.append(t)
            if self.stop.should_stop(len(s.tokens), t, int(pos_h[i])):
                self._finish(s.request_id, s.tokens)
                s.done = True
                freed.append(i)
        if freed:
            self._done = self._done.at[np.asarray(freed)].set(True)
        return {r: self.results[r] for r in set(self.results) - before}

    def drain(self) -> Dict[int, List[int]]:
        """step() until no queued or in-flight work remains."""
        while not self.idle:
            self.step()
        return self.results

    def run(self, requests: Sequence[GroupRequest]
            ) -> Dict[int, List[int]]:
        """Batch convenience: submit everything, drain, return
        {rid: tokens}."""
        for req in requests:
            self.submit(req)
        return self.drain()
