"""One exchange-protocol API for DDAL knowledge exchange.

DDAL (paper §4–5) is one protocol with four orthogonal axes — *which
graph* (:class:`TopologySchedule`), *how relevant* (:class:`
RelevanceEstimator`), *how stale* (:class:`DelayModel`), and *how
combined* (:class:`Combiner`). :func:`build_exchange` assembles one
:class:`ExchangeProtocol` from a ``GroupSpec`` via the string-keyed
registries, and **both** trainers (`repro.core.ddal.DDAL`,
`repro.core.sharded_ddal.make_group_train_step`) are thin loops over
it — adding a scenario means registering a strategy, not threading a
flag through two trainers. See ``docs/exchange.md`` for the interface
contracts, a worked custom-estimator example, and the migration table
from the legacy ``GroupSpec`` flags.
"""
from repro.core.exchange.build import (
    KINDS,
    ExchangeProtocol,
    build_exchange,
)
from repro.core.exchange.combiners import Combiner
from repro.core.exchange.delays import DelayModel
from repro.core.exchange.estimators import (
    ObsStatsState,
    RelevanceEstimator,
)
from repro.core.exchange.registry import (
    COMBINERS,
    DELAYS,
    ESTIMATORS,
    REGISTRIES,
    SCHEDULES,
    TRANSPORTS,
    Registry,
    cli_options,
    validate_choice,
)
from repro.core.exchange.schedules import (
    DynamicSchedule,
    RelevanceTopKSchedule,
    StaticSchedule,
    TopologySchedule,
)

# registers the "none"/"faulty" transport strategies (the module only
# needs the registry above — no import cycle)
import repro.core.transport  # noqa: E402,F401

__all__ = [
    "KINDS",
    "ExchangeProtocol",
    "build_exchange",
    "TopologySchedule",
    "StaticSchedule",
    "DynamicSchedule",
    "RelevanceTopKSchedule",
    "RelevanceEstimator",
    "ObsStatsState",
    "DelayModel",
    "Combiner",
    "Registry",
    "REGISTRIES",
    "SCHEDULES",
    "ESTIMATORS",
    "DELAYS",
    "COMBINERS",
    "TRANSPORTS",
    "cli_options",
    "validate_choice",
]
