"""Roofline analysis: v5e constants, HLO collective parsing, the
three-term model (compute / memory / collective)."""
from repro.roofline import constants  # noqa: F401
from repro.roofline.hlo import collective_bytes, count_ops  # noqa: F401
from repro.roofline.report import (  # noqa: F401
    Roofline,
    active_param_count,
    analyze,
    model_flops,
    param_count,
)
