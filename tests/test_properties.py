"""Hypothesis property tests on system invariants beyond eq. 4:
dispatch-index correctness for arbitrary routings, RoPE norm
preservation, CartPole reward accounting, cache slot mapping."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8),
       st.integers(1, 4), st.integers(1, 6))
def test_dispatch_indices_properties(seed, ne, k, cap):
    """For ANY routing: every valid slot holds a token routed to that
    expert; slots within an expert are in original token order; no
    token appears twice; drops are exactly the tokens whose in-expert
    rank ≥ C."""
    from repro.models.moe import _dispatch_indices
    B, S = 2, 8
    T = S * k
    key = jax.random.PRNGKey(seed)
    e_flat = jax.random.randint(key, (B, T), 0, ne)
    gate = jax.random.uniform(jax.random.fold_in(key, 1), (B, T),
                              minval=0.01)
    idx, w, src, valid = _dispatch_indices(e_flat, gate, ne, cap, k)
    idx, w, valid = map(np.asarray, (idx, w, valid))
    ef = np.asarray(e_flat)
    for b in range(B):
        seen = set()
        for e in range(ne):
            toks = [int(idx[b, e, c]) for c in range(cap)
                    if valid[b, e, c]]
            for t in toks:
                assert ef[b, t] == e
                assert t not in seen
                seen.add(t)
            assert toks == sorted(toks)          # original order
        # drop rule: kept ⇔ in-expert rank < cap
        for t in range(T):
            rank = int((ef[b, :t] == ef[b, t]).sum())
            assert (t in seen) == (rank < cap)
        # weights: kept slots carry the gate, empty slots zero
    assert (w[~valid.astype(bool)] == 0).all()


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 64))
def test_rope_preserves_norm(seed, pos):
    """RoPE is a rotation — per-head vector norms are invariant."""
    from repro.models.rope import rope
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 3, 2, 32))
    positions = jnp.full((1, 3), pos, jnp.int32)
    y = rope(x, positions, theta=1e4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


@given(st.integers(0, 2 ** 31 - 1))
def test_cartpole_reward_equals_steps_alive(seed):
    """Total reward == number of live steps (gym semantics)."""
    from repro.rl import CartPole, episode_return, run_episode
    env = CartPole(max_steps=50)
    key = jax.random.PRNGKey(seed)

    def rand_policy(obs, k):
        return jax.random.randint(k, (), 0, 2)

    traj = run_episode(env, rand_policy, key)
    ret = float(episode_return(traj))
    assert ret == float(np.asarray(traj.mask).sum())
    assert 1.0 <= ret <= 50.0


@given(st.integers(1, 300), st.integers(8, 64))
def test_sliding_window_slot_mapping(pos, window):
    """Ring-buffer slot mapping: injective over any `window`-length
    position range."""
    from repro.models.attention import _slots_for
    from repro.configs import get_arch_config
    cfg = get_arch_config("llama3.2-3b").with_(sliding_window=window)
    positions = jnp.arange(pos, pos + window)[None]
    slots = np.asarray(_slots_for(cfg, positions))[0]
    assert len(set(slots.tolist())) == window
    assert slots.max() < window
