"""Host-side sharded batch construction.

On a real multi-host pod each host materialises only its addressable
shard of the global batch; ``device_put_sharded_batch`` builds a
globally-sharded array from per-shard callbacks via
``jax.make_array_from_callback`` — no host ever holds the full
(global_batch, seq) array. On the CPU test rig (1 device) this reduces
to a plain device_put, so the same launcher code runs in both places.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import numpy as np


def device_put_sharded_batch(batch: Dict[str, Any], mesh,
                             spec_of: Callable[[str, Any],
                                               jax.sharding.PartitionSpec]
                             ) -> Dict[str, Any]:
    """Place ``batch`` (host numpy/jnp leaves) on ``mesh`` with
    per-leaf PartitionSpecs from ``spec_of(name, leaf)``."""
    out = {}
    for name, leaf in batch.items():
        sharding = jax.sharding.NamedSharding(mesh, spec_of(name, leaf))
        arr = np.asarray(leaf)
        out[name] = jax.make_array_from_callback(
            arr.shape, sharding, lambda idx, a=arr: a[idx])
    return out
