"""Mamba2 block (arXiv:2405.21060): input projections → causal
depthwise conv → SSD sequence mixing → gated RMSNorm → out-proj.

The reference implementation fuses (z, x, B, C, dt) into one in_proj;
we keep **separate projections and per-stream convs** so each weight
shards cleanly on the TPU mesh (the depthwise conv is per-channel, so
splitting the streams is mathematically identical to the fused form —
see DESIGN.md hardware-adaptation notes). x/z (d_inner) shard over the
"model" axis; B/C/dt are group/head-level and stay replicated.

Functional decode state (per-stream conv tails + SSM state) gives
O(1)-per-token generation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import shard
from repro.models.common import dense_init, rms_norm
from repro.models.ssd import ssd_chunked, ssd_decode_step


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    d_bc = s.n_groups * s.d_state
    return d_inner, n_heads, d_bc


def init_mamba2(cfg, key):
    s = cfg.ssm
    d_inner, H, d_bc = _dims(cfg)
    dt = cfg.dtype("param")
    ks = jax.random.split(key, 7)
    return {
        "w_z": dense_init(ks[0], (cfg.d_model, d_inner), dt),
        "w_x": dense_init(ks[1], (cfg.d_model, d_inner), dt),
        "w_B": dense_init(ks[2], (cfg.d_model, d_bc), dt),
        "w_C": dense_init(ks[3], (cfg.d_model, d_bc), dt),
        "w_dt": dense_init(ks[4], (cfg.d_model, H), dt),
        "conv_x": {"w": dense_init(ks[5], (s.d_conv, d_inner), dt,
                                   scale=0.3),
                   "b": jnp.zeros((d_inner,), dt)},
        "conv_B": {"w": dense_init(jax.random.fold_in(ks[5], 1),
                                   (s.d_conv, d_bc), dt, scale=0.3),
                   "b": jnp.zeros((d_bc,), dt)},
        "conv_C": {"w": dense_init(jax.random.fold_in(ks[5], 2),
                                   (s.d_conv, d_bc), dt, scale=0.3),
                   "b": jnp.zeros((d_bc,), dt)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            ks[6], (H,), jnp.float32, jnp.log(1e-3), jnp.log(1e-1))))
        ).astype(dt),
        "norm_w": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(jax.random.fold_in(ks[6], 1),
                               (d_inner, cfg.d_model), dt),
    }


def _causal_conv(x, conv, tail=None):
    """Depthwise causal conv over (B, S, C); ``tail`` is the (B, d_conv-1,
    C) history for streaming continuation. Returns (out, new_tail)."""
    w = conv["w"].astype(x.dtype)
    b = conv["b"].astype(x.dtype)
    d_conv = w.shape[0]
    if tail is not None:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    else:
        xp = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(xp[:, i:i + S, :] * w[i] for i in range(d_conv))
    new_tail = xp[:, -(d_conv - 1):, :]
    return jax.nn.silu(out + b), new_tail


def _conv_step(window, conv):
    """Single-token depthwise conv. window: (B, d_conv, C)."""
    w = conv["w"].astype(window.dtype)
    out = jnp.einsum("bkc,kc->bc", window, w) + conv["b"].astype(window.dtype)
    return jax.nn.silu(out)


def _proj_streams(cfg, p, x):
    cdt = cfg.dtype("compute")
    z = shard(x @ p["w_z"].astype(cdt), "batch", None, "ssm_inner")
    xs = shard(x @ p["w_x"].astype(cdt), "batch", None, "ssm_inner")
    Bs = x @ p["w_B"].astype(cdt)
    Cs = x @ p["w_C"].astype(cdt)
    dt_raw = x @ p["w_dt"].astype(cdt)
    return z, xs, Bs, Cs, dt_raw


def _finalize(cfg, p, y_heads, xh, z, lead_shape):
    d_inner, H, _ = _dims(cfg)
    cdt = cfg.dtype("compute")
    y = y_heads + p["D"].astype(jnp.float32).reshape(
        (1,) * (y_heads.ndim - 2) + (H, 1)) * xh.astype(jnp.float32)
    y = y.reshape(*lead_shape, d_inner).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"].astype(cdt)


def mamba2_forward(cfg, p, x, state: Optional[dict] = None
                   ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence pass. x: (B, S, E). Returns (out, decode_state)."""
    s = cfg.ssm
    d_inner, H, d_bc = _dims(cfg)
    Bsz, S, _ = x.shape
    z, xs, Bs, Cs, dt_raw = _proj_streams(cfg, p, x)
    tails = {} if state is None else state
    xc, tail_x = _causal_conv(xs, p["conv_x"], tails.get("conv_x"))
    Bc, tail_B = _causal_conv(Bs, p["conv_B"], tails.get("conv_B"))
    Cc, tail_C = _causal_conv(Cs, p["conv_C"], tails.get("conv_C"))

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xc.reshape(Bsz, S, H, s.head_dim)
    Bm = Bc.reshape(Bsz, S, s.n_groups, s.d_state)
    Cm = Cc.reshape(Bsz, S, s.n_groups, s.d_state)
    init_state = None if state is None else state["ssm"]
    y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk,
                                 initial_state=init_state,
                                 impl=cfg.ssd_impl)
    out = _finalize(cfg, p, y.astype(jnp.float32), xh, z, (Bsz, S))
    new_state = None
    if state is not None:
        new_state = {"conv_x": tail_x, "conv_B": tail_B,
                     "conv_C": tail_C, "ssm": final_state}
    return out, new_state


def mamba2_decode(cfg, p, x, state: dict) -> Tuple[jnp.ndarray, dict]:
    """Single-token step. x: (B, 1, E)."""
    s = cfg.ssm
    d_inner, H, d_bc = _dims(cfg)
    Bsz = x.shape[0]
    z, xs, Bs, Cs, dt_raw = _proj_streams(cfg, p, x[:, 0:1])
    z, xs, Bs, Cs, dt_raw = (z[:, 0], xs[:, 0], Bs[:, 0], Cs[:, 0],
                             dt_raw[:, 0])

    def step(name, val, conv):
        window = jnp.concatenate(
            [state[name].astype(val.dtype), val[:, None, :]], axis=1)
        return _conv_step(window, conv), window[:, 1:]

    xc, tail_x = step("conv_x", xs, p["conv_x"])
    Bc, tail_B = step("conv_B", Bs, p["conv_B"])
    Cc, tail_C = step("conv_C", Cs, p["conv_C"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xc.reshape(Bsz, H, s.head_dim)
    Bm = Bc.reshape(Bsz, s.n_groups, s.d_state)
    Cm = Cc.reshape(Bsz, s.n_groups, s.d_state)
    y, new_ssm = ssd_decode_step(state["ssm"], xh, dt, A, Bm, Cm)
    out = _finalize(cfg, p, y.astype(jnp.float32), xh, z, (Bsz,))
    return out[:, None, :], {"conv_x": tail_x, "conv_B": tail_B,
                             "conv_C": tail_C, "ssm": new_ssm}


def make_mamba_state(cfg, batch: int, n_layers: int, dtype=None):
    s = cfg.ssm
    d_inner, H, d_bc = _dims(cfg)
    cdt = dtype or cfg.dtype("compute")
    return {
        "conv_x": jnp.zeros((n_layers, batch, s.d_conv - 1, d_inner), cdt),
        "conv_B": jnp.zeros((n_layers, batch, s.d_conv - 1, d_bc), cdt),
        "conv_C": jnp.zeros((n_layers, batch, s.d_conv - 1, d_bc), cdt),
        "ssm": jnp.zeros((n_layers, batch, H, s.head_dim, s.d_state),
                         jnp.float32),
    }
