"""Chaos lane (ISSUE 7): elastic membership under a seeded fault
injector, in both trainers.

The load-bearing oracles are *survivor-restriction* arguments: when an
agent dies before anything it sent could reach a survivor, the
surviving group's trajectory must be **bitwise** what it would have
been had the corpse never participated — checked both against a
dead-from-birth run of the same group and against a genuinely smaller
group containing only the survivors. On top of that: dead agents are
frozen in amber and go dark on the wire, revival replays nothing
stale (delay-line scrubbing), a checkpoint-restored agent rejoins
without perturbing any survivor's next update, and a dead pod leader
carries nothing across the pod axis. Long schedules are
``@pytest.mark.slow``; the injector itself is pure seeded numpy, so
every schedule here replays identically on CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.checkpoint import restore, save
from repro.configs.base import GroupSpec
from repro.core import DDAL
from repro.core import topology as T
from repro.core.chaos import chaos_schedule, membership_events
from repro.core.pod_dispatch import make_pod_dispatch
from repro.core.sharded_ddal import Knowledge, _combine_topo, mask_knowledge


# ----------------------------------------------------------------------
# toy group (same quadratic agent as test_core_ddal)
# ----------------------------------------------------------------------
def _toy_ddal(spec, delay=None):
    def gen_grads(state, key):
        del key
        g = {"w": state["w"] - state["target"]}
        return g, {"w": state["w"]}, state

    def apply_grads(state, g):
        return {"w": state["w"] - 0.5 * g["w"],
                "target": state["target"]}

    def params_of(state):
        return {"w": state["w"]}

    return DDAL(spec, gen_grads, apply_grads, params_of, delay=delay)


def _toy_states(n):
    return {"w": jnp.zeros((n,)),
            "target": jnp.arange(n, dtype=jnp.float32)}


def _run(ddal, gs, epochs, start=0, events=None):
    """Drive epoch_step; ``events`` maps epoch -> (kill, revive) masks
    applied *before* that epoch runs."""
    step = jax.jit(ddal.epoch_step)
    n = ddal.spec.n_agents
    for e in range(start, start + epochs):
        if events and e in events:
            kill, revive = events[e]
            if kill is not None and kill.any():
                gs = ddal.kill(gs, jnp.asarray(kill))
            if revive is not None and revive.any():
                gs = ddal.revive(gs, jnp.asarray(revive))
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
    return gs


# ----------------------------------------------------------------------
# the fault injector is deterministic and bounded
# ----------------------------------------------------------------------
def test_chaos_schedule_is_deterministic():
    a = chaos_schedule(3, 8, 50, kill_prob=0.2, revive_after=4)
    b = chaos_schedule(3, 8, 50, kill_prob=0.2, revive_after=4)
    assert np.array_equal(a, b)
    c = chaos_schedule(4, 8, 50, kill_prob=0.2, revive_after=4)
    assert not np.array_equal(a, c)
    assert a.shape == (50, 8) and a.dtype == bool
    assert a[0].all()                      # epoch 0 all-alive


def test_chaos_schedule_floor_and_exact_downtime():
    a = chaos_schedule(7, 4, 200, kill_prob=0.5, revive_after=3,
                       min_alive=2)
    assert (a.sum(axis=1) >= 2).all()      # never below the floor
    assert (~a).any()                      # ...but faults do happen
    # every outage is a whole number of revive_after windows (an
    # agent can be re-killed the very epoch it comes back, merging
    # adjacent outages — but never a partial window)
    for i in range(4):
        col = a[:, i].astype(np.int8)
        starts = np.flatnonzero(np.diff(col) == -1) + 1
        ends = np.flatnonzero(np.diff(col) == 1) + 1
        for s, e in zip(starts, ends):
            assert (e - s) % 3 == 0 and e > s


def test_membership_events_reconstruct_schedule():
    a = chaos_schedule(11, 6, 60, kill_prob=0.3, revive_after=2)
    cur = np.ones(6, bool)
    rebuilt = np.ones_like(a)
    ev = dict((e, (k, r)) for e, k, r in membership_events(a))
    for e in range(1, 60):
        if e in ev:
            kill, revive = ev[e]
            assert not (kill & revive).any()
            cur = (cur & ~kill) | revive
        rebuilt[e] = cur
    assert np.array_equal(rebuilt, a)


# ----------------------------------------------------------------------
# survivor-restriction oracles (buffer trainer)
# ----------------------------------------------------------------------
def test_warmup_kill_matches_survivor_only_group():
    """Agents killed before their first send never existed: the
    survivors' full trajectory is bitwise a 2-agent group's."""
    n, surv = 4, np.asarray([0, 1])
    big = _toy_ddal(GroupSpec(n_agents=n, threshold=3, minibatch=2,
                              m_pieces=6, elastic=True))
    small = _toy_ddal(GroupSpec(n_agents=2, threshold=3, minibatch=2,
                                m_pieces=6))
    kill = np.asarray([False, False, True, True])
    gs = _run(big, big.init(_toy_states(n)), 14,
              events={3: (kill, None)})
    gss = _run(small, small.init(_toy_states(2)), 14)
    np.testing.assert_array_equal(
        np.asarray(gs.agent_states["w"])[surv],
        np.asarray(gss.agent_states["w"]))
    # and the dead stayed frozen at their last warmup value
    np.testing.assert_array_equal(
        np.asarray(gs.agent_states["w"])[2:],
        np.arange(2, 4) * (1 - 0.5 ** 3))


@pytest.mark.parametrize("topology,kw", [
    ("full", {}),
    ("ring", {}),
    ("random_k", {"degree": 2}),
])
def test_warmup_kill_matches_dead_from_birth(topology, kw):
    """Same-shape restriction oracle, any graph: killing during
    warmup ≡ the agent was dead from epoch 0."""
    spec = GroupSpec(n_agents=5, threshold=2, minibatch=1, m_pieces=4,
                     elastic=True, topology=topology, **kw)
    ddal = _toy_ddal(spec)
    kill = np.asarray([False, False, True, False, False])
    g1 = _run(ddal, ddal.init(_toy_states(5)), 12,
              events={2: (kill, None)})
    g2 = _run(ddal, ddal.init(_toy_states(5)), 12,
              events={0: (kill, None)})
    m = ~kill
    np.testing.assert_array_equal(
        np.asarray(g1.agent_states["w"])[m],
        np.asarray(g2.agent_states["w"])[m])
    np.testing.assert_array_equal(np.asarray(g1.stores.T)[m],
                                  np.asarray(g2.stores.T)[m])


def test_dead_agent_is_frozen_and_dark():
    """Mid-sharing kill: the corpse's params freeze, its store is
    scrubbed, and the wire goes dark — no plane in flight to or from
    it, no future delivery lands in its ring."""
    spec = GroupSpec(n_agents=3, threshold=0, minibatch=1, m_pieces=4,
                     elastic=True)
    ddal = _toy_ddal(spec)
    gs = _run(ddal, ddal.init(_toy_states(3)), 4)
    dead = np.asarray([False, True, False])
    gs = ddal.kill(gs, jnp.asarray(dead))
    assert not bool(np.asarray(gs.stores.valid[1]).any())
    # flight rows touching agent 1 (as dst, or as src via nbr) cleared
    nbr = np.asarray(gs.nbr)
    valid = np.asarray(gs.flight.valid)
    assert not valid[1].any()
    assert not valid[nbr == 1].any()
    w_dead = float(gs.agent_states["w"][1])
    gs = _run(ddal, gs, 5, start=4)
    assert float(gs.agent_states["w"][1]) == w_dead
    assert not bool(np.asarray(gs.stores.valid[1]).any())
    # survivors kept exchanging with each other
    assert bool(np.asarray(gs.stores.valid[0]).any())


def test_revival_replays_nothing_stale():
    """With per-edge delay d, planes sent before the death must not
    surface after revival: every piece in the revived ring was sent at
    an epoch >= the revival epoch (T metadata is the send epoch)."""
    n, d = 3, 3
    delay = jnp.full((n, n), d, jnp.int32)
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1, m_pieces=8,
                     elastic=True, t_weighting="epochs")
    ddal = _toy_ddal(spec, delay=delay)
    dead = np.asarray([False, True, False])
    e_kill, e_rev = 5, 7
    gs = _run(ddal, ddal.init(_toy_states(n)), 12,
              events={e_kill: (dead, None), e_rev: (None, dead)})
    Tmeta = np.asarray(gs.stores.T[1])
    valid = np.asarray(gs.stores.valid[1])
    assert valid.any()                     # it did rejoin the stream
    # t_weighting="epochs" stamps T = max(send_epoch, 1); anything
    # sent pre-kill (epoch < 5) still riding the d=3 delay line at
    # revival would surface as T < 7
    assert (Tmeta[valid] >= e_rev).all()


def test_checkpoint_rejoin_does_not_perturb_survivors():
    """The acceptance gate: a killed agent restored from its
    exchange-state checkpoint rejoins mid-stream without perturbing
    any survivor's next update (delay >= 1, so its fresh planes only
    surface later), and its own rows come back bitwise from the
    checkpoint."""
    n = 3
    delay = jnp.ones((n, n), jnp.int32)
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1, m_pieces=8,
                     elastic=True)
    ddal = _toy_ddal(spec, delay=delay)
    dead = np.asarray([False, True, False])
    surv = ~dead

    gs = _run(ddal, ddal.init(_toy_states(n)), 4)
    import tempfile
    import os
    with tempfile.TemporaryDirectory() as td:
        ckpt_path = os.path.join(td, "group.npz")
        save(ckpt_path, gs, step=4)        # full exchange state
        gs = ddal.kill(gs, jnp.asarray(dead))
        gs = _run(ddal, gs, 3, start=4)

        ckpt = restore(ckpt_path, jax.eval_shape(lambda: gs))
        rejoined = ddal.revive(gs, jnp.asarray(dead), restore=ckpt)
        stayed = gs                         # control: agent stays dead

        # the revived rows are bitwise the checkpointed ones
        np.testing.assert_array_equal(
            np.asarray(rejoined.agent_states["w"])[dead],
            np.asarray(ckpt.agent_states["w"])[dead])
        np.testing.assert_array_equal(
            np.asarray(rejoined.stores.T)[dead],
            np.asarray(ckpt.stores.T)[dead])
        # ...and no survivor row moved at all
        for a, b in [(rejoined.agent_states, stayed.agent_states),
                     (rejoined.stores, stayed.stores)]:
            jax.tree.map(lambda x, y: np.testing.assert_array_equal(
                np.asarray(x)[surv], np.asarray(y)[surv]), a, b)

        # survivors' next update is identical whether or not the
        # agent rejoined (its first post-revive plane is still in
        # flight behind the 1-epoch delay)
        step = jax.jit(ddal.epoch_step)
        keys = jax.random.split(jax.random.PRNGKey(7), n)
        g_re, _ = step(rejoined, keys)
        g_st, _ = step(stayed, keys)
        np.testing.assert_array_equal(
            np.asarray(g_re.agent_states["w"])[surv],
            np.asarray(g_st.agent_states["w"])[surv])


def test_injector_driven_run_keeps_survivor_invariants():
    """A full chaos_schedule drives kill/revive through a real run:
    whoever is dead at epoch e is bitwise-frozen across e, and the
    group's params stay finite throughout."""
    n, epochs = 6, 24
    sched = chaos_schedule(13, n, epochs, kill_prob=0.25,
                           revive_after=3, min_alive=2)
    events = dict((e, (k, r)) for e, k, r in membership_events(sched))
    spec = GroupSpec(n_agents=n, threshold=4, minibatch=2, m_pieces=6,
                     elastic=True)
    ddal = _toy_ddal(spec)
    gs = ddal.init(_toy_states(n))
    step = jax.jit(ddal.epoch_step)
    for e in range(epochs):
        if e in events:
            kill, revive = events[e]
            if kill.any():
                gs = ddal.kill(gs, jnp.asarray(kill))
            if revive.any():
                gs = ddal.revive(gs, jnp.asarray(revive))
        before = np.asarray(gs.agent_states["w"]).copy()
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
        after = np.asarray(gs.agent_states["w"])
        dead_now = ~sched[e]
        np.testing.assert_array_equal(after[dead_now],
                                      before[dead_now])
        assert np.isfinite(after).all()
        assert np.array_equal(np.asarray(gs.alive), sched[e])


# ----------------------------------------------------------------------
# property suite (mirrored by the no-hypothesis conftest shim)
# ----------------------------------------------------------------------
@given(st.integers(2, 6), st.integers(0, 3),
       st.sampled_from(["full", "ring"]))
def test_property_all_alive_is_bitwise_current_path(n, threshold,
                                                    topology):
    """elastic=True with nobody ever dying traces to the same numbers
    as the historical non-elastic program."""
    kw = dict(n_agents=n, threshold=threshold, minibatch=2,
              m_pieces=4, topology=topology)
    d0 = _toy_ddal(GroupSpec(**kw))
    d1 = _toy_ddal(GroupSpec(elastic=True, **kw))
    g0 = _run(d0, d0.init(_toy_states(n)), 8)
    g1 = _run(d1, d1.init(_toy_states(n)), 8)
    np.testing.assert_array_equal(np.asarray(g0.agent_states["w"]),
                                  np.asarray(g1.agent_states["w"]))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), g0.stores, g1.stores)


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8))
def test_property_dead_agents_receive_no_deliveries(seed, n):
    """However the group churns, no delivery ever lands in a dead
    ring and a dead agent's plane is never in flight."""
    rng = np.random.default_rng(seed)
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1, m_pieces=4,
                     elastic=True)
    ddal = _toy_ddal(spec)
    gs = ddal.init(_toy_states(n))
    step = jax.jit(ddal.epoch_step)
    for e in range(6):
        mask = rng.random(n) < 0.3
        mask[int(rng.integers(n))] = False          # keep one alive
        cur = np.asarray(gs.alive)
        kill = cur & mask
        revive = ~cur & (rng.random(n) < 0.3)
        if kill.any():
            gs = ddal.kill(gs, jnp.asarray(kill))
        if revive.any():
            gs = ddal.revive(gs, jnp.asarray(revive))
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
        dead = ~np.asarray(gs.alive)
        # dead rings never gain a piece (kill scrubbed them to empty)
        assert not np.asarray(gs.stores.valid)[dead].any()
        # and nothing of theirs rides the delay lines
        valid = np.asarray(gs.flight.valid)
        nbr = np.asarray(gs.nbr)
        assert not valid[dead].any()                 # as destination
        assert not valid[dead[nbr]].any()            # as source


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 6),
       st.integers(1, 5))
def test_property_dead_weight_in_eq4_is_exactly_zero(seed, n, p):
    """Streaming eq. 4: a dead agent's numerator *and* denominator
    contributions are exactly zero — survivors' rows are invariant to
    arbitrary garbage in dead rows of the window."""
    rng = np.random.default_rng(seed)
    alive = rng.random(n) < 0.6
    alive[int(rng.integers(n))] = True
    topo = T.ring(n)
    base_tg = rng.normal(size=(n, p)).astype(np.float32)
    base_rg = rng.normal(size=(n, p)).astype(np.float32)
    tsum = rng.uniform(1, 3, n).astype(np.float32)
    rsum = rng.uniform(1, 3, n).astype(np.float32)

    def build(fill):
        tg = base_tg.copy()
        rg = base_rg.copy()
        ts, rs = tsum.copy(), rsum.copy()
        tg[~alive] = fill
        rg[~alive] = fill
        ts[~alive] = fill
        rs[~alive] = fill
        return Knowledge(tg={"w": jnp.asarray(tg)},
                         tsum=jnp.asarray(ts),
                         rg={"w": jnp.asarray(rg)},
                         rsum=jnp.asarray(rs))

    a = jnp.asarray(alive)
    g1 = _combine_topo(mask_knowledge(build(0.0), a), topo)
    g2 = _combine_topo(mask_knowledge(build(1e6), a), topo)
    np.testing.assert_array_equal(np.asarray(g1["w"])[alive],
                                  np.asarray(g2["w"])[alive])
    # and a fully-masked window combines to exactly zero
    gz = _combine_topo(mask_knowledge(build(1.0), jnp.zeros(n, bool)),
                       topo)
    np.testing.assert_array_equal(np.asarray(gz["w"]),
                                  np.zeros((n, p), np.float32))


# ----------------------------------------------------------------------
# streaming trainer
# ----------------------------------------------------------------------
def _streaming_rig(elastic, n=3, threshold=2, minibatch=2):
    from repro import optim
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.core import init_train_state, make_group_train_step
    from repro.data import StreamSpec, make_group_batch

    cfg = get_arch_config("llama3.2-3b").reduced()
    opt = optim.sgd(0.1)
    shape = ShapeConfig("chaos", 32, 2, "train")
    spec = GroupSpec(n_agents=n, threshold=threshold,
                     minibatch=minibatch, knowledge_mode="streaming",
                     elastic=elastic)
    state = init_train_state(cfg, spec, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_group_train_step(cfg, spec, opt))

    def batch(i):
        return make_group_batch(cfg, shape, StreamSpec(), n, i)

    return state, step, batch


def test_streaming_warmup_kill_matches_dead_from_birth():
    """Streaming trainer restriction oracle: kill before the first
    share ≡ dead from step 0, bitwise on every survivor row."""
    from repro.core import kill_agents
    n = 3
    dead = jnp.asarray([False, False, True])
    surv = np.asarray([True, True, False])
    s1, step, batch = _streaming_rig(True, n=n)
    s2 = kill_agents(s1, dead)                       # dead from birth
    s1_killed_later = s1
    for i in range(5):
        if i == 1:                                   # still warmup
            s1_killed_later = kill_agents(s1_killed_later, dead)
        s1_killed_later, _ = step(s1_killed_later, batch(i))
        s2, _ = step(s2, batch(i))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a)[surv], np.asarray(b)[surv]),
        s1_killed_later.params, s2.params)


@pytest.mark.slow
def test_streaming_injector_schedule_freezes_dead():
    """Injector-driven streaming run: dead rows are bitwise-frozen
    across every step they are down, revived rows move again."""
    from repro.core import kill_agents, revive_agents
    n, steps = 3, 10
    sched = chaos_schedule(5, n, steps, kill_prob=0.3, revive_after=2,
                           min_alive=1)
    events = dict((e, (k, r)) for e, k, r in membership_events(sched))
    state, step, batch = _streaming_rig(True, n=n, threshold=1,
                                        minibatch=2)
    for i in range(steps):
        if i in events:
            kill, revive = events[i]
            if kill.any():
                state = kill_agents(state, jnp.asarray(kill))
            if revive.any():
                state = revive_agents(state, jnp.asarray(revive))
        before = jax.tree.map(lambda x: np.asarray(x).copy(),
                              state.params)
        state, m = step(state, batch(i))
        dead_now = ~sched[i]
        if dead_now.any():
            jax.tree.map(lambda a, b: np.testing.assert_array_equal(
                np.asarray(a)[dead_now], np.asarray(b)[dead_now]),
                state.params, before)
        assert np.array_equal(np.asarray(state.know.alive), sched[i])


# ----------------------------------------------------------------------
# pod dispatch: a dead leader carries nothing across the pod axis
# ----------------------------------------------------------------------
def _pod_rig(rng, n=8, pod_size=4, p=6):
    topo = T.hierarchical(n, pod_size)
    lay = T.hierarchical_layout(n, pod_size)
    know = Knowledge(
        tg={"w": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)},
        tsum=jnp.asarray(rng.uniform(1, 3, n), jnp.float32),
        rg={"w": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)},
        rsum=jnp.asarray(rng.uniform(1, 3, n), jnp.float32))
    return topo, lay, know


def test_pod_dead_leader_reference():
    """Reference decomposition: with pod 1's leader dead, (a) the
    dispatch matches the flat masked oracle on every live row, and
    (b) pod 0's rows are invariant to garbage planted anywhere in
    pod 1 — nothing of a leaderless pod crosses the pod axis."""
    rng = np.random.default_rng(21)
    topo, lay, know = _pod_rig(rng)
    alive = np.ones(8, bool)
    alive[4] = False                       # pod 1's leader
    a = jnp.asarray(alive)
    combine = make_pod_dispatch(topo, lay)
    got = jax.jit(lambda k: combine(k, alive=a))(know)
    ref = _combine_topo(mask_knowledge(know, a), topo)
    np.testing.assert_array_equal(np.asarray(got["w"])[alive],
                                  np.asarray(ref["w"])[alive])
    # garbage-invariance across the dead leader
    poisoned = know._replace(
        tg={"w": know.tg["w"].at[4:].set(1e9)},
        rg={"w": know.rg["w"].at[4:].set(-1e9)},
        tsum=know.tsum.at[4:].set(1e9),
        rsum=know.rsum.at[4:].set(1e9))
    got_p = jax.jit(lambda k: combine(k, alive=a))(poisoned)
    np.testing.assert_array_equal(np.asarray(got["w"])[:4],
                                  np.asarray(got_p["w"])[:4])


@pytest.mark.multi_device
@pytest.mark.parametrize("dead", [
    [],                  # control: all-alive elastic ≡ alive=None
    [4],                 # pod 1's leader
    [2],                 # a plain member
    [0, 5],              # pod 0's leader + a pod-1 member
])
def test_pod_kill_matrix_on_mesh(multi_device, dead):
    """Kill/revive matrix through the real shard_map collectives on a
    (2, 4) pod mesh: every membership pattern matches the flat masked
    oracle on live rows, and the all-alive control is bitwise the
    mask-free path."""
    from repro.launch.mesh import make_pod_mesh
    rng = np.random.default_rng(22)
    mesh = make_pod_mesh(2)
    topo, lay, know = _pod_rig(rng, n=8, pod_size=4)
    alive = np.ones(8, bool)
    alive[dead] = False
    a = jnp.asarray(alive)
    combine = make_pod_dispatch(topo, lay, mesh=mesh)
    got = jax.jit(lambda k: combine(k, alive=a))(know)
    ref = _combine_topo(mask_knowledge(know, a), topo)
    np.testing.assert_allclose(np.asarray(got["w"])[alive],
                               np.asarray(ref["w"])[alive],
                               rtol=1e-5, atol=1e-6)
    if not dead:
        plain = jax.jit(lambda k: combine(k))(know)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(plain["w"]))
