"""eq. 4 weighted-average kernel roofline bench (beyond-paper table).

The kernel's value is HBM-traffic reduction: XLA's unfused form reads
the accumulator m times (traffic ≈ (2m)·4N bytes fp32), the fused
Pallas kernel reads G once and writes ḡ once (traffic ≈ (m+1)·4N).
CPU wall-clock is NOT the metric (interpret mode runs Python) — we
report the analytic v5e HBM roofline for both traffic models plus a
correctness check, and CPU wall time of the XLA reference for context.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ddal_wavg import ops, ref
from repro.roofline.constants import HBM_BW

SIZES = [(4, 1_000_000), (8, 10_000_000),
         (16, 10_000_000), (8, 100_000_000)]
SMOKE_SIZES = [(4, 1_000_000), (8, 2_000_000)]


def main(verbose: bool = True, smoke: bool = False):
    rows = []
    for m, n_params in (SMOKE_SIZES if smoke else SIZES):
        key = jax.random.PRNGKey(0)
        # correctness at a reduced size (same tiling)
        n_small = 262_144
        G = jax.random.normal(key, (m, n_small), jnp.float32)
        w = jax.random.uniform(key, (m,))
        got = ops.wavg(G, w, interpret=True)
        want = ref.wavg(G, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

        # CPU wall time of the XLA reference at full size
        Gf = jnp.zeros((m, n_params), jnp.float32)
        rfn = jax.jit(ref.wavg)
        rfn(Gf, w).block_until_ready()
        t0 = time.time()
        rfn(Gf, w).block_until_ready()
        cpu_s = time.time() - t0

        bytes_fused = 4.0 * n_params * (m + 1)
        bytes_unfused = 4.0 * n_params * 2 * m
        rows.append({
            "m": m, "n_params": n_params,
            "v5e_roofline_fused_us": bytes_fused / HBM_BW * 1e6,
            "v5e_roofline_unfused_us": bytes_unfused / HBM_BW * 1e6,
            "traffic_saving": bytes_unfused / bytes_fused,
            "cpu_ref_ms": cpu_s * 1e3,
        })
    if verbose:
        print(f"{'m':>3} {'N':>12} {'fused µs':>10} {'unfused µs':>11} "
              f"{'saving':>7} {'cpu-ref ms':>11}")
        for r in rows:
            print(f"{r['m']:3d} {r['n_params']:12,} "
                  f"{r['v5e_roofline_fused_us']:10.1f} "
                  f"{r['v5e_roofline_unfused_us']:11.1f} "
                  f"{r['traffic_saving']:6.2f}x "
                  f"{r['cpu_ref_ms']:11.2f}")
        print("correctness: interpret-mode kernel == jnp oracle ✓")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI fast path: reduced sizes only")
    args = p.parse_args()
    main(smoke=args.smoke)
