"""The general group MDP: heterogeneous agents, ring topology,
relevance weighting.

The paper's experiments use the homogeneous special case (§6); its
formulation (§4) is more general — agents with *different*
environments, coupled only by the relevance matrix R[j, i]. Here three
GridWorld agents of different sizes learn together over a ring
topology: each agent's knowledge flows only to its ring neighbours,
and R weights down knowledge from dissimilar worlds.

    PYTHONPATH=src python examples/heterogeneous_group.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import GroupSpec
from repro.core import DDAL, GroupMDP, AgentEnv
from repro.rl import GridWorld, init_a2c, make_a2c_callbacks

# three agents in different-size worlds — same state/action *types*
# (one-hot obs padded to the largest world) so knowledge is exchangeable
SIZE = 5
envs = [GridWorld(size=SIZE), GridWorld(size=SIZE),
        GridWorld(size=SIZE, max_steps=30)]
group_mdp = GroupMDP(
    agents=tuple(AgentEnv(e, gamma=0.95) for e in envs),
    spec=GroupSpec(n_agents=3, threshold=300, minibatch=50,
                   m_pieces=16, topology="ring"),
    relevance=jnp.asarray([[1.0, 0.8, 0.5],
                           [0.8, 1.0, 0.8],
                           [0.5, 0.8, 1.0]]),
)

env = envs[0]
opt = optim.adamw(3e-3)
gen, app, pof = make_a2c_callbacks(env, opt, gamma=0.95)
ddal = DDAL(group_mdp.spec, gen, app, pof,
            relevance=group_mdp.relevance)

key = jax.random.PRNGKey(0)
astates = jax.vmap(lambda k: init_a2c(k, env, opt))(
    jax.random.split(key, 3))
group = ddal.init(astates)
group, metrics = jax.jit(lambda g, k: ddal.run(g, k, 1_200))(
    group, jax.random.PRNGKey(1))
rewards = np.asarray(metrics["return"])

print("GridWorld group (ring topology, graded relevance):")
for a in range(3):
    print(f"  agent {a}: warm-up mean={rewards[:300, a].mean():6.2f}  "
          f"final mean={rewards[-200:, a].mean():6.2f} "
          f"(optimum ≈ {1.0 - 0.01 * (2 * (SIZE - 1)):.2f})")
