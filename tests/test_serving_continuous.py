"""Continuous batching: slot refill correctness and equivalence with
the fixed-batch engine on greedy decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch_config
from repro.models import get_model
from repro.serving import ContinuousBatcher, ServeConfig, ServeEngine


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-780m",
                                  "deepseek-v2-lite-16b"])
def test_continuous_matches_fixed_batch_greedy(arch):
    cfg = get_arch_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    serve = ServeConfig(max_len=64, max_new_tokens=5)
    cb = ContinuousBatcher(cfg, params, serve, batch_size=2,
                           prompt_pad=8)
    reqs = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
    out = cb.run(reqs)
    assert set(out) == {0, 1, 2}
    eng = ServeEngine(cfg, params, serve)
    for rid, req in enumerate(reqs):
        toks = np.zeros((1, 8), np.int32)
        toks[0, :len(req)] = req
        ref = np.asarray(eng.generate(jnp.asarray(toks),
                                      jnp.asarray([len(req)],
                                                  jnp.int32)))[0]
        np.testing.assert_array_equal(np.asarray(out[rid]), ref[:5])


def test_more_requests_than_slots():
    cfg = get_arch_config("granite-3-8b").reduced()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    cb = ContinuousBatcher(cfg, params,
                           ServeConfig(max_len=32, max_new_tokens=3),
                           batch_size=2, prompt_pad=8)
    out = cb.run([[i + 1] for i in range(7)])
    assert set(out) == set(range(7))
    assert all(len(v) == 3 for v in out.values())
