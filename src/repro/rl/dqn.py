"""Double-dueling DQN agent for DDADQN (paper §5.1).

Gradients follow paper eq. 5–6:

    ∇θ L = ∇θ ( y_t − Q(φ_t, a_t; θ) )²
    y_t  = r                                          (terminal)
         = r + γ Q(φ', argmax_a' Q(φ', a'; θ); θ⁻)    (double DQN)

with the dueling head combine of eq. 7 (repro.rl.networks) and a
target network θ⁻ refreshed every ``target_period`` updates (Mnih et
al. 2015). Experiences go through a fixed-size replay ring buffer; one
epoch = one episode collected + one minibatch gradient (Algorithm 1
lines 2–4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_map
from repro.optim import Optimizer
from repro.rl import networks as nets
from repro.rl.rollout import episode_return, obs_moments, run_episode


class Replay(NamedTuple):
    obs: jnp.ndarray        # (C, obs_dim)
    actions: jnp.ndarray    # (C,) int32
    rewards: jnp.ndarray    # (C,)
    next_obs: jnp.ndarray   # (C, obs_dim)
    dones: jnp.ndarray      # (C,) bool
    ptr: jnp.ndarray        # () int32
    size: jnp.ndarray       # () int32


def make_replay(capacity: int, obs_dim: int) -> Replay:
    return Replay(
        obs=jnp.zeros((capacity, obs_dim), jnp.float32),
        actions=jnp.zeros((capacity,), jnp.int32),
        rewards=jnp.zeros((capacity,), jnp.float32),
        next_obs=jnp.zeros((capacity, obs_dim), jnp.float32),
        dones=jnp.zeros((capacity,), bool),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def replay_add_traj(rep: Replay, traj) -> Replay:
    """Append the (masked) steps of one trajectory."""
    C = rep.actions.shape[0]

    def body(r, i):
        live = traj.mask[i] > 0
        slot = r.ptr % C
        en = live

        def put(buf, x):
            new = buf.at[slot].set(x.astype(buf.dtype))
            return jnp.where(jnp.reshape(en, (1,) * new.ndim), new, buf)

        r2 = Replay(
            obs=put(r.obs, traj.obs[i]),
            actions=put(r.actions, traj.actions[i]),
            rewards=put(r.rewards, traj.rewards[i]),
            next_obs=put(r.next_obs, traj.next_obs[i]),
            dones=put(r.dones, traj.dones[i]),
            ptr=r.ptr + en.astype(jnp.int32),
            size=jnp.minimum(r.size + en.astype(jnp.int32), C),
        )
        return r2, None

    T = traj.actions.shape[0]
    rep, _ = jax.lax.scan(body, rep, jnp.arange(T))
    return rep


def replay_sample(rep: Replay, key, batch: int):
    idx = jax.random.randint(key, (batch,), 0,
                             jnp.maximum(rep.size, 1))
    return (rep.obs[idx], rep.actions[idx], rep.rewards[idx],
            rep.next_obs[idx], rep.dones[idx])


class DQNState(NamedTuple):
    params: Any
    target_params: Any
    opt_state: Any
    replay: Replay
    step: jnp.ndarray       # () int32 — number of updates so far
    eps_t: jnp.ndarray      # () int32 — exploration anneal counter


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    gamma: float = 0.99
    batch: int = 64
    capacity: int = 10_000
    target_period: int = 100     # copy θ→θ⁻ every C updates
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay: int = 2_000       # linear anneal epochs
    hidden: int = 64


def init_dqn(key, env, opt: Optimizer, cfg: DQNConfig) -> DQNState:
    params = nets.init_dueling_q(key, env.obs_dim, env.n_actions,
                                 cfg.hidden)
    return DQNState(
        params=params,
        target_params=tree_map(lambda x: x, params),
        opt_state=opt.init(params),
        replay=make_replay(cfg.capacity, env.obs_dim),
        step=jnp.zeros((), jnp.int32),
        eps_t=jnp.zeros((), jnp.int32),
    )


def dqn_loss(params, target_params, batch, gamma: float):
    obs, actions, rewards, next_obs, dones = batch
    q = nets.dueling_q_values(params, obs)                  # (B, A)
    q_a = jnp.take_along_axis(q, actions[:, None], axis=-1)[:, 0]
    # double DQN: online net selects, target net evaluates (eq. 6)
    q_next_online = nets.dueling_q_values(params, next_obs)
    a_star = jnp.argmax(q_next_online, axis=-1)
    q_next_tgt = nets.dueling_q_values(target_params, next_obs)
    q_star = jnp.take_along_axis(q_next_tgt, a_star[:, None],
                                 axis=-1)[:, 0]
    y = rewards + gamma * jnp.where(dones, 0.0,
                                    jax.lax.stop_gradient(q_star))
    return jnp.mean(jnp.square(y - q_a))                    # eq. 5


def make_dqn_callbacks(env, opt: Optimizer, cfg: DQNConfig,
                       track_obs: bool = False):
    """(gen_grads, apply_grads, params_of) for repro.core.ddal.DDAL.

    With ``track_obs`` the metrics carry the episode's observation
    moments (``repro.rl.rollout.obs_moments``) — the side channel the
    ``obs_stats`` relevance estimator consumes."""

    def epsilon(t):
        frac = jnp.clip(t.astype(jnp.float32) / cfg.eps_decay, 0.0, 1.0)
        return cfg.eps_start + frac * (cfg.eps_end - cfg.eps_start)

    def gen_grads(state: DQNState, key) -> Tuple[Any, Any, DQNState]:
        k_ep, k_sample = jax.random.split(key)
        eps = epsilon(state.eps_t)

        def select(obs, k):
            kg, ke = jax.random.split(k)
            greedy = jnp.argmax(nets.dueling_q_values(state.params, obs))
            rand = jax.random.randint(ke, (), 0, env.n_actions)
            return jnp.where(jax.random.uniform(kg) < eps, rand, greedy)

        traj = run_episode(env, select, k_ep)
        replay = replay_add_traj(state.replay, traj)
        batch = replay_sample(replay, k_sample, cfg.batch)
        loss, grads = jax.value_and_grad(dqn_loss)(
            state.params, state.target_params, batch, cfg.gamma)
        # don't learn from a near-empty buffer
        ok = (replay.size >= cfg.batch).astype(jnp.float32)
        grads = tree_map(lambda g: g * ok, grads)
        new_state = DQNState(state.params, state.target_params,
                             state.opt_state, replay, state.step,
                             state.eps_t + 1)
        metrics = {"loss": loss, "return": episode_return(traj),
                   "epsilon": eps}
        if track_obs:
            metrics["obs_moments"] = obs_moments(traj)
        return grads, metrics, new_state

    def apply_grads(state: DQNState, grads) -> DQNState:
        params, opt_state = opt.update(grads, state.opt_state,
                                       state.params, state.step)
        step = state.step + 1
        sync = (step % cfg.target_period) == 0
        target = tree_map(
            lambda t, p: jnp.where(sync, p, t),
            state.target_params, params)
        return DQNState(params, target, opt_state, state.replay, step,
                        state.eps_t)

    def params_of(state: DQNState):
        return state.params

    return gen_grads, apply_grads, params_of


def make_dqn_group(env, opt: Optimizer, spec, key,
                   cfg: Optional[DQNConfig] = None, topology=None,
                   relevance: Optional[jnp.ndarray] = None,
                   delay: Optional[jnp.ndarray] = None):
    """Entry point for a DDADQN group: builds the exchange protocol
    for ``spec`` (``repro.core.exchange.build_exchange`` — schedule,
    relevance estimator, delay model and combiner strategies; an
    explicit ``Topology`` / ``DynamicTopology`` overrides the graph),
    the DDAL loop over it, and the initial GroupState. A static
    relevance prior (e.g. ``repro.core.relevance.obs_overlap``) can
    be passed as a dense ``relevance`` matrix; with
    ``spec.exchange_estimator="obs_stats"`` the callbacks stream each
    episode's observation moments so that prior maintains itself.
    Returns (ddal, group_state)."""
    from repro.core import DDAL
    from repro.core.exchange import build_exchange
    cfg = cfg or DQNConfig()
    exchange = build_exchange(spec, kind="buffer", topology=topology,
                              relevance=relevance, delay=delay,
                              obs_dim=env.obs_dim)
    gen, app, pof = make_dqn_callbacks(env, opt, cfg,
                                       track_obs=exchange.wants_obs)
    ddal = DDAL(spec, gen, app, pof, exchange=exchange)
    astates = jax.vmap(lambda k: init_dqn(k, env, opt, cfg))(
        jax.random.split(key, spec.n_agents))
    return ddal, ddal.init(astates)
