"""MoE dispatch-engine equivalence: the expert-parallel shard_map path
(gather dispatch + fp32 psum combine — §Perf iteration 1) must be
numerically identical to the dense scatter reference, for losses AND
gradients, including under the vmapped agent axis."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.sharding import axis_rules, set_mesh
from repro.configs import get_arch_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import train_rules
from repro.models import get_model, make_batch
from repro.models.moe import _dispatch_indices


@pytest.mark.parametrize(
    "arch",
    ["qwen3-moe-30b-a3b",
     # the deepseek cell is the slowest single test in the fast lane
     # (~16s) and exercises the same dispatch path with shared-expert
     # routing on top; the qwen cell keeps the dense-parity oracle in
     # tier-1, deepseek rides the slow lane
     pytest.param("deepseek-v2-lite-16b", marks=pytest.mark.slow)])
def test_expert_parallel_equals_dense(arch):
    cfg = get_arch_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    batch = make_batch(cfg, ShapeConfig("t", 64, 2, "train"), key)

    l_dense = model.loss(cfg.with_(moe_dispatch="dense"), params, batch)
    g_dense = jax.grad(lambda p: model.loss(
        cfg.with_(moe_dispatch="dense"), p, batch))(params)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with set_mesh(mesh), axis_rules(train_rules(mesh)):
        l_ep = jax.jit(lambda p, b: model.loss(cfg, p, b))(params, batch)
        g_ep = jax.jit(jax.grad(
            lambda p: model.loss(cfg, p, batch)))(params)
    np.testing.assert_allclose(float(l_dense), float(l_ep), rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-5),
        g_dense, g_ep)


def test_expert_parallel_under_vmap():
    """The DDAL train step vmaps over agents — shard_map must batch."""
    cfg = get_arch_config("qwen3-moe-30b-a3b").reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    batch = make_batch(cfg, ShapeConfig("t", 64, 2, "train"), key)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with set_mesh(mesh), axis_rules(train_rules(mesh)):
        vg = jax.jit(jax.vmap(jax.value_and_grad(
            lambda p, b: model.loss(cfg, p, b))))
        pp = jax.tree.map(lambda x: jnp.stack([x, x]), params)
        bb = jax.tree.map(lambda x: jnp.stack([x, x]), batch)
        losses, grads = vg(pp, bb)
    l_ref = model.loss(cfg.with_(moe_dispatch="dense"), params, batch)
    np.testing.assert_allclose(np.asarray(losses),
                               np.full(2, float(l_ref)), rtol=1e-5)


def test_dispatch_indices_match_cumsum_semantics():
    """Sort-based slots == cumsum-scatter slots (same drops)."""
    key = jax.random.PRNGKey(3)
    B, S, k, Ne, C = 3, 16, 2, 4, 5
    T = S * k
    e_flat = jax.random.randint(key, (B, T), 0, Ne)
    gate_flat = jax.random.uniform(jax.random.fold_in(key, 1), (B, T),
                                   minval=0.1)
    token_idx, w, src, valid = _dispatch_indices(e_flat, gate_flat,
                                                 Ne, C, k)
    # reference: cumsum position per token
    onehot = jax.nn.one_hot(e_flat, Ne, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1,
                              e_flat[..., None], axis=2)[..., 0]
    keep = np.asarray(pos < C)
    for b in range(B):
        got = set()
        for e in range(Ne):
            for c in range(C):
                if bool(valid[b, e, c]):
                    t = int(token_idx[b, e, c])
                    assert int(e_flat[b, t]) == e
                    got.add(t)
        want = {t for t in range(T) if keep[b, t]}
        assert got == want
