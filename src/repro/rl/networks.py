"""Small MLP networks for the RL agents (paper §5.1–5.2).

* ``policy_value``: A2C's two networks — policy π_θ(a|s) and state
  value V(s) (paper eq. 8–9).
* ``dueling_q``: the dueling architecture (paper eq. 7):
  Q(s, a) = A(s, a) + V(s) from two heads over a shared trunk.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp


def _init_linear(key, din: int, dout: int) -> Dict[str, jnp.ndarray]:
    k1, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / din)
    return {"w": jax.random.normal(k1, (din, dout), jnp.float32) * scale,
            "b": jnp.zeros((dout,), jnp.float32)}


def _linear(p, x):
    return x @ p["w"] + p["b"]


def init_mlp(key, dims: Sequence[int]) -> list:
    keys = jax.random.split(key, len(dims) - 1)
    return [_init_linear(k, a, b)
            for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp(params: list, x, final_act: bool = False):
    for i, p in enumerate(params):
        x = _linear(p, x)
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ----------------------------------------------------------------------
# A2C: policy network + value network
# ----------------------------------------------------------------------
def init_policy_value(key, obs_dim: int, n_actions: int,
                      hidden: int = 64) -> Dict[str, Any]:
    kp, kv = jax.random.split(key)
    return {
        "policy": init_mlp(kp, (obs_dim, hidden, hidden, n_actions)),
        "value": init_mlp(kv, (obs_dim, hidden, hidden, 1)),
    }


def policy_logits(params, obs):
    return mlp(params["policy"], obs)


def state_value(params, obs):
    return mlp(params["value"], obs)[..., 0]


# ----------------------------------------------------------------------
# Dueling double-DQN (paper §5.1): shared trunk, A and V heads,
# Q(s,a) = V(s) + A(s,a) - mean_a A(s,a)  (Wang et al. 2016 combine;
# the paper's eq. 7 omits the mean-baseline — we keep it for
# identifiability, which only shifts Q by a constant per state).
# ----------------------------------------------------------------------
def init_dueling_q(key, obs_dim: int, n_actions: int,
                   hidden: int = 64) -> Dict[str, Any]:
    kt, ka, kv = jax.random.split(key, 3)
    return {
        "trunk": init_mlp(kt, (obs_dim, hidden)),
        "adv": init_mlp(ka, (hidden, hidden, n_actions)),
        "val": init_mlp(kv, (hidden, hidden, 1)),
    }


def dueling_q_values(params, obs):
    h = mlp(params["trunk"], obs, final_act=True)
    a = mlp(params["adv"], h)
    v = mlp(params["val"], h)
    return v + a - jnp.mean(a, axis=-1, keepdims=True)


# ----------------------------------------------------------------------
# Serving entry points (repro.serving.group): the policy forward a
# serving engine routes per request, for RL policies what the token
# engines' decode step is for LLM policies.
# ----------------------------------------------------------------------
def policy_forward(params, obs):
    """One tenant's policy forward for serving: action logits for a
    (batched or unbatched) observation."""
    return policy_logits(params, obs)


def group_policy_act(planes, agent_ids, obs, key=None,
                     temperature: float = 0.0):
    """Multi-tenant RL policy serving: one forward serves a batch of
    requests routed across the group.

    ``planes`` carries the stacked per-agent policy parameters (leaves
    ``(A, *param)`` — the same leading agent axis DDAL trains and
    ``GroupServeEngine`` decodes under); ``agent_ids`` is the (B,)
    routing vector and ``obs`` the (B, obs_dim) request batch. Each
    request's parameters are gathered from the planes and a single
    vmapped forward advances every tenant — the RL-policy analogue of
    the group engine's decode step. Returns ``(actions, logits)``;
    temperature ≤ 0 is greedy argmax, otherwise a softmax sample
    (``key`` required).
    """
    params_b = jax.tree.map(lambda p: p[agent_ids], planes)
    logits = jax.vmap(policy_forward)(params_b, obs)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits
    if key is None:
        raise ValueError("temperature sampling needs a PRNG key")
    act = jax.random.categorical(key, logits / temperature)
    return act.astype(jnp.int32), logits
