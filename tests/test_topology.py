"""Topology subsystem tests: neighbor-table constructors, the sparse
delay line's bitwise equivalence with the dense all-to-all reference on
the ``full`` topology, graph-local delivery (ring/star), eq. 4
invariants over sparsely-populated stores, the streaming trainer's
segment-sum combine, and the dynamic-gossip subsystem (hypothesis
property suite, static-limit equivalence oracles, hop-count delay
staleness)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.configs.base import GroupSpec
from repro.core import DDAL, knowledge as K, topology as T
from repro.core.sharded_ddal import Knowledge, _combine, _combine_topo
from repro.core.weighting import eq4_weights


# ----------------------------------------------------------------------
# constructors
# ----------------------------------------------------------------------
def _neighbors(topo, i):
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    return {int(s) for s, m in zip(nbr[i], mask[i]) if m}


def test_full_is_dense_layout():
    topo = T.full(5)
    assert topo.nbr.shape == (5, 5)
    # slot j ↔ source j: the invariant the bitwise-equivalence relies on
    np.testing.assert_array_equal(
        np.asarray(topo.nbr), np.tile(np.arange(5), (5, 1)))
    assert bool(np.asarray(topo.mask).all())


@pytest.mark.parametrize("make,n", [
    (lambda: T.full(6), 6),
    (lambda: T.ring(6), 6),
    (lambda: T.torus2d(2, 3), 6),
    (lambda: T.star(6), 6),
    (lambda: T.random_k(6, 3), 6),
    (lambda: T.hierarchical(6, 3), 6),
])
def test_every_topology_has_self_loops(make, n):
    """An agent's own pieces always reach its own store K_i."""
    topo = make()
    assert topo.n_agents == n
    for i in range(n):
        assert i in _neighbors(topo, i)


def test_ring_neighbor_sets():
    topo = T.ring(6)
    for i in range(6):
        assert _neighbors(topo, i) == {(i - 1) % 6, i, (i + 1) % 6}


def test_torus2d_neighbor_sets():
    topo = T.torus2d(3, 3)
    # agent 4 = centre of the 3x3 torus: self + 4-mesh
    assert _neighbors(topo, 4) == {1, 3, 4, 5, 7}


def test_star_hub_and_leaves():
    topo = T.star(5)
    assert _neighbors(topo, 0) == {0, 1, 2, 3, 4}
    for leaf in range(1, 5):
        assert _neighbors(topo, leaf) == {0, leaf}


def test_random_k_is_regular_and_seeded():
    a = T.random_k(16, 4, seed=7)
    b = T.random_k(16, 4, seed=7)
    c = T.random_k(16, 4, seed=8)
    np.testing.assert_array_equal(np.asarray(a.nbr), np.asarray(b.nbr))
    assert not np.array_equal(np.asarray(a.nbr), np.asarray(c.nbr))
    for i in range(16):
        nb = _neighbors(a, i)
        assert len(nb) == 4 and i in nb


def test_hierarchical_pods_and_leaders():
    topo = T.hierarchical(8, pod_size=4)
    # pod member (non-leader): its own pod only
    assert _neighbors(topo, 1) == {0, 1, 2, 3}
    # leader of pod 0: own pod + the other leader
    assert _neighbors(topo, 0) == {0, 1, 2, 3, 4}
    # leader of pod 1
    assert _neighbors(topo, 4) == {0, 4, 5, 6, 7}


def test_make_topology_dispatch_and_errors():
    spec = GroupSpec(n_agents=9, topology="torus2d")
    topo = T.make_topology(spec)
    assert topo.n_agents == 9 and topo.degree == 5
    spec = GroupSpec(n_agents=8, topology="random_k", degree=3,
                     topology_seed=5)
    topo = T.make_topology(spec)
    np.testing.assert_array_equal(
        np.asarray(topo.nbr), np.asarray(T.random_k(8, 3, 5).nbr))
    with pytest.raises(ValueError, match="unknown topology"):
        T.make_topology(GroupSpec(n_agents=4, topology="moebius"))


def test_with_delay_and_relevance_gather_dense_matrices():
    n = 4
    topo = T.ring(n)
    D = jnp.arange(n * n, dtype=jnp.int32).reshape(n, n)   # D[src,dst]
    R = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n) + 1.0
    topo = topo.with_delay(D).with_relevance(R)
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    for i in range(n):
        for j in range(topo.degree):
            if mask[i, j]:
                src = nbr[i, j]
                assert int(topo.delay[i, j]) == int(D[src, i])
                assert float(topo.relevance[i, j]) == float(R[src, i])


def test_dense_relevance_round_trip():
    n = 5
    R = jnp.asarray(np.random.default_rng(0).uniform(0.1, 1, (n, n)),
                    jnp.float32)
    topo = T.ring(n).with_relevance(R)
    Rd = np.asarray(topo.dense_relevance())
    ring_mask = np.zeros((n, n))
    for i in range(n):
        for s in [(i - 1) % n, i, (i + 1) % n]:
            ring_mask[s, i] = 1.0
    np.testing.assert_allclose(Rd, np.asarray(R) * ring_mask, rtol=1e-6)


# ----------------------------------------------------------------------
# dense-vs-sparse delay-line equivalence (full topology ⇒ bitwise)
# ----------------------------------------------------------------------
def _rand_pieces(rng, n, p):
    return {"w": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)}


def test_sparse_full_equals_dense_reference_bitwise():
    """N epochs of send/deliver over random pieces and random per-edge
    delays: the sparse path on the ``full`` topology must reproduce the
    dense all-to-all reference bit for bit."""
    n, D, p, epochs = 3, 2, 5, 7
    rng = np.random.default_rng(0)
    delay = jnp.asarray(rng.integers(0, D + 1, (n, n)), jnp.int32)
    params = {"w": jnp.zeros((p,))}
    topo = T.full(n).with_delay(delay)
    dense = K.make_inflight(params, n, D)
    sparse = K.make_sparse_inflight(params, topo, D)
    stores_d = jax.vmap(lambda _: K.make_store(params, 4))(jnp.arange(n))
    stores_s = jax.vmap(lambda _: K.make_store(params, 4))(jnp.arange(n))
    R = jnp.ones((n, n))
    for e in range(epochs):
        pieces = _rand_pieces(rng, n, p)
        Tw = jnp.asarray(rng.uniform(1, 5, (n,)), jnp.float32)
        dense = K.send(dense, pieces, Tw, R, delay, e, True)
        dense, stores_d = K.deliver(dense, stores_d, e)
        sparse = K.sparse_send(sparse, topo, pieces, Tw, e, True)
        sparse, stores_s = K.sparse_deliver(sparse, stores_s, e)
    for a, b in zip(jax.tree.leaves(stores_d), jax.tree.leaves(stores_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_regular_fast_path_equals_dense_reference_bitwise():
    """The contiguous k-block delivery fast path (full mask, uniform
    nonzero delay, m % k == 0 — see ``_regular_exchange``) must stay
    bitwise-identical to the dense reference, including across the
    warm-up → sharing transition (disabled sends write the scratch
    plane; disabled deliveries hold ptr)."""
    n, d, m, p, epochs = 4, 1, 8, 5, 10
    rng = np.random.default_rng(3)
    topo = T.full(n).with_delay(d)
    assert K._regular_exchange(topo, m, n)
    params = {"w": jnp.zeros((p,))}
    delay = jnp.full((n, n), d, jnp.int32)
    dense = K.make_inflight(params, n, d)
    sparse = K.make_sparse_inflight(params, topo, d)
    stores_d = jax.vmap(lambda _: K.make_store(params, m))(jnp.arange(n))
    stores_s = jax.vmap(lambda _: K.make_store(params, m))(jnp.arange(n))
    R = jnp.ones((n, n))
    for e in range(epochs):
        enabled = e >= 3                    # warm-up, then sharing
        pieces = _rand_pieces(rng, n, p)
        Tw = jnp.asarray(rng.uniform(1, 5, (n,)), jnp.float32)
        dense = K.send(dense, pieces, Tw, R, delay, e, enabled)
        dense, stores_d = K.deliver(dense, stores_d, e)
        sparse = K.sparse_send(sparse, topo, pieces, Tw, e, enabled)
        sparse, stores_s = K.sparse_deliver(sparse, stores_s, e, topo)
    for a, b in zip(jax.tree.leaves(stores_d), jax.tree.leaves(stores_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ddal_full_topology_equals_dense_reference_groupstate():
    """Full DDAL loop vs a reference epoch loop built on the dense
    InFlight: identical agent params and stores after N epochs."""
    n, epochs = 3, 12
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=2, m_pieces=6)
    delay = jnp.asarray([[0, 1, 2], [1, 0, 1], [2, 1, 0]], jnp.int32)

    def gen(state, key):
        del key
        return {"w": state["w"] - state["t"]}, {}, state

    def app(state, g):
        return {"w": state["w"] - 0.5 * g["w"], "t": state["t"]}

    ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]}, delay=delay)
    states0 = {"w": jnp.zeros((n,)),
               "t": jnp.arange(n, dtype=jnp.float32)}
    gs = ddal.init(states0)
    step = jax.jit(ddal.epoch_step)
    for e in range(epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))

    # dense reference: same update schedule over the seed's delay line
    from repro.core.weighting import training_experience
    params0 = {"w": jnp.zeros(())}
    stores = jax.vmap(lambda _: K.make_store(params0, spec.m_pieces))(
        jnp.arange(n))
    flight = K.make_inflight(params0, n, int(delay.max()))
    astates = states0
    R = jnp.ones((n, n))
    for e in range(epochs):
        grads = {"w": astates["w"] - astates["t"]}
        Tw = jnp.broadcast_to(training_experience(e, "epochs"), (n,))
        flight = K.send(flight, grads, Tw, R, delay, e, True)
        flight, stores = K.deliver(flight, stores, e)
        if e % spec.minibatch == 0:
            gbar, wsum = jax.vmap(K.weighted_average)(stores)
            new = jax.vmap(app)(astates, gbar)
            keep = wsum > 0
            astates = {"w": jnp.where(keep, new["w"], astates["w"]),
                       "t": astates["t"]}
    np.testing.assert_array_equal(np.asarray(gs.agent_states["w"]),
                                  np.asarray(astates["w"]))
    for a, b in zip(jax.tree.leaves(gs.stores), jax.tree.leaves(stores)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# graph-local delivery
# ----------------------------------------------------------------------
def _sources_seen(gs, n):
    """Piece payloads encode the source agent id; return per-dst sets."""
    vals = np.asarray(gs.stores.grads["w"])      # (n, m, 1)
    valid = np.asarray(gs.stores.valid)          # (n, m)
    return [{int(v) for v in vals[i, valid[i], 0]} for i in range(n)]


def _run_id_stamped_group(spec, epochs=6):
    """Each agent 'gradient' is its own id ⇒ stores reveal provenance."""
    def gen(state, key):
        del key
        return {"w": state["id"]}, {}, state

    def app(state, g):
        return state                     # params frozen; stores matter

    ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]})
    gs = ddal.init({"w": jnp.zeros((spec.n_agents, 1)),
                    "id": jnp.arange(spec.n_agents,
                                     dtype=jnp.float32)[:, None]})
    step = jax.jit(ddal.epoch_step)
    for e in range(epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e),
                                          spec.n_agents))
    return gs


def test_ring_delivery_reaches_only_graph_neighbors():
    n = 6
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=32, topology="ring")
    gs = _run_id_stamped_group(spec)
    seen = _sources_seen(gs, n)
    for i in range(n):
        assert seen[i] == {(i - 1) % n, i, (i + 1) % n}


def test_star_delivery_is_hub_centric():
    n = 5
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=32, topology="star")
    gs = _run_id_stamped_group(spec)
    seen = _sources_seen(gs, n)
    assert seen[0] == set(range(n))
    for leaf in range(1, n):
        assert seen[leaf] == {0, leaf}


def test_random_k_delivery_matches_neighbor_table():
    n = 8
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=32, topology="random_k", degree=3,
                     topology_seed=11)
    gs = _run_id_stamped_group(spec)
    topo = T.make_topology(spec)
    seen = _sources_seen(gs, n)
    for i in range(n):
        assert seen[i] == _neighbors(topo, i)


def test_warmup_still_blocks_sharing_on_sparse_path():
    spec = GroupSpec(n_agents=4, threshold=100, minibatch=1,
                     m_pieces=8, topology="random_k", degree=2)
    gs = _run_id_stamped_group(spec, epochs=4)
    assert not bool(np.asarray(gs.stores.valid).any())


# ----------------------------------------------------------------------
# eq. 4 over sparse stores (hypothesis)
# ----------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 12),
       st.integers(1, 6))
def test_eq4_weights_sum_to_one_over_sparse_store(seed, n, k):
    """Deliver over a random_k topology, then eq. 4 over each store's
    (sparsely populated) slots: weights are non-negative, zero on
    invalid slots, and sum to 1 wherever any piece is valid."""
    k = min(k, n)
    topo = T.random_k(n, k, seed=seed % 10_000)
    params = {"w": jnp.zeros((2,))}
    flight = K.make_sparse_inflight(params, topo, max_delay=0)
    stores = jax.vmap(lambda _: K.make_store(params, 4))(jnp.arange(n))
    rng = np.random.default_rng(seed)
    epochs = int(rng.integers(1, 4))
    for e in range(epochs):
        pieces = {"w": jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)}
        Tw = jnp.asarray(rng.uniform(0.5, 9, (n,)), jnp.float32)
        flight = K.sparse_send(flight, topo, pieces, Tw, e, True)
        flight, stores = K.sparse_deliver(flight, stores, e)
    Tm = np.asarray(stores.T)
    Rm = np.asarray(stores.R)
    valid = np.asarray(stores.valid)
    for i in range(n):
        w = np.asarray(eq4_weights(jnp.asarray(Tm[i]), jnp.asarray(Rm[i]),
                                   jnp.asarray(valid[i])))
        assert (w >= 0).all()
        assert (w[~valid[i]] == 0).all()
        if valid[i].any():
            np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
        else:
            assert w.sum() == 0.0


# ----------------------------------------------------------------------
# streaming trainer: segment-sum combine
# ----------------------------------------------------------------------
def _rand_knowledge(rng, A, p):
    return Knowledge(
        tg={"w": jnp.asarray(rng.normal(size=(A, p)), jnp.float32)},
        tsum=jnp.asarray(rng.uniform(1, 3, A), jnp.float32),
        rg={"w": jnp.asarray(rng.normal(size=(A, p)), jnp.float32)},
        rsum=jnp.asarray(rng.uniform(1, 3, A), jnp.float32),
    )


def test_combine_topo_full_matches_global_sum():
    rng = np.random.default_rng(0)
    know = _rand_knowledge(rng, 4, 7)
    g_uniform = _combine(know, jnp.ones((4, 4)), uniform=True)
    g_topo = _combine_topo(know, T.full(4))
    np.testing.assert_allclose(np.asarray(g_uniform["w"]),
                               np.asarray(g_topo["w"]), rtol=1e-5)


def test_combine_topo_is_neighbor_local():
    rng = np.random.default_rng(1)
    A, p = 5, 3
    know = _rand_knowledge(rng, A, p)
    g = np.asarray(_combine_topo(know, T.ring(A))["w"])
    tg = np.asarray(know.tg["w"])
    rg = np.asarray(know.rg["w"])
    for i in range(A):
        nb = sorted({(i - 1) % A, i, (i + 1) % A})
        t = sum(tg[j] for j in nb) / sum(float(know.tsum[j]) for j in nb)
        r = sum(rg[j] for j in nb) / sum(float(know.rsum[j]) for j in nb)
        np.testing.assert_allclose(g[i], 0.5 * (t + r), rtol=1e-5)


# ----------------------------------------------------------------------
# dynamic gossip: hypothesis property suite
# ----------------------------------------------------------------------
def _dyn(n, k, seed, resample_every=1):
    return T.DynamicTopology(base=T.random_k(n, k, seed),
                             resample_every=resample_every, seed=seed)


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 16),
       st.integers(1, 6), st.integers(0, 500))
def test_dynamic_resample_is_k_regular_with_valid_mask(seed, n, k,
                                                       epoch):
    """Every resampled graph is k-in-regular: k distinct neighbors per
    destination, the self-loop in its dedicated slot 0, no self-loop
    among the k−1 sampled gossip edges, and an all-True mask."""
    k = min(k, n - 1) if n > 1 else 1
    topo = _dyn(n, k, seed % 10_000, resample_every=3).at_epoch(epoch)
    nbr = np.asarray(topo.nbr)
    assert nbr.shape == (n, k)
    assert bool(np.asarray(topo.mask).all())
    assert bool(np.asarray(topo.delay == 0).all())
    for i in range(n):
        row = nbr[i]
        assert row[0] == i                       # dedicated self slot
        assert (row[1:] != i).all()              # sampled edges: no self
        assert len(set(row.tolist())) == k       # distinct (k-regular)
        assert ((0 <= row) & (row < n)).all()


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 12),
       st.integers(1, 5), st.integers(0, 200), st.integers(1, 7))
def test_dynamic_resample_is_deterministic_in_seed_and_epoch(
        seed, n, k, epoch, every):
    """Resampling is a pure function of (topology_seed, epoch): two
    independently built schedules agree epoch-by-epoch, epochs within
    one resample round share a table, and a different seed diverges."""
    k = min(k, n - 1) if n > 1 else 1
    seed = seed % 10_000
    a = _dyn(n, k, seed, every).at_epoch(epoch)
    b = _dyn(n, k, seed, every).at_epoch(epoch)
    np.testing.assert_array_equal(np.asarray(a.nbr), np.asarray(b.nbr))
    # same resample round ⇒ same table
    same_round = (epoch // every) * every
    c = _dyn(n, k, seed, every).at_epoch(same_round)
    np.testing.assert_array_equal(np.asarray(a.nbr), np.asarray(c.nbr))


def test_dynamic_resample_changes_across_rounds():
    dt = _dyn(12, 3, seed=0, resample_every=2)
    t0 = np.asarray(dt.at_epoch(0).nbr)
    t1 = np.asarray(dt.at_epoch(1).nbr)      # same round as epoch 0
    t2 = np.asarray(dt.at_epoch(2).nbr)      # next round
    np.testing.assert_array_equal(t0, t1)
    assert not np.array_equal(t0, t2)


@pytest.mark.parametrize("n,k,seed", [(8, 2, 0), (12, 3, 1),
                                      (16, 4, 7), (10, 2, 3)])
def test_dynamic_union_over_rounds_is_connected(n, k, seed):
    """With the fixed seed schedule, the union of the neighbor sets
    over n // k consecutive resample rounds forms a connected
    (undirected) graph — gossip reaches everyone eventually."""
    dt = _dyn(n, k, seed, resample_every=1)
    adj = np.zeros((n, n), bool)
    for e in range(max(1, n // k)):
        nbr = np.asarray(dt.at_epoch(e).nbr)
        for i in range(n):
            for s in nbr[i]:
                adj[i, s] = adj[s, i] = True
    seen = {0}
    frontier = [0]
    while frontier:
        u = frontier.pop()
        for v in np.nonzero(adj[u])[0]:
            if int(v) not in seen:
                seen.add(int(v))
                frontier.append(int(v))
    assert seen == set(range(n))


def test_make_topology_dynamic_dispatch_and_errors():
    spec = GroupSpec(n_agents=8, topology="random_k", degree=3,
                     topology_seed=5, resample_every=4)
    dt = T.make_topology(spec)
    assert isinstance(dt, T.DynamicTopology)
    assert dt.resample_every == 4 and dt.seed == 5
    np.testing.assert_array_equal(
        np.asarray(dt.base.nbr), np.asarray(T.random_k(8, 3, 5).nbr))
    # per-edge (n, k) annotations cannot follow a resample
    with pytest.raises(ValueError, match="dense"):
        T.make_topology(spec, delay=jnp.zeros((8, 3), jnp.int32))
    with pytest.raises(ValueError, match="dense"):
        T.make_topology(spec, relevance=jnp.ones((8, 3)))
    # non-uniform base delay without a dense matrix is rejected early
    bad = dt._replace(base=dt.base.with_delay(
        jnp.arange(24, dtype=jnp.int32).reshape(8, 3), per_edge=True))
    with pytest.raises(ValueError, match="uniform"):
        bad._uniform_base_delay()


def test_groupspec_validation_errors():
    """Invalid group wiring fails at construction with a clear
    message, not deep inside jit (ISSUE 2 satellite)."""
    with pytest.raises(ValueError, match="unknown topology"):
        GroupSpec(n_agents=4, topology="moebius")
    with pytest.raises(ValueError, match="unknown relevance_mode"):
        GroupSpec(n_agents=4, relevance_mode="psychic")
    with pytest.raises(ValueError, match="resample_every"):
        GroupSpec(n_agents=4, resample_every=-1)
    with pytest.raises(ValueError, match="random_k"):
        GroupSpec(n_agents=4, topology="ring", resample_every=2)
    with pytest.raises(ValueError, match="degree"):
        GroupSpec(n_agents=4, topology="random_k", degree=4)
    with pytest.raises(ValueError, match="degree"):
        GroupSpec(n_agents=4, topology="random_k", degree=0)
    with pytest.raises(ValueError, match="relevance_ema"):
        GroupSpec(n_agents=4, relevance_ema=1.0)
    # the valid corners still construct
    GroupSpec(n_agents=4, topology="random_k", degree=3,
              resample_every=2, relevance_mode="grad_cos")


# ----------------------------------------------------------------------
# dynamic gossip: equivalence oracles (pinned next to the dense↔sparse
# oracle above so refactors cannot silently drift either limit)
# ----------------------------------------------------------------------
def _run_group(spec, epochs=12, topology=None):
    """The toy quadratic group the dense↔sparse oracle uses, returning
    the final GroupState (deterministic given spec/topology)."""
    n = spec.n_agents

    def gen(state, key):
        del key
        return {"w": state["w"] - state["t"]}, {}, state

    def app(state, g):
        return {"w": state["w"] - 0.5 * g["w"], "t": state["t"]}

    ddal = DDAL(spec, gen, app, lambda s: {"w": s["w"]},
                topology=topology)
    gs = ddal.init({"w": jnp.zeros((n, 3)),
                    "t": jnp.arange(n, dtype=jnp.float32)[:, None]})
    step = jax.jit(ddal.epoch_step)
    for e in range(epochs):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
    return gs


def _assert_groupstates_bitwise_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.agent_states["w"]),
                                  np.asarray(b.agent_states["w"]))
    for x, y in zip(jax.tree.leaves(a.stores), jax.tree.leaves(b.stores)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_dynamic_never_resample_equals_static_random_k_bitwise():
    """resample_every = 0 is the static limit: a DynamicTopology that
    never resamples must reproduce the static random_k sparse path
    bit for bit (agent params and stores)."""
    static_spec = GroupSpec(n_agents=6, threshold=2, minibatch=2,
                            m_pieces=6, topology="random_k", degree=3,
                            topology_seed=9)
    gs_static = _run_group(static_spec)
    dyn_topo = T.DynamicTopology(base=T.random_k(6, 3, 9),
                                 resample_every=0, seed=9)
    gs_dyn = _run_group(static_spec, topology=dyn_topo)
    _assert_groupstates_bitwise_equal(gs_static, gs_dyn)


def test_uniform_relevance_mode_is_bitwise_static_eq4():
    """relevance_mode="uniform" (the default) must reproduce the
    static eq. 4 weighting exactly: identical GroupState to an
    explicitly-uniform run, learned estimate untouched at its
    all-ones prior, and the stores' R metadata equal to the
    topology's static relevance table."""
    spec = GroupSpec(n_agents=5, threshold=2, minibatch=2, m_pieces=8,
                     topology="ring", relevance_mode="uniform")
    gs = _run_group(spec)
    np.testing.assert_array_equal(np.asarray(gs.relevance),
                                  np.ones((5, 5), np.float32))
    # R delivered into the stores is exactly the static per-edge table
    R = np.asarray(gs.stores.R)
    valid = np.asarray(gs.stores.valid)
    assert set(np.unique(R[valid]).tolist()) <= {1.0}
    # and the run is bitwise-identical to the pre-relevance-mode
    # construction (explicit static topology object, no spec modes)
    gs_ref = _run_group(spec, topology=T.ring(5))
    _assert_groupstates_bitwise_equal(gs, gs_ref)


def test_dynamic_sparse_delivery_stays_graph_local_per_round():
    """Pieces delivered under a resampling topology come only from
    the round's neighbor table (delay 0 ⇒ same-epoch delivery), and
    successive rounds use different tables."""
    n, k, every = 8, 3, 1
    spec = GroupSpec(n_agents=n, threshold=0, minibatch=1_000,
                     m_pieces=k, topology="random_k", degree=k,
                     topology_seed=2, resample_every=every)

    def gen(state, key):
        del key
        return {"w": state["id"]}, {}, state

    ddal = DDAL(spec, gen, lambda s, g: s, lambda s: {"w": s["w"]})
    gs = ddal.init({"w": jnp.zeros((n, 1)),
                    "id": jnp.arange(n, dtype=jnp.float32)[:, None]})
    step = jax.jit(ddal.epoch_step)
    dt = ddal.topology
    for e in range(4):
        gs, _ = step(gs, jax.random.split(jax.random.PRNGKey(e), n))
        # m_pieces == k ⇒ the store holds exactly this epoch's delivery
        nbr = np.asarray(dt.at_epoch(e).nbr)
        vals = np.asarray(gs.stores.grads["w"])[:, :, 0]   # (n, k)
        valid = np.asarray(gs.stores.valid)
        for i in range(n):
            assert valid[i].all()
            assert set(vals[i].astype(int).tolist()) == \
                set(nbr[i].tolist())


# ----------------------------------------------------------------------
# topology-aware delays: hop distances + staleness
# ----------------------------------------------------------------------
def test_hop_distances_ring_and_star():
    d = T.hop_distances(T.ring(8))
    idx = np.arange(8)
    expect = np.minimum((idx[:, None] - idx[None, :]) % 8,
                        (idx[None, :] - idx[:, None]) % 8)
    np.testing.assert_array_equal(d, expect)
    ds = T.hop_distances(T.star(5))
    assert ds[1, 2] == 2 and ds[1, 0] == 1 and ds[0, 2] == 1
    np.testing.assert_array_equal(np.diag(ds), np.zeros(5))


def test_hop_distances_disconnected_raises():
    two_islands = T._from_neighbor_lists([[0], [1]])
    with pytest.raises(ValueError, match="not strongly connected"):
        T.hop_distances(two_islands)


def test_delay_from_hops_attaches_graph_distance_delays():
    latency = 3
    topo = T.delay_from_hops(T.full(6), latency, graph=T.ring(6))
    hops = T.hop_distances(T.ring(6))
    nbr = np.asarray(topo.nbr)
    delay = np.asarray(topo.delay)
    for i in range(6):
        for j in range(topo.degree):
            assert delay[i, j] == hops[nbr[i, j], i] * latency
    with pytest.raises(ValueError, match="latency"):
        T.delay_from_hops(T.ring(6), -1)


def test_hop_delay_staleness_arrival_times():
    """Full communication over a ring(8) physical graph with hop-count
    delays: a piece sent at epoch e by an agent at graph distance d
    arrives exactly at epoch e + d·latency — never earlier, never
    later (extends the graph-local delivery test to the time axis)."""
    n, latency, epochs = 8, 2, 12
    topo = T.delay_from_hops(T.full(n), latency, graph=T.ring(n))
    hops = T.hop_distances(T.ring(n))
    D = topo.max_delay
    params = {"w": jnp.zeros((1,))}
    flight = K.make_sparse_inflight(params, topo, D)
    stores = jax.vmap(lambda _: K.make_store(params, n * (D + 2)))(
        jnp.arange(n))
    first_seen = np.full((n, n), -1)         # [dst, src] arrival epoch
    for e in range(epochs):
        pieces = {"w": jnp.arange(n, dtype=jnp.float32)[:, None]}
        Tw = jnp.ones((n,), jnp.float32)
        flight = K.sparse_send(flight, topo, pieces, Tw, e, True)
        flight, stores = K.sparse_deliver(flight, stores, e)
        vals = np.asarray(stores.grads["w"])[:, :, 0]
        valid = np.asarray(stores.valid)
        for dst in range(n):
            for src in set(vals[dst, valid[dst]].astype(int).tolist()):
                if first_seen[dst, src] < 0:
                    first_seen[dst, src] = e
    # sending starts at epoch 0 ⇒ first arrival is exactly the delay
    np.testing.assert_array_equal(first_seen,
                                  (hops * latency).T)


@pytest.mark.slow
def test_streaming_ring_topology_trains():
    """End-to-end: the streaming trainer share-steps over a ring
    without NaNs and with per-agent loss movement."""
    from repro import optim
    from repro.configs import get_arch_config
    from repro.configs.base import ShapeConfig
    from repro.core import init_train_state, make_group_train_step
    from repro.data import StreamSpec, make_group_batch

    cfg = get_arch_config("llama3.2-3b").reduced()
    spec = GroupSpec(n_agents=4, threshold=0, minibatch=1,
                     topology="ring", knowledge_mode="streaming")
    opt = optim.sgd(0.1)
    state = init_train_state(cfg, spec, opt, jax.random.PRNGKey(0))
    shape = ShapeConfig("t", 32, 4, "train")
    step = jax.jit(make_group_train_step(cfg, spec, opt))
    losses = []
    for i in range(3):
        batch = make_group_batch(cfg, shape, StreamSpec(), 4, i)
        state, m = step(state, batch)
        assert bool(jnp.isfinite(m["loss"]).all())
        losses.append(np.asarray(m["loss"]))
    assert not np.allclose(losses[0], losses[-1])
