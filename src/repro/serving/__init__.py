"""Serving: batched prefill + decode over functional KV/SSM caches,
plus vLLM-style continuous batching (repro.serving.continuous)."""
from repro.serving.continuous import ContinuousBatcher  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    DecodeState,
    ServeConfig,
    ServeEngine,
    serve_batches,
)
