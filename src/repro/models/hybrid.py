"""Zamba2-style hybrid: super-blocks of Mamba2 layers punctuated by a
SHARED attention/MLP block with per-call-site LoRA adapters
(arXiv:2411.15242). The outer scan runs over super-blocks (the shared
block's weights are captured by closure — one copy in HLO), the inner
scan over the Mamba2 layers of each block.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp

from repro.common.sharding import shard
from repro.models import attention as attn
from repro.models.common import cross_entropy, dense_init, embed_init, rms_norm
from repro.models.mamba2 import (init_mamba2, make_mamba_state,
                                 mamba2_decode, mamba2_forward)
from repro.models.mlp import init_swiglu, swiglu

_LORA_TARGETS = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
}


def _init_lora(cfg, key, shapes):
    r = cfg.hybrid.lora_rank
    dt = cfg.dtype("param")
    p = {}
    for name, (din, dout) in shapes.items():
        ka = jax.random.fold_in(key, zlib.crc32(name.encode()) % 2**31)
        p[name] = {"a": dense_init(ka, (din, r), dt),
                   "b": jnp.zeros((r, dout), dt)}
    return p


def _lora_shapes(cfg):
    E, H, K, D, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                     cfg.head_dim, cfg.d_ff)
    return {
        "wq": (E, H * D), "wk": (E, K * D), "wv": (E, K * D),
        "wo": (H * D, E),
        "w_gate": (E, F), "w_up": (E, F), "w_down": (F, E),
    }


def _merge_lora(shared, lora, cdt):
    """Effective weights for one call-site: W + A·B."""
    out = dict(shared)
    out["attn"] = dict(shared["attn"])
    out["mlp"] = dict(shared["mlp"])
    for grp, names in _LORA_TARGETS.items():
        for n in names:
            delta = (lora[n]["a"].astype(cdt) @ lora[n]["b"].astype(cdt))
            out[grp][n] = shared[grp][n].astype(cdt) + delta
    return out


def init_hybrid(cfg, key):
    hy = cfg.hybrid
    k_e, k_m, k_s, k_l, k_t, k_h = jax.random.split(key, 6)
    dt = cfg.dtype("param")
    params = {
        "embed": embed_init(k_e, (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(k_h, (cfg.d_model, cfg.vocab_size), dt),
    }
    # shared attention/MLP block (single copy)
    ka, kf = jax.random.split(k_s)
    params["shared"] = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": attn.init_self_attention(cfg, ka),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "mlp": init_swiglu(kf, cfg.d_model, cfg.d_ff, dt),
    }

    def one_mamba(k):
        return {"ln": jnp.ones((cfg.d_model,), dt),
                "mamba": init_mamba2(cfg, k)}

    nb, mpb = hy.n_super_blocks, hy.mamba_per_block
    keys = jax.random.split(k_m, (nb, mpb))
    params["mamba_blocks"] = jax.vmap(jax.vmap(one_mamba))(keys)
    params["lora"] = jax.vmap(
        lambda k: _init_lora(cfg, k, _lora_shapes(cfg)))(
        jax.random.split(k_l, nb))
    if hy.tail_mamba:
        params["tail"] = jax.vmap(one_mamba)(
            jax.random.split(k_t, hy.tail_mamba))
    return params


def _shared_block(cfg, weights, x, positions, kv_cache):
    h = rms_norm(x, weights["ln1"], cfg.norm_eps)
    a, new_kv = attn.self_attention(cfg, weights["attn"], h, positions,
                                    layer_cache=kv_cache)
    x = x + a
    h2 = rms_norm(x, weights["ln2"], cfg.norm_eps)
    x = x + swiglu(weights["mlp"], h2, cfg.dtype("compute"))
    return x, new_kv


def _mamba_sublayer(cfg, lp, x, lstate, decode: bool):
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    fn = mamba2_decode if decode else mamba2_forward
    o, new_state = fn(cfg, lp["mamba"], h, lstate)
    return x + o, new_state


def hybrid_forward(cfg, params, batch, cache=None, decode=False):
    """cache: {"mamba": stacked (nb, mpb, ...) states, "kv": (nb, ...)
    KV caches, "tail": (tail, ...) states} or None (training)."""
    cdt = cfg.dtype("compute")
    x = params["embed"].astype(cdt)[batch["tokens"]]
    x = shard(x, "batch", None, None)
    positions = batch["positions"]
    want_cache = cache is not None
    shared = params["shared"]

    def inner(xc, per_layer):
        lp, lstate = per_layer
        xo, st = _mamba_sublayer(cfg, lp, xc, lstate, decode)
        return xo, (st if want_cache else None)

    def super_block(xc, xs):
        mparams, lora, mstate, kvc = xs
        if want_cache:
            xc, states = jax.lax.scan(inner, xc, (mparams, mstate),
                                      unroll=cfg.unroll_layers)
        else:
            xc, _ = jax.lax.scan(lambda c, lp: inner(c, (lp, None)),
                                 xc, mparams, unroll=cfg.unroll_layers)
            states = None
        weights = _merge_lora(shared, lora, cdt)
        xc, new_kv = _shared_block(cfg, weights, xc, positions, kvc)
        return xc, (states, new_kv)

    body_fn = super_block
    if cfg.remat and not want_cache:
        body_fn = jax.checkpoint(
            super_block, policy=jax.checkpoint_policies.nothing_saveable)

    if want_cache:
        xs = (params["mamba_blocks"], params["lora"],
              cache["mamba"], cache["kv"])
    else:
        xs = (params["mamba_blocks"], params["lora"], None, None)
    x, (new_mstates, new_kvs) = jax.lax.scan(body_fn, x, xs,
                                             unroll=cfg.unroll_layers)

    new_tail = None
    if cfg.hybrid.tail_mamba:
        tstate = cache["tail"] if want_cache else None
        if want_cache:
            x, new_tail = jax.lax.scan(inner, x,
                                       (params["tail"], tstate),
                                       unroll=cfg.unroll_layers)
        else:
            x, _ = jax.lax.scan(lambda c, lp: inner(c, (lp, None)),
                                x, params["tail"],
                                unroll=cfg.unroll_layers)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x @ params["lm_head"].astype(cdt), "batch", None, "vocab")
    new_cache = None
    if want_cache:
        new_cache = {"mamba": new_mstates, "kv": new_kvs,
                     "tail": new_tail}
    return logits, jnp.float32(0.0), new_cache


def hybrid_decode(cfg, params, batch, cache):
    logits, _, new_cache = hybrid_forward(cfg, params, batch, cache,
                                          decode=True)
    return logits, new_cache


def hybrid_loss(cfg, params, batch):
    logits, aux, _ = hybrid_forward(cfg, params, batch)
    return cross_entropy(logits, batch["labels"]) + aux


def make_hybrid_cache(cfg, batch: int, max_len: int):
    hy = cfg.hybrid
    nb, mpb = hy.n_super_blocks, hy.mamba_per_block
    cache = {
        "mamba": jax.tree.map(
            lambda x: x.reshape((nb, mpb) + x.shape[1:]),
            make_mamba_state(cfg, batch, nb * mpb)),
        "kv": attn.make_kv_cache(cfg, batch, max_len, nb),
        "tail": make_mamba_state(cfg, batch, hy.tail_mamba),
    }
    return cache
