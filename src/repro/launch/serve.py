"""Serving launcher: batched prefill+decode for any model-zoo arch.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --requests 6 --batch 2 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="llama3.2-3b")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--full", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_arch_config
    from repro.models import get_model
    from repro.serving import ServeConfig, ServeEngine, serve_batches

    cfg = get_arch_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, ServeConfig(
        max_len=args.max_len, max_new_tokens=args.new_tokens,
        temperature=args.temperature))

    rng = np.random.default_rng(args.seed)
    requests = [list(rng.integers(0, cfg.vocab_size,
                                  rng.integers(2, args.prompt_len)))
                for _ in range(args.requests)]
    t0 = time.time()
    n_out = 0
    for bi, (toks, lens) in enumerate(serve_batches(requests,
                                                    args.batch)):
        out = engine.generate(toks, lens, jax.random.PRNGKey(bi))
        n_out += out.shape[0] * out.shape[1]
        for row in range(out.shape[0]):
            print(f"batch {bi} slot {row}: "
                  f"prompt={np.asarray(toks[row][:int(lens[row])])} "
                  f"-> {np.asarray(out[row])}")
    dt = time.time() - t0
    print(f"{n_out} tokens in {dt:.1f}s ({n_out / dt:,.0f} tok/s, "
          f"incl. compile)")


if __name__ == "__main__":
    main()
