"""Batched serving engine: prefill + token-by-token decode over the
model zoo's functional KV caches (full / sliding-window ring / MLA
latent / SSM state — whichever ``model.make_cache`` builds for the
arch).

The decode loop is a single jitted ``lax.scan`` over new tokens with
per-slot done masking; the host-side ``serve_batches`` helper packs a
request list into fixed-size batches (static shapes → one compilation).
Decode-shape dry-runs lower exactly ``decode_step`` (one token + cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import get_model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512           # cache capacity
    max_new_tokens: int = 64
    temperature: float = 0.0     # 0 → greedy
    eos_id: int = -1             # -1 → never stops early


class DecodeState(NamedTuple):
    cache: Any
    tokens: jnp.ndarray          # (B, 1) last emitted token
    pos: jnp.ndarray             # (B,) next absolute position
    done: jnp.ndarray            # (B,) bool


def _decode_batch(cfg: ArchConfig, tokens, positions):
    """Wrap a (B, 1) token into the arch's decode-batch dict."""
    if cfg.family == "audio":
        t = jnp.broadcast_to(tokens[:, None, :],
                             (tokens.shape[0], cfg.n_codebooks, 1))
        return {"tokens": t, "positions": positions}
    if cfg.family == "vlm":
        pos3 = jnp.broadcast_to(positions[:, None, :],
                                (positions.shape[0], 3, 1))
        return {"tokens": tokens, "positions": pos3}
    return {"tokens": tokens, "positions": positions}


def _last_logits(cfg: ArchConfig, logits):
    """(B, V) next-token logits from a decode/prefill output."""
    if cfg.family == "audio":                  # (B, C, T, V): codebook 0
        return logits[:, 0, -1, :]
    return logits[:, -1, :]


class ServeEngine:
    """One arch, one batch size, one cache capacity → compiled once."""

    def __init__(self, cfg: ArchConfig, params, serve: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.model = get_model(cfg)
        self._prefill = jax.jit(self._prefill_impl)
        self._generate = jax.jit(self._generate_impl)

    # -- prefill -------------------------------------------------------
    def _prefill_impl(self, params, tokens, lengths):
        """tokens: (B, P) prompt ids (right-padded); lengths: (B,)."""
        B, P = tokens.shape
        cfg = self.cfg
        pos = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
        cache = self.model.make_cache(cfg, B, self.serve.max_len)
        if cfg.family == "audio":
            batch = {"tokens": jnp.broadcast_to(
                        tokens[:, None, :], (B, cfg.n_codebooks, P)),
                     "positions": pos,
                     "cond": jnp.zeros((B, cfg.cond_len, cfg.d_model),
                                       cfg.dtype("compute"))}
        elif cfg.family == "vlm":
            batch = {"tokens": tokens,
                     "vision": jnp.zeros((B, cfg.vision_prefix,
                                          cfg.d_model),
                                         cfg.dtype("compute")),
                     "positions": jnp.broadcast_to(
                         jnp.arange(P + cfg.vision_prefix,
                                    dtype=jnp.int32),
                         (B, 3, P + cfg.vision_prefix))}
        else:
            batch = {"tokens": tokens, "positions": pos}
        logits, cache = self.model.forward(cfg, params, batch, cache)
        # next-token logits come from each prompt's LAST real token
        idx = jnp.maximum(lengths - 1, 0)
        if cfg.family == "audio":
            nxt = logits[jnp.arange(B), 0, idx, :]
        else:
            nxt = logits[jnp.arange(B), idx, :]
        return nxt, cache

    # -- decode loop ---------------------------------------------------
    def _sample(self, logits, key):
        if self.serve.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.serve.temperature).astype(jnp.int32)

    def _generate_impl(self, params, tokens, lengths, key):
        cfg, serve = self.cfg, self.serve
        B = tokens.shape[0]
        first_logits, cache = self._prefill_impl(params, tokens, lengths)
        k0, key = jax.random.split(key)
        tok0 = self._sample(first_logits, k0)
        state = DecodeState(
            cache=cache,
            tokens=tok0[:, None],
            pos=lengths.astype(jnp.int32),
            done=tok0 == serve.eos_id,
        )

        def step(st: DecodeState, k):
            batch = _decode_batch(cfg, st.tokens, st.pos[:, None])
            logits, cache = self.model.decode(cfg, params, batch,
                                              st.cache)
            nxt = self._sample(_last_logits(cfg, logits), k)
            nxt = jnp.where(st.done, st.tokens[:, 0], nxt)
            done = st.done | (nxt == serve.eos_id)
            new = DecodeState(cache=cache, tokens=nxt[:, None],
                              pos=st.pos + 1, done=done)
            return new, nxt

        keys = jax.random.split(key, serve.max_new_tokens - 1)
        state, rest = jax.lax.scan(step, state, keys)
        out = jnp.concatenate([tok0[:, None], rest.T], axis=1)
        return out                                  # (B, max_new_tokens)

    # -- public --------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, lengths: jnp.ndarray,
                 key=None) -> jnp.ndarray:
        """prompts: (B, P) right-padded int32; lengths: (B,)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return self._generate(self.params, prompts, lengths, key)


def serve_batches(requests: Sequence[Sequence[int]], batch_size: int,
                  pad_id: int = 0) -> List[Tuple[Any, Any]]:
    """Pack a request list into fixed-(B, P) numpy batches (static
    shapes → single compilation); returns [(tokens, lengths), ...]."""
    import numpy as np
    out = []
    for i in range(0, len(requests), batch_size):
        chunk = list(requests[i:i + batch_size])
        while len(chunk) < batch_size:          # pad the tail batch
            chunk.append([pad_id])
        P = max(len(r) for r in chunk)
        toks = np.full((batch_size, P), pad_id, np.int32)
        lens = np.zeros((batch_size,), np.int32)
        for j, r in enumerate(chunk):
            toks[j, :len(r)] = r
            lens[j] = len(r)
        out.append((jnp.asarray(toks), jnp.asarray(lens)))
    return out
