"""Benchmark orchestrator — one benchmark per paper table/figure plus
the beyond-paper tables.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip-rl]

  fig2   DDA3C 1 vs 2 agents            (paper Fig. 2)
  fig34  DDA3C 4- and 6-agent scaling   (paper Figs. 3–4)
  fig5   DDADQN 1 vs 2 agents           (paper Fig. 5)
  wavg   eq. 4 kernel roofline          (beyond paper)
  cadence DDAL cadence vs traffic       (beyond paper)
  roofline 40-pair dry-run table        (from dryrun JSON, if present)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _roofline_table(path: str):
    with open(path) as f:
        recs = json.load(f)
    ok = [r for r in recs if r.get("ok")]
    print(f"\n== roofline (from {path}: {len(ok)}/{len(recs)} pairs) ==")
    print(f"{'arch':22s} {'shape':12s} {'dom':10s} {'t_comp':>10s} "
          f"{'t_mem':>10s} {'t_coll':>10s} {'useful':>7s} {'GiB/dev':>8s}")
    for r in ok:
        rf = r["roofline"]
        gib = (r.get("memory") or {}).get("total_bytes_per_device")
        print(f"{r['arch']:22s} {r['shape']:12s} {rf['dominant']:10s} "
              f"{rf['t_compute']:10.3e} {rf['t_memory']:10.3e} "
              f"{rf['t_collective']:10.3e} {rf['useful_ratio']:7.2f} "
              f"{(gib / 2**30 if gib else 0):8.2f}")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--full", action="store_true",
                   help="paper-scale epoch budgets (slow)")
    p.add_argument("--skip-rl", action="store_true",
                   help="skip the RL figure benches (CI speed)")
    p.add_argument("--quick", action="store_true",
                   help="tiny epoch budgets (smoke only)")
    args = p.parse_args(argv)

    t0 = time.time()
    print("== bench: eq.4 weighted-average kernel (beyond paper) ==")
    from benchmarks.bench_wavg_kernel import main as wavg
    wavg()

    print("\n== bench: DDAL cadence vs traffic (beyond paper) ==")
    from benchmarks.bench_train_throughput import main as cad
    cad(steps=4 if args.quick else 12)

    if not args.skip_rl:
        e2 = 800 if args.quick else (50_000 if args.full else 5_000)
        e5 = 600 if args.quick else (7_000 if args.full else 4_000)
        print("\n== bench: paper Fig. 2 (DDA3C 1 vs 2 agents) ==")
        from benchmarks.paper_fig2_a2c import main as fig2
        fig2(epochs=e2)
        print("\n== bench: paper Figs. 3-4 (4/6-agent scaling) ==")
        from benchmarks.paper_fig34_scaling import main as fig34
        if args.quick:
            fig34(epochs4=600, epochs6=400)
        elif args.full:
            fig34(epochs4=20_000, epochs6=10_000)
        else:
            fig34()
        print("\n== bench: paper Fig. 5 (DDADQN 1 vs 2 agents) ==")
        from benchmarks.paper_fig5_dqn import main as fig5
        fig5(epochs=e5)
        if not args.quick:
            print("\n== bench: DDAL ablations (delay / T-weighting / "
                  "topology — beyond paper) ==")
            from benchmarks.ablation_ddal import main as abl
            abl()

    for path in ("dryrun_single_pod.json", "dryrun_multi_pod.json",
                 "dryrun_single_pod_optimized.json",
                 "dryrun_multi_pod_optimized.json"):
        if os.path.exists(path):
            _roofline_table(path)

    print(f"\nall benchmarks done in {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
