"""Serving: batched prefill + decode over functional KV/SSM caches,
vLLM-style continuous batching (repro.serving.continuous), and
multi-tenant group serving of every agent's policy from one mesh
(repro.serving.group) with train→serve hot-swap and request metrics
(repro.serving.metrics). Shared primitives live in repro.serving.api.
"""
from repro.serving.api import (  # noqa: F401
    Sampler,
    ServeConfig,
    StopCriteria,
    build_prefill_batch,
    cli_options,
)
from repro.serving.continuous import ContinuousBatcher  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    DecodeState,
    ServeEngine,
    serve_batches,
)
from repro.serving.group import (  # noqa: F401
    GroupRequest,
    GroupServeEngine,
    ParamStore,
    Router,
    publish_from_trainer,
)
from repro.serving.metrics import ServeMetrics  # noqa: F401
