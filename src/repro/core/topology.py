"""Communication topologies for DDAL — neighbor-indexed sparse graphs.

The paper's group is a set of geographically distributed agents that
exchange knowledge over a *communication graph*, not a shared
environment (paper §5; arXiv 2501.11818 and 1912.03821 make the same
point for networked MARL). The seed repo simulated that graph with a
dense all-to-all delay line — O(n²·D·|params|) memory — and used
``GroupSpec.topology`` only as a relevance prior. This module makes the
graph first-class:

A ``Topology`` is a *neighbor index table*: for every destination agent
``i``, ``nbr[i, j]`` names the source agent feeding its ``j``-th
incoming edge slot (``j < k``), with a validity ``mask`` for
non-uniform in-degrees and per-edge ``delay`` / ``relevance``
annotations. All arrays are static (host-built with numpy) so they jit
as constants; knowledge exchange becomes gather/scatter over the table
(``repro.core.knowledge.sparse_send`` / ``sparse_deliver``) with
delay-line memory O(n·k·D) instead of O(n²·D). The dense ``full``
topology is the ``k = n`` special case, so the seed semantics are a
strict subset.

Every constructor includes the self-loop edge (an agent's own pieces
always enter its own store K_i, paper Algorithm 1 line 8) with delay 0
unless overridden.

Two extensions make the wiring *adaptive* (ISSUE 2):

* ``DynamicTopology`` — time-varying gossip (arXiv 1912.03821): the
  ``random_k`` neighbor table is resampled every ``resample_every``
  epochs inside the jitted loop, seeded by a fold of
  ``(topology_seed, epoch // resample_every)`` so resampling is
  deterministic and replayable. ``at_epoch`` returns a *traced*
  ``Topology`` that ``sparse_send`` / ``sparse_deliver`` consume
  directly; ``resample_every = 0`` degenerates to the static base
  table (bitwise-identical to the static ``random_k`` path).
* ``delay_from_hops`` — topology-aware delay models: per-edge delays
  proportional to graph distance (hop count × latency) on an
  underlying physical graph, so a piece from a distance-d agent
  arrives exactly d·latency epochs later.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class Topology(NamedTuple):
    """Sparse communication graph over ``n`` agents.

    nbr:       (n, k) int32 — ``nbr[i, j]`` = source agent of dst i's
               j-th incoming edge (arbitrary value where masked out).
    mask:      (n, k) bool — which edge slots are real edges.
    delay:     (n, k) int32 — per-edge delivery delay in epochs.
    relevance: (n, k) float32 — per-edge relevance R[src→dst] fed to
               the eq. 4 weighting on delivery.
    """
    nbr: jnp.ndarray
    mask: jnp.ndarray
    delay: jnp.ndarray
    relevance: jnp.ndarray

    # ------------------------------------------------------------------
    @property
    def n_agents(self) -> int:
        return self.nbr.shape[0]

    @property
    def degree(self) -> int:
        """Max in-degree k (the padded edge-slot count)."""
        return self.nbr.shape[1]

    @property
    def n_edges(self) -> int:
        """Number of real (unmasked) edges, self-loops included."""
        return int(np.asarray(self.mask).sum())

    @property
    def max_delay(self) -> int:
        return int(np.asarray(jnp.max(self.delay * self.mask)))

    # ------------------------------------------------------------------
    def with_delay(self, delay, per_edge: bool = False) -> "Topology":
        """Attach delays: a scalar, an (n, n) src→dst matrix (gathered
        onto the edge table), or an (n, k) per-edge array. When k == n
        the two array forms are shape-ambiguous and the dense src→dst
        reading wins — pass ``per_edge=True`` to force the
        (dst, edge-slot) interpretation (they differ by a transpose on
        the ``full`` topology)."""
        n, k = self.nbr.shape
        d = jnp.asarray(delay, jnp.int32)
        if d.ndim == 0:
            d = jnp.full((n, k), d, jnp.int32)
        elif d.shape == (n, n) and not per_edge:
            dst = jnp.arange(n)[:, None]
            d = d[self.nbr, dst]                      # (n, k)
        elif d.shape != (n, k):
            raise ValueError(f"delay shape {d.shape} != (), ({n},{n}) "
                             f"or ({n},{k})")
        return self._replace(delay=jnp.where(self.mask, d, 0))

    def with_relevance(self, relevance,
                       per_edge: bool = False) -> "Topology":
        """Attach relevance: an (n, n) matrix R[src, dst] (gathered
        onto the edge table) or an (n, k) per-edge array. See
        ``with_delay`` for the k == n ambiguity and ``per_edge``."""
        n, k = self.nbr.shape
        r = jnp.asarray(relevance, jnp.float32)
        if r.shape == (n, n) and not per_edge:
            dst = jnp.arange(n)[:, None]
            r = r[self.nbr, dst]
        elif r.shape != (n, k):
            raise ValueError(f"relevance shape {r.shape} != ({n},{n}) "
                             f"or ({n},{k})")
        return self._replace(
            relevance=jnp.where(self.mask, r, 0.0))

    def dense_relevance(self) -> jnp.ndarray:
        """Scatter the edge relevance back to an (n, n) R[src, dst]
        matrix (zeros off-graph) — for code still wanting the dense
        form (e.g. the streaming trainer's matmul path)."""
        n, k = self.nbr.shape
        R = jnp.zeros((n, n), jnp.float32)
        dst = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
        vals = jnp.where(self.mask, self.relevance, 0.0)
        return R.at[self.nbr, dst].add(vals)

    def delay_line_bytes(self, n_params: int, max_delay: int,
                         dtype_bytes: int = 4) -> int:
        """Static memory of a SparseInFlight over this topology
        (D+1 delivery planes + 1 scratch plane)."""
        n, k = self.nbr.shape
        planes = max_delay + 2
        meta = 3 * n * k * planes * 4        # T, R (+valid ≈ 1B, round)
        return n * k * planes * n_params * dtype_bytes + meta


# ---------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------
def _from_neighbor_lists(nbrs: Sequence[Sequence[int]]) -> Topology:
    """Build a padded (n, k) table from per-dst in-neighbor lists.

    A source repeated in one destination's list would double-count its
    plane in every eq. 4 sum (the segment-sum adds one term per edge
    slot), so duplicates are a construction error, not a graph choice.
    The constructors all build from sets, but the hierarchical leader
    wiring composes two overlapping sets (pod members ∪ leaders) —
    this guard keeps that overlap from ever reaching the edge table.
    """
    n = len(nbrs)
    k = max(1, max(len(v) for v in nbrs))
    nbr = np.zeros((n, k), np.int32)
    mask = np.zeros((n, k), bool)
    for i, v in enumerate(nbrs):
        if len(set(v)) != len(v):
            raise ValueError(
                f"duplicate in-neighbor for destination {i}: {v} — "
                f"a repeated source double-counts its plane in eq. 4")
        nbr[i, :len(v)] = v
        mask[i, :len(v)] = True
    return Topology(
        nbr=jnp.asarray(nbr),
        mask=jnp.asarray(mask),
        delay=jnp.zeros((n, k), jnp.int32),
        relevance=jnp.asarray(mask, jnp.float32),
    )


def full(n: int) -> Topology:
    """All-to-all: k = n, ``nbr[i, j] = j`` — the dense seed layout as
    a special case (edge slot order == source order, so the sparse
    path is bitwise-identical to the dense reference)."""
    return _from_neighbor_lists([list(range(n)) for _ in range(n)])


def ring(n: int) -> Topology:
    """Bidirectional ring: each agent hears itself and its two ring
    neighbours (matches ``relevance_matrix(n, "ring")``'s support)."""
    return _from_neighbor_lists(
        [sorted({(i - 1) % n, i, (i + 1) % n}) for i in range(n)])


def torus2d(rows: int, cols: int) -> Topology:
    """2-D torus (rows × cols grid, wrap-around): self + the 4-mesh
    neighbourhood — the classic pod-interconnect shape."""
    n = rows * cols
    nbrs = []
    for i in range(n):
        r, c = divmod(i, cols)
        nbrs.append(sorted({
            i,
            ((r - 1) % rows) * cols + c,
            ((r + 1) % rows) * cols + c,
            r * cols + (c - 1) % cols,
            r * cols + (c + 1) % cols,
        }))
    return _from_neighbor_lists(nbrs)


def star(n: int, hub: int = 0) -> Topology:
    """Hub-and-spoke: every leaf exchanges with the hub only. The hub's
    in-degree is n (it hears everyone), so the padded k is n — star is
    inherently centralised; use it for parameter-server-style groups."""
    nbrs = []
    for i in range(n):
        if i == hub:
            nbrs.append(list(range(n)))
        else:
            nbrs.append(sorted({i, hub}))
    return _from_neighbor_lists(nbrs)


def random_k(n: int, k: int, seed: int = 0) -> Topology:
    """Seeded gossip graph: each destination hears itself plus k−1
    distinct uniformly-drawn other agents. Regular in-degree k, so the
    delay line is exactly (n, k, D+1) with no padding waste."""
    if k < 1:
        raise ValueError("random_k needs k >= 1 (the self-loop)")
    k = min(k, n)
    rng = np.random.default_rng(seed)
    nbrs = []
    for i in range(n):
        others = np.delete(np.arange(n), i)
        pick = rng.choice(others, size=k - 1, replace=False)
        nbrs.append(sorted({i, *pick.tolist()}))
    return _from_neighbor_lists(nbrs)


def hierarchical(n: int, pod_size: int = 4) -> Topology:
    """Pods-of-pods: dense all-to-all inside each pod of ``pod_size``
    agents; the first agent of each pod is a *leader* additionally
    connected all-to-all with the other leaders. Knowledge crosses pods
    in two hops (member → leader → member), mirroring ICI-dense /
    DCN-sparse pod fabrics.

    A leader belongs to both sets it is wired from (its pod's members
    and the leader clique), so its own id must enter its neighbor list
    exactly once — the set union here plus the duplicate guard in
    ``_from_neighbor_lists`` pin that; ``repro.core.pod_dispatch``
    additionally masks the leader self-edge out of the cross-pod edge
    list (the leader's own plane enters through the intra-pod sum)."""
    pod_size = max(1, min(pod_size, n))
    leaders = list(range(0, n, pod_size))
    nbrs = []
    for i in range(n):
        pod = i // pod_size
        members = [j for j in range(pod * pod_size,
                                    min((pod + 1) * pod_size, n))]
        s = set(members) | {i}
        if i in leaders:
            s |= set(leaders)
        nbrs.append(sorted(s))
    return _from_neighbor_lists(nbrs)


# ---------------------------------------------------------------------
# pod placement metadata (multi-host dispatch, ISSUE 3)
# ---------------------------------------------------------------------
class PodLayout(NamedTuple):
    """Static agent→pod placement for the ``hierarchical`` topology.

    pod_id:      (n,) int32 — pod of each agent.
    leader_mask: (n,) bool  — True for the one leader per pod.
    leaders:     (pods,) int32 — the leader agent of each pod.
    pod_size:    agents per pod (uniform — validated).

    All arrays are host numpy (the layout is placement, not data): it
    parameterises which mesh axis each edge's exchange crosses, so it
    must be static at trace time.
    """
    pod_id: np.ndarray
    leader_mask: np.ndarray
    leaders: np.ndarray
    pod_size: int

    @property
    def n_agents(self) -> int:
        return int(self.pod_id.shape[0])

    @property
    def n_pods(self) -> int:
        return int(self.leaders.shape[0])


def hierarchical_layout(n: int, pod_size: int) -> PodLayout:
    """The placement emitted alongside ``hierarchical(n, pod_size)``:
    contiguous pods of ``pod_size`` agents, first agent of each pod is
    its leader. Dispatch onto a two-level mesh needs uniform pods, so
    ``pod_size`` must divide ``n``."""
    if pod_size < 1 or n % pod_size:
        raise ValueError(
            f"hierarchical_layout needs pod_size >= 1 dividing "
            f"n_agents, got n={n}, pod_size={pod_size}")
    pod_id = (np.arange(n, dtype=np.int32) // pod_size).astype(np.int32)
    leaders = np.arange(0, n, pod_size, dtype=np.int32)
    leader_mask = np.zeros((n,), bool)
    leader_mask[leaders] = True
    return PodLayout(pod_id=pod_id, leader_mask=leader_mask,
                     leaders=leaders, pod_size=pod_size)


def edge_pod_ids(topo: Topology, layout: PodLayout) -> np.ndarray:
    """(n, k) int32 — the pod of each edge slot's *source* agent
    (arbitrary where masked out, like ``nbr`` itself)."""
    return np.asarray(layout.pod_id)[np.asarray(topo.nbr)]


def cross_pod_mask(topo: Topology, layout: PodLayout) -> np.ndarray:
    """(n, k) bool — which real edges cross a pod boundary (these are
    the only edges whose exchange must ride the slow ``pod`` mesh
    axis; everything else stays on the fast intra-pod axis)."""
    src_pod = edge_pod_ids(topo, layout)
    dst_pod = np.asarray(layout.pod_id)[:, None]
    return np.asarray(topo.mask) & (src_pod != dst_pod)


# ---------------------------------------------------------------------
# dynamic gossip (time-varying random_k)
# ---------------------------------------------------------------------
def sample_gossip(key, n: int, k: int, alive=None) -> jnp.ndarray:
    """Jit-traceable k-regular gossip table: for every destination,
    edge slot 0 is the self-loop and slots 1..k-1 are k−1 distinct
    uniformly-drawn other agents. Returns an (n, k) int32 ``nbr``
    table; the mask is all-True (regular in-degree, no padding).

    Sampling without replacement is an argsort over per-row uniforms
    with the diagonal pushed past every real value — O(n² log n)
    scalars, negligible next to the delay line, and fully traceable so
    the table can be resampled *inside* the scanned epoch loop.

    ``alive`` ((n,) bool, optional) demotes dead sources below every
    live candidate (and below the diagonal), so a dead agent is only
    ever drawn once fewer than k−1 live others exist; those residual
    edges carry nothing because the send gate also ANDs in ``alive``.
    ``alive=None`` is byte-for-byte the historical sampler.
    """
    if not 1 <= k <= n:
        raise ValueError(f"sample_gossip needs 1 <= k <= n, got k={k}")
    u = jax.random.uniform(key, (n, n))
    u = u + 2.0 * jnp.eye(n)            # self never among the draws
    if alive is not None:
        # dead columns land in (3, 4): past live non-self (0, 1) and
        # past the live diagonal (2, 3)
        u = u + 3.0 * (~jnp.asarray(alive, bool)).astype(u.dtype)[None, :]
    order = jnp.argsort(u, axis=1).astype(jnp.int32)   # (n, n)
    self_col = jnp.arange(n, dtype=jnp.int32)[:, None]
    return jnp.concatenate([self_col, order[:, :k - 1]], axis=1)


class DynamicTopology(NamedTuple):
    """Time-varying gossip graph: a static ``base`` (the
    ``resample_every = 0`` limit, also fixing all shapes) plus the
    resampling schedule. ``at_epoch(e)`` materialises the epoch's
    ``Topology`` — a traced neighbor table when resampling, the base
    table verbatim when not.

    Per-edge annotations cannot survive a resample (the edge set
    changes), so delays/relevance are carried as dense (n, n) src→dst
    matrices (``dense_delay`` / ``dense_relevance``) and re-gathered
    onto the fresh edge table each round; ``None`` means the base's
    uniform delay / unit relevance.
    """
    base: Topology
    resample_every: int
    seed: int
    dense_delay: Optional[jnp.ndarray] = None       # (n, n) src→dst
    dense_relevance: Optional[jnp.ndarray] = None   # (n, n) src→dst

    @property
    def n_agents(self) -> int:
        return self.base.n_agents

    @property
    def degree(self) -> int:
        return self.base.degree

    @property
    def max_delay(self) -> int:
        if self.dense_delay is not None:
            return int(np.asarray(self.dense_delay).max())
        return self.base.max_delay

    def _uniform_base_delay(self) -> int:
        d = np.asarray(self.base.delay)
        if d.size and not (d == d.flat[0]).all():
            raise ValueError(
                "DynamicTopology needs a uniform base delay or a dense "
                "(n, n) dense_delay matrix — per-edge delays cannot be "
                "re-gathered after a resample")
        return int(d.flat[0]) if d.size else 0

    def with_dense(self, delay=None,
                   relevance=None) -> "DynamicTopology":
        """Attach delay / relevance in the only forms that survive a
        resample: a scalar (uniform) delay or dense (n, n) src→dst
        matrices. Shapes are validated here — a mis-shaped matrix
        would otherwise be clamp-gathered into silently wrong weights
        inside jit. Annotations are also attached to the static base
        so the ``resample_every = 0`` limit carries them."""
        n = self.n_agents
        out = self
        if delay is not None:
            d = np.asarray(delay)
            if d.ndim == 0:
                out = out._replace(base=out.base.with_delay(delay),
                                   dense_delay=None)
            elif d.shape == (n, n):
                out = out._replace(
                    base=out.base.with_delay(delay),
                    dense_delay=jnp.asarray(d, jnp.int32))
            else:
                raise ValueError(
                    f"dynamic topology delay must be scalar or "
                    f"({n},{n}) dense, got {d.shape}")
        if relevance is not None:
            r = np.asarray(relevance)
            if r.shape != (n, n):
                raise ValueError(
                    f"dynamic topology relevance must be ({n},{n}) "
                    f"dense, got {r.shape}")
            out = out._replace(
                base=out.base.with_relevance(relevance),
                dense_relevance=jnp.asarray(r, jnp.float32))
        return out

    def round_table(self, epoch, alive=None) -> jnp.ndarray:
        """The (traced) gossip table of ``epoch``'s resample round:
        ``sample_gossip`` keyed by
        ``fold_in(PRNGKey(seed), epoch // resample_every)`` —
        deterministic in ``(seed, epoch)`` and constant within a
        round. ``alive`` excludes dead sources from the draw (elastic
        membership); it does not enter the key, so a round's table is
        still a pure function of ``(seed, round, alive)``."""
        n, k = self.base.nbr.shape
        rnd = jnp.asarray(epoch, jnp.int32) // self.resample_every
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), rnd)
        return sample_gossip(key, n, k, alive)

    def refresh_table(self, epoch, nbr, alive=None) -> jnp.ndarray:
        """Carried-table refresh for scanned loops: resample only at
        round boundaries (``epoch % resample_every == 0``), otherwise
        keep ``nbr``. Equivalent to ``round_table(epoch)`` when
        epochs are visited in order from 0, but skips the O(n² log n)
        sampler on every off-boundary epoch (the table is tiny, so
        the ``lax.cond`` copy is cheap — unlike the multi-MB flight,
        which never enters a conditional)."""
        if self.resample_every <= 0:
            return nbr
        boundary = (jnp.asarray(epoch, jnp.int32)
                    % self.resample_every) == 0
        return jax.lax.cond(
            boundary,
            lambda _: self.round_table(epoch, alive),
            lambda _: jnp.asarray(nbr, jnp.int32),
            None)

    def with_table(self, nbr) -> Topology:
        """Materialise the epoch's ``Topology`` around a (possibly
        traced) gossip table: all-True mask, dense annotations
        re-gathered onto the fresh edges."""
        n, k = self.base.nbr.shape
        mask = jnp.ones((n, k), bool)
        dst = jnp.arange(n)[:, None]
        if self.dense_delay is not None:
            delay = jnp.asarray(self.dense_delay, jnp.int32)[nbr, dst]
        else:
            delay = jnp.full((n, k), self._uniform_base_delay(),
                             jnp.int32)
        if self.dense_relevance is not None:
            rel = jnp.asarray(self.dense_relevance,
                              jnp.float32)[nbr, dst]
        else:
            rel = jnp.ones((n, k), jnp.float32)
        return Topology(nbr=nbr, mask=mask, delay=delay, relevance=rel)

    def at_epoch(self, epoch, alive=None) -> Topology:
        """The communication graph in force at ``epoch``. With
        ``resample_every <= 0`` this is the static base — the exact
        object, so the static-limit equivalence is structural, not
        just numerical. ``alive`` only shapes the resampled draw; the
        static base is masked downstream by the send/combine gates."""
        if self.resample_every <= 0:
            return self.base
        return self.with_table(self.round_table(epoch, alive))


# ---------------------------------------------------------------------
# topology-aware delay models
# ---------------------------------------------------------------------
def hop_distances(topo: Topology) -> np.ndarray:
    """All-pairs directed hop count over the topology's edges
    (``dist[src, dst]`` = fewest edges from src to dst; 0 on the
    diagonal). Host-side BFS over the static table — raises on a
    disconnected pair, which cannot be assigned a finite delay."""
    n = topo.n_agents
    nbr = np.asarray(topo.nbr)
    mask = np.asarray(topo.mask)
    # out[src] = destinations src feeds (edge src→dst when src ∈ nbr[dst])
    out = [[] for _ in range(n)]
    for dst in range(n):
        for j in range(topo.degree):
            if mask[dst, j]:
                out[int(nbr[dst, j])].append(dst)
    dist = np.full((n, n), -1, np.int64)
    for s in range(n):
        dist[s, s] = 0
        frontier = [s]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for u in frontier:
                for v in out[u]:
                    if dist[s, v] < 0:
                        dist[s, v] = d
                        nxt.append(v)
            frontier = nxt
    if (dist < 0).any():
        bad = np.argwhere(dist < 0)[0]
        raise ValueError(
            f"graph is not strongly connected: no path "
            f"{int(bad[0])}→{int(bad[1])}; hop delays are undefined")
    return dist


def delay_from_hops(topo: Topology, latency: int = 1,
                    graph: Optional[Topology] = None) -> Topology:
    """Attach graph-distance delays: each edge of ``topo`` gets delay
    ``hops(src→dst) · latency`` measured on ``graph`` (default:
    ``topo`` itself), so knowledge from a distance-d agent is exactly
    d·latency epochs stale on arrival. Pass a denser ``topo`` (e.g.
    ``full``) over a sparse physical ``graph`` (e.g. ``ring``) to
    model far-apart agents hearing each other late."""
    if latency < 0:
        raise ValueError(f"latency must be >= 0, got {latency}")
    hops = hop_distances(topo if graph is None else graph)
    return topo.with_delay(jnp.asarray(hops * latency, jnp.int32))


# ---------------------------------------------------------------------
# GroupSpec dispatch
# ---------------------------------------------------------------------
TOPOLOGIES = ("full", "ring", "torus2d", "star", "random_k",
              "hierarchical")


def _torus_dims(n: int):
    """Most-square rows × cols factorisation of n."""
    r = int(math.isqrt(n))
    while n % r:
        r -= 1
    return r, n // r


def make_topology(spec, delay=None,
                  relevance=None) -> "Topology | DynamicTopology":
    """Build the topology named by a ``GroupSpec`` (``topology``,
    ``degree``, ``topology_seed``), then attach optional dense or
    per-edge ``delay`` / ``relevance`` overrides.

    With ``spec.resample_every > 0`` (random_k only) the result is a
    ``DynamicTopology`` whose gossip table resamples every
    ``resample_every`` epochs; dense (n, n) ``delay`` / ``relevance``
    overrides are then carried as matrices and re-gathered after each
    resample (per-edge (n, k) overrides are rejected — they cannot
    follow a changing edge set)."""
    n = spec.n_agents
    name = spec.topology
    if name == "full":
        topo = full(n)
    elif name == "ring":
        topo = ring(n)
    elif name == "torus2d":
        topo = torus2d(*_torus_dims(n))
    elif name == "star":
        topo = star(n)
    elif name == "random_k":
        topo = random_k(n, spec.degree, spec.topology_seed)
    elif name == "hierarchical":
        topo = hierarchical(n, pod_size=spec.degree)
    else:
        raise ValueError(
            f"unknown topology {name!r}; expected one of {TOPOLOGIES}")
    resample = getattr(spec, "resample_every", 0)
    if resample > 0:
        if name != "random_k":
            raise ValueError(
                f"resample_every > 0 needs topology='random_k', "
                f"got {name!r}")
        return DynamicTopology(
            base=topo, resample_every=resample,
            seed=spec.topology_seed).with_dense(delay=delay,
                                                relevance=relevance)
    if relevance is not None:
        topo = topo.with_relevance(relevance)
    if delay is not None:
        topo = topo.with_delay(delay)
    return topo
