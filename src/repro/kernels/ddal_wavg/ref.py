"""Pure-jnp oracle for the DDAL eq. 4 weighted-average kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wavg(G: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Σ_j w_j · G[j]  for G: (m, N), w: (m,) → (N,) fp32."""
    return jnp.einsum("m,mn->n", w.astype(jnp.float32),
                      G.astype(jnp.float32))


def tree_wavg(grads_stacked, w):
    """Reference over a pytree whose leaves have leading axis m."""
    def leaf(x):
        m = x.shape[0]
        flat = x.reshape(m, -1).astype(jnp.float32)
        return wavg(flat, w).reshape(x.shape[1:])
    return jax.tree.map(leaf, grads_stacked)
