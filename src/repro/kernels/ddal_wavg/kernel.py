"""Pallas-TPU kernel for DDAL's eq. 4 contraction: ḡ = Σ_j w_j·G[j].

The op is a streaming m-way weighted reduction over the full gradient
vector — at LLM scale it is HBM-bandwidth-bound (arithmetic intensity
≈ 0.5 FLOP/byte). XLA typically emits m separate scaled adds (reading
the fp32 accumulator m times); this kernel streams each (m, TILE) slab
through VMEM once and keeps one fp32 accumulator tile, so HBM traffic
is exactly one pass over G plus one write of ḡ — the roofline floor.

Tiling: the flat parameter vector is viewed as (tiles, ROWS, 128)
— 128 lanes, ROWS sublane-multiples — and the grid walks tiles. The
m-loop is unrolled inside the block (the paper's store holds ≤ tens of
pieces). Weights ride along as a tiny VMEM block replicated per tile.

Beyond the plain contraction (``wavg_flat``, weights precomputed on
the host side of the launch), the *fused* exchange kernels fold the
whole eq. 4 share step into the block loop:

* ``fused_wavg_flat`` — reads the raw (T, R, valid) metadata as tiny
  (m, 1) VMEM blocks, regenerates the eq. 4 weights *inside* the
  kernel (the way ``grad_sketch`` regenerates its signs in VMEM —
  nothing weight-shaped ever reaches HBM) and emits (ḡ, Σw) directly:
  one HBM pass over G, one write of ḡ, one (1, 1) write of Σw.
* ``fused_wavg_q_flat`` — the same pass over **int8 block-quantized**
  knowledge planes: per-block fp32 scales ride along as a small
  second operand and the dequantisation happens inside the block
  loop, so HBM reads ~N bytes of int8 instead of 4N of fp32 — the
  ~4× delay-line/cross-pod traffic saving at a pinned accuracy bound.

Quantization blocks are ``q_block`` consecutive elements of the flat
vector with ``q_block % 128 == 0`` and ``tile % q_block == 0``, i.e. a
block is a whole group of sublane rows — the in-kernel dequant is a
broadcast multiply over row groups, no lane-crossing reshuffle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_ROWS = 64                  # tile = 64·128 = 8192 elements
EQ4_EPS = 1e-12                    # eq4_weights' normalisation clamp


def _wavg_kernel(w_ref, g_ref, o_ref):
    """w_ref: (m, 1); g_ref: (m, 1, ROWS, LANES); o_ref: (1, ROWS, LANES)."""
    m = g_ref.shape[0]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for j in range(m):                       # m is static & small
        acc = acc + w_ref[j, 0] * g_ref[j].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def wavg_flat(G: jnp.ndarray, w: jnp.ndarray,
              rows: int = DEFAULT_ROWS,
              interpret: bool = False) -> jnp.ndarray:
    """G: (m, N) float, w: (m,) → (N,) fp32 = Σ_j w[j]·G[j]."""
    m, n = G.shape
    tile = rows * LANES
    n_pad = max(tile, ((n + tile - 1) // tile) * tile)
    if n_pad != n:
        G = jnp.pad(G, ((0, 0), (0, n_pad - n)))
    tiles = n_pad // tile
    G4 = G.reshape(m, tiles, rows, LANES)
    w2 = w.astype(jnp.float32).reshape(m, 1)

    out = pl.pallas_call(
        _wavg_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, 1, rows, LANES), lambda i: (0, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rows, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, rows, LANES),
                                       jnp.float32),
        interpret=interpret,
    )(w2, G4)
    return out.reshape(n_pad)[:n]


# ---------------------------------------------------------------------
# fused eq. 4 share step: weights computed in VMEM, (ḡ, Σw) emitted
# ---------------------------------------------------------------------
def _eq4_weights_block(T, R, V, eps):
    """eq. 4 weights on (m, 1) VMEM blocks — the *same float ops in
    the same order* as ``repro.core.weighting.eq4_weights`` (mask,
    sum, clamp, normalise, average), so the in-kernel weights match
    the multi-op path's bit for bit."""
    Tm = T * V
    Rm = R * V
    t_hat = Tm / jnp.maximum(jnp.sum(Tm), eps)
    r_hat = Rm / jnp.maximum(jnp.sum(Rm), eps)
    return 0.5 * (t_hat + r_hat)                         # (m, 1)


def _fused_wavg_kernel(T_ref, R_ref, V_ref, g_ref, o_ref, ws_ref, *,
                       eps):
    """T/R/V_ref: (m, 1); g_ref: (m, 1, ROWS, LANES);
    o_ref: (1, ROWS, LANES); ws_ref: (1, 1)."""
    m = g_ref.shape[0]
    w = _eq4_weights_block(T_ref[...], R_ref[...], V_ref[...], eps)

    @pl.when(pl.program_id(0) == 0)
    def _():                       # Σw once — revisited blocks alias
        ws_ref[...] = jnp.sum(w).reshape(1, 1)

    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for j in range(m):                       # m is static & small
        acc = acc + w[j, 0] * g_ref[j].astype(jnp.float32)
    o_ref[...] = acc


def _fused_wavg_q_kernel(T_ref, R_ref, V_ref, q_ref, s_ref, o_ref,
                         ws_ref, *, eps, q_rows):
    """Quantized planes: q_ref (m, 1, ROWS, LANES) int8, s_ref
    (m, 1, ROWS // q_rows) fp32 per-block scales — dequantised inside
    the block loop (one int8 HBM pass, never an fp32 copy of G)."""
    m, _, rows, lanes = q_ref.shape
    nb = rows // q_rows
    w = _eq4_weights_block(T_ref[...], R_ref[...], V_ref[...], eps)

    @pl.when(pl.program_id(0) == 0)
    def _():
        ws_ref[...] = jnp.sum(w).reshape(1, 1)

    acc = jnp.zeros((nb, q_rows, lanes), jnp.float32)
    for j in range(m):
        qf = q_ref[j].astype(jnp.float32).reshape(nb, q_rows, lanes)
        sc = s_ref[j].reshape(nb, 1, 1)      # broadcast over the block
        acc = acc + w[j, 0] * (qf * sc)
    o_ref[...] = acc.reshape(1, rows, lanes)


def _fused_call(kernel, extra_in, extra_specs, T, R, valid, tiles,
                rows, m, interpret):
    """Shared pallas_call plumbing for both fused variants."""
    meta = [jnp.asarray(x, jnp.float32).reshape(m, 1)
            for x in (T, R, valid)]
    meta_specs = [pl.BlockSpec((m, 1), lambda i: (0, 0))] * 3
    out, wsum = pl.pallas_call(
        kernel,
        grid=(tiles,),
        in_specs=meta_specs + extra_specs,
        out_specs=[
            pl.BlockSpec((1, rows, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*meta, *extra_in)
    return out, wsum[0, 0]


@functools.partial(jax.jit, static_argnames=("rows", "interpret",
                                             "eps"))
def fused_wavg_flat(G, T, R, valid, rows: int = DEFAULT_ROWS,
                    interpret: bool = False, eps: float = EQ4_EPS):
    """G: (m, N) float; T, R: (m,); valid: (m,) bool →
    (ḡ: (N,) fp32, Σw: () fp32) — eq. 4 in one HBM pass."""
    m, n = G.shape
    tile = rows * LANES
    n_pad = max(tile, ((n + tile - 1) // tile) * tile)
    if n_pad != n:
        G = jnp.pad(G, ((0, 0), (0, n_pad - n)))
    tiles = n_pad // tile
    G4 = G.reshape(m, tiles, rows, LANES)
    out, wsum = _fused_call(
        functools.partial(_fused_wavg_kernel, eps=eps),
        [G4],
        [pl.BlockSpec((m, 1, rows, LANES), lambda i: (0, i, 0, 0))],
        T, R, valid, tiles, rows, m, interpret)
    return out.reshape(n_pad)[:n], wsum


@functools.partial(jax.jit, static_argnames=("q_block", "rows",
                                             "interpret", "eps"))
def fused_wavg_q_flat(Q, scale, T, R, valid, q_block: int,
                      rows: int = DEFAULT_ROWS,
                      interpret: bool = False, eps: float = EQ4_EPS):
    """Q: (m, N) int8 block-quantized planes; scale: (m, ⌈N/q_block⌉)
    fp32 per-block scales → (ḡ, Σw) with dequant fused into the block
    loop. ``q_block`` must be a multiple of ``LANES`` dividing the
    tile (``rows * LANES``)."""
    if q_block % LANES or (rows * LANES) % q_block:
        raise ValueError(
            f"q_block must be a multiple of {LANES} dividing the "
            f"{rows * LANES}-element tile, got {q_block}")
    m, n = Q.shape
    tile = rows * LANES
    n_pad = max(tile, ((n + tile - 1) // tile) * tile)
    nb_pad = n_pad // q_block
    if n_pad != n:
        Q = jnp.pad(Q, ((0, 0), (0, n_pad - n)))
    if scale.shape[1] != nb_pad:
        scale = jnp.pad(scale, ((0, 0), (0, nb_pad - scale.shape[1])))
    tiles = n_pad // tile
    q_rows = q_block // LANES
    nb_tile = rows // q_rows
    Q4 = Q.reshape(m, tiles, rows, LANES)
    S3 = scale.reshape(m, tiles, nb_tile)
    out, wsum = _fused_call(
        functools.partial(_fused_wavg_q_kernel, eps=eps,
                          q_rows=q_rows),
        [Q4, S3],
        [pl.BlockSpec((m, 1, rows, LANES), lambda i: (0, i, 0, 0)),
         pl.BlockSpec((m, 1, nb_tile), lambda i: (0, i, 0))],
        T, R, valid, tiles, rows, m, interpret)
    return out.reshape(n_pad)[:n], wsum
